"""Occupancy sweep: the block-sparse win of occupancy-aware stacks.

DBCSR's reason to exist is that the Generation phase enumerates only
*present* block triples (paper section II).  This benchmark quantifies
that against the dense-enumeration baseline the executor used before
occupancy threading: for each fill in the sweep it draws random A/B
block masks, builds both the dense plan (every triple, zero blocks
multiplied) and the occupancy-filtered plan, and times the fused
executor's dispatch of each on identical masked payloads.

Reported per fill: triple counts (dense vs filtered), effective
occupancy, and wall-clock of both dispatches (CPU interpret-mode — the
*ratio* is the transferable number; absolute times are not TPU truth).
Dense masks are also checked bit-identical against the dense plan.

A second section sweeps the stack executor's size-bin cap
(``stack_bins`` / DBCSR_STACK_BINS, core/engine.py) on a ragged
low-fill workload: each extra bin is one more scan trace but pads
short stacks less — the ROADMAP "bin cap trades trace count against
padding" sweep, recorded per cap as bins/padding/dispatch time.

A third section (``--patterns``) exercises *rank-exact execution*
(core/multiply.py, ISSUE 9) on structured sparsity: banded,
block-diagonal and power-law block patterns on a 2x2 device mesh,
comparing the legacy union-of-ranks plan (``rank_exact=False``)
against per-rank plan slabs.  Reported per pattern: executed
non-padding triples per rank under each mode (union: every rank runs
the whole union plan; rank-exact: the busiest rank's own total),
per-rank imbalance, and a bitwise product check; a dense uniform-fill
row checks the collapse adds no dispatch-time regression beyond
jitter.  ``--patterns --check`` gates banded >= 1.5x triple reduction.

    PYTHONPATH=src python -m benchmarks.bench_sparse [--smoke] [--patterns]

``--smoke`` runs a small geometry with few reps and writes
artifacts/bench/sparse_smoke.json (scripts/ci.sh tracks it); the full
run writes artifacts/bench/sparse.json.  ``--patterns`` writes
artifacts/bench/sparse_patterns.json (and runs only that section).
"""
import os
import sys
# rank-exact patterns need a real 2x2 device mesh; the plain sweeps
# stay single-device (the flag must be sniffed before jax imports)
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count="
    + ("4" if "--patterns" in sys.argv else "1"))

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.densify import to_blocks
from repro.core.engine import build_executor_plan, execute_plan
from repro.kernels.smm.autotune import FILL_BINS

# one grid shared with the winners table (keep the sweeps in lockstep);
# descending so the monotonic-dispatch-time check reads left to right
FILLS = tuple(sorted(FILL_BINS, reverse=True))


def time_call(fn, *args, reps=5):
    """Best-of-reps wall time (min is the standard low-noise estimator
    for microbenchmarks; the mean smears scheduler hiccups into the
    CI-tracked monotonicity claim)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def sweep(block, n_blocks, stack_size, reps, kernel="ref"):
    m = k = n = block * n_blocks
    rng = np.random.RandomState(0)
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    dense_plan = build_executor_plan(m, k, n, block, block, block, stack_size)

    rows = []
    for fill in FILLS:
        if fill >= 1.0:
            a_mask = b_mask = None
            af, bf = a, b
        else:
            a_mask = rng.rand(n_blocks, n_blocks) < fill
            b_mask = rng.rand(n_blocks, n_blocks) < fill
            a_mask[0, 0] = b_mask[0, 0] = True  # keep the plan non-empty
            af = a * np.repeat(np.repeat(a_mask, block, 0), block, 1)
            bf = b * np.repeat(np.repeat(b_mask, block, 0), block, 1)
        plan = build_executor_plan(m, k, n, block, block, block, stack_size,
                                   a_mask=a_mask, b_mask=b_mask)
        if fill >= 1.0:
            assert np.array_equal(plan.triples, dense_plan.triples), \
                "dense masks must be bit-identical to the dense plan"

        ab = to_blocks(jnp.asarray(af), block, block)
        bb = to_blocks(jnp.asarray(bf), block, block)
        c0 = jnp.zeros((n_blocks * n_blocks, block, block), jnp.float32)

        t_sparse = time_call(
            jax.jit(lambda ab, bb, c0, p=plan: execute_plan(
                p, ab, bb, c0, kernel=kernel)), ab, bb, c0, reps=reps)
        t_dense = time_call(
            jax.jit(lambda ab, bb, c0, p=dense_plan: execute_plan(
                p, ab, bb, c0, kernel=kernel)), ab, bb, c0, reps=reps)

        rows.append({
            "fill": fill,
            "n_dense_triples": plan.n_dense_triples,
            "n_triples": plan.n_entries,
            "occupancy": plan.occupancy,
            "n_stacks": plan.n_stacks,
            "t_sparse_s": t_sparse,
            "t_dense_s": t_dense,
            "dense_over_sparse": t_dense / t_sparse,
        })
        print(f"fill {fill:4g}: {plan.n_entries:7d}/{plan.n_dense_triples} "
              f"triples (occ {plan.occupancy:6.3f})  "
              f"sparse {t_sparse*1e3:8.2f} ms  dense {t_dense*1e3:8.2f} ms  "
              f"({t_dense/t_sparse:5.2f}x)")
    return rows


BIN_CAPS = (1, 2, 4, 8)


def bin_cap_sweep(block, n_blocks, stack_size, reps, kernel="ref",
                  fill=0.05):
    """Sweep the executor's size-bin cap on a ragged low-fill plan
    (one dense mask row on top of a sparse background makes the run
    lengths wildly ragged, the regime binning exists for)."""
    # the sweep needs enough blocks (and a tight enough stack cap) for
    # the plan to actually go multi-stack and ragged — the smoke
    # geometry alone collapses to one short stack
    n_blocks = max(n_blocks, 16)
    stack_size = min(stack_size, 2 * n_blocks)
    m = block * n_blocks
    rng = np.random.RandomState(1)
    a_mask = rng.rand(n_blocks, n_blocks) < fill
    b_mask = rng.rand(n_blocks, n_blocks) < fill
    a_mask[0, :] = True  # ragged: one dense row among sparse runs
    a = rng.randn(m, m).astype(np.float32) \
        * np.repeat(np.repeat(a_mask, block, 0), block, 1)
    b = rng.randn(m, m).astype(np.float32) \
        * np.repeat(np.repeat(b_mask, block, 0), block, 1)
    ab = to_blocks(jnp.asarray(a), block, block)
    bb = to_blocks(jnp.asarray(b), block, block)
    c0 = jnp.zeros((n_blocks * n_blocks, block, block), jnp.float32)

    rows = []
    for cap in BIN_CAPS:
        plan = build_executor_plan(m, m, m, block, block, block, stack_size,
                                   a_mask=a_mask, b_mask=b_mask,
                                   stack_bins=cap)
        t = time_call(
            jax.jit(lambda ab, bb, c0, p=plan: execute_plan(
                p, ab, bb, c0, kernel=kernel)), ab, bb, c0, reps=reps)
        rows.append({
            "stack_bins": cap,
            "n_bins": plan.n_bins,
            "n_entries": plan.n_entries,
            "n_padding": plan.n_padding,
            "n_padding_unbinned": plan.n_padding_unbinned,
            "t_dispatch_s": t,
        })
        print(f"stack_bins {cap}: {plan.n_bins} bins  "
              f"padding {plan.n_padding:6d} "
              f"(unbinned {plan.n_padding_unbinned})  "
              f"dispatch {t*1e3:8.2f} ms")
    return rows


# ---------------------------------------------------------------------------
# structured sparsity patterns (rank-exact execution, ISSUE 9)
# ---------------------------------------------------------------------------


def banded_mask(nb, halfwidth=1):
    """Banded occupancy (|i - j| <= halfwidth): the nearest-neighbour
    Hamiltonian pattern; halfwidth=1 at nb=64 is ~5% fill."""
    i = np.arange(nb)
    return np.abs(i[:, None] - i[None, :]) <= halfwidth


def block_diagonal_mask(nb, n_groups=8):
    """Block-diagonal occupancy: nb block rows/cols in n_groups dense
    diagonal groups (isolated molecular fragments)."""
    group = np.arange(nb) * n_groups // nb
    return group[:, None] == group[None, :]


def power_law_mask(nb, fill=0.08, alpha=1.5, seed=0):
    """Power-law occupancy: presence probability ~ (i*j)^-alpha, mass
    concentrated in the low-index corner — the worst case for a
    contiguous block distribution (what rebalancing exists for)."""
    rng = np.random.RandomState(seed)
    w = np.arange(1, nb + 1, dtype=np.float64) ** -alpha
    p = np.outer(w, w)
    p *= fill * nb * nb / p.sum()
    mask = rng.rand(nb, nb) < np.minimum(p, 1.0)
    mask[0, 0] = True
    return mask


def patterns_sweep(reps):
    """Union-of-ranks vs rank-exact execution on a 2x2 mesh."""
    from repro.core.multiply import distributed_matmul
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 2), ("data", "model"))
    nb, bs = 64, 4  # banded halfwidth-1 at nb=64 is ~4.6% fill
    rng = np.random.RandomState(0)

    def payload(mask, bs_):
        m = mask.shape[0] * bs_
        x = rng.randn(m, m).astype(np.float32)
        return x * np.repeat(np.repeat(mask, bs_, 0), bs_, 1)

    def run(mask_a, mask_b, rank_exact, bs_=bs):
        af, bf = payload(mask_a, bs_), payload(mask_b, bs_)
        # ref kernel: the comparison is about plan sizes and dispatch,
        # not pallas interpret-mode overhead (absolute times are not
        # TPU truth either way; the triple counts are exact)
        call = dict(mesh=mesh, algorithm="cannon", densify=False,
                    block_m=bs_, block_k=bs_, block_n=bs_,
                    local_kernel="ref", pipeline_depth=1,
                    a_mask=mask_a, b_mask=mask_b, rank_exact=rank_exact)
        c, plan = distributed_matmul(af, bf, return_plan=True, **call)
        t = time_call(lambda: jax.block_until_ready(
            distributed_matmul(af, bf, **call)), reps=reps)
        return np.asarray(c), plan.executor_stats, t

    patterns = {
        "banded": banded_mask(nb, 1),
        "block_diagonal": block_diagonal_mask(nb, 8),
        "power_law": power_law_mask(nb, 0.08),
    }
    rows = []
    for name, mask in patterns.items():
        rng = np.random.RandomState(0)  # same payloads in both modes
        cu, es_u, t_u = run(mask, mask, False)
        rng = np.random.RandomState(0)
        cr, es_r, t_r = run(mask, mask, None)
        union_per_rank = es_u["n_entries"]       # every rank: whole union
        rank_busiest = es_r["max_rank_entries"]  # busiest rank's own plan
        row = {
            "pattern": name,
            "fill": float(mask.mean()),
            "union_triples_per_rank": int(union_per_rank),
            "rank_exact_max_rank_triples": int(rank_busiest),
            "rank_exact_rank_entries": es_r["rank_entries"],
            "rank_imbalance": es_r["rank_imbalance"],
            "triples_reduction": union_per_rank / max(rank_busiest, 1),
            "bitwise_equal": bool(np.array_equal(cu, cr)),
            "t_union_s": t_u,
            "t_rank_exact_s": t_r,
        }
        rows.append(row)
        print(f"{name:>14s} (fill {row['fill']:6.3f}): union "
              f"{union_per_rank:6d}/rank vs rank-exact busiest "
              f"{rank_busiest:6d}  ({row['triples_reduction']:5.2f}x, "
              f"imbalance {row['rank_imbalance']:5.2f})  "
              f"bitwise={row['bitwise_equal']}  "
              f"union {t_u*1e3:7.2f} ms vs rank {t_r*1e3:7.2f} ms")
    # dense uniform fill: the rank path must collapse to the union
    # executor — bitwise-identical product, dispatch within jitter
    # (smaller grid: the dense triple count is cubic in nb and the
    # collapse property is geometry-independent)
    dense = np.ones((16, 16), bool)
    rng = np.random.RandomState(0)
    cu, _, t_u = run(dense, dense, False, bs_=8)
    rng = np.random.RandomState(0)
    cr, es_r, t_r = run(dense, dense, None, bs_=8)
    dense_row = {
        "pattern": "dense",
        "bitwise_equal": bool(np.array_equal(cu, cr)),
        "collapsed": "rank_entries" not in es_r,
        "t_union_s": t_u,
        "t_rank_exact_s": t_r,
        # 25% relative + 5 ms absolute: interpret-mode jitter bound
        "no_dispatch_regression": t_r <= t_u * 1.25 + 5e-3,
    }
    print(f"{'dense':>14s}: collapse={dense_row['collapsed']}  "
          f"bitwise={dense_row['bitwise_equal']}  union {t_u*1e3:7.2f} ms "
          f"vs rank {t_r*1e3:7.2f} ms")
    return rows, dense_row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small geometry, few reps, -> sparse_smoke.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless dispatch time falls "
                         "monotonically with occupancy (CI gate)")
    ap.add_argument("--patterns", action="store_true",
                    help="rank-exact vs union on structured patterns "
                         "(2x2 mesh) -> sparse_patterns.json; runs only "
                         "this section")
    ap.add_argument("--block", type=int, default=None)
    ap.add_argument("--n-blocks", type=int, default=None)
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()

    if args.patterns:
        reps = 3 if args.smoke else 5
        rows, dense_row = patterns_sweep(reps)
        banded = next(r for r in rows if r["pattern"] == "banded")
        result = {
            "mesh": [2, 2],
            "rows": rows,
            "dense": dense_row,
            "all_bitwise": all(r["bitwise_equal"] for r in rows)
            and dense_row["bitwise_equal"],
            "banded_reduction": banded["triples_reduction"],
        }
        os.makedirs(args.out, exist_ok=True)
        path = os.path.join(args.out, "sparse_patterns.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        print("wrote ->", path)
        if args.check:
            if not result["all_bitwise"]:
                raise SystemExit(
                    "rank-exact product differs from union bitwise")
            if result["banded_reduction"] < 1.5:
                raise SystemExit(
                    f"banded rank-exact triple reduction "
                    f"{result['banded_reduction']:.2f}x < 1.5x")
            if not dense_row["no_dispatch_regression"]:
                raise SystemExit(
                    "dense collapse dispatch regressed beyond jitter")
        return

    if args.smoke:
        block, n_blocks, stack_size, reps = 8, 8, 64, 3
    else:
        block, n_blocks, stack_size, reps = 16, 16, 512, 5
    if args.block:
        block = args.block
    if args.n_blocks:
        n_blocks = args.n_blocks

    rows = sweep(block, n_blocks, stack_size, reps)
    print("-- stack-bin cap sweep (ragged low fill) --")
    bin_rows = bin_cap_sweep(block, n_blocks, stack_size, reps)
    # padding must be non-increasing in the cap (refinement property)
    paddings = [r["n_padding"] for r in bin_rows]
    times = [r["t_sparse_s"] for r in rows]  # FILLS is descending
    result = {
        "block": block,
        "n_blocks": n_blocks,
        "stack_size": stack_size,
        "rows": rows,
        "bin_sweep": bin_rows,
        "bin_padding_monotone": all(
            paddings[i] >= paddings[i + 1] for i in range(len(paddings) - 1)),
        # 10% relative slack + 1 ms absolute floor: interpret-mode
        # timings of near-equal sub-ms plans jitter by multiples of
        # themselves (the floor matches the planner/overlap gates); a
        # genuine occupancy regression far exceeds both
        "monotonic_dispatch_time": all(
            times[i] + 1e-3 >= times[i + 1] * 0.9
            for i in range(len(times) - 1)),
    }
    os.makedirs(args.out, exist_ok=True)
    name = "sparse_smoke.json" if args.smoke else "sparse.json"
    path = os.path.join(args.out, name)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"monotonic dispatch time over falling occupancy: "
          f"{result['monotonic_dispatch_time']}")
    print(f"bin-cap padding non-increasing: "
          f"{result['bin_padding_monotone']}")
    print("wrote ->", path)
    if args.check and not result["monotonic_dispatch_time"]:
        raise SystemExit("sparse dispatch time did not fall with occupancy")
    if args.check and not result["bin_padding_monotone"]:
        raise SystemExit("size-bin padding grew with a larger bin cap")


if __name__ == "__main__":
    main()
