"""Paper table IV-A: grid-configuration sweep.

The paper varies MPI ranks x OpenMP threads per node (1x12 / 4x3 /
6x2 / 12x1) and finds the balanced 4x3 best (worst-to-best spread
~23%).  The TPU analogue of that trade is the process-grid aspect
ratio for a fixed chip count: (16x1, 8x2, 4x4, 2x8, 1x16) on 16
devices.  We measure wall time of the densified multiply per grid and
the Cannon/SUMMA collective volume per device (square grids minimise
the shift volume; degenerate grids degrade, mirroring the paper).
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import json
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.blocking import GridSpec
from repro.core.multiply import distributed_matmul
from repro.launch.mesh import make_mesh


def time_call(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def main(n=1536, block=64, out="artifacts/bench"):
    rng = np.random.RandomState(0)
    A = rng.randn(n, n).astype(np.float32)
    B = rng.randn(n, n).astype(np.float32)
    results = []
    for (r, c) in [(4, 4), (2, 8), (8, 2), (16, 1), (1, 16)]:
        mesh = make_mesh((r, c), ("data", "model"))
        grid = GridSpec("data", "model")
        sh = NamedSharding(mesh, P("data", "model"))
        Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
        algo = "cannon" if r == c else "summa"

        fn = jax.jit(lambda a, b: distributed_matmul(
            a, b, mesh=mesh, grid=grid, algorithm=algo, densify=True))
        dt = time_call(fn, Ad, Bd)
        # per-device communication volume (analytic, fp32 bytes)
        if algo == "cannon":
            vol = (n * n // (r * c)) * 4 * 2 * r  # A+B shifted r steps
        else:
            import math
            panels = math.lcm(r, c)
            vol = panels * ((n // r) * (n // panels) + (n // panels) * (n // c)) * 4 * 2
        results.append({"grid": f"{r}x{c}", "algorithm": algo,
                        "time_s": dt, "comm_bytes_per_dev": vol})
        print(f"grid {r:2d}x{c:<2d} [{algo:6s}]  {dt*1e3:8.2f} ms   "
              f"comm/dev {vol/2**20:7.1f} MiB")

    best = min(r["time_s"] for r in results)
    worst = max(r["time_s"] for r in results)
    print(f"worst/best degradation: {worst/best:.2f}x "
          f"(paper reports ~1.23x across rank x thread grids)")
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, "grid_config.json"), "w") as f:
        json.dump({"n": n, "block": block, "results": results,
                   "degradation": worst / best}, f, indent=1)


if __name__ == "__main__":
    import sys
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 1536
    main(n=n)
