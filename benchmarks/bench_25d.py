"""2.5D Cannon benchmark (beyond-paper, DBCSR lineage ref [10]).

Measures 2D Cannon on a flat 4x4 grid vs 2.5D Cannon on a (2, 2x2... )
— here (2, 4, 4): 2 replicas of a 4x4 grid — for the same global
matrix.  The 2.5D variant executes half the shift steps per replica at
the cost of replicated operands plus one C-reduction over the stack
(pod) axis: per-device shift volume halves, which is exactly the
multi-pod production-mesh story (EXPERIMENTS.md §Perf A-3's pod-axis
halving, isolated to the engine).

Analytic per-device communication (fp32, n x n, grid side P=4, c=2):
  cannon 2D : 2 shifts/step x P steps x n^2/P^2 x 4B
  cannon 2.5D: same shifts x P/c steps + allreduce(n^2/P^2)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=32")

import json
import time

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.blocking import GridSpec
from repro.core.cannon import cannon_matmul
from repro.core.cannon25d import cannon25d_matmul
from repro.launch.mesh import make_mesh


def timed(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def main(n=1408, out="artifacts/bench"):
    rng = np.random.RandomState(0)
    A = rng.randn(n, n).astype(np.float32)
    B = rng.randn(n, n).astype(np.float32)
    ref = A @ B
    results = []

    # --- flat 2D Cannon on 4x4 (16 devices) ---------------------------
    mesh2d = make_mesh((4, 4), ("data", "model"))
    grid2d = GridSpec("data", "model")
    sh = NamedSharding(mesh2d, P("data", "model"))
    Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
    t2d = timed(jax.jit(lambda a, b: cannon_matmul(
        a, b, mesh=mesh2d, grid=grid2d)), Ad, Bd)
    vol2d = 2 * 4 * (n * n // 16) * 4  # 2 operands x P steps x block x 4B
    results.append({"algo": "cannon2d", "devices": 16, "time_s": t2d,
                    "comm_bytes_per_dev": vol2d})
    print(f"cannon 2D  (4x4):    {t2d*1e3:8.2f} ms  "
          f"shift vol/dev {vol2d/2**20:.1f} MiB")

    # --- 2.5D on (2, 4, 4): same 4x4 grid, 2 replicas ------------------
    mesh3d = make_mesh((2, 4, 4), ("pod", "data", "model"))
    grid3d = GridSpec("data", "model", stack_axis="pod")
    sh3 = NamedSharding(mesh3d, P("data", "model"))
    A3, B3 = jax.device_put(A, sh3), jax.device_put(B, sh3)
    for reduce in ("all_reduce", "reduce_scatter"):
        t25 = timed(jax.jit(lambda a, b, r=reduce: cannon25d_matmul(
            a, b, mesh=mesh3d, grid=grid3d, reduce=r)), A3, B3)
        blk = n * n // 16
        vol25 = 2 * 2 * blk * 4 + 2 * blk * 4  # half the shifts + C allreduce
        c = cannon25d_matmul(A3, B3, mesh=mesh3d, grid=grid3d, reduce="all_reduce")
        err = float(np.max(np.abs(np.asarray(c) - ref)))
        results.append({"algo": f"cannon25d_{reduce}", "devices": 32,
                        "time_s": t25, "comm_bytes_per_dev": vol25,
                        "max_err": err})
        print(f"cannon 2.5D ({reduce:14s}): {t25*1e3:8.2f} ms  "
              f"shift+reduce vol/dev {vol25/2**20:.1f} MiB  err {err:.1e}")

    print("\n2.5D halves the per-device shift volume (2 steps vs 4) at the "
          "cost of 2x operand replication — the pod-axis production story.")
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, "cannon25d.json"), "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
