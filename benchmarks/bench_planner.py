"""Planner regret sweep: auto vs every fixed (algorithm, local path).

The planner's job (repro.planner) is to make ``algorithm="auto"`` pick
the winning configuration per (shape, occupancy, mesh) — the paper's
driver behaviour.  This benchmark measures how well it does that: for
each sweep point (square / tall / skinny x occupancy fills) it times
every feasible fixed (algorithm, local-path) candidate AND the
planner's choice, and reports the *regret* — how much slower the auto
plan is than the best fixed choice at that point.

Before sweeping it (re)calibrates the cost-model constants on this
machine and mesh (repro.planner.calibrate.micro_calibrate ->
artifacts/planner_calibration.json), so the planner is judged against
constants measured in the same process — the calibration workflow a
real deployment would run once per system.

    PYTHONPATH=src python benchmarks/bench_planner.py [--smoke] [--check]

``--smoke`` runs the small grid and writes
artifacts/bench/planner_smoke.json (scripts/ci.sh gates on it:
``--check`` fails unless regret <= --tol at every sweep point); the
full run writes artifacts/bench/planner.json.  CPU interpret-mode: the
*ranking* is the transferable result, absolute times are not TPU truth.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.core.blocking import GridSpec
from repro.core.multiply import distributed_matmul
from repro.kernels.smm.autotune import FILL_BINS
from repro.planner import calibrate
from repro.planner.plan import plan_cache_clear

FILLS = tuple(sorted(FILL_BINS, reverse=True))  # 1.0, 0.5, 0.2, 0.05
BLOCK = 16

# (name, m, k, n): the paper's square and rectangular regimes plus the
# skinny transpose of the latter.  Sized so genuine algorithm/path cost
# gaps dominate the ~0.5 ms host dispatch jitter.
SMOKE_SHAPES = [("square", 384, 384, 384),
                ("tall", 128, 4096, 128),
                ("skinny", 4096, 128, 128)]
FULL_SHAPES = [("square", 512, 512, 512),
               ("tall", 128, 8192, 128),
               ("skinny", 8192, 128, 128)]


def time_interleaved(fns, args, reps=5):
    """Median-of-reps wall time per callable, reps interleaved
    round-robin so machine-load drift hits every candidate equally
    (timing them in separate blocks seconds apart would bias the
    comparison).  Median, not min: the regret gate takes an argmin over
    ~10 near-tied candidates, and the min-of-reps extreme-value bias
    would deflate t_best and inflate regret under pure noise."""
    import statistics

    for fn in fns:
        jax.block_until_ready(fn(*args))  # warm (compile)
    samples = [[] for _ in fns]
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            samples[i].append(time.perf_counter() - t0)
    return [statistics.median(s) for s in samples]


def make_masks(rng, m, k, n, fill):
    if fill >= 1.0:
        return None, None
    am = rng.rand(m // BLOCK, k // BLOCK) < fill
    bm = rng.rand(k // BLOCK, n // BLOCK) < fill
    am[0, 0] = bm[0, 0] = True  # keep the product non-empty
    return am, bm


def zeroed(x, mask):
    if mask is None:
        return x
    return x * np.repeat(np.repeat(mask, BLOCK, 0), BLOCK, 1)


def sweep_point(mesh, grid, rng, m, k, n, fill, reps, dens_fns):
    a_mask, b_mask = make_masks(rng, m, k, n, fill)
    A = zeroed(rng.randn(m, k).astype(np.float32), a_mask)
    B = zeroed(rng.randn(k, n).astype(np.float32), b_mask)
    sh = NamedSharding(mesh, P("data", "model"))
    Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
    ref = A @ B

    kw = dict(mesh=mesh, grid=grid, block_m=BLOCK, block_k=BLOCK,
              block_n=BLOCK, a_mask=a_mask, b_mask=b_mask)

    # the auto plan carries every candidate's predicted cost and
    # feasibility — that's the measurement grid
    C_auto, plan = distributed_matmul(
        Ad, Bd, algorithm="auto", local_kernel="ref", return_plan=True, **kw)
    err_auto = float(np.max(np.abs(np.asarray(C_auto) - ref)))

    cands, fns = [], []
    for cand in plan.candidates:
        if not cand.feasible:
            continue
        key = (cand.algorithm, cand.densify)
        if cand.densify:
            # densified ignores the masks -> one trace per (shape, algo)
            # reused across fills (values change, shapes don't)
            if key not in dens_fns:
                dens_fns[key] = jax.jit(lambda a, b, algo=cand.algorithm: \
                    distributed_matmul(a, b, mesh=mesh, grid=grid,
                                       algorithm=algo, densify=True))
            fns.append(dens_fns[key])
        else:
            fns.append(jax.jit(
                lambda a, b, algo=cand.algorithm: distributed_matmul(
                    a, b, algorithm=algo, densify=False, local_kernel="ref",
                    **kw)))
        cands.append(cand)
    # the auto dispatch itself rides in the same interleaved rounds
    # (same computation as its fixed twin; the min of the two is the
    # auto configuration's measured time)
    fns.append(jax.jit(lambda a, b: distributed_matmul(
        a, b, algorithm="auto", local_kernel="ref", **kw)))
    times = time_interleaved(fns, (Ad, Bd), reps=reps)
    t_auto_direct = times[-1]
    rows = [{"algorithm": c.algorithm, "densify": c.densify,
             "predicted_s": c.total_s, "time_s": t}
            for c, t in zip(cands, times[:-1])]
    chosen = [r for r in rows if r["algorithm"] == plan.algorithm
              and r["densify"] == plan.densify]
    t_auto = min([t_auto_direct] + [r["time_s"] for r in chosen])
    t_best = min(r["time_s"] for r in rows)
    best = min(rows, key=lambda r: r["time_s"])
    regret = t_auto / t_best - 1.0
    return {
        "fill": fill, "m": m, "k": k, "n": n,
        "occupancy": plan.occupancy,
        "auto_algorithm": plan.algorithm,
        "auto_densify": plan.densify,
        "auto_err": err_auto,
        "t_auto_s": t_auto,
        "t_auto_direct_s": t_auto_direct,
        "t_best_s": t_best,
        "best_algorithm": best["algorithm"],
        "best_densify": best["densify"],
        "regret": regret,
        "candidates": rows,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grid, few reps -> planner_smoke.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless regret <= --tol at every "
                         "sweep point (CI gate)")
    ap.add_argument("--tol", type=float, default=0.10)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()

    shapes = SMOKE_SHAPES if args.smoke else FULL_SHAPES
    reps = args.reps or 5

    mesh = make_mesh((2, 2), ("data", "model"))
    grid = GridSpec("data", "model")

    # calibration workflow: artifact fits + live micro-measurement on
    # this mesh, persisted for any later planner call on this machine
    constants = calibrate.fit_from_artifacts()
    constants.update(calibrate.micro_calibrate(mesh=mesh, grid=grid))
    path = calibrate.save_calibration(constants)
    plan_cache_clear()  # plans keyed on the old constants are stale
    print("calibrated ->", path)
    for key, val in sorted(constants.items()):
        print(f"  {key:20s} {val:12.4g}")

    def gate_ok(p):
        # 1 ms absolute slack: interpret-mode dispatch jitter floor on
        # near-tied few-ms points; a genuine planner miss dwarfs it
        return bool(p["t_auto_s"] <= p["t_best_s"] * (1 + args.tol) + 1e-3)

    def report(pt):
        print(f"{pt['shape']:7s} fill {pt['fill']:4g}: "
              f"auto={pt['auto_algorithm']}"
              f"+{'dens' if pt['auto_densify'] else 'blk'} "
              f"{pt['t_auto_s'] * 1e3:8.2f} ms  "
              f"best={pt['best_algorithm']}"
              f"+{'dens' if pt['best_densify'] else 'blk'} "
              f"{pt['t_best_s'] * 1e3:8.2f} ms  "
              f"regret {pt['regret'] * 100:6.1f}%", flush=True)

    rng = np.random.RandomState(0)
    points = []
    for name, m, k, n in shapes:
        dens_fns = {}
        for fill in FILLS:
            pt = sweep_point(mesh, grid, rng, m, k, n, fill, reps, dens_fns)
            pt["shape"] = name
            points.append(pt)
            report(pt)

    # ambient machine load can swing identical few-ms configs by tens
    # of percent between medians; a point that fails the gate gets ONE
    # fresh re-measurement (same inputs, more reps) before it counts —
    # a genuine planner miss fails both times
    retry = [i for i, p in enumerate(points) if not gate_ok(p)]
    if retry:
        print(f"re-measuring {len(retry)} gate-failing point(s)...")
        rng = np.random.RandomState(0)
        idx = 0
        for name, m, k, n in shapes:
            dens_fns = {}
            for fill in FILLS:
                if idx in retry:
                    pt = sweep_point(mesh, grid, rng, m, k, n, fill,
                                     reps + 2, dens_fns)
                    pt["shape"] = name
                    pt["retried"] = True
                    if pt["regret"] < points[idx]["regret"]:
                        points[idx] = pt
                    report(points[idx])
                else:
                    # keep the RNG stream aligned with the first pass
                    make_masks(rng, m, k, n, fill)
                    rng.randn(m, k)
                    rng.randn(k, n)
                idx += 1

    for p in points:
        p["gate_ok"] = gate_ok(p)
    ok = all(p["gate_ok"] for p in points)
    result = {
        "block": BLOCK,
        "mesh": [2, 2],
        "tol": args.tol,
        "calibration": constants,
        "points": points,
        "max_regret": max(p["regret"] for p in points),
        "regret_ok": ok,
    }
    os.makedirs(args.out, exist_ok=True)
    name = "planner_smoke.json" if args.smoke else "planner.json"
    out_path = os.path.join(args.out, name)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"max regret {result['max_regret'] * 100:.1f}% "
          f"(tol {args.tol * 100:.0f}%) -> {'OK' if ok else 'FAIL'}")
    print("wrote ->", out_path)
    if args.check and not ok:
        raise SystemExit("planner regret exceeded tolerance")


if __name__ == "__main__":
    main()
