"""Telemetry layer: tracing overhead + trace-schema + scoreboard gates.

The observability contract (repro.obs) has three measurable halves,
and this bench gates all of them in CI:

  overhead    a TRACED multiply (spans, per-step timeline, plan-outcome
              logging) vs the identical untraced one on the pinned
              deterministic config — tracing must cost <= 5% (or fall
              inside an absolute jitter floor; the disabled-by-default
              path is separately bitwise-gated in tests/test_obs.py)
  trace       the Chrome-trace JSON exported for one traced
              ``dbcsr.multiply(return_plan=True)`` must pass
              ``validate_chrome_trace`` (schema, nesting, finite
              timestamps), and the synthetic schedule-step spans must
              sum consistently with the measured dispatch wall time
  scoreboard  a pinned algorithm sweep must leave one
              predicted-vs-actual row per executed algorithm, each
              with a finite signed relative error — the input
              ``planner.calibrate --check-drift`` consumes

    PYTHONPATH=src python -m benchmarks.bench_obs [--smoke] [--check]

``--smoke`` shrinks geometry/reps and writes
artifacts/bench/obs_smoke.json (scripts/ci.sh runs it with --check);
the full run writes artifacts/bench/obs.json.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

import argparse
import json
import time

import numpy as np
import jax

from repro import obs
from repro.compat import make_mesh
from repro.core import dbcsr

# pinned deterministic config: traced-vs-untraced is the IDENTICAL
# execution path, so the delta is pure telemetry cost
EXEC_KW = dict(algorithm="cannon", densify=False, local_kernel="ref",
               pipeline_depth=1)

OVERHEAD_GATE = 0.05          # traced <= 5% over untraced ...
OVERHEAD_ABS_FLOOR_S = 2e-3   # ... or within the host-timing jitter floor
STEP_SUM_TOL = 0.05           # children-vs-dispatch duration agreement
SWEEP_ALGOS = ("cannon", "summa", "ts_k")


def bench_overhead(mesh, geometry, block, reps, rng):
    """Interleaved best-of-``reps`` traced vs untraced wall time.

    Eager shard_map dispatch on the host backend has run-to-run jitter
    far above the telemetry cost, so the two paths are timed in
    ALTERNATION (machine-state drift hits both equally) and the gate
    allows the delta to fall inside the baseline's own observed spread
    — the untraced path disagreeing with itself by more than the
    traced-vs-untraced delta means no measurable overhead.
    """
    m, k, n = geometry
    a = dbcsr.create(rng.randn(m, k).astype(np.float32), mesh=mesh,
                     block_size=block)
    b = dbcsr.create(rng.randn(k, n).astype(np.float32), mesh=mesh,
                     block_size=block)
    kw = dict(mesh=mesh, **EXEC_KW)

    def run_once():
        c = dbcsr.multiply(a, b, **kw)
        jax.block_until_ready(c.data)

    def timed():
        t0 = time.perf_counter()
        run_once()
        return time.perf_counter() - t0

    obs.disable()
    run_once()                      # compile before timing either path
    plain, traced = [], []
    for _ in range(reps):
        obs.disable()
        plain.append(timed())
        obs.enable()                # in-memory tracer, no log files
        traced.append(timed())
    obs.disable()

    t_plain, t_traced = min(plain), min(traced)
    jitter = max(plain) - min(plain)
    overhead = (t_traced - t_plain) / t_plain
    ok = (overhead <= OVERHEAD_GATE
          or (t_traced - t_plain) <= max(OVERHEAD_ABS_FLOOR_S, jitter))
    row = {
        "geometry": list(geometry), "block": block, "reps": reps,
        "untraced_s": t_plain, "traced_s": t_traced,
        "untraced_all_s": plain, "traced_all_s": traced,
        "overhead_frac": overhead, "gate": OVERHEAD_GATE,
        "abs_floor_s": OVERHEAD_ABS_FLOOR_S, "jitter_s": jitter, "ok": ok,
    }
    print(f"overhead: {m}x{k}x{n} block {block}  "
          f"untraced {t_plain*1e3:8.2f} ms  traced {t_traced*1e3:8.2f} ms  "
          f"{overhead*100:+5.1f}%  (gate {OVERHEAD_GATE*100:.0f}% or "
          f"jitter floor {max(OVERHEAD_ABS_FLOOR_S, jitter)*1e3:.1f} ms)")
    return row


def bench_trace_schema(mesh, geometry, block, rng, out_dir):
    """One traced multiply -> valid Chrome trace + consistent durations."""
    m, k, n = geometry
    a = dbcsr.create(rng.randn(m, k).astype(np.float32), mesh=mesh,
                     block_size=block)
    b = dbcsr.create(rng.randn(k, n).astype(np.float32), mesh=mesh,
                     block_size=block)
    obs.enable()
    c, plan = dbcsr.multiply(a, b, mesh=mesh, return_plan=True, **EXEC_KW)
    jax.block_until_ready(c.data)
    obs.disable()
    spans = obs.last_trace()

    trace_path = os.path.join(out_dir, "obs_multiply_trace.json")
    chrome = obs.to_chrome_trace(spans)
    obs.write_chrome_trace(trace_path, spans)
    errors = obs.validate_chrome_trace(chrome)

    by_id = {s.span_id: s for s in spans}
    roots = [s for s in spans if s.parent_id is None]
    dispatches = [s for s in spans if s.name == "dispatch"]
    consistency = {"n_spans": len(spans), "n_roots": len(roots),
                   "n_dispatch": len(dispatches)}
    durations_ok = len(roots) == 1 and len(dispatches) == 1
    if durations_ok:
        root, disp = roots[0], dispatches[0]
        kids = [s for s in spans if s.parent_id == disp.span_id]
        kid_sum = sum(s.dur for s in kids)
        rel_gap = (abs(kid_sum - disp.dur) / disp.dur
                   if disp.dur > 0 else float("inf"))
        durations_ok = (bool(kids) and rel_gap <= STEP_SUM_TOL
                        and root.dur >= disp.dur > 0)
        consistency.update({
            "root_s": root.dur, "dispatch_s": disp.dur,
            "step_children": len(kids), "children_sum_s": kid_sum,
            "rel_gap": rel_gap, "tol": STEP_SUM_TOL,
        })
    row = {"trace_path": trace_path, "schema_errors": errors,
           "consistency": consistency, "durations_ok": durations_ok}
    print(f"trace:    {len(spans)} spans -> {trace_path}  "
          f"schema errors: {len(errors)}  "
          f"step-sum gap: {consistency.get('rel_gap', float('nan'))*100:.1f}% "
          f"(tol {STEP_SUM_TOL*100:.0f}%)")
    return row


def bench_scoreboard(mesh, geometry, block, rng, log_dir):
    """Pinned algorithm sweep -> one scoreboard row per algorithm."""
    m, k, n = geometry
    a = dbcsr.create(rng.randn(m, k).astype(np.float32), mesh=mesh,
                     block_size=block)
    b = dbcsr.create(rng.randn(k, n).astype(np.float32), mesh=mesh,
                     block_size=block)
    obs.clear_plan_outcomes()
    obs.enable(log_dir=log_dir)
    for algo in SWEEP_ALGOS:
        kw = dict(EXEC_KW, algorithm=algo)
        c = dbcsr.multiply(a, b, mesh=mesh, **kw)
        jax.block_until_ready(c.data)
    obs.disable()
    outcomes = obs.plan_outcomes()
    sb = obs.planner_scoreboard(outcomes)
    print(obs.render_scoreboard(sb))
    complete = all(
        algo in sb and sb[algo]["n"] >= 1
        and np.isfinite(sb[algo]["rel_err_median"])
        for algo in SWEEP_ALGOS)
    return {"algorithms": list(SWEEP_ALGOS), "n_outcomes": len(outcomes),
            "scoreboard": sb, "complete": complete,
            "plan_log": os.path.join(log_dir, obs.PLAN_OUTCOMES_LOG)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small geometry, few reps -> obs_smoke.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless tracing overhead <= 5%, the "
                         "Chrome trace validates with consistent "
                         "durations, and the sweep scoreboard has a "
                         "finite predicted-vs-actual row per algorithm "
                         "(CI gate)")
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--obs-dir", default="artifacts/obs",
                    help="log dir for the sweep's plan_outcomes.jsonl "
                         "(what calibrate --check-drift reads)")
    args = ap.parse_args()

    if args.smoke:
        geometry, block, reps = (256, 256, 256), 32, 3
    else:
        geometry, block, reps = (512, 512, 512), 32, 5

    mesh = make_mesh((1, 1), ("data", "model"))
    rng = np.random.RandomState(0)
    os.makedirs(args.out, exist_ok=True)
    os.makedirs(args.obs_dir, exist_ok=True)

    overhead = bench_overhead(mesh, geometry, block, reps, rng)
    trace = bench_trace_schema(mesh, geometry, block, rng, args.out)
    scoreboard = bench_scoreboard(mesh, geometry, block, rng, args.obs_dir)

    gates = {
        "overhead_ok": bool(overhead["ok"]),
        "trace_valid": not trace["schema_errors"],
        "durations_consistent": bool(trace["durations_ok"]),
        "scoreboard_complete": bool(scoreboard["complete"]),
    }
    result = {
        "exec_kw": {k: str(v) for k, v in EXEC_KW.items()},
        "overhead": overhead,
        "trace": trace,
        "scoreboard": scoreboard,
        "gates": gates,
    }
    name = "obs_smoke.json" if args.smoke else "obs.json"
    path = os.path.join(args.out, name)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print("gates:", gates)
    print("wrote ->", path)
    if args.check and not all(gates.values()):
        raise SystemExit(f"telemetry gate failed: "
                         f"{[k for k, v in gates.items() if not v]}")


if __name__ == "__main__":
    main()
