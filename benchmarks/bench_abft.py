"""ABFT verified multiply: checksum overhead + chaos detection gate.

Huang–Abraham block checksums (repro.robustness.abft) make every
product self-verifying: two O(N^2/nblocks) residual reductions bound
each block row/column of C against independently-computed checksums of
A and B, with a norm-aware tolerance (PR 5's block-norm cache) that
absorbs float accumulation order and eps-filtered triples.  This bench
answers the two questions that decide whether ``verify=`` is usable in
production:

  overhead   wall-clock cost of ``verify="checksum"`` vs ``verify=None``
             on the pinned deterministic config — the CI gate requires
             <= 25% (ISSUE acceptance; the planner prices the same
             ratio analytically for ``verify="auto"``, reported next to
             the measurement)
  chaos      an injected corruption sweep (bitflip / NaN / scale into
             the max-norm result block) must be detected, localized to
             the exact block, repaired, and bitwise-equal to the clean
             product; clean and eps-filtered runs must report ZERO
             false positives

    PYTHONPATH=src python -m benchmarks.bench_abft [--smoke] [--check]

``--smoke`` shrinks geometry/reps and writes
artifacts/bench/abft_smoke.json (scripts/ci.sh runs it with --check);
the full run writes artifacts/bench/abft.json.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

import argparse
import json
import time

import numpy as np
import jax

from repro.compat import make_mesh
from repro.core import dbcsr
from repro.robustness import chaos

# pinned deterministic config: overhead is verified-vs-unverified on
# the IDENTICAL execution path, so the delta is pure ABFT cost
EXEC_KW = dict(algorithm="cannon", densify=False, local_kernel="ref",
               pipeline_depth=1)

OVERHEAD_GATE = 0.25


def bench_overhead(mesh, geometry, block, reps, rng):
    m, k, n = geometry
    a = dbcsr.create(rng.randn(m, k).astype(np.float32), mesh=mesh,
                     block_size=block)
    b = dbcsr.create(rng.randn(k, n).astype(np.float32), mesh=mesh,
                     block_size=block)
    kw = dict(mesh=mesh, **EXEC_KW)

    # warm-up: compile both paths before timing
    for v in (None, "checksum"):
        c = dbcsr.multiply(a, b, verify=v, **kw)
        jax.block_until_ready(c.data)

    def best_of(verify):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            c = dbcsr.multiply(a, b, verify=verify, **kw)
            jax.block_until_ready(c.data)
            best = min(best, time.perf_counter() - t0)
        return best

    t_plain = best_of(None)
    t_verified = best_of("checksum")
    overhead = (t_verified - t_plain) / t_plain

    # the planner's analytic price for the same decision (verify="auto")
    c, plan = dbcsr.multiply(a, b, verify="auto", return_plan=True, **kw)
    pricing = {key: plan.verification[key]
               for key in ("predicted_overhead_s", "overhead_frac",
                           "budget") if key in plan.verification}
    pricing["auto_enabled"] = bool(plan.verification["enabled"])

    row = {
        "geometry": list(geometry), "block": block, "reps": reps,
        "unverified_s": t_plain, "verified_s": t_verified,
        "overhead_frac": overhead, "gate": OVERHEAD_GATE,
        "planner": pricing,
    }
    print(f"overhead: {m}x{k}x{n} block {block}  "
          f"plain {t_plain*1e3:8.2f} ms  verified {t_verified*1e3:8.2f} ms  "
          f"+{overhead*100:5.1f}%  (gate {OVERHEAD_GATE*100:.0f}%, "
          f"planner predicts {pricing.get('overhead_frac', float('nan'))*100:5.1f}%)")
    return row


def bench_chaos(mesh, geometry, block, seed):
    rows = chaos.run_injection_matrix(
        mesh, "1x1", algorithms=("cannon", "summa"), fills=(1.0, 0.05),
        modes=("bitflip", "nan", "scale"), geometry=geometry, block=block,
        seed=seed)
    inject = [r for r in rows if r["mode"] not in ("clean", "clean_eps")]
    clean = [r for r in rows if r["mode"] in ("clean", "clean_eps")]
    summary = {
        "n_injections": len(inject),
        "n_detected": sum(r["detected"] for r in inject),
        "n_localized_exact": sum(r["localized_exact"] for r in inject),
        "n_repaired_bitwise": sum(r["bitwise_clean"] for r in inject),
        "n_clean_runs": len(clean),
        "n_false_positives": sum(r["detected"] for r in clean),
        "rows": rows,
    }
    print(f"chaos:    {summary['n_detected']}/{summary['n_injections']} "
          f"detected, {summary['n_localized_exact']} localized exactly, "
          f"{summary['n_repaired_bitwise']} repaired bitwise-clean; "
          f"{summary['n_false_positives']}/{summary['n_clean_runs']} "
          f"false positives on clean runs")
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small geometry, few reps -> abft_smoke.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless verified overhead <= 25%, "
                         "every injection is detected+localized+repaired "
                         "bitwise, and clean runs have zero false "
                         "positives (CI gate)")
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()

    if args.smoke:
        geometry, block, reps = (256, 256, 256), 32, 2
        chaos_geometry = (128, 128, 128)
    else:
        geometry, block, reps = (512, 512, 512), 32, 3
        chaos_geometry = (256, 256, 256)

    mesh = make_mesh((1, 1), ("data", "model"))
    rng = np.random.RandomState(0)

    overhead = bench_overhead(mesh, geometry, block, reps, rng)
    chaos_summary = bench_chaos(mesh, chaos_geometry, block, seed=0)

    gates = {
        "overhead_ok": bool(overhead["overhead_frac"] <= OVERHEAD_GATE),
        "all_detected": chaos_summary["n_detected"]
        == chaos_summary["n_injections"],
        "all_localized": chaos_summary["n_localized_exact"]
        == chaos_summary["n_injections"],
        "all_repaired_bitwise": chaos_summary["n_repaired_bitwise"]
        == chaos_summary["n_injections"],
        "no_false_positives": chaos_summary["n_false_positives"] == 0,
    }
    result = {
        "exec_kw": {k: str(v) for k, v in EXEC_KW.items()},
        "overhead": overhead,
        "chaos": chaos_summary,
        "gates": gates,
    }
    os.makedirs(args.out, exist_ok=True)
    name = "abft_smoke.json" if args.smoke else "abft.json"
    path = os.path.join(args.out, name)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print("gates:", gates)
    print("wrote ->", path)
    if args.check and not all(gates.values()):
        raise SystemExit(f"ABFT gate failed: "
                         f"{[k for k, v in gates.items() if not v]}")


if __name__ == "__main__":
    main()
