"""Benchmark driver: runs each paper-table benchmark in its own
subprocess (each sets its own XLA_FLAGS device count; this parent never
imports jax) and aggregates artifacts/bench/*.json.

    PYTHONPATH=src python -m benchmarks.run [--only <substr>]
"""
import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BENCHES = [
    ("kernels (smm / dense / grouped)", "benchmarks.bench_kernels"),
    ("IV-A grid configuration", "benchmarks.bench_grid_config"),
    ("IV-B blocked vs densified", "benchmarks.bench_densify"),
    ("block-sparse occupancy sweep", "benchmarks.bench_sparse"),
    ("norm filtering eps sweep + purification", "benchmarks.bench_filter"),
    ("multiply planner regret (auto vs fixed)", "benchmarks.bench_planner"),
    ("schedule-engine pipeline depth (comm/compute overlap)",
     "benchmarks.bench_overlap"),
    ("batched multiply service (fused vs looped dispatch)",
     "benchmarks.bench_batched"),
    ("ABFT verified multiply (checksum overhead + chaos gate)",
     "benchmarks.bench_abft"),
    ("telemetry (tracing overhead + trace schema + planner scoreboard)",
     "benchmarks.bench_obs"),
    ("tensor contraction (layout regret + fill scaling)",
     "benchmarks.bench_tensor"),
    ("IV-C DBCSR vs PDGEMM(SUMMA)", "benchmarks.bench_vs_pgemm"),
    ("2.5D Cannon (pod-axis, beyond-paper)", "benchmarks.bench_25d"),
    ("roofline summary (from dry-run artifacts)", "benchmarks.bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO, env.get("PYTHONPATH", "")])
    failures = []
    for name, mod in BENCHES:
        if args.only and args.only not in mod:
            continue
        print(f"\n=== {name} ===", flush=True)
        proc = subprocess.run([sys.executable, "-m", mod],
                              env=env, cwd=REPO)
        if proc.returncode != 0:
            failures.append(name)
    print("\n=== benchmark artifacts ===")
    bdir = os.path.join(REPO, "artifacts", "bench")
    if os.path.isdir(bdir):
        for f in sorted(os.listdir(bdir)):
            print(" ", os.path.join("artifacts/bench", f))
    if failures:
        print("FAILED:", failures)
        raise SystemExit(1)
    print("all benchmarks complete")


if __name__ == "__main__":
    main()
