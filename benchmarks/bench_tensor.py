"""Tensor-contraction layout regret + fill scaling (repro.tensor).

Two CI-gated claims about ``dbcsr.contract``:

  regret      the planner's matricization choice (``layout="auto"``)
              must be within 10% (+1 ms jitter floor) of the best
              FIXED layout, measured over square / tall / skinny
              contraction geometries — i.e. the per-layout pricing
              (occupancy, imbalance, unfold/refold copy cost) actually
              ranks layouts on this machine, mirroring the 2D
              planner-regret gate in bench_planner
  fill        on the pinned blocked path the end-to-end contraction
              dispatch must get no slower as block fill FALLS
              (100/50/20/5%): lowered masks reach the 2D engine's
              retained-triple machinery, so sparser tensors do less
              work — the tensor-frame replica of bench_sparse's
              monotonic-dispatch gate

    PYTHONPATH=src python benchmarks/bench_tensor.py [--smoke] [--check]

``--smoke`` shrinks geometry/reps and writes
artifacts/bench/tensor_smoke.json (scripts/ci.sh runs it with
--check); the full run writes artifacts/bench/tensor.json.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

import argparse
import json
import statistics
import time

import numpy as np
import jax

from repro.compat import make_mesh
from repro.core import dbcsr
from repro.core.blocking import GridSpec
from repro.planner.plan import contract_cache_clear
from repro.tensor import enumerate_layouts, parse_contraction

# pinned deterministic blocked path for the fill sweep (the regret
# sweep leaves algorithm/path to the planner — that choice is part of
# what a layout's priced multiply_s covers)
BLOCKED_KW = dict(algorithm="summa", densify=False, local_kernel="ref",
                  pipeline_depth=1)

REGRET_TOL = 0.10       # auto within 10% of the best fixed layout ...
ABS_FLOOR_S = 1e-3      # ... plus the interpret-mode jitter floor
FILLS = (1.0, 0.5, 0.2, 0.05)  # descending: monotone gate reads left-right

# (name, spec, a shape, a blocks, b shape, b blocks): the fused-row
# dimension ranges from dominant (tall) to dominated (skinny), which
# is exactly what moves the copy/imbalance trade-off between layouts
SMOKE_CASES = [
    ("square", "ijk,kl->ijl", (32, 8, 32), (8, 4, 8), (32, 32), (8, 8)),
    ("tall", "ijk,kl->ijl", (64, 16, 16), (8, 4, 8), (16, 64), (8, 8)),
    ("skinny", "ijk,kl->ijl", (16, 4, 64), (8, 4, 8), (64, 128), (8, 8)),
]
FULL_CASES = [
    ("square", "ijk,kl->ijl", (64, 16, 64), (8, 4, 8), (64, 64), (8, 8)),
    ("tall", "ijk,kl->ijl", (128, 32, 16), (8, 4, 8), (16, 64), (8, 8)),
    ("skinny", "ijk,kl->ijl", (16, 8, 128), (8, 4, 8), (128, 256), (8, 8)),
]


def make_tensor(rng, mesh, shape, blocks, fill):
    data = rng.randn(*shape).astype(np.float32)
    mask = None
    if fill < 1.0:
        bg = tuple(d // b for d, b in zip(shape, blocks))
        mask = rng.rand(*bg) < fill
        mask.flat[0] = True
    return dbcsr.create_tensor(data, mesh=mesh, grid=GridSpec(),
                               block_sizes=blocks, block_mask=mask)


def time_interleaved(fns, reps):
    """Median-of-reps per callable, reps interleaved round-robin so
    machine-load drift hits every candidate equally (same rationale as
    bench_planner: median because the gate argmins near-tied times)."""
    for fn in fns:
        jax.block_until_ready(fn().data)  # warm: compile + plan cache
    samples = [[] for _ in fns]
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn().data)
            samples[i].append(time.perf_counter() - t0)
    return [statistics.median(s) for s in samples]


def regret_point(mesh, rng, case, fill, reps):
    name, spec, ash, abl, bsh, bbl = case
    A = make_tensor(rng, mesh, ash, abl, fill)
    B = make_tensor(rng, mesh, bsh, bbl, fill)
    layouts = enumerate_layouts(parse_contraction(spec))
    _, plan = dbcsr.contract(spec, A, B, mesh=mesh, return_plan=True)

    def fixed(L):
        return lambda: dbcsr.contract(spec, A, B, mesh=mesh, layout=L)

    fns = [fixed(L) for L in layouts]
    fns.append(lambda: dbcsr.contract(spec, A, B, mesh=mesh))  # auto
    times = time_interleaved(fns, reps)
    rows = [{"layout": L.label, "time_s": t}
            for L, t in zip(layouts, times[:-1])]
    # the auto dispatch's fixed twin ran the identical computation; the
    # min of the two is the auto configuration's measured time
    twin = [r["time_s"] for r in rows if r["layout"] == plan.layout]
    t_auto = min([times[-1]] + twin)
    best = min(rows, key=lambda r: r["time_s"])
    return {
        "case": name, "spec": spec, "fill": fill,
        "auto_layout": plan.layout, "auto_algorithm": plan.algorithm,
        "t_auto_s": t_auto, "t_best_s": best["time_s"],
        "best_layout": best["layout"],
        "regret": t_auto / best["time_s"] - 1.0,
        "layouts": rows,
    }


def gate_ok(pt):
    return bool(pt["t_auto_s"] <= pt["t_best_s"] * (1 + REGRET_TOL)
                + ABS_FLOOR_S)


def report(pt):
    print(f"{pt['case']:7s} fill {pt['fill']:4g}: "
          f"auto={pt['auto_layout']:16s} {pt['t_auto_s']*1e3:8.2f} ms  "
          f"best={pt['best_layout']:16s} {pt['t_best_s']*1e3:8.2f} ms  "
          f"regret {pt['regret']*100:6.1f}%", flush=True)


def bench_regret(mesh, cases, reps):
    points = []
    for i, case in enumerate(cases):
        pt = regret_point(mesh, np.random.RandomState(i), case, 0.5, reps)
        points.append(pt)
        report(pt)
    # ambient load swings near-tied few-ms timings: one fresh
    # re-measurement before a point counts as a planner miss
    for i, pt in enumerate(points):
        if gate_ok(pt):
            continue
        print(f"re-measuring gate-failing point {pt['case']}...")
        fresh = regret_point(mesh, np.random.RandomState(i), cases[i],
                             0.5, reps + 2)
        fresh["retried"] = True
        if fresh["regret"] < pt["regret"]:
            points[i] = fresh
        report(points[i])
    return points


def bench_fill(mesh, reps, stack_size=64):
    """Blocked executor dispatch vs falling tensor fill: the N-d masks
    lower through the unfold into the 2D executor plan, so a sparser
    tensor builds a smaller retained-triple stack — timed as the
    jitted ``execute_plan`` exactly like bench_sparse's monotone gate
    (the eager shard_map wrapper's fixed host overhead would otherwise
    swamp the occupancy signal at CI-sized geometry)."""
    import jax.numpy as jnp

    from repro.core.densify import to_blocks
    from repro.core.engine import build_executor_plan, execute_plan
    from repro.tensor import unfold_tensor

    spec, ash, abl, bsh, bbl = \
        "ijk,kl->ijl", (64, 16, 64), (8, 4, 8), (64, 64), (8, 8)
    con = parse_contraction(spec)
    rows = []
    for fill in FILLS:
        rng = np.random.RandomState(7)
        A = make_tensor(rng, mesh, ash, abl, fill)
        B = make_tensor(rng, mesh, bsh, bbl, fill)
        ma = unfold_tensor(A, con.a_indices, con.a_free, con.contracted,
                           mesh=mesh)
        mb = unfold_tensor(B, con.b_indices, con.contracted, con.b_free,
                           mesh=mesh)
        (m2, k2), (_, n2) = ma.shape, mb.shape
        bm, bk = ma.layout.block_rows, ma.layout.block_cols
        bn = mb.layout.block_cols
        plan = build_executor_plan(m2, k2, n2, bm, bk, bn, stack_size,
                                   a_mask=ma.block_mask,
                                   b_mask=mb.block_mask)
        ab = to_blocks(jnp.asarray(ma.data), bm, bk)
        bb = to_blocks(jnp.asarray(mb.data), bk, bn)
        c0 = jnp.zeros(((m2 // bm) * (n2 // bn), bm, bn), jnp.float32)
        fn = jax.jit(lambda ab, bb, c0, p=plan: execute_plan(
            p, ab, bb, c0, kernel="ref"))
        jax.block_until_ready(fn(ab, bb, c0))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(ab, bb, c0))
            best = min(best, time.perf_counter() - t0)
        rows.append({"fill": fill, "occupancy_a": A.occupancy,
                     "n_triples": plan.n_entries,
                     "n_dense_triples": plan.n_dense_triples,
                     "time_s": best})
        print(f"fill {fill:4g}: {plan.n_entries:6d}/"
              f"{plan.n_dense_triples} triples  blocked dispatch "
              f"{best*1e3:8.2f} ms", flush=True)
    times = [r["time_s"] for r in rows]
    triples = [r["n_triples"] for r in rows]
    # same slack as bench_sparse: 10% relative + 1 ms absolute floor;
    # the retained-triple count must fall strictly (mask lowering is
    # exact, so this half of the gate is deterministic)
    monotone = all(
        times[i] + 1e-3 >= times[i + 1] * 0.9
        and triples[i] > triples[i + 1]
        for i in range(len(times) - 1))
    return rows, monotone


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small geometry, few reps -> tensor_smoke.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless auto-layout regret <= 10% "
                         "(+1 ms) at every sweep point and the blocked "
                         "dispatch time is monotone over falling fill "
                         "(CI gate)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()

    cases = SMOKE_CASES if args.smoke else FULL_CASES
    reps = args.reps or (3 if args.smoke else 5)

    mesh = make_mesh((1, 1), ("data", "model"))
    contract_cache_clear()

    print("== layout regret (auto vs every fixed matricization) ==")
    points = bench_regret(mesh, cases, reps)
    print("== blocked dispatch vs fill ==")
    fill_rows, monotone = bench_fill(mesh, reps)

    gates = {
        "regret_ok": all(gate_ok(p) for p in points),
        "fill_monotone": bool(monotone),
    }
    result = {
        "regret_tol": REGRET_TOL, "abs_floor_s": ABS_FLOOR_S,
        "reps": reps, "points": points,
        "fill_sweep": fill_rows, "gates": gates,
    }
    os.makedirs(args.out, exist_ok=True)
    name = "tensor_smoke.json" if args.smoke else "tensor.json"
    path = os.path.join(args.out, name)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print("gates:", gates)
    print("wrote ->", path)
    if args.check and not all(gates.values()):
        raise SystemExit(f"tensor gate failed: "
                         f"{[k for k, v in gates.items() if not v]}")


if __name__ == "__main__":
    main()
