"""Roofline summary table: aggregates artifacts/dryrun/*.json (produced
by repro.launch.dryrun) into the EXPERIMENTS.md §Roofline table.  No
jax import — purely a report over the compiled-artifact analysis."""
import glob
import json
import os

HEADERS = ("arch", "shape", "mesh", "C_ms", "M_ms", "X_ms", "dominant",
           "useful_ratio", "peak_GiB")


def load(art_dir="artifacts/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        r = json.load(open(path))
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r.get("mesh", "?"), "skipped": r.get("why")})
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "C_ms": t["compute_s"] * 1e3, "M_ms": t["memory_s"] * 1e3,
            "X_ms": t["collective_s"] * 1e3, "dominant": t["dominant"],
            "useful_ratio": r["useful_flop_ratio"],
            "peak_GiB": r["memory"]["peak_per_device_bytes"] / 2**30,
        })
    return rows


def main():
    rows = load()
    if not rows:
        print("no dry-run artifacts found; run repro.launch.dryrun first")
        return
    print(f"{'arch':26s} {'shape':12s} {'mesh':11s} {'C(ms)':>8s} {'M(ms)':>8s}"
          f" {'X(ms)':>8s} {'dom':>10s} {'useful':>7s} {'GiB/dev':>8s}")
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:11s} "
                  f"-- skipped: {r['skipped'][:60]}")
            continue
        print(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:11s} "
              f"{r['C_ms']:8.2f} {r['M_ms']:8.2f} {r['X_ms']:8.2f} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
              f"{r['peak_GiB']:8.2f}")


if __name__ == "__main__":
    main()
