"""Paper table IV-C (Fig. 4): densified DBCSR vs PDGEMM (ScaLAPACK).

Our PDGEMM stand-in is the SUMMA baseline (core/summa.py) — the same
algorithm family as Cray LibSci_acc's PGEMM.  Reported as the paper
does: T_pdgemm / T_dbcsr across device counts, for square and
tall-and-skinny multiplications.  DBCSR dispatches Cannon (square) and
the O(1)-communication algorithm (tall-skinny), which is exactly where
the paper's 2.5x win comes from.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import json
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.blocking import GridSpec
from repro.core.cannon import cannon_matmul
from repro.core.summa import summa_matmul
from repro.core.tall_skinny import tall_skinny_matmul
from repro.launch.mesh import make_mesh


def time_call(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def main(out="artifacts/bench"):
    rng = np.random.RandomState(0)
    results = []

    for side in (2, 4):  # 4 and 16 devices
        mesh = make_mesh((side, side), ("data", "model"))
        grid = GridSpec("data", "model")
        sh = NamedSharding(mesh, P("data", "model"))

        # --- square ---------------------------------------------------
        n = 1408
        A = rng.randn(n, n).astype(np.float32)
        B = rng.randn(n, n).astype(np.float32)
        Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
        t_dbcsr = time_call(jax.jit(
            lambda a, b: cannon_matmul(a, b, mesh=mesh, grid=grid)), Ad, Bd)
        t_pgemm = time_call(jax.jit(
            lambda a, b: summa_matmul(a, b, mesh=mesh, grid=grid)), Ad, Bd)
        results.append({"case": "square", "devices": side * side,
                        "t_dbcsr_s": t_dbcsr, "t_pgemm_s": t_pgemm,
                        "speedup": t_pgemm / t_dbcsr})
        print(f"square      {side*side:3d} dev: PDGEMM/DBCSR = "
              f"{t_pgemm/t_dbcsr:5.2f}x  ({t_pgemm*1e3:.1f}ms / {t_dbcsr*1e3:.1f}ms)")

        # --- tall-and-skinny (paper: 1408 x 1'982'464) ------------------
        m = nn = 352
        k = 45056
        A2 = rng.randn(m, k).astype(np.float32)
        B2 = rng.randn(k, nn).astype(np.float32)
        # DBCSR: K sharded over all devices, one reduce
        A2d = jax.device_put(A2, NamedSharding(mesh, P(None, ("data", "model"))))
        B2d = jax.device_put(B2, NamedSharding(mesh, P(("data", "model"), None)))
        t_dbcsr = time_call(jax.jit(lambda a, b: tall_skinny_matmul(
            a, b, mesh=mesh, grid=grid, reduce="reduce_scatter")), A2d, B2d)
        # PGEMM: 2D block layout + SUMMA panels
        A2s = jax.device_put(A2, sh)
        B2s = jax.device_put(B2, sh)
        t_pgemm = time_call(jax.jit(
            lambda a, b: summa_matmul(a, b, mesh=mesh, grid=grid)), A2s, B2s)
        results.append({"case": "tall_skinny", "devices": side * side,
                        "t_dbcsr_s": t_dbcsr, "t_pgemm_s": t_pgemm,
                        "speedup": t_pgemm / t_dbcsr})
        print(f"tall-skinny {side*side:3d} dev: PDGEMM/DBCSR = "
              f"{t_pgemm/t_dbcsr:5.2f}x  ({t_pgemm*1e3:.1f}ms / {t_dbcsr*1e3:.1f}ms)")

    print("\npaper reference: 10-20% win on square, up to 2.5x on "
          "rectangular (Fig. 4)")
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, "vs_pgemm.json"), "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
