"""Paper table IV-B: blocked vs densified multiplication.

Measures T_blocked / T_densified for the paper's block sizes (22, 64)
on square and tall-and-skinny shapes, plus the stack statistics the
paper quotes (~8M stack entries for block 22 at full scale; scaled
sizes here).  The blocked path runs the stack plans through the smm
ref/kernel; the densified path is one large GEMM — the exact trade of
paper section III.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.blocking import BlockLayout
from repro.core.engine import execute_plans_looped
from repro.core.stacks import build_stacks, stack_statistics
from repro.core.densify import (blocked_local_matmul, densified_local_matmul,
                                from_blocks, to_blocks)


def time_call(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def bench_case(name, m, k, n, block, rng, results):
    a = jnp.asarray(rng.randn(m, k).astype(np.float32))
    b = jnp.asarray(rng.randn(k, n).astype(np.float32))
    blocked_fn = blocked_local_matmul(
        m, k, n, block_m=block, block_k=block, block_n=block,
        kernel="ref")
    blocked = jax.jit(blocked_fn)
    densified = jax.jit(densified_local_matmul())
    plan = blocked_fn.executor_plan
    stats = stack_statistics(list(plan.plans), stack_tile=plan.stack_tile)

    # before/after stack dispatch: the seed's per-plan jit loop vs the
    # fused scan executor the blocked path now uses
    def looped(a, b):
        ab = to_blocks(a, block, block)
        bb = to_blocks(b, block, block)
        c0 = jnp.zeros((plan.nbr * plan.nbc, block, block), jnp.float32)
        c = execute_plans_looped(list(plan.plans), ab, bb, c0, kernel="ref")
        return from_blocks(c, plan.nbr, plan.nbc)

    t_b = time_call(blocked, a, b)
    t_loop = time_call(jax.jit(looped), a, b)
    t_d = time_call(densified, a, b)
    err = float(jnp.max(jnp.abs(blocked(a, b) - densified(a, b))))
    rec = {"case": name, "m": m, "k": k, "n": n, "block": block,
           "t_blocked_s": t_b, "t_blocked_looped_s": t_loop,
           "t_densified_s": t_d,
           "ratio": t_b / t_d, "dispatch_speedup": t_loop / t_b,
           "n_stacks": stats["n_stacks"],
           "n_stack_entries": stats["n_multiplications"],
           "stack_fill": stats.get("fill", 1.0),
           "max_err": err}
    results.append(rec)
    print(f"{name:12s} block={block:3d}  T_blocked/T_densified = "
          f"{t_b/t_d:6.2f}x   looped/fused = {t_loop/t_b:5.2f}x   "
          f"({stats['n_multiplications']} stack entries in "
          f"{stats['n_stacks']} stacks, err {err:.1e})")


def main(out="artifacts/bench"):
    rng = np.random.RandomState(0)
    results = []
    # square (paper: 63'360^3 at full scale; scaled to CPU)
    for block in (22, 64):
        n = 704  # divisible by both 22 and 64? 704 = 22*32 = 64*11
        bench_case("square", n, n, n, block, rng, results)
    # rectangular tall-and-skinny (paper: 1408 x 1'982'464); dims chosen
    # divisible by the block size under test
    bench_case("rectangular", 352, 14080, 352, 22, rng, results)
    bench_case("rectangular", 384, 16384, 384, 64, rng, results)

    print("\npaper reference: densification wins up to ~1.8x at small "
          "node counts, block 22 benefits most (Fig. 3)")
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, "densify.json"), "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
