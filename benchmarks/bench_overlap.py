"""Pipeline-depth sweep: the schedule engine's comm/compute overlap.

The paper's GPU speedup rests on overlapping inter-rank transfer with
local stack processing (MPI/CUDA-stream double buffering).  The
schedule engine (core/schedule.py) expresses that as ``pipeline_depth``:
depth 1 issues every transfer strictly after the previous multiply,
depth 2 issues step t+1's ppermute / panel broadcast while step t
computes.  This benchmark times depth 1 vs depth 2 for every multi-step
algorithm — cannon, summa, cannon25d — with the interleaved
median-of-reps protocol (machine-load drift hits both depths equally),
reports the achieved overlap, and runs ``calibrate.measure_overlap`` so
the planner's per-algorithm ``overlap_*`` constants come from the same
machine (artifacts/planner_calibration.json is updated in place).

    PYTHONPATH=src python -m benchmarks.bench_overlap [--smoke] [--check]

``--smoke`` writes artifacts/bench/overlap_smoke.json (scripts/ci.sh
gates on it: ``--check`` fails if depth 2 is slower than depth 1 beyond
the jitter floor at any sweep point on a >= 2-device mesh); the full
run writes artifacts/bench/overlap.json.  CPU interpret-mode cannot
hide collectives, so the expected depth-2 win here is ~0 — the *gate*
(no regression) and the calibration workflow are what transfer to real
hardware.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import statistics
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.core.blocking import GridSpec
from repro.core.multiply import distributed_matmul
from repro.planner import calibrate

DEPTHS = (1, 2)


def time_interleaved(fns, args, reps=5):
    """Median-of-reps wall time per callable, reps interleaved
    round-robin so machine-load drift hits every candidate equally."""
    for fn in fns:
        jax.block_until_ready(fn(*args))  # warm (compile)
    samples = [[] for _ in fns]
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            samples[i].append(time.perf_counter() - t0)
    return [statistics.median(s) for s in samples]


def sweep_point(mesh, grid, algo, m, k, n, reps):
    rng = np.random.RandomState(0)
    A = rng.randn(m, k).astype(np.float32)
    B = rng.randn(k, n).astype(np.float32)
    sh = NamedSharding(mesh, P(grid.row_axis, grid.col_axis))
    Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
    ref = A @ B

    fns = [jax.jit(lambda a, b, d=d: distributed_matmul(
        a, b, mesh=mesh, grid=grid, algorithm=algo, densify=True,
        pipeline_depth=d)) for d in DEPTHS]
    errs = [float(np.max(np.abs(np.asarray(fn(Ad, Bd)) - ref)))
            for fn in fns]
    times = time_interleaved(fns, (Ad, Bd), reps=reps)
    t1, t2 = times
    return {
        "algorithm": algo, "m": m, "k": k, "n": n,
        "n_devices": int(mesh.devices.size),
        "t_depth1_s": t1, "t_depth2_s": t2,
        "speedup": t1 / t2 if t2 > 0 else 1.0,
        "achieved_overlap_frac": max(0.0, (t1 - t2) / t1) if t1 > 0 else 0.0,
        "max_err": max(errs),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes, few reps -> overlap_smoke.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if depth 2 is slower than depth 1 "
                         "beyond the jitter floor at any point (CI gate)")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="relative jitter tolerance for the gate")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()

    reps = args.reps or (5 if args.smoke else 9)
    side = 256 if args.smoke else 512

    # 8 host devices: (2, 2, 2) pod mesh for cannon25d, a (2, 2) submesh
    # for cannon/summa
    mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    grid3 = GridSpec("data", "model", stack_axis="pod")
    mesh2 = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                 ("data", "model"))
    grid2 = GridSpec("data", "model")

    points = []
    for algo, mesh, grid in (("cannon", mesh2, grid2),
                             ("summa", mesh2, grid2),
                             ("cannon25d", mesh3, grid3)):
        pt = sweep_point(mesh, grid, algo, side, side, side, reps)
        points.append(pt)
        print(f"{algo:10s} depth1 {pt['t_depth1_s'] * 1e3:8.2f} ms  "
              f"depth2 {pt['t_depth2_s'] * 1e3:8.2f} ms  "
              f"overlap {pt['achieved_overlap_frac'] * 100:5.1f}%  "
              f"err {pt['max_err']:.2e}", flush=True)

    # calibration workflow: persist the measured per-algorithm overlap
    # constants next to the other planner calibration data
    existing = calibrate._load_json(calibrate.DEFAULT_CALIBRATION) or {}
    overlap = calibrate.measure_overlap(mesh2, grid2, reps=reps)
    if overlap:
        existing.update(overlap)
        path = calibrate.save_calibration(existing)
        print("calibrated overlap ->", path)
        for key, val in sorted(overlap.items()):
            print(f"  {key:20s} {val:8.3f}")

    # gate: on a >= 2-device mesh the pipelined driver must never lose
    # to the serial one beyond timing jitter (2 ms absolute floor:
    # interpret-mode dispatch noise swings identical few-ms programs by
    # large fractions; a genuine pipelining regression on real hardware
    # dwarfs it)
    for pt in points:
        pt["gate_ok"] = bool(
            pt["n_devices"] < 2
            or pt["t_depth2_s"] <= pt["t_depth1_s"] * (1 + args.tol) + 2e-3)
        pt["correct"] = bool(pt["max_err"] < 2e-3)
    ok = all(pt["gate_ok"] and pt["correct"] for pt in points)

    result = {
        "depths": list(DEPTHS),
        "tol": args.tol,
        "reps": reps,
        "points": points,
        "overlap_calibration": overlap,
        "gate_ok": ok,
    }
    os.makedirs(args.out, exist_ok=True)
    name = "overlap_smoke.json" if args.smoke else "overlap.json"
    out_path = os.path.join(args.out, name)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"depth-2 vs depth-1 gate -> {'OK' if ok else 'FAIL'}")
    print("wrote ->", out_path)
    if args.check and not ok:
        raise SystemExit("pipelined depth-2 regressed vs serial depth-1")


if __name__ == "__main__":
    main()
