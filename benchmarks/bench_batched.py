"""Batched multiply service: fused dispatch vs per-request loop.

The serving workload DBCSR never had a story for: G independent small
block-sparse products arriving as a stream.  Looped execution pays the
full per-request dispatch price G times — and on this stack the
dominant term is the host-side one (each ``distributed_matmul`` call
builds a fresh shard_map closure, so every request retraces).  The
fused path (``dbcsr.multiply_batched``) stacks same-bucket requests
into one ``(G, m, k) x (G, k, n)`` product: ONE trace, ONE schedule,
ONE fused stack dispatch.

Per request mix this reports throughput (requests/s) and completion
latency percentiles (p50/p99 of "request done" measured from batch
start; looped latencies are cumulative — request i waits for requests
0..i-1):

  uniform_small   G identical small dense products — the amortization
                  best case and the CI gate: fused must clear 2x the
                  looped requests/s
  mixed_geometry  two geometry buckets — fusion happens per bucket
  sparse_mix      occupancy spread inside one geometry — buckets split
                  by fill bin, fused groups pad against each other

    PYTHONPATH=src python -m benchmarks.bench_batched [--smoke] [--check]

``--smoke`` shrinks geometry/reps and writes
artifacts/bench/batched_smoke.json (scripts/ci.sh tracks it, gated by
``--check``); the full run writes artifacts/bench/batched.json.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

import argparse
import json
import time

import numpy as np
import jax

from repro.compat import make_mesh
from repro.core import dbcsr

# pinned execution config: the comparison is fused-vs-looped DISPATCH,
# so both sides run the identical deterministic blocked path
EXEC_KW = dict(algorithm="cannon", densify=False, local_kernel="ref",
               pipeline_depth=1)


def make_requests(mesh, spec, block_size, rng):
    """spec: list of ((m, k, n), fill) request descriptors."""
    reqs = []
    for (m, k, n), fill in spec:
        A = rng.randn(m, k).astype(np.float32)
        B = rng.randn(k, n).astype(np.float32)
        mask = None
        if fill < 1.0:
            mask = rng.rand(m // block_size, k // block_size) < fill
            mask[0, 0] = True
        a = dbcsr.create(A, mesh=mesh, block_size=block_size,
                         block_mask=mask)
        b = dbcsr.create(B, mesh=mesh, block_size=block_size)
        reqs.append((a, b))
    return reqs


def run_looped(reqs, mesh):
    """Sequential per-request multiplies; latency of request i is
    cumulative (it completes only after requests 0..i-1)."""
    t0 = time.perf_counter()
    lat = []
    outs = []
    for a, b in reqs:
        c = dbcsr.multiply(a, b, mesh=mesh, **EXEC_KW)
        jax.block_until_ready(c.data)
        lat.append(time.perf_counter() - t0)
        outs.append(c)
    return outs, time.perf_counter() - t0, lat


def run_fused(reqs, mesh):
    """One ``multiply_batched`` call; every request in a bucket
    completes when its fused dispatch does."""
    t0 = time.perf_counter()
    outs, report = dbcsr.multiply_batched(reqs, mesh=mesh, fused=True,
                                          return_plan=True, **EXEC_KW)
    for c in outs:
        jax.block_until_ready(c.data)
    total = time.perf_counter() - t0
    # all buckets finish inside the single call — per-request
    # completion is the call's end (conservative: charges every
    # request the full batch wall time)
    return outs, total, [total] * len(reqs), report


def bench_mix(name, mesh, spec, block_size, reps):
    rng = np.random.RandomState(0)
    reqs = make_requests(mesh, spec, block_size, rng)
    g = len(reqs)

    best = None
    for _ in range(reps):
        looped_out, t_loop, lat_loop = run_looped(reqs, mesh)
        fused_out, t_fuse, lat_fuse, report = run_fused(reqs, mesh)
        for cf, cl in zip(fused_out, looped_out):
            assert np.array_equal(np.asarray(cf.data), np.asarray(cl.data)), \
                f"{name}: fused result diverged from looped"
        row = {
            "mix": name,
            "n_requests": g,
            "n_buckets": report["n_buckets"],
            "n_fused_requests": report["n_fused_requests"],
            "looped_s": t_loop,
            "fused_s": t_fuse,
            "looped_rps": g / t_loop,
            "fused_rps": g / t_fuse,
            "looped_p50_s": float(np.percentile(lat_loop, 50)),
            "looped_p99_s": float(np.percentile(lat_loop, 99)),
            "fused_p50_s": float(np.percentile(lat_fuse, 50)),
            "fused_p99_s": float(np.percentile(lat_fuse, 99)),
        }
        row["speedup"] = row["fused_rps"] / row["looped_rps"]
        if best is None or row["fused_s"] + row["looped_s"] \
                < best["fused_s"] + best["looped_s"]:
            best = row
    print(f"{name:15s}: {g:3d} reqs in {best['n_buckets']} bucket(s)  "
          f"looped {best['looped_rps']:7.1f} req/s "
          f"(p99 {best['looped_p99_s']*1e3:7.1f} ms)  "
          f"fused {best['fused_rps']:7.1f} req/s "
          f"(p99 {best['fused_p99_s']*1e3:7.1f} ms)  "
          f"{best['speedup']:5.2f}x")
    return best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small geometry, few reps -> batched_smoke.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the fused path clears 2x "
                         "looped requests/s on the uniform mix (CI gate)")
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()

    if args.smoke:
        geom, block_size, g, reps = (64, 64, 64), 16, 16, 2
    else:
        geom, block_size, g, reps = (256, 256, 256), 32, 32, 3

    mesh = make_mesh((1, 1), ("data", "model"))
    m, k, n = geom
    mixes = {
        "uniform_small": [(geom, 1.0)] * g,
        "mixed_geometry": [(geom, 1.0)] * (g // 2)
        + [((m, k, 2 * n), 1.0)] * (g // 2),
        "sparse_mix": [(geom, 1.0)] * (g // 2) + [(geom, 0.5)] * (g // 4)
        + [(geom, 0.05)] * (g - g // 2 - g // 4),
    }
    rows = [bench_mix(name, mesh, spec, block_size, reps)
            for name, spec in mixes.items()]

    uniform = rows[0]
    result = {
        "geometry": geom,
        "block_size": block_size,
        "n_requests": g,
        "exec_kw": {k_: str(v) for k_, v in EXEC_KW.items()},
        "rows": rows,
        # the acceptance gate: on >= 16 small same-geometry requests
        # one fused dispatch must at least double looped throughput
        "fused_2x_uniform": bool(uniform["speedup"] >= 2.0),
    }
    os.makedirs(args.out, exist_ok=True)
    name = "batched_smoke.json" if args.smoke else "batched.json"
    path = os.path.join(args.out, name)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"fused >= 2x looped on uniform mix: {result['fused_2x_uniform']}")
    print("wrote ->", path)
    if args.check and not result["fused_2x_uniform"]:
        raise SystemExit(
            f"fused dispatch only {uniform['speedup']:.2f}x looped "
            f"requests/s (gate: 2x)")


if __name__ == "__main__":
    main()
