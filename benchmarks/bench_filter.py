"""Norm-based filtering: eps sweep + purification trace.

Two sections, both written into one artifact:

  * **eps sweep** — one block workload whose per-block norms span
    several decades (log-uniform block scales, the shape of a real
    decaying-interaction matrix).  For retention targets
    {100%, 50%, 20%, 5%, ~0%} the eps threshold is read off the
    norm-product quantiles, the filtered plan is built, and its fused
    dispatch is timed against the unfiltered plan on identical
    payloads.  Reported per point: eps, retained triples/FLOPs,
    dispatch wall-clock, speedup (CPU interpret-mode — the *ratio*
    transfers, absolute times are not TPU truth).
  * **purification trace** — McWeeny iterations via
    ``dbcsr.multiply(filter_eps=...)`` (repro.sparsity.workloads) with
    per-iteration occupancy, retained/filtered FLOPs and wall time:
    the dispatch-time curve of a workload whose sparsity *evolves*.

    PYTHONPATH=src python -m benchmarks.bench_filter [--smoke] [--check]

``--smoke`` runs a small geometry and writes
artifacts/bench/filter_smoke.json (scripts/ci.sh gates it with
``--check``: the filtered dispatch at the 5%-retention point must not
be slower than the unfiltered dispatch beyond the jitter floor, and
retained triples must fall monotonically with eps); the full run
writes artifacts/bench/filter.json.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.densify import to_blocks
from repro.core.engine import build_executor_plan, execute_plan
from repro.sparsity.norms import compute_block_norms

RETENTION_TARGETS = (1.0, 0.5, 0.2, 0.05, 0.0)


def time_call(fn, *args, reps=5):
    """Best-of-reps wall time (min is the standard low-noise estimator
    for microbenchmarks)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _decaying_payload(block, n_blocks, rng):
    """Dense blocked payload whose block norms span ~4 decades."""
    scales = 10.0 ** rng.uniform(-4, 0, size=(n_blocks, n_blocks))
    a = rng.randn(n_blocks * block, n_blocks * block).astype(np.float32)
    a *= np.repeat(np.repeat(scales, block, 0), block, 1).astype(np.float32)
    return a


def eps_sweep(block, n_blocks, stack_size, reps, kernel="ref"):
    m = block * n_blocks
    rng = np.random.RandomState(0)
    a = _decaying_payload(block, n_blocks, rng)
    b = _decaying_payload(block, n_blocks, rng)
    an = compute_block_norms(a, block, block)
    bn = compute_block_norms(b, block, block)
    # the norm-product distribution sets the eps grid: eps at the
    # (1 - target) quantile retains ~target of the triples
    prods = (an.astype(np.float64)[:, :, None]
             * bn.astype(np.float64)[None, :, :]).ravel()
    flop_per_triple = 2 * block ** 3

    ab = to_blocks(jnp.asarray(a), block, block)
    bb = to_blocks(jnp.asarray(b), block, block)
    c0 = jnp.zeros((n_blocks * n_blocks, block, block), jnp.float32)

    dense_plan = build_executor_plan(m, m, m, block, block, block, stack_size)
    t_dense = time_call(
        jax.jit(lambda ab, bb, c0, p=dense_plan: execute_plan(
            p, ab, bb, c0, kernel=kernel)), ab, bb, c0, reps=reps)

    rows = []
    for target in RETENTION_TARGETS:
        if target >= 1.0:
            eps = 0.0
        elif target <= 0.0:
            eps = float(prods.max()) * 2.0
        else:
            eps = float(np.quantile(prods, 1.0 - target))
        plan = build_executor_plan(m, m, m, block, block, block, stack_size,
                                   a_norms=an, b_norms=bn, filter_eps=eps)
        if plan.n_stacks:
            t = time_call(
                jax.jit(lambda ab, bb, c0, p=plan: execute_plan(
                    p, ab, bb, c0, kernel=kernel)), ab, bb, c0, reps=reps)
        else:
            t = 0.0  # empty product: nothing dispatches
        retained = plan.n_entries
        rows.append({
            "retention_target": target,
            "filter_eps": eps,
            "n_triples_unfiltered": plan.n_unfiltered_entries,
            "n_triples_retained": retained,
            "retained_fraction": retained / max(plan.n_unfiltered_entries, 1),
            "retained_flops": retained * flop_per_triple,
            "filtered_flops": plan.n_norm_filtered_triples * flop_per_triple,
            "t_filtered_s": t,
            "t_unfiltered_s": t_dense,
            # null for the empty-product row: nothing dispatched, and a
            # bare Infinity would make the artifact invalid JSON
            "speedup": t_dense / t if t else None,
        })
        print(f"retention {target:4g} (eps {eps:9.3g}): "
              f"{retained:6d}/{plan.n_unfiltered_entries} triples  "
              f"filtered {t * 1e3:8.2f} ms  dense {t_dense * 1e3:8.2f} ms")
    return rows


def purification_trace(n, block, n_iter, filter_eps):
    from repro.compat import make_mesh
    from repro.core import dbcsr
    from repro.core.blocking import GridSpec
    from repro.sparsity.workloads import (banded_hamiltonian,
                                          initial_density, mcweeny_purify)

    H, mask = banded_hamiltonian(n, block)
    mesh = make_mesh((1, 1), ("data", "model"))
    grid = GridSpec("data", "model")
    P0 = dbcsr.create(initial_density(H).astype(np.float32), mesh=mesh,
                      grid=grid, block_size=block, block_mask=mask)
    rows = []
    P = P0
    for it in range(n_iter):
        t0 = time.perf_counter()
        P, tr = mcweeny_purify(
            P, mesh=mesh, n_iter=1, filter_eps=filter_eps,
            multiply_kw=dict(densify=False, local_kernel="ref"))
        dt = time.perf_counter() - t0
        entry = dict(tr[0], iteration=it, wall_s=dt)
        rows.append(entry)
        print(f"iter {it}: occ {entry['occupancy']:.4f}  "
              f"retained {entry.get('n_retained_triples', 0):6d}  "
              f"filtered {entry.get('n_norm_filtered_triples', 0):6d}  "
              f"{dt * 1e3:8.1f} ms")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small geometry, few reps -> filter_smoke.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless retained triples fall "
                         "monotonically with eps AND the 5%%-retention "
                         "dispatch is not slower than the unfiltered one "
                         "beyond the jitter floor (CI gate)")
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()

    if args.smoke:
        block, n_blocks, stack_size, reps = 8, 8, 64, 3
        purif = dict(n=128, block=16, n_iter=6, filter_eps=1e-6)
    else:
        block, n_blocks, stack_size, reps = 16, 16, 512, 5
        purif = dict(n=512, block=32, n_iter=10, filter_eps=1e-6)

    print(f"== eps sweep ({n_blocks}x{n_blocks} blocks of {block}) ==")
    sweep_rows = eps_sweep(block, n_blocks, stack_size, reps)
    print(f"== purification trace (n={purif['n']}, "
          f"eps={purif['filter_eps']:g}) ==")
    purif_rows = purification_trace(**purif)

    retained = [r["n_triples_retained"] for r in sweep_rows]
    monotone_triples = all(retained[i] >= retained[i + 1]
                           for i in range(len(retained) - 1))
    # the 5%-retention point must not dispatch slower than unfiltered:
    # 10% relative slack + 1 ms absolute floor, matching the other CI
    # gates (interpret-mode sub-ms jitter)
    low = min((r for r in sweep_rows if 0 < r["retention_target"] <= 0.05),
              key=lambda r: r["retention_target"], default=None)
    low_not_slower = (low is None or
                      low["t_filtered_s"] <= low["t_unfiltered_s"] * 1.1
                      + 1e-3)
    occs = [r["occupancy"] for r in purif_rows]
    peak = occs.index(max(occs))
    purif_decays = all(occs[i + 1] <= occs[i] + 1e-12
                       for i in range(peak, len(occs) - 1))
    result = {
        "block": block,
        "n_blocks": n_blocks,
        "stack_size": stack_size,
        "eps_sweep": sweep_rows,
        "purification": purif_rows,
        "monotone_retained_triples": monotone_triples,
        "low_retention_not_slower": low_not_slower,
        "purification_occupancy_decays": purif_decays,
    }
    os.makedirs(args.out, exist_ok=True)
    name = "filter_smoke.json" if args.smoke else "filter.json"
    path = os.path.join(args.out, name)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"monotone retained triples: {monotone_triples}   "
          f"5%-retention not slower: {low_not_slower}   "
          f"purification occupancy decays: {purif_decays}")
    print("wrote ->", path)
    if args.check and not (monotone_triples and low_not_slower
                           and purif_decays):
        raise SystemExit("filter benchmark gate failed")


if __name__ == "__main__":
    main()
