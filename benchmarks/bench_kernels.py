"""Kernel micro-benchmarks: smm / tiled_matmul / grouped_gemm vs their
jnp oracles (CPU wall time; interpret-mode Pallas is a correctness
vehicle on CPU, so the oracle is also the perf reference here — real
kernel perf is a TPU measurement, see EXPERIMENTS.md §Roofline)."""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.blocking import BlockLayout
from repro.core.engine import (build_executor_plan, execute_plan,
                               execute_plans_looped)
from repro.core.stacks import build_stacks
from repro.core.densify import to_blocks
from repro.kernels.smm.ref import smm_process_stack_ref
from repro.kernels.tiled_matmul.ref import tiled_matmul_ref
from repro.kernels.grouped_gemm.ref import grouped_gemm_ref


def time_call(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def main(out="artifacts/bench"):
    rng = np.random.RandomState(0)
    results = []

    # smm: stack throughput for paper block sizes
    for block in (22, 64):
        m = k = n = 704
        a = jnp.asarray(rng.randn(m, k).astype(np.float32))
        b = jnp.asarray(rng.randn(k, n).astype(np.float32))
        ab = to_blocks(a, block, block)
        bb = to_blocks(b, block, block)
        plans = build_stacks(BlockLayout(m, k, block, block),
                             BlockLayout(k, n, block, block))
        triples = jnp.asarray(np.concatenate([p.triples for p in plans]))
        nbr = nbc = m // block
        c0 = jnp.zeros((nbr * nbc, block, block), jnp.float32)
        f = jax.jit(smm_process_stack_ref)
        dt = time_call(f, ab, bb, c0, triples)
        flops = 2 * m * k * n
        results.append({"kernel": "smm_ref", "block": block,
                        "time_s": dt, "gflops": flops / dt / 1e9,
                        "stack_entries": int(triples.shape[0])})
        print(f"smm  block={block:3d}: {dt*1e3:8.2f} ms  "
              f"{flops/dt/1e9:7.2f} GF/s  ({triples.shape[0]} entries)")

    # fused vs looped stack dispatch: the engine's single-scan executor
    # against the seed's one-jit-call-per-stack loop (same math, same
    # stacks; the delta is dispatch + per-stack retrace overhead)
    for block in (22, 64):
        m = k = n = 704
        a = jnp.asarray(rng.randn(m, k).astype(np.float32))
        b = jnp.asarray(rng.randn(k, n).astype(np.float32))
        ab = to_blocks(a, block, block)
        bb = to_blocks(b, block, block)
        nbr = nbc = m // block
        nbk = k // block
        # force a multi-stack plan (8-ish stacks) so dispatch count matters
        stack_tile = max(nbk, (nbr * nbc * nbk) // 8 // nbk * nbk)
        plan = build_executor_plan(m, k, n, block, block, block, stack_tile)
        c0 = jnp.zeros((nbr * nbc, block, block), jnp.float32)

        fused = jax.jit(lambda ab, bb, c0, plan=plan: execute_plan(
            plan, ab, bb, c0, kernel="ref"))
        t_fused = time_call(fused, ab, bb, c0)

        def looped(ab, bb, c0, plans=list(plan.plans)):
            return execute_plans_looped(plans, ab, bb, c0, kernel="ref")

        t_looped = time_call(jax.jit(looped), ab, bb, c0)
        flops = 2 * m * k * n
        results.append({
            "kernel": "smm_dispatch", "block": block,
            "n_stacks": plan.n_stacks, "stack_tile": plan.stack_tile,
            "t_fused_s": t_fused, "t_looped_s": t_looped,
            "fused_gflops": flops / t_fused / 1e9,
            "looped_gflops": flops / t_looped / 1e9,
            "looped_over_fused": t_looped / t_fused,
        })
        print(f"smm dispatch block={block:3d} ({plan.n_stacks} stacks): "
              f"fused {t_fused*1e3:8.2f} ms  looped {t_looped*1e3:8.2f} ms  "
              f"(looped/fused = {t_looped/t_fused:.2f}x)")

    # tiled matmul vs XLA dot
    m = k = n = 1024
    a = jnp.asarray(rng.randn(m, k).astype(np.float32))
    b = jnp.asarray(rng.randn(k, n).astype(np.float32))
    dt = time_call(jax.jit(tiled_matmul_ref), a, b)
    results.append({"kernel": "dense_dot", "time_s": dt,
                    "gflops": 2 * m * k * n / dt / 1e9})
    print(f"dense 1024^3 dot: {dt*1e3:8.2f} ms  "
          f"{2*m*k*n/dt/1e9:7.2f} GF/s")

    # grouped gemm (densified MoE)
    e, c, d, f_ = 16, 256, 512, 1024
    t = jnp.asarray(rng.randn(e, c, d).astype(np.float32))
    w = jnp.asarray(rng.randn(e, d, f_).astype(np.float32))
    dt = time_call(jax.jit(grouped_gemm_ref), t, w)
    results.append({"kernel": "grouped_gemm_ref", "time_s": dt,
                    "gflops": 2 * e * c * d * f_ / dt / 1e9})
    print(f"grouped ({e}x{c}x{d}x{f_}): {dt*1e3:8.2f} ms  "
          f"{2*e*c*d*f_/dt/1e9:7.2f} GF/s")

    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, "kernels.json"), "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
