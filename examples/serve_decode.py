"""Serving example: prefill a batch of prompts, then decode tokens
greedily with the KV/state caches — exercises the same decode_step the
decode_32k / long_500k dry-run shapes lower.

Works for every family: attention KV caches, MLA latent caches, Mamba
conv+ssm states, RWKV wkv states (try --arch rwkv6_1_6b).

    PYTHONPATH=src python examples/serve_decode.py --arch qwen2_1_5b
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import argparse
import time

import jax
from repro.compat import set_mesh
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.serve import engine
from repro.serve.prefill import prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    mesh = make_mesh((2, 2), ("data", "model"))
    params = T.model_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    max_len = args.prompt_len + args.gen + 8

    if cfg.input_mode == "embeddings":
        prompts = jnp.asarray(rng.randn(
            args.batch, args.prompt_len, cfg.d_model).astype(np.float32))
    else:
        prompts = jnp.asarray(rng.randint(
            0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32))

    with set_mesh(mesh):
        # ---- prefill ---------------------------------------------------
        t0 = time.perf_counter()
        first_tok, cache, cur = jax.jit(
            lambda p, x: prefill_step(p, x, cfg, mesh))(params, prompts)
        jax.block_until_ready(first_tok)
        t_prefill = time.perf_counter() - t0
        # embed prefill caches into the decode cache of max_len
        target = T.cache_shapes(cfg, args.batch, max_len)
        cache = jax.tree_util.tree_map(
            lambda x, t: jnp.pad(jnp.asarray(x),
                                 [(0, ts - xs) for xs, ts in
                                  zip(x.shape, t.shape)]).astype(t.dtype),
            cache, target)
        state = {"cache": cache, "cur_len": cur}

        # ---- decode loop -------------------------------------------------
        decode = jax.jit(lambda p, s, t: engine.decode_step(p, s, t, cfg, mesh),
                         donate_argnums=(1,))
        tok = first_tok
        generated = [np.asarray(tok)]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            if cfg.input_mode == "embeddings":
                # stub frontend: feed the embedding of the sampled token id
                feed = jnp.take(params["embed"], tok[:, 0], axis=0)[:, None]
                tok, state = decode(params, state, feed.astype(jnp.float32))
            else:
                tok, state = decode(params, state, tok)
            generated.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0

    toks = np.concatenate(generated, axis=1)
    print(f"arch={cfg.name}  prefill({args.prompt_len} toks): "
          f"{t_prefill*1e3:.1f} ms   decode: "
          f"{t_decode/max(args.gen-1,1)*1e3:.2f} ms/token")
    print(f"generated token ids (first sequence): {toks[0][:16]} ...")
    assert toks.shape == (args.batch, args.gen)
    print("OK")


if __name__ == "__main__":
    main()
