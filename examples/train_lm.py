"""End-to-end LM training driver (deliverable (b)): synthetic data ->
sharded train loop -> checkpoints -> recovery, on any of the 10 archs
at a reduced width.

Default runs a ~25M-param qwen2-style model for 30 steps on CPU in a
couple of minutes; ``--preset 100m --steps 300`` is the full
"train ~100M model for a few hundred steps" configuration (same code
path, bigger dims — budget ~hours on 1 CPU core, minutes on a real
accelerator).

    PYTHONPATH=src python examples/train_lm.py --steps 30
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import argparse
import time

import jax
import jax.numpy as jnp
from repro.compat import set_mesh
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.train.data import make_batch
from repro.train.elastic import StragglerWatchdog, run_loop
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig, make_optimizer
from repro.train.train_step import make_train_step

PRESETS = {
    # ~25M params: quick CPU sanity run
    "25m": dict(d_model=256, num_layers=8, num_heads=8, num_kv_heads=2,
                head_dim=32, d_ff=1024, vocab_size=4096, dtype="float32"),
    # ~100M params: the deliverable configuration
    "100m": dict(d_model=640, num_layers=12, num_heads=10, num_kv_heads=2,
                 head_dim=64, d_ff=2560, vocab_size=32768, dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--preset", default="25m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch), **PRESETS[args.preset])
    mesh = make_mesh((2, 2), ("data", "model"))
    print(f"arch={cfg.name} (reduced {args.preset}), mesh {mesh.devices.shape}")

    params = T.model_init(cfg, jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    opt = make_optimizer(OptConfig(lr=args.lr))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, mesh, opt), donate_argnums=(0, 1))

    def mb(step):
        b = make_batch(step, global_batch=args.batch, seq_len=args.seq,
                       vocab=cfg.vocab_size, input_mode=cfg.input_mode,
                       d_model=cfg.d_model)
        return {k: jnp.asarray(v) for k, v in b.items()}

    watchdog = StragglerWatchdog()
    t0 = time.time()
    with set_mesh(mesh):
        result = run_loop(
            train_step=step_fn, make_batch=mb, params=params,
            opt_state=opt_state, n_steps=args.steps,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            watchdog=watchdog)
    hist = result["history"]
    dt = time.time() - t0
    print(f"\n{len(hist)} steps in {dt:.1f}s "
          f"({dt/max(len(hist),1):.2f} s/step), restarts={result['restarts']}")
    for h in hist[:3] + hist[-3:]:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  {h['dt']*1e3:.0f} ms")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'OK: decreasing' if last < first else 'WARNING: not decreasing'})")


if __name__ == "__main__":
    main()
