"""Quickstart: DBCSR-style distributed matmul in 30 lines.

Creates two matrices block-cyclic distributed over a 4x4 device grid,
multiplies them with Cannon's algorithm (densified local GEMMs), and
checks the result — the whole paper pipeline at toy scale.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import numpy as np
import jax

from repro.core import dbcsr
from repro.core.blocking import GridSpec
from repro.launch.mesh import make_mesh


def main():
    mesh = make_mesh((4, 4), ("data", "model"))
    grid = GridSpec(row_axis="data", col_axis="model")
    rng = np.random.RandomState(0)

    n = 1024
    A = rng.randn(n, n).astype(np.float32)
    B = rng.randn(n, n).astype(np.float32)

    # create: the library owns the distribution (block-cyclic a la
    # ScaLAPACK; block size 64 like the paper's large-block case)
    Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=64)
    Bm = dbcsr.create(B, mesh=mesh, grid=grid, block_size=64)

    # multiply: 'auto' dispatches Cannon (square shapes) with densified
    # local multiplication — the paper's optimized configuration
    Cm = dbcsr.multiply(Am, Bm, mesh=mesh, algorithm="auto")

    err = float(np.max(np.abs(np.asarray(Cm.data) - A @ B)))
    print(f"C = A @ B on a {mesh.devices.shape} mesh: max err {err:.2e}")
    print(f"occupancy: {Cm.occupancy:.0%}, blocks: "
          f"{Cm.layout.nblock_rows}x{Cm.layout.nblock_cols} "
          f"of {Cm.layout.block_rows}x{Cm.layout.block_cols}")
    assert err < 1e-3
    print("OK")


if __name__ == "__main__":
    main()
