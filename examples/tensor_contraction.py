"""Blocked sparse tensor contraction — the 3-index RPA/THC workload
the DBCSR tensor extension exists for (arXiv:1910.13555).

Post-Hartree-Fock methods (RPA, THC-scaled MP2) contract 3-index
integral tensors ``B[i,a,P]`` against 2-index transformation matrices
``M[P,Q]``.  The integral tensor is block-sparse with exponentially
decaying magnitude away from a diagonal locality band — exactly the
structure DBCSR's norm-based filtering exploits.

This demo builds that workload on a 4-device (2x2) mesh and runs

    C[i,a,Q] = sum_P  B[i,a,P] * M[P,Q]

through ``dbcsr.contract("iaP,PQ->iaQ", ...)``:

  * the 3-index tensor is created as a ``DBCSRTensor`` with per-block
    occupancy mask + Frobenius norms,
  * the planner enumerates every legal matricization (here: fuse
    (i,a) into matrix rows vs transposed variants), prices each with
    the lowered per-layout occupancy/imbalance and unfold/refold copy
    cost, and picks one — the printed ``explain()`` shows the layout
    table and which row won,
  * masks and norms lower through the unfold, so the 2D engine's eps
    filtering drops negligible-norm triples without ever seeing the
    N-d frame,
  * the result folds back to the 3-index output frame and is checked
    against a dense ``jnp.einsum`` oracle.

    PYTHONPATH=src python examples/tensor_contraction.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import numpy as np
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.core import dbcsr
from repro.core.blocking import GridSpec

# problem geometry: occupied x virtual x auxiliary basis
N_I, N_A, N_P = 32, 64, 128
B_I, B_A, B_P = 8, 16, 16
FILTER_EPS = 1e-8


def build_integral_tensor(rng):
    """3-index THC-style integral tensor with exponential block decay
    away from the (i, P) locality diagonal."""
    data = rng.randn(N_I, N_A, N_P).astype(np.float32)
    nbi, nba, nbp = N_I // B_I, N_A // B_A, N_P // B_P
    # block magnitude ~ exp(-|i_blk/nbi - P_blk/nbp| * rate): orbitals
    # couple strongly only to spatially nearby auxiliary functions
    bi = np.arange(nbi)[:, None] / nbi
    bp = np.arange(nbp)[None, :] / nbp
    scale = np.exp(-30.0 * np.abs(bi - bp))           # (nbi, nbp)
    full = np.repeat(np.repeat(scale, B_I, 0), B_P, 1)  # (N_I, N_P)
    data *= full[:, None, :]
    mask = (scale > 1e-6)[:, None, :] * np.ones((1, nba, 1), dtype=bool)
    return data, mask


def main():
    mesh = make_mesh((2, 2), ("data", "model"))
    grid = GridSpec("data", "model")
    rng = np.random.RandomState(0)

    data, mask = build_integral_tensor(rng)
    B = dbcsr.create_tensor(data, mesh=mesh, grid=grid,
                            block_sizes=(B_I, B_A, B_P),
                            block_mask=mask, compute_norms=True)
    M = dbcsr.create_tensor(rng.randn(N_P, N_P).astype(np.float32),
                            mesh=mesh, grid=grid,
                            block_sizes=(B_P, B_P))
    print(f"integral tensor  {B.shape}  blocks {B.block_sizes}  "
          f"occupancy {B.occupancy:.1%}")

    C, plan = dbcsr.contract("iaP,PQ->iaQ", B, M, mesh=mesh,
                             filter_eps=FILTER_EPS, return_plan=True)
    print()
    print(plan.explain())
    print()
    print(f"chosen matricization: {plan.layout}  "
          f"(algorithm {plan.algorithm})")

    oracle = jnp.einsum("iaP,PQ->iaQ", jnp.asarray(B.data),
                        jnp.asarray(M.data))
    err = float(np.abs(np.asarray(C.data) - np.asarray(oracle)).max())
    scale = float(np.abs(np.asarray(oracle)).max())
    print(f"result {C.shape}  occupancy {C.occupancy:.1%}  "
          f"max |err| vs dense einsum = {err:.3g} (scale {scale:.3g})")
    assert err < 1e-4 * max(scale, 1.0), "contract deviates from einsum"
    print("OK: contraction matches the dense einsum oracle")


if __name__ == "__main__":
    main()
