"""The paper's two benchmark workloads, end to end on a host-device mesh:

  * square multiplication  -> Cannon's algorithm (O(1/sqrt(P)) comm)
  * tall-and-skinny        -> the O(1)-communication algorithm

with the SUMMA (ScaLAPACK PDGEMM analogue) baseline timed next to each,
and the blocked vs densified local-multiply comparison.

    PYTHONPATH=src python examples/distributed_matmul.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import time

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.blocking import GridSpec
from repro.core.multiply import distributed_matmul
from repro.core.tall_skinny import classify_shape
from repro.launch.mesh import make_mesh
from repro.planner import plan_multiply


def timed(tag, fn, *args):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(3):
        out = fn(*args)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / 3
    print(f"  {tag:34s} {dt*1e3:9.2f} ms")
    return out, dt


def main():
    mesh = make_mesh((4, 4), ("data", "model"))
    grid = GridSpec("data", "model")
    sh = NamedSharding(mesh, P("data", "model"))
    rng = np.random.RandomState(0)

    print("== square multiplication (paper: 63'360^3; scaled) ==")
    n = 1408
    A = rng.randn(n, n).astype(np.float32)
    B = rng.randn(n, n).astype(np.float32)
    Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
    # algorithm="auto" routes through the cost-model planner
    # (repro.planner.plan_multiply); return_plan exposes the decision,
    # and plan.explain() prints the per-candidate predicted costs, e.g.:
    #
    #   plan: cannon + densified  occupancy=1  predicted=1.4 ms
    #     candidate          comm_ms  compute_ms  overhead_ms  total_ms
    #   * cannon+densified     0.79      0.39        0.21        1.40
    #     summa+densified      1.59      0.39        0.41        2.39
    #     ts_k+densified       3.17      0.39        0.21        3.77
    #     ...
    #     cannon25d+densified     -         -           -           -
    #                           infeasible: no replication axis
    print(plan_multiply(n, n, n, mesh_shape=(4, 4)).explain())
    # one TRACED run: the telemetry layer (repro.obs) turns the same
    # schedule metadata into a span timeline + Chrome trace instead of
    # a raw stats dump (open artifacts/obs/multiply_trace.json in
    # ui.perfetto.dev or chrome://tracing)
    obs.enable(log_dir="artifacts/obs")
    _, xplan = distributed_matmul(Ad, Bd, mesh=mesh, grid=grid,
                                  return_plan=True)
    trace = obs.last_trace()
    obs.write_chrome_trace("artifacts/obs/multiply_trace.json", trace)
    print("  trace timeline (spans; full trace -> "
          "artifacts/obs/multiply_trace.json):")
    print(obs.render_timeline(trace))
    print(obs.render_breakdown(trace))
    obs.disable()  # timed comparisons below run with zero overhead
    c1, t_auto = timed("auto (planner)", jax.jit(
        lambda a, b: distributed_matmul(a, b, mesh=mesh, grid=grid)), Ad, Bd)
    c2, t_summa = timed("SUMMA (PDGEMM baseline)", jax.jit(
        lambda a, b: distributed_matmul(a, b, mesh=mesh, grid=grid,
                                        algorithm="summa")), Ad, Bd)
    print(f"  speedup vs PDGEMM: {t_summa/t_auto:.2f}x   "
          f"agreement: {float(np.max(np.abs(np.asarray(c1)-np.asarray(c2)))):.1e}")

    print("== tall-and-skinny (paper: 1'408 x 1'982'464; scaled) ==")
    m = nn = 352
    k = 45056
    A2 = rng.randn(m, k).astype(np.float32)
    B2 = rng.randn(k, nn).astype(np.float32)
    print(f"  shape-only classification: {classify_shape(m, k, nn)}")
    A2d = jax.device_put(A2, NamedSharding(mesh, P(None, ("data", "model"))))
    B2d = jax.device_put(B2, NamedSharding(mesh, P(("data", "model"), None)))
    print(plan_multiply(m, k, nn, mesh_shape=(4, 4)).explain())
    c3, t_ts = timed("auto (planner)", jax.jit(
        lambda a, b: distributed_matmul(a, b, mesh=mesh, grid=grid)),
        A2d, B2d)
    A2s, B2s = jax.device_put(A2, sh), jax.device_put(B2, sh)
    c4, t_sm = timed("SUMMA (PDGEMM baseline)", jax.jit(
        lambda a, b: distributed_matmul(a, b, mesh=mesh, grid=grid,
                                        algorithm="summa")), A2s, B2s)
    print(f"  speedup vs PDGEMM: {t_sm/t_ts:.2f}x  "
          "(paper reports up to 2.5x on this shape)")

    # traced tall-skinny run: every traced multiply also logs the
    # planner's predicted cost next to the measured dispatch time
    # (artifacts/obs/plan_outcomes.jsonl — the input to
    #  `python -m repro.planner.calibrate --check-drift`)
    obs.enable(log_dir="artifacts/obs", reset=False)
    distributed_matmul(A2d, B2d, mesh=mesh, grid=grid)
    obs.disable()
    print("== planner scoreboard (predicted vs measured) ==")
    print(obs.render_scoreboard(obs.planner_scoreboard(obs.plan_outcomes())))


if __name__ == "__main__":
    main()
