"""Density-matrix purification — the sparsity-evolving workload
norm-based filtering exists for (CP2K's linear-scaling SCF on DBCSR).

McWeeny's iteration  P <- 3 P^2 - 2 P^3  is run end to end through
``dbcsr.multiply(filter_eps=1e-6)`` on a 16-device (4x4) mesh:

  * the Hamiltonian is a gapped block-banded insulator
    (repro.sparsity.workloads.banded_hamiltonian); the initial guess is
    its linear spectral rescale, support = the Hamiltonian's band,
  * every multiply computes per-block Frobenius norms, drops
    contributions with norm(A_ik) * norm(B_kj) < eps before they reach
    a multiplication stack, skips data-exchange steps with no retained
    triple, and the planner prices candidates with the norm-predicted
    retained occupancy,
  * each iterate is re-filtered from its actual block norms
    (``DBCSRMatrix.filter``, the post-multiply pass).

The printed trace is the canonical purification signature: occupancy
RISES for an iteration or two (P^2 spreads the band), then DECAYS
monotonically to the converged density's support (here: exactly the
diagonal) while the idempotency error ||P^2 - P|| crashes to zero and
tr(P) stays pinned at the electron count.

The trajectory is run twice — once with the legacy union-of-ranks
plans (``rank_exact=False``) and once rank-exact (the default) — and
the per-iteration busiest-rank executed triples are compared: on the
banded support a 4x4 grid's union plan makes every rank execute every
rank's band chunks, so rank-exact execution must shrink the busiest
rank's load on every sparse iteration (asserted at the end).

    PYTHONPATH=src python examples/purification.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=16")

import time

import numpy as np

from repro import obs
from repro.compat import make_mesh
from repro.core import dbcsr
from repro.core.blocking import GridSpec
from repro.sparsity.workloads import (banded_hamiltonian, initial_density,
                                      mcweeny_purify)

N_ITER = 10
FILTER_EPS = 1e-6


def main():
    n, bs = 512, 32
    H, mask = banded_hamiltonian(n, bs)
    P0_host = initial_density(H)

    mesh = make_mesh((4, 4), ("data", "model"))
    grid = GridSpec("data", "model")
    P0 = dbcsr.create(P0_host.astype(np.float32), mesh=mesh, grid=grid,
                      block_size=bs, block_mask=mask)
    nb = P0.layout.nblock_rows
    print(f"== McWeeny purification: {n}x{n}, {nb}x{nb} blocks of {bs}, "
          f"4x4 mesh, filter_eps={FILTER_EPS:g} ==")
    print(f"initial guess: occupancy {P0.occupancy:.4f} "
          f"({int(mask.sum())}/{nb * nb} blocks), "
          f"tr(P0) = {float(P0.trace()):.2f} (electrons: {n // 2})")

    # blocked path + jnp reference kernel: the stack executor runs
    # the eps-filtered plans (interpret-mode Pallas is the same
    # math, just slower on this host container)
    base_kw = dict(densify=False, local_kernel="ref")

    # union baseline: every rank executes the union-of-ranks plan
    _, union_trace = mcweeny_purify(
        P0, mesh=mesh, n_iter=N_ITER, filter_eps=FILTER_EPS,
        multiply_kw=dict(base_kw, rank_exact=False))

    t0 = time.time()
    # traced rank-exact run: every multiply leaves a span tree, and the
    # workload publishes per-iteration occupancy into the metrics
    # registry — the gauge's sample history IS the decay curve
    obs.enable(log_dir="artifacts/obs")
    P, trace = mcweeny_purify(
        P0, mesh=mesh, n_iter=N_ITER, filter_eps=FILTER_EPS,
        multiply_kw=base_kw)
    obs.disable()
    dt = time.time() - t0

    print(f"{'iter':>4s} {'occupancy':>10s} {'blocks':>7s} "
          f"{'retained':>9s} {'filtered':>9s} {'MFLOP_kept':>10s} "
          f"{'idempotency':>12s} {'tr(P)':>8s}")
    for t in trace:
        print(f"{t['iteration']:4d} {t['occupancy']:10.4f} "
              f"{t['n_blocks']:7d} {t.get('n_retained_triples', 0):9d} "
              f"{t.get('n_norm_filtered_triples', 0):9d} "
              f"{t.get('retained_flops', 0) / 1e6:10.2f} "
              f"{t['idempotency']:12.3e} {t['trace_P']:8.2f}")
    print(f"{N_ITER} iterations in {dt:.1f} s")

    occs = [t["occupancy"] for t in trace]
    peak = occs.index(max(occs))
    monotone = all(occs[i + 1] <= occs[i] + 1e-12
                   for i in range(peak, len(occs) - 1))
    decayed = occs[-1] < occs[0]
    print(f"occupancy peaks at iteration {peak} "
          f"({occs[peak]:.4f}), converges to {occs[-1]:.4f}")
    print(f"monotone decay after the peak: {monotone}   "
          f"net sparsification vs initial guess: {decayed}")
    samples = obs.gauge("purification.occupancy").samples
    bars = " ".join(f"{s:.3f}" for s in samples)
    print(f"occupancy decay as telemetry gauge samples "
          f"(obs.gauge('purification.occupancy'), {len(samples)} pts):")
    print(f"  {bars}")
    assert samples == occs, "gauge samples should mirror the trace"
    assert monotone and decayed, \
        "purification occupancy did not decay monotonically after the peak"
    assert abs(trace[-1]["trace_P"] - n // 2) < 0.5, "electron count drifted"

    # rank-exact vs union: busiest-rank executed triples per iteration
    print(f"{'iter':>4s} {'union/rank':>10s} {'busiest':>8s} "
          f"{'shrink':>7s} {'imbalance':>9s}")
    shrunk = []
    for tu, tr in zip(union_trace, trace):
        u = tu.get("max_rank_entries", 0)     # union: == n_entries
        r = tr.get("max_rank_entries", 0)
        if not (u and r):
            continue
        shrunk.append(r < u)
        print(f"{tr['iteration']:4d} {u:10d} {r:8d} {u / r:6.2f}x "
              f"{tr.get('rank_imbalance', 1.0):9.2f}")
    assert shrunk and all(shrunk), \
        "rank-exact busiest-rank load did not shrink vs the union plan"
    print("purification trace OK; rank-exact shrank the busiest rank's "
          "load on every iteration")


if __name__ == "__main__":
    main()
