"""Prefill: full-sequence forward that also materialises the KV /
state caches decode will consume.  The prefill_32k dry-run shape lowers
``prefill_step``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T

__all__ = ["prefill_step"]


def prefill_step(params, inputs, cfg, mesh):
    """inputs: (B, S) tokens or (B, S, d) embeddings.

    Returns (next_tokens (B, 1), prefill_cache, cur_len).
    The cache covers positions [0, S); decode continues at S.
    """
    logits, _hidden, _aux, cache = T.forward(
        params, inputs, cfg, mesh, collect_cache=True)
    next_tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    s = inputs.shape[1]
    return next_tokens, cache, jnp.asarray(s, jnp.int32)
