"""Continuous-batching request layer over ``dbcsr.multiply_batched``.

The batched executor (core/multiply_batched.py) turns N
same-configuration products into one dispatch — but somebody has to
FIND those N products.  In a serving setting (property evaluations,
k-point workers, ensemble members issuing multiplies independently)
they arrive one at a time; this module is the accumulation layer that
turns the stream into fused batches:

  * ``submit(a, b)`` validates the request structurally
    (repro.robustness.guards — a malformed request is rejected
    synchronously with a typed error, never at drain time), enqueues
    it, and returns a ticket id — nothing executes yet;
  * requests accumulate in buckets keyed by the batching contract
    ``(geometry, occupancy-bin, eps)`` (the same ``_bucket_key`` as
    ``dbcsr.multiply_batched`` — only key-identical requests can share
    a fused dispatch);
  * a bucket drains — ONE fused dispatch for its whole contents —
    when it reaches ``max_batch`` requests OR its oldest request's
    latency SLO expires (``slo_s`` seconds after submission),
    whichever comes first.  The SLO bounds the latency cost of waiting
    for batch-mates: a request never waits longer than ``slo_s`` past
    submission before its bucket is dispatched (modulo the caller
    actually pumping ``poll``).

Robustness (the degradation ladder).  A dispatch failure must never
lose tickets or let one poison request kill its batch-mates, so
``_dispatch`` walks a ladder and never raises:

  1. **fused** (or planner's choice) — retried up to ``max_retries``
     times with exponential backoff on any failure (transient backend
     errors, injected chaos faults);
  2. **looped** — the bucket re-executes as per-request dispatches
     sharing one call (cheap, still batched at the Python level);
  3. **per-request isolation** — each request executes alone inside
     its own try/except: a poison request becomes an *error ticket*
     (its exception is stored and re-raised by ``result()``) while
     every healthy batch-mate completes normally — bit-identical to a
     clean run (the fused/looped bit-identity contract).

Delivered results additionally pass a NaN/Inf tripwire
(``check_finite``): a non-finite product is quarantined as an error
ticket (``NonFiniteResultError``) instead of poisoning downstream
iterations.  ``result()`` distinguishes the ticket states with a typed
taxonomy (all ``KeyError`` subclasses for backwards compatibility):
``TicketPendingError`` (still queued — pump ``poll()``),
``UnknownTicketError`` (never submitted, or already retrieved), and
errored tickets re-raise their stored exception.  ``stats()`` reports
retry / degradation / error-ticket counters next to the fusion
accounting.

The service is deliberately SYNCHRONOUS (no threads): draining happens
inside ``poll()`` / ``flush()`` on the caller's thread, so the caller
controls when device work runs — the natural fit for a jax host
process, and trivially testable with injected ``clock`` / ``sleep`` /
``fault_injector``.

Typical pump loop::

    svc = MultiplyService(mesh, slo_s=0.005, max_batch=32)
    tickets = [svc.submit(a, b) for (a, b) in stream]
    svc.flush()                      # or poll() inside the loop
    results = [svc.result(t) for t in tickets]
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional

from repro import obs
from repro.core.dbcsr import (DBCSRMatrix, _bucket_key, multiply,
                              multiply_batched)
from repro.robustness import guards

__all__ = ["MultiplyService", "PendingRequest", "TicketPendingError",
           "UnknownTicketError"]

# Per-process instance ids so each service's metrics are isolated under a
# ``service=svc-<n>`` label in the shared obs registry.
_SERVICE_IDS = itertools.count()


class TicketPendingError(KeyError):
    """The ticket exists but its bucket has not drained yet — pump
    ``poll()`` / ``flush()`` first."""


class UnknownTicketError(KeyError):
    """The ticket was never submitted, or its result/error was already
    retrieved (results pop exactly once)."""


@dataclasses.dataclass
class PendingRequest:
    """One queued multiply: operands plus its SLO accounting."""

    ticket: int
    a: DBCSRMatrix
    b: DBCSRMatrix
    submit_t: float

    def deadline(self, slo_s: float) -> float:
        return self.submit_t + slo_s


class MultiplyService:
    """Accumulate multiply requests and drain them as fused batches.

    Parameters
    ----------
    mesh        the device mesh every request executes on
    slo_s       latency SLO: a bucket is dispatched no later than the
                first ``poll()`` after its OLDEST request has waited
                ``slo_s`` seconds (0 = dispatch every request on the
                next poll — batching only among same-poll arrivals)
    max_batch   dispatch a bucket as soon as it holds this many
                requests, SLO notwithstanding
    filter_eps  norm-filter threshold applied to every request (part of
                the bucket key — a service instance is eps-uniform)
    fused       pin the fuse-or-loop choice per bucket (None = planner);
                ``False`` starts the ladder at its looped rung
    validate    structural request validation at ``submit()`` time
                (guards.validate_multiply_request — reject malformed
                requests synchronously with a typed
                ``DbcsrValidationError``)
    check_finite  NaN/Inf tripwire on every delivered result: a
                non-finite product becomes an error ticket
                (``NonFiniteResultError``) instead of a poisoned result
    max_retries number of retries of the first ladder rung before
                degrading (transient-failure budget)
    backoff_s   base of the exponential retry backoff
                (``backoff_s * 2**attempt`` between attempts)
    clock       injectable time source (``time.monotonic``-like), for
                deterministic tests
    sleep       injectable backoff sleep (``time.sleep``-like)
    fault_injector  chaos hook (``repro.robustness.chaos.
                DispatchFaultInjector``-like): ``check(stage=...)`` is
                called before every dispatch attempt and may raise
    **kw        forwarded to ``dbcsr.multiply_batched`` (algorithm,
                densify, local_kernel, pipeline_depth, verify, ...)

    ``stats()`` reports request/dispatch counters, per-bucket fusion
    accounting, retry/degradation/error-ticket counts, and
    completion-latency percentiles (p50/p99 of ``completion - submit``
    over finished requests).
    """

    def __init__(
        self,
        mesh,
        *,
        slo_s: float = 0.01,
        max_batch: int = 32,
        filter_eps: Optional[float] = None,
        fused: Optional[bool] = None,
        validate: bool = True,
        check_finite: bool = True,
        max_retries: int = 2,
        backoff_s: float = 0.05,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        fault_injector=None,
        **kw,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.mesh = mesh
        self.slo_s = float(slo_s)
        self.max_batch = int(max_batch)
        self.filter_eps = filter_eps
        self.fused = fused
        self.validate = bool(validate)
        self.check_finite = bool(check_finite)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.clock = clock
        self.sleep = sleep
        self.fault_injector = fault_injector
        self.kw = kw
        self._next_ticket = 0
        self._queues: Dict[tuple, List[PendingRequest]] = {}
        self._results: Dict[int, DBCSRMatrix] = {}
        self._errors: Dict[int, BaseException] = {}
        self._pending_tickets: set = set()
        self._bucket_reports: List[dict] = []
        # All counters/latencies live in the process-wide obs metrics
        # registry (one source of truth), isolated per instance by the
        # ``service=`` label; ``stats()`` is a thin view over it.
        self.service_id = f"svc-{next(_SERVICE_IDS)}"

    # -- metrics (registry-backed; ``stats()`` reads these back) -------
    def _counter(self, name: str) -> obs.Counter:
        return obs.counter(f"service.{name}", service=self.service_id)

    def _latency_hist(self) -> obs.Histogram:
        return obs.histogram("service.latency_s", service=self.service_id)

    # -- request side --------------------------------------------------
    def submit(self, a: DBCSRMatrix, b: DBCSRMatrix) -> int:
        """Enqueue C = A @ B; returns a ticket for ``result()``.

        The request is validated structurally FIRST (``validate=True``):
        block-geometry / grid / mask / norm-cache inconsistencies raise
        a typed ``DbcsrValidationError`` here, synchronously, instead of
        failing the whole bucket at drain time.  Nothing executes here —
        the request waits for batch-mates until its bucket fills
        (``max_batch``) or its SLO expires, both checked by
        ``poll()``/``flush()``.
        """
        if self.validate:
            guards.validate_multiply_request(a, b)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._counter("requests").inc()
        key = _bucket_key(a, b, self.filter_eps)
        self._queues.setdefault(key, []).append(
            PendingRequest(ticket, a, b, self.clock()))
        self._pending_tickets.add(ticket)
        return ticket

    def poll(self) -> List[int]:
        """Dispatch every bucket that is due (full, or oldest request
        past its SLO deadline); returns the tickets settled by this
        call (results AND error tickets — both are retrievable via
        ``result()``).  Buckets still inside their SLO window keep
        waiting for batch-mates.  ``_dispatch`` never raises: a failed
        request becomes an error ticket, never a lost one."""
        now = self.clock()
        done: List[int] = []
        for key in list(self._queues):
            q = self._queues[key]
            while len(q) >= self.max_batch:
                batch = q[:self.max_batch]
                del q[:self.max_batch]
                done += self._dispatch(key, batch)
            if q and q[0].deadline(self.slo_s) <= now:
                batch = list(q)
                q.clear()
                done += self._dispatch(key, batch)
            if not q:
                self._queues.pop(key, None)
        return done

    def flush(self) -> List[int]:
        """Dispatch everything queued regardless of SLO/size."""
        done: List[int] = []
        for key in list(self._queues):
            done += self._dispatch(key, self._queues.pop(key))
        return done

    def result(self, ticket: int) -> DBCSRMatrix:
        """Pop a settled ticket: returns the product, or re-raises the
        stored exception for an errored ticket.  Raises
        ``TicketPendingError`` while the ticket is still queued
        (``poll()``/``flush()`` first) and ``UnknownTicketError`` for a
        ticket that was never submitted or was already retrieved (both
        are ``KeyError`` subclasses)."""
        if ticket in self._results:
            return self._results.pop(ticket)
        if ticket in self._errors:
            raise self._errors.pop(ticket)
        if ticket in self._pending_tickets:
            raise TicketPendingError(
                f"ticket {ticket} is still queued; call poll()/flush()")
        raise UnknownTicketError(
            f"ticket {ticket} was never submitted or already retrieved")

    @property
    def n_pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # -- dispatch ------------------------------------------------------
    def _check_fault(self, stage: str, attempt: int) -> None:
        if self.fault_injector is not None:
            self.fault_injector.check(stage=stage, attempt=attempt)

    def _deliver(self, key: tuple, batch: List[PendingRequest], results,
                 report, *, fused: bool, stage: str, n_errors: int = 0):
        """Record one drained bucket: results (finite-screened), bucket
        report, counters, latencies."""
        t_done = self.clock()
        self._counter("dispatches").inc()
        for r, c in zip(batch, results):
            if c is None:
                continue  # error ticket already recorded by the caller
            if self.check_finite and not guards.all_finite(c.data):
                self._set_error(r.ticket, guards.NonFiniteResultError(
                    f"request {r.ticket}: product contains NaN/Inf "
                    f"(result tripwire)"))
                self._counter("nonfinite_quarantined").inc()
                n_errors += 1
                continue
            self._results[r.ticket] = c
            self._pending_tickets.discard(r.ticket)
            self._latency_hist().observe(t_done - r.submit_t)
        if fused:
            self._counter("fused_requests").inc(len(batch))
        else:
            self._counter("looped_requests").inc(len(batch))
        self._bucket_reports.append({
            "key": key, "n_requests": len(batch), "fused": fused,
            "stage": stage, "n_errors": n_errors, "report": report})

    def _set_error(self, ticket: int, exc: BaseException) -> None:
        self._errors[ticket] = exc
        self._pending_tickets.discard(ticket)
        self._counter("error_tickets").inc()

    def _dispatch(self, key: tuple, batch: List[PendingRequest]) -> List[int]:
        """Drain one bucket through the degradation ladder.  NEVER
        raises: every ticket in ``batch`` ends settled — with a result
        or with a retrievable error."""
        pairs = [(r.a, r.b) for r in batch]
        # ladder rungs above per-request isolation: the pinned/planner
        # batched dispatch first (retried — transient failures), then
        # the looped bucket (skipped when fused=False already IS the
        # first rung)
        stages = []
        if self.fused is not False:
            stages.append(("fused", self.fused))
        stages.append(("looped", False))
        for si, (stage, fused_arg) in enumerate(stages):
            attempts = 1 + (self.max_retries if si == 0 else 0)
            for attempt in range(attempts):
                try:
                    self._check_fault(stage, attempt)
                    results, report = multiply_batched(
                        pairs, mesh=self.mesh, filter_eps=self.filter_eps,
                        fused=fused_arg, return_plan=True, **self.kw)
                except Exception:
                    if attempt + 1 < attempts:
                        self._counter("retries").inc()
                        self.sleep(self.backoff_s * (2 ** attempt))
                    continue
                fused = bool(report["buckets"]
                             and all(b["fused"] for b in report["buckets"]))
                self._deliver(key, batch, results, report,
                              fused=fused, stage=stage)
                return [r.ticket for r in batch]
            self._counter("degradations").inc()
        # final rung: per-request isolation — a poison request is
        # quarantined with its own error ticket, batch-mates complete
        results: List[Optional[DBCSRMatrix]] = []
        n_errors = 0
        for r in batch:
            try:
                self._check_fault("per_request", 0)
                results.append(multiply(
                    r.a, r.b, mesh=self.mesh, filter_eps=self.filter_eps,
                    **self.kw))
            except Exception as exc:
                self._set_error(r.ticket, exc)
                results.append(None)
                n_errors += 1
        self._deliver(key, batch, results, None, fused=False,
                      stage="per_request", n_errors=n_errors)
        return [r.ticket for r in batch]

    # -- observability -------------------------------------------------
    def stats(self) -> dict:
        """Legacy stats dict, now a thin view over the obs metrics
        registry (``service.*`` metrics labeled with this instance's
        ``service=`` id).  Keys and values are unchanged; the histogram
        percentiles match ``np.percentile(..., 'linear')`` exactly."""
        lat = self._latency_hist()
        return {
            "n_requests": int(self._counter("requests").value),
            "n_pending": self.n_pending,
            "n_completed": lat.count,
            "n_dispatches": int(self._counter("dispatches").value),
            "n_fused_requests": int(self._counter("fused_requests").value),
            "n_looped_requests": int(self._counter("looped_requests").value),
            "n_retries": int(self._counter("retries").value),
            "n_degradations": int(self._counter("degradations").value),
            "n_error_tickets": int(self._counter("error_tickets").value),
            "n_nonfinite_quarantined": int(
                self._counter("nonfinite_quarantined").value),
            "latency_p50_s": lat.percentile(50),
            "latency_p99_s": lat.percentile(99),
            "buckets": list(self._bucket_reports),
        }
