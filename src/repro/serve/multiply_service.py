"""Continuous-batching request layer over ``dbcsr.multiply_batched``.

The batched executor (core/multiply_batched.py) turns N
same-configuration products into one dispatch — but somebody has to
FIND those N products.  In a serving setting (property evaluations,
k-point workers, ensemble members issuing multiplies independently)
they arrive one at a time; this module is the accumulation layer that
turns the stream into fused batches:

  * ``submit(a, b)`` enqueues a request and returns a ticket id —
    nothing executes yet;
  * requests accumulate in buckets keyed by the batching contract
    ``(geometry, occupancy-bin, eps)`` (the same ``_bucket_key`` as
    ``dbcsr.multiply_batched`` — only key-identical requests can share
    a fused dispatch);
  * a bucket drains — ONE fused dispatch for its whole contents —
    when it reaches ``max_batch`` requests OR its oldest request's
    latency SLO expires (``slo_s`` seconds after submission),
    whichever comes first.  The SLO bounds the latency cost of waiting
    for batch-mates: a request never waits longer than ``slo_s`` past
    submission before its bucket is dispatched (modulo the caller
    actually pumping ``poll``).

The service is deliberately SYNCHRONOUS (no threads): draining happens
inside ``poll()`` / ``flush()`` on the caller's thread, so the caller
controls when device work runs — the natural fit for a jax host
process, and trivially testable with an injected ``clock``.

Typical pump loop::

    svc = MultiplyService(mesh, slo_s=0.005, max_batch=32)
    tickets = [svc.submit(a, b) for (a, b) in stream]
    svc.flush()                      # or poll() inside the loop
    results = [svc.result(t) for t in tickets]
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dbcsr import DBCSRMatrix, _bucket_key, multiply_batched

__all__ = ["MultiplyService", "PendingRequest"]


@dataclasses.dataclass
class PendingRequest:
    """One queued multiply: operands plus its SLO accounting."""

    ticket: int
    a: DBCSRMatrix
    b: DBCSRMatrix
    submit_t: float

    def deadline(self, slo_s: float) -> float:
        return self.submit_t + slo_s


class MultiplyService:
    """Accumulate multiply requests and drain them as fused batches.

    Parameters
    ----------
    mesh        the device mesh every request executes on
    slo_s       latency SLO: a bucket is dispatched no later than the
                first ``poll()`` after its OLDEST request has waited
                ``slo_s`` seconds (0 = dispatch every request on the
                next poll — batching only among same-poll arrivals)
    max_batch   dispatch a bucket as soon as it holds this many
                requests, SLO notwithstanding
    filter_eps  norm-filter threshold applied to every request (part of
                the bucket key — a service instance is eps-uniform)
    fused       pin the fuse-or-loop choice per bucket (None = planner)
    clock       injectable time source (``time.monotonic``-like), for
                deterministic tests
    **kw        forwarded to ``dbcsr.multiply_batched`` (algorithm,
                densify, local_kernel, pipeline_depth, ...)

    ``stats()`` reports request/dispatch counters, per-bucket fusion
    accounting, and completion-latency percentiles (p50/p99 of
    ``completion - submit`` over finished requests).
    """

    def __init__(
        self,
        mesh,
        *,
        slo_s: float = 0.01,
        max_batch: int = 32,
        filter_eps: Optional[float] = None,
        fused: Optional[bool] = None,
        clock: Callable[[], float] = time.monotonic,
        **kw,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.mesh = mesh
        self.slo_s = float(slo_s)
        self.max_batch = int(max_batch)
        self.filter_eps = filter_eps
        self.fused = fused
        self.clock = clock
        self.kw = kw
        self._next_ticket = 0
        self._queues: Dict[tuple, List[PendingRequest]] = {}
        self._results: Dict[int, DBCSRMatrix] = {}
        self._latencies: List[float] = []
        self._n_dispatches = 0
        self._n_fused_requests = 0
        self._n_looped_requests = 0
        self._bucket_reports: List[dict] = []

    # -- request side --------------------------------------------------
    def submit(self, a: DBCSRMatrix, b: DBCSRMatrix) -> int:
        """Enqueue C = A @ B; returns a ticket for ``result()``.

        Nothing executes here — the request waits for batch-mates
        until its bucket fills (``max_batch``) or its SLO expires,
        both checked by ``poll()``/``flush()``.
        """
        ticket = self._next_ticket
        self._next_ticket += 1
        key = _bucket_key(a, b, self.filter_eps)
        self._queues.setdefault(key, []).append(
            PendingRequest(ticket, a, b, self.clock()))
        return ticket

    def poll(self) -> List[int]:
        """Dispatch every bucket that is due (full, or oldest request
        past its SLO deadline); returns the tickets completed by this
        call.  Buckets still inside their SLO window keep waiting for
        batch-mates."""
        now = self.clock()
        done: List[int] = []
        for key in list(self._queues):
            q = self._queues[key]
            while len(q) >= self.max_batch:
                done += self._dispatch(key, q[:self.max_batch])
                del q[:self.max_batch]
            if q and q[0].deadline(self.slo_s) <= now:
                done += self._dispatch(key, q)
                q.clear()
            if not q:
                del self._queues[key]
        return done

    def flush(self) -> List[int]:
        """Dispatch everything queued regardless of SLO/size."""
        done: List[int] = []
        for key in list(self._queues):
            done += self._dispatch(key, self._queues.pop(key))
        return done

    def result(self, ticket: int) -> DBCSRMatrix:
        """Pop a completed product (KeyError while still queued —
        ``poll()``/``flush()`` first)."""
        return self._results.pop(ticket)

    @property
    def n_pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # -- dispatch ------------------------------------------------------
    def _dispatch(self, key: tuple, batch: List[PendingRequest]) -> List[int]:
        results, report = multiply_batched(
            [(r.a, r.b) for r in batch], mesh=self.mesh,
            filter_eps=self.filter_eps, fused=self.fused,
            return_plan=True, **self.kw)
        t_done = self.clock()
        self._n_dispatches += 1
        fused = bool(report["buckets"]
                     and all(b["fused"] for b in report["buckets"]))
        if fused:
            self._n_fused_requests += len(batch)
        else:
            self._n_looped_requests += len(batch)
        self._bucket_reports.append({
            "key": key, "n_requests": len(batch), "fused": fused,
            "report": report})
        for r, c in zip(batch, results):
            self._results[r.ticket] = c
            self._latencies.append(t_done - r.submit_t)
        return [r.ticket for r in batch]

    # -- observability -------------------------------------------------
    def stats(self) -> dict:
        lat = np.asarray(self._latencies, dtype=np.float64)
        return {
            "n_requests": self._next_ticket,
            "n_pending": self.n_pending,
            "n_completed": len(self._latencies),
            "n_dispatches": self._n_dispatches,
            "n_fused_requests": self._n_fused_requests,
            "n_looped_requests": self._n_looped_requests,
            "latency_p50_s": float(np.percentile(lat, 50)) if lat.size else 0.0,
            "latency_p99_s": float(np.percentile(lat, 99)) if lat.size else 0.0,
            "buckets": list(self._bucket_reports),
        }
