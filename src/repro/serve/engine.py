"""Serving: prefill + decode steps over the segment-structured cache.

``prefill_step`` runs the full-sequence forward while returning the
caches each layer would have written (the per-layer (k, v) / latent /
state tuples), laid out exactly like ``decode_step`` consumes them.
``decode_step`` appends one token: the decode_32k / long_500k dry-run
shapes lower this function.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as T

__all__ = ["decode_step", "serve_input_specs", "decode_shardings",
           "init_serve_state"]


def init_serve_state(cfg, batch: int, max_len: int):
    """Zero caches + cur_len = 0."""
    return {"cache": T.cache_init(cfg, batch, max_len),
            "cur_len": jnp.zeros((), jnp.int32)}


def decode_step(params, state, tokens_or_embeds, cfg, mesh):
    """One decode step.

    tokens_or_embeds: (B, 1) int32 (or (B, 1, d) for stub-frontend
    archs).  Returns (next_tokens (B, 1), new_state).
    """
    logits, _hidden, _aux, new_cache = T.forward(
        params, tokens_or_embeds, cfg, mesh,
        cache=state["cache"], cur_len=state["cur_len"])
    next_tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    return next_tokens, {"cache": new_cache,
                         "cur_len": state["cur_len"] + 1}


def decode_shardings(cfg, mesh, *, batch=None, kv_len=None):
    from repro.models.common import resolve_specs
    ns = lambda spec: NamedSharding(mesh, spec)
    cspecs = T.cache_specs(cfg, mesh, batch=batch)
    if batch is not None and kv_len is not None:
        cshapes = T.cache_shapes(cfg, batch, kv_len)
        cspecs = resolve_specs(cspecs, cshapes, mesh)
    cspecs = jax.tree_util.tree_map(
        ns, cspecs, is_leaf=lambda x: isinstance(x, P))
    state_sh = {"cache": cspecs, "cur_len": ns(P())}
    dp = T.dp_axes(mesh)
    if batch is not None:
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        if batch % max(n_dp, 1) != 0:
            dp = ()
    if cfg.input_mode == "embeddings":
        tok_sh = ns(P(dp, None, None))
    else:
        tok_sh = ns(P(dp, None))
    return state_sh, tok_sh


def serve_input_specs(cfg, *, batch: int, kv_len: int):
    """ShapeDtypeStructs for the decode dry-run: one new token with a
    KV cache of kv_len."""
    dt = jnp.int32
    if cfg.input_mode == "embeddings":
        tokens = jax.ShapeDtypeStruct((batch, 1, cfg.d_model),
                                      getattr(jnp, cfg.dtype))
    else:
        tokens = jax.ShapeDtypeStruct((batch, 1), dt)
    state = {
        "cache": T.cache_shapes(cfg, batch, kv_len),
        "cur_len": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return state, tokens
