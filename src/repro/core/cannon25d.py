"""2.5D Cannon over the pod axis (beyond-paper, from the DBCSR lineage).

Lazzaro et al. [paper ref 10] extended DBCSR with a 2.5D algorithm:
keep c replicas of A and B on c stacked process grids, let replica p
execute only 1/c of the k-shift steps (offset by p * P/c), and combine
the partial C's with one reduction over the stack axis.  Per-replica
communication drops from O(sqrt(P)) shifts to O(sqrt(P)/c) at the cost
of c-fold operand replication — the classic communication-avoiding
trade.

On the production mesh the replication axis is the **pod** axis
(2 pods => c = 2): inter-pod ICI/DCN carries only the final C
reduction, while all Cannon shifts stay on the intra-pod torus.  This
is exactly the property you want at 1000+ node scale: the slow
cross-pod links see O(M*N/P) bytes once, never the O(sqrt(P)) shift
traffic.

SPMD note: the per-replica step offset must NOT be implemented with
control flow on the replica index — collectives inside divergent
branches deadlock (all devices must issue the same collective
sequence).  Instead the offset is folded into the initial skew as one
*static* joint-axis ppermute over (stack, row, col): device (p, i, j)
starts from A(i, (i + j + p*P/c) % P) and B((i + j + p*P/c) % P, j).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .blocking import GridSpec
from .cannon import cannon_local_steps, _default_local_matmul

__all__ = ["cannon25d_matmul"]


def _skew25d_perm(pg: int, c_repl: int, spr: int, which: str):
    """Static permutation over flattened (stack, row, col):
    destination (p, i, j) receives
      A block (i, (i + j + p*spr) % P)  — held by source (p, i, (i+j+p*spr)%P)
      B block ((i + j + p*spr) % P, j)  — held by source (p, (i+j+p*spr)%P, j)
    (sources stay within their own pod: A/B enter replicated over pods).
    """
    flat = lambda p, i, j: (p * pg + i) * pg + j
    pairs = []
    for p in range(c_repl):
        for i in range(pg):
            for j in range(pg):
                k = (i + j + p * spr) % pg
                if which == "a":
                    pairs.append((flat(p, i, k), flat(p, i, j)))
                else:
                    pairs.append((flat(p, k, j), flat(p, i, j)))
    return pairs


def cannon25d_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec,
    local_matmul: Optional[Callable] = None,
    out_dtype=None,
    precision=jax.lax.Precision.DEFAULT,
    double_buffer: bool = True,
    reduce: str = "all_reduce",  # or "reduce_scatter"
) -> jax.Array:
    """C = A @ B, 2.5D Cannon with replication over ``grid.stack_axis``.

    A, B enter 2D-sharded over (row, col) and replicated over the stack
    axis — spec P(row, col).  C leaves with the same spec (all_reduce)
    or additionally row-sharded over the stack axis (reduce_scatter).
    """
    if grid.stack_axis is None:
        raise ValueError("cannon25d needs grid.stack_axis (e.g. 'pod')")
    pg = grid.validate_square(mesh)
    c_repl = grid.stack_size(mesh)
    if pg % c_repl:
        raise ValueError(f"grid side {pg} not divisible by replication {c_repl}")
    spr = pg // c_repl  # steps per replica
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    lm = local_matmul or _default_local_matmul(precision)
    axes3 = (grid.stack_axis, grid.row_axis, grid.col_axis)

    def body(a_blk, b_blk):
        # fused skew + replica offset: one static joint-axis ppermute
        a_blk = jax.lax.ppermute(a_blk, axes3, _skew25d_perm(pg, c_repl, spr, "a"))
        b_blk = jax.lax.ppermute(b_blk, axes3, _skew25d_perm(pg, c_repl, spr, "b"))
        c_partial = cannon_local_steps(
            a_blk,
            b_blk,
            pg=pg,
            row_axis=grid.row_axis,
            col_axis=grid.col_axis,
            local_matmul=lm,
            out_dtype=jnp.float32,
            skew=False,           # already done (with the pod offset)
            double_buffer=double_buffer,
            steps=spr,
        )
        if reduce == "all_reduce":
            c_blk = jax.lax.psum(c_partial, grid.stack_axis)
        elif reduce == "reduce_scatter":
            c_blk = jax.lax.psum_scatter(
                c_partial, grid.stack_axis, scatter_dimension=0, tiled=True
            )
        else:
            raise ValueError(reduce)
        return c_blk.astype(out_dtype)

    spec2d = P(grid.row_axis, grid.col_axis)
    if reduce == "all_reduce":
        out_spec = spec2d
    else:
        # psum_scatter chunk p of the local block goes to pod p => the
        # stack axis is the *minor* factor of the row partition.
        out_spec = P((grid.row_axis, grid.stack_axis), grid.col_axis)
    fn = shard_map(body, mesh=mesh, in_specs=(spec2d, spec2d),
                   out_specs=out_spec, check_vma=False)
    return fn(a, b)
