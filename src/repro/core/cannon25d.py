"""2.5D Cannon over the pod axis (beyond-paper, from the DBCSR lineage).

Lazzaro et al. [paper ref 10] extended DBCSR with a 2.5D algorithm:
keep c replicas of A and B on c stacked process grids, let replica p
execute only 1/c of the k-shift steps (offset by p * P/c), and combine
the partial C's with one reduction over the stack axis.  Per-replica
communication drops from O(sqrt(P)) shifts to O(sqrt(P)/c) at the cost
of c-fold operand replication — the classic communication-avoiding
trade.

On the production mesh the replication axis is the **pod** axis
(2 pods => c = 2): inter-pod ICI/DCN carries only the final C
reduction, while all Cannon shifts stay on the intra-pod torus.  This
is exactly the property you want at 1000+ node scale: the slow
cross-pod links see O(M*N/P) bytes once, never the O(sqrt(P)) shift
traffic.

SPMD note: the per-replica step offset must NOT be implemented with
control flow on the replica index — collectives inside divergent
branches deadlock (all devices must issue the same collective
sequence).  Instead the offset is folded into the initial skew as one
*static* joint-axis ppermute over (stack, row, col): device (p, i, j)
starts from A(i, (i + j + p*P/c) % P) and B((i + j + p*P/c) % P, j).

The step loop itself is the unified schedule engine (core/schedule.py):
``build_cannon25d_schedule`` composes the Cannon shift schedule with
the fused-skew prologue and the stack-axis reduction epilogue.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .blocking import GridSpec
from .cannon import _default_local_matmul, build_cannon_schedule
from .schedule import Schedule, execute_schedule, resolve_pipeline_depth

__all__ = ["cannon25d_matmul", "build_cannon25d_schedule"]


def _skew25d_perm(pg: int, c_repl: int, spr: int, which: str):
    """Static permutation over flattened (stack, row, col):
    destination (p, i, j) receives
      A block (i, (i + j + p*spr) % P)  — held by source (p, i, (i+j+p*spr)%P)
      B block ((i + j + p*spr) % P, j)  — held by source (p, (i+j+p*spr)%P, j)
    (sources stay within their own pod: A/B enter replicated over pods).
    """
    flat = lambda p, i, j: (p * pg + i) * pg + j
    pairs = []
    for p in range(c_repl):
        for i in range(pg):
            for j in range(pg):
                k = (i + j + p * spr) % pg
                if which == "a":
                    pairs.append((flat(p, i, k), flat(p, i, j)))
                else:
                    pairs.append((flat(p, k, j), flat(p, i, j)))
    return pairs


def build_cannon25d_schedule(
    pg: int,
    c_repl: int,
    *,
    row_axis: str,
    col_axis: str,
    stack_axis: str,
    reduce: str = "all_reduce",
    empty_steps: frozenset = frozenset(),
    local_shape: Optional[tuple] = None,
    itemsize: int = 4,
) -> Schedule:
    """Schedule for 2.5D Cannon: the Cannon shift steps (1/c of them,
    replica-offset via the fused-skew prologue) plus one partial-C
    reduction over the stack axis as the epilogue."""
    if pg % c_repl:
        raise ValueError(f"grid side {pg} not divisible by replication {c_repl}")
    spr = pg // c_repl  # steps per replica
    base = build_cannon_schedule(
        pg, row_axis=row_axis, col_axis=col_axis, skew=False, steps=spr,
        empty_steps=empty_steps, local_shape=local_shape, itemsize=itemsize)
    axes3 = (stack_axis, row_axis, col_axis)

    def prologue(a_blk, b_blk):
        # fused skew + replica offset: one static joint-axis ppermute
        a_blk = jax.lax.ppermute(a_blk, axes3,
                                 _skew25d_perm(pg, c_repl, spr, "a"))
        b_blk = jax.lax.ppermute(b_blk, axes3,
                                 _skew25d_perm(pg, c_repl, spr, "b"))
        return (a_blk, b_blk)

    def epilogue(c_partial):
        if reduce == "all_reduce":
            return jax.lax.psum(c_partial, stack_axis)
        if reduce == "reduce_scatter":
            return jax.lax.psum_scatter(
                c_partial, stack_axis, scatter_dimension=0, tiled=True)
        raise ValueError(reduce)

    prologue_bytes = epilogue_bytes = 0
    if local_shape is not None:
        ml, kl, nl = local_shape
        prologue_bytes = (ml * kl + kl * nl) * itemsize
        # partial C's reduce in f32 over the stack axis
        epilogue_bytes = 2 * ml * nl * 4

    return base.replace(
        algorithm="cannon25d",
        prologue=prologue,
        epilogue=epilogue,
        prologue_comm_bytes=prologue_bytes,
        epilogue_comm_bytes=epilogue_bytes,
    )


def cannon25d_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec,
    local_matmul: Optional[Callable] = None,
    out_dtype=None,
    precision=jax.lax.Precision.DEFAULT,
    pipeline_depth: Optional[int] = None,
    double_buffer: Optional[bool] = None,
    reduce: str = "all_reduce",  # or "reduce_scatter"
) -> jax.Array:
    """C = A @ B, 2.5D Cannon with replication over ``grid.stack_axis``.

    A, B enter 2D-sharded over (row, col) and replicated over the stack
    axis — spec P(row, col).  C leaves with the same spec (all_reduce)
    or additionally row-sharded over the stack axis (reduce_scatter).
    ``pipeline_depth`` follows core/schedule.py semantics.
    """
    if grid.stack_axis is None:
        raise ValueError("cannon25d needs grid.stack_axis (e.g. 'pod')")
    pg = grid.validate_square(mesh)
    c_repl = grid.stack_size(mesh)
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    lm = local_matmul or _default_local_matmul(precision)
    depth = resolve_pipeline_depth(pipeline_depth, double_buffer)
    sched = build_cannon25d_schedule(
        pg, c_repl, row_axis=grid.row_axis, col_axis=grid.col_axis,
        stack_axis=grid.stack_axis, reduce=reduce,
        empty_steps=getattr(lm, "empty_steps", frozenset()))

    def body(a_blk, b_blk):
        return execute_schedule(sched, a_blk, b_blk, local_matmul=lm,
                                out_dtype=out_dtype, pipeline_depth=depth)

    spec2d = P(grid.row_axis, grid.col_axis)
    if reduce == "all_reduce":
        out_spec = spec2d
    else:
        # psum_scatter chunk p of the local block goes to pod p => the
        # stack axis is the *minor* factor of the row partition.
        out_spec = P((grid.row_axis, grid.stack_axis), grid.col_axis)
    fn = shard_map(body, mesh=mesh, in_specs=(spec2d, spec2d),
                   out_specs=out_spec, check_vma=False)
    return fn(a, b)
