"""Top-level distributed multiply dispatcher.

Implements DBCSR's algorithm selection (paper section II): Cannon for
general shapes, the tall-and-skinny algorithm when one dimension
dominates, plus the beyond-paper 2.5D variant when a stack (pod) axis
is available.  The local multiply is either 'densified' (one big GEMM
— the paper's section III optimization, default for dense matrices) or
'blocked' (stack-of-small-GEMMs via the smm kernel).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .blocking import GridSpec
from .cannon import cannon_matmul
from .cannon25d import cannon25d_matmul
from .densify import blocked_local_matmul, densified_local_matmul
from .summa import summa_matmul
from .tall_skinny import classify_shape, tall_skinny_matmul

__all__ = ["distributed_matmul"]


def distributed_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec = GridSpec(),
    algorithm: str = "auto",
    densify: bool = True,
    block_m: int = 64,
    block_k: int = 64,
    block_n: int = 64,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    local_kernel: Optional[str] = None,
    precision=jax.lax.Precision.DEFAULT,
    double_buffer: bool = True,
    **kw,
) -> jax.Array:
    """C = A @ B on the mesh. ``algorithm``:

      auto         — DBCSR dispatch: shape-classify into cannon / ts_*
      cannon       — Cannon's algorithm (square grids)
      cannon25d    — 2.5D Cannon over grid.stack_axis
      ts_k|ts_m|ts_n — tall-and-skinny variants
      summa        — the ScaLAPACK-PDGEMM-style baseline

    For the blocked path (``densify=False``) ``stack_size``/``align``
    default to the smm autotune winners table for the block geometry.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims disagree: {a.shape} @ {b.shape}")

    if algorithm == "auto":
        algorithm = classify_shape(m, k, n)
        if algorithm == "cannon" and grid.stack_axis is not None:
            algorithm = "cannon25d"
    if algorithm not in ("cannon", "cannon25d", "ts_k", "ts_m", "ts_n",
                        "summa"):
        raise ValueError(f"unknown algorithm {algorithm!r}")

    # ---- local multiply strategy (densified vs blocked) --------------
    if densify:
        lm = densified_local_matmul(precision, kernel=local_kernel)
    else:
        pr, pc = grid.grid_shape(mesh)
        if algorithm.startswith("ts_"):
            p_all = pr * pc * grid.stack_size(mesh)
            shapes = {
                "ts_k": (m, k // p_all, n),
                "ts_m": (m // p_all, k, n),
                "ts_n": (m, k, n // p_all),
            }
            ml, kl, nl = shapes[algorithm]
        elif algorithm in ("cannon", "cannon25d"):
            # Local multiply is (m/pg, k/pg) @ (k/pg, n/pg) on the square
            # grid Cannon requires.  Deriving the inner dim from pc alone
            # (the old ``k // pc``) silently mis-sized B's stack-plan
            # geometry whenever pr != pc: gathers clamp out-of-range
            # block indices instead of failing, producing wrong C.
            pg = grid.validate_square(mesh)
            if m % pg or k % pg or n % pg:
                raise ValueError(
                    f"shape ({m},{k},{n}) not divisible by grid side {pg}")
            ml, kl, nl = m // pg, k // pg, n // pg
        else:
            # summa hands the full local operands to the local multiply
            # only on square grids (otherwise panels are strict slices of
            # the local K extent and a fixed stack plan cannot describe
            # them).
            if pr != pc:
                raise ValueError(
                    f"blocked local multiply requires a square grid for "
                    f"{algorithm!r}; got {pr}x{pc} (use densify=True)")
            if m % pr or k % pc or n % pc:
                raise ValueError(
                    f"shape ({m},{k},{n}) not divisible by grid {pr}x{pc}")
            if kw.get("bcast") == "gather":
                # PUMMA-style broadcast: the local multiply sees the
                # all-gathered full-K row of A / column of B
                ml, kl, nl = m // pr, k, n // pc
            else:
                ml, kl, nl = m // pr, k // pc, n // pc
        lm = blocked_local_matmul(
            ml, kl, nl, block_m=block_m, block_k=block_k, block_n=block_n,
            stack_size=stack_size, align=align,
            kernel=local_kernel or "smm",
        )

    # ---- data-exchange algorithm --------------------------------------
    if algorithm == "cannon":
        return cannon_matmul(
            a, b, mesh=mesh, grid=grid, local_matmul=lm,
            precision=precision, double_buffer=double_buffer, **kw)
    if algorithm == "cannon25d":
        return cannon25d_matmul(
            a, b, mesh=mesh, grid=grid, local_matmul=lm,
            precision=precision, double_buffer=double_buffer, **kw)
    if algorithm in ("ts_k", "ts_m", "ts_n"):
        return tall_skinny_matmul(
            a, b, mesh=mesh, grid=grid, mode=algorithm, local_matmul=lm,
            precision=precision, **kw)
    if algorithm == "summa":
        return summa_matmul(
            a, b, mesh=mesh, grid=grid, local_matmul=lm,
            precision=precision, **kw)
    raise ValueError(f"unknown algorithm {algorithm!r}")
