"""Top-level distributed multiply dispatcher.

Implements DBCSR's algorithm selection (paper section II): with
``algorithm="auto"`` (the default) the cost-model planner
(repro.planner.plan_multiply) evaluates every feasible candidate —
Cannon / SUMMA / 2.5D Cannon / the tall-and-skinny variants, each with
a densified or blocked local path — against calibrated hardware
constants and picks the cheapest, which is the paper's driver
behaviour (the "different sizes and shapes" headline).  A fixed
``algorithm=`` string bypasses the planner entirely.  The local
multiply is either 'densified' (one big GEMM — the paper's section III
optimization) or 'blocked' (stack-of-small-GEMMs via the smm kernel);
``densify=None`` leaves that choice to the planner too.

Every algorithm executes through the unified schedule engine
(core/schedule.py): the algorithm module emits a step schedule (comm
op, per-step mask slice, local multiply geometry) and the pipelined
driver runs it with software double-buffering — ``pipeline_depth=2``
(default) issues the ppermute / panel broadcast for step t+1 while
step t's stacks execute, ``pipeline_depth=1`` is strictly serial with
bit-identical output.

Occupancy threading (blocked path): ``a_mask`` / ``b_mask`` are the
*global* block-occupancy masks of the operands (host-side numpy bool).
For every data-exchange step of the chosen algorithm — each cannon
shift, each summa panel — the per-algorithm mask builders
(``cannon_step_masks`` / ``summa_step_masks`` / ``ts_step_masks``)
slice the global masks down to the block ranges every mesh rank holds
at that step and union them over ranks (shard_map traces ONE program
for all devices, so the per-step plan must cover every rank's present
triples; the union is the tightest SPMD-uniform *shared* plan).  Plans
are memoized per shifted-mask content fingerprint (core/engine.py), and
a step whose unioned mask product is empty skips its ``execute_plan`` —
and for summa, the panel broadcast — entirely.  The densified path
ignores the masks: absent blocks are stored as zeros, so one big GEMM
is already correct.

Rank-exact execution (default for masked/filtered blocked multi-rank
paths; ``rank_exact=False`` restores the union): instead of one shared
union plan per step, the per-rank builders (``cannon_rank_steps`` /
``summa_rank_steps`` / ``ts_rank_steps``) emit each rank's EXACT
mask/norm slice and the engine stacks the per-rank plans into one
host-constant slab every rank indexes with ``jax.lax.axis_index``
inside shard_map (core/engine.rank_stack_executor) — still one traced
program, but a rank executes only its own retained triples, never the
union's.  Per-step emptiness stays host-static as the all-ranks-empty
intersection (identical to union emptiness: the max norm product over
ranks clears eps iff some rank retains a triple).  Steps whose
per-rank slices are content-identical (dense padding, uniform fill)
collapse to the shared union executor, bitwise-identical to the legacy
trace.  On top of that, the planner's costed permutation pass
(repro.sparsity.balance) can permute block rows/cols of A/B before the
multiply and invert the permutation on C, flattening per-rank load
imbalance when the predicted compute saved exceeds the shuffle's cost
(DBCSR's randomized-distribution trick, arXiv:1910.04796 sec. 2).
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .blocking import GridSpec
from .cannon import (build_cannon_schedule, cannon_matmul, cannon_rank_steps,
                     cannon_step_masks, cannon_step_norms)
from .cannon25d import build_cannon25d_schedule, cannon25d_matmul
from .densify import blocked_local_matmul, densified_local_matmul
from .engine import rank_stack_executor
from .schedule import resolve_pipeline_depth, schedule_step_meta
from .stacks import normalize_block_masks
from .summa import (build_summa_gather_schedule, build_summa_schedule,
                    summa_gather_masks, summa_gather_norms,
                    summa_gather_rank_steps, summa_matmul, summa_n_panels,
                    summa_rank_steps, summa_step_masks, summa_step_norms)
from .tall_skinny import (build_ts_schedule, tall_skinny_matmul,
                          ts_rank_steps, ts_step_masks, ts_step_norms)

__all__ = ["distributed_matmul"]


def _block_masks(
    m: int, k: int, n: int,
    block_m: int, block_k: int, block_n: int,
    a_mask: Optional[np.ndarray], b_mask: Optional[np.ndarray],
) -> Tuple[np.ndarray, np.ndarray]:
    """Normalise the *global* occupancy masks; a missing mask means the
    operand is dense (all blocks present)."""
    return normalize_block_masks(m // block_m, k // block_k, n // block_n,
                                 a_mask, b_mask)


def _masks_empty(mask_kwargs: dict) -> bool:
    """Host-static per-step emptiness: no mask-present triple — or,
    under a ``filter_eps`` with norms, no triple whose norm-product
    bound clears eps (norm filtering can empty a step whose binary
    masks are non-empty; the schedule driver then skips it exactly
    like a mask-empty step)."""
    eps = mask_kwargs.get("filter_eps")
    if "pair_mask" in mask_kwargs or "pair_norms" in mask_kwargs:
        pm = mask_kwargs.get("pair_mask")
        if pm is not None and not pm.any():
            return True
        pn = mask_kwargs.get("pair_norms")
        if eps and pn is not None:
            kept = pn if pm is None else np.where(pm, pn, 0.0)
            return not bool((kept.astype(np.float64) >= float(eps)).any())
        return False
    ua, ub = mask_kwargs["a_mask"], mask_kwargs["b_mask"]
    if not bool(np.any(ua.any(axis=0) & ub.any(axis=1))):
        return True
    un, vn = mask_kwargs.get("a_norms"), mask_kwargs.get("b_norms")
    if eps and un is not None and vn is not None:
        # max retained product per k: (max_i masked a) * (max_j masked b)
        ka = np.where(ua, un.astype(np.float64), 0.0).max(axis=0)
        kb = np.where(ub, vn.astype(np.float64), 0.0).max(axis=1)
        return not bool((ka * kb >= float(eps)).any())
    return False


def _global_occupancy(
    m: int, k: int, n: int,
    block_m: int, block_k: int, block_n: int,
    a_mask: Optional[np.ndarray], b_mask: Optional[np.ndarray],
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
    filter_eps: Optional[float] = None,
) -> float:
    """Retained-triple fraction of the global dense triple grid — the
    occupancy the planner discounts blocked-path flops by.  With block
    norms and a ``filter_eps`` this is the NORM-PREDICTED fraction
    (mask-present triples whose norm product clears eps), so the
    planner's blocked-path discount reflects the on-the-fly filter, not
    just binary occupancy.  An empty product returns 0.0, which the
    planner short-circuits to a trivial plan — including the case where
    eps filtering empties a product whose binary masks are non-empty
    (the same contract as ``_masks_empty`` per step: the blocked cost
    model must never divide by zero occupancy)."""
    filtering = filter_eps is not None and (
        a_norms is not None or b_norms is not None)
    if a_mask is None and b_mask is None and not filtering:
        return 1.0
    from .engine import _mask_fill

    return _mask_fill(m // block_m, k // block_k, n // block_n,
                      a_mask, b_mask, None,
                      a_norms, b_norms, None, filter_eps)


def _collect_executor_stats(lm, densify: bool) -> Optional[dict]:
    """Executed-plan stack statistics for plan observability
    (dbcsr.multiply exposes these as ``last_plan.executor_stats``)."""
    if densify:
        return None
    if getattr(lm, "stepwise", False):
        ex = [f.executor_plan for f in lm.step_executors if f is not None]
        n_entries = sum(p.n_entries for p in ex)
        n_dense = sum(p.n_dense_triples for p in ex)
        n_padding = sum(p.n_padding for p in ex)
        n_padding_unbinned = sum(p.n_padding_unbinned for p in ex)
        n_unfiltered = sum(
            p.n_entries if p.n_unfiltered_entries is None
            else p.n_unfiltered_entries for p in ex)
        stats = {
            "n_steps": len(lm.step_executors),
            "n_empty_steps": len(lm.empty_steps),
            "n_entries": n_entries,
            "n_dense_triples": n_dense,
            "n_skipped_triples": n_dense - n_entries,
            "occupancy": n_entries / n_dense if n_dense else 1.0,
            "n_padding": n_padding,
            "n_padding_unbinned": n_padding_unbinned,
            "padding_triples_saved": n_padding_unbinned - n_padding,
            # on-the-fly filter accounting (repro.sparsity): triples the
            # binary masks admitted but the norm-product bound dropped
            "n_unfiltered_triples": n_unfiltered,
            "n_norm_filtered_triples": n_unfiltered - n_entries,
        }
        totals = _rank_totals(lm)
        if totals is not None:
            # rank-exact accounting: the busiest rank's total bounds
            # wall time; mean is the flattened-load floor rebalancing
            # aims for (n_entries above already sums per-step maxima)
            stats.update(
                rank_exact=True,
                rank_entries=[int(x) for x in totals],
                max_rank_entries=int(totals.max()),
                mean_rank_entries=float(totals.mean()),
                rank_imbalance=_rank_imbalance_of(totals),
            )
        return stats
    plan = getattr(lm, "executor_plan", None)
    if plan is None:
        return None
    stats = plan.stats()
    if hasattr(plan, "rank_entries"):
        stats["rank_exact"] = True
    return stats


def _stepwise_blocked_lm(
    ml: int, kl: int, nl: int, *, mask_steps: List[dict], **blocked_kw,
):
    """A stepwise local multiply: one fused stack executor per data-
    exchange step (plans deduplicated by mask fingerprint through the
    engine memo).  Steps whose mask product is empty carry no executor;
    the schedule driver skips them host-side.
    """
    fns, empty = [], set()
    for t, mask_kwargs in enumerate(mask_steps):
        if _masks_empty(mask_kwargs):
            fns.append(None)
            empty.add(t)
        else:
            fns.append(blocked_local_matmul(ml, kl, nl, **mask_kwargs,
                                            **blocked_kw))

    def lm(a_loc: jax.Array, b_loc: jax.Array, step: int = 0):
        f = fns[step]
        return None if f is None else f(a_loc, b_loc)

    lm.stepwise = True
    lm.empty_steps = frozenset(empty)
    lm.step_executors = fns
    return lm


# ---------------------------------------------------------------------------
# rank-exact execution (ISSUE 9): per-rank plan slabs + costed rebalance
# ---------------------------------------------------------------------------


def _rank_kwargs_equal(rank_kwargs: List[dict]) -> bool:
    """True when every rank's step kwargs are content-identical — the
    dense / uniform-fill collapse: one shared plan IS every rank's
    exact plan, so the union executor (today's trace) already executes
    rank-exactly and we keep its bitwise-identical program."""
    first = rank_kwargs[0]
    keys = set(first)
    for rk in rank_kwargs[1:]:
        if set(rk) != keys:
            return False
        for key in keys:
            u, v = first[key], rk[key]
            if u is None or v is None:
                if u is not v:
                    return False
            elif u.shape != v.shape or not np.array_equal(u, v):
                return False
    return True


def _rank_index_fn(algorithm: str, grid: GridSpec, mesh):
    """Zero-arg closure returning this rank's traced flat index inside
    the shard_map body (``jax.lax.axis_index`` over the mesh axes),
    matching the rank orderings the per-rank step builders emit:
    cannon ``i*pg + j``; cannon25d / stacked tall-skinny stack-major
    ``(s*pr + i)*pc + j``; summa / flat tall-skinny ``i*pc + j``."""
    pr, pc = grid.grid_shape(mesh)
    row, col = grid.row_axis, grid.col_axis
    stacked = (algorithm == "cannon25d"
               or (algorithm.startswith("ts_")
                   and grid.stack_axis is not None))
    if stacked:
        stack = grid.stack_axis
        return lambda: ((jax.lax.axis_index(stack) * pr
                         + jax.lax.axis_index(row)) * pc
                        + jax.lax.axis_index(col))
    return lambda: jax.lax.axis_index(row) * pc + jax.lax.axis_index(col)


def _single_rank_lm(ml: int, kl: int, nl: int, *, rank_kwargs: List[dict],
                    rank_index_fn, filter_eps: Optional[float] = None,
                    **blocked_kw):
    """Rank-exact local multiply for single-plan schedules (tall-skinny,
    summa with the gather broadcast): one slab executor, or the union
    ``blocked_local_matmul`` when every rank's slice is identical."""
    if _rank_kwargs_equal(rank_kwargs):
        return blocked_local_matmul(ml, kl, nl, **rank_kwargs[0],
                                    filter_eps=filter_eps, **blocked_kw)
    return rank_stack_executor(ml, kl, nl, rank_masks=rank_kwargs,
                               rank_index_fn=rank_index_fn,
                               filter_eps=filter_eps, **blocked_kw)


def _stepwise_rank_blocked_lm(
    ml: int, kl: int, nl: int, *, rank_steps: List[List[dict]],
    rank_index_fn, filter_eps: Optional[float] = None, **blocked_kw,
):
    """Rank-exact stepwise local multiply: one stacked per-rank slab
    executor per data-exchange step (core/engine.rank_stack_executor).

    Step emptiness stays HOST-STATIC as the all-ranks-empty
    intersection — ``max_r norm_product >= eps`` iff some rank retains
    a triple, so this is exactly the union path's per-step skip set and
    the comm schedule stays SPMD-uniform.  A step whose per-rank slices
    are content-identical (uniform fill) collapses to the shared union
    executor, bitwise-identical to the legacy trace."""
    fns, empty = [], set()
    for t, rkw in enumerate(rank_steps):
        if all(_masks_empty({**r, "filter_eps": filter_eps}) for r in rkw):
            fns.append(None)
            empty.add(t)
        elif _rank_kwargs_equal(rkw):
            fns.append(blocked_local_matmul(
                ml, kl, nl, **rkw[0], filter_eps=filter_eps, **blocked_kw))
        else:
            fns.append(rank_stack_executor(
                ml, kl, nl, rank_masks=rkw, rank_index_fn=rank_index_fn,
                filter_eps=filter_eps, **blocked_kw))

    def lm(a_loc: jax.Array, b_loc: jax.Array, step: int = 0):
        f = fns[step]
        return None if f is None else f(a_loc, b_loc)

    lm.stepwise = True
    lm.empty_steps = frozenset(empty)
    lm.step_executors = fns
    return lm


def _rank_totals(lm) -> Optional[np.ndarray]:
    """Per-rank executed-entry totals over the whole multiply (summed
    across steps; collapsed/union steps charge every rank the shared
    plan's entries).  None when no step executed rank-exactly."""
    fns = getattr(lm, "step_executors", None)
    if fns is None:
        fns = [lm]
    plans = [getattr(f, "executor_plan", None)
             for f in fns if f is not None]
    ranked = [p for p in plans if hasattr(p, "rank_entries")]
    if not ranked:
        return None
    totals = np.zeros(ranked[0].n_ranks, dtype=np.int64)
    for p in plans:
        if p is None:
            continue
        if hasattr(p, "rank_entries"):
            totals += np.asarray(p.rank_entries, dtype=np.int64)
        else:
            totals += int(p.n_entries)
    return totals


def _rank_imbalance_of(totals: Optional[np.ndarray]) -> Optional[float]:
    if totals is None:
        return None
    mean = float(totals.mean())
    return float(totals.max()) / mean if mean > 0 else 1.0


# ---------------------------------------------------------------------------
# schedule observability: per-step comm/compute split
# ---------------------------------------------------------------------------


def _build_meta_schedule(algorithm: str, *, grid, mesh, local_shape,
                         itemsize: int, empty_steps, reduce_kw: dict):
    """Rebuild the executed schedule purely for its host-side metadata
    (building a Schedule traces nothing — see core/schedule.py)."""
    pr, pc = grid.grid_shape(mesh)
    if algorithm == "cannon":
        return build_cannon_schedule(
            pr, row_axis=grid.row_axis, col_axis=grid.col_axis,
            empty_steps=empty_steps, local_shape=local_shape,
            itemsize=itemsize)
    if algorithm == "cannon25d":
        return build_cannon25d_schedule(
            pr, grid.stack_size(mesh), row_axis=grid.row_axis,
            col_axis=grid.col_axis, stack_axis=grid.stack_axis,
            reduce=reduce_kw.get("reduce", "all_reduce"),
            empty_steps=empty_steps, local_shape=local_shape,
            itemsize=itemsize)
    if algorithm == "summa":
        if reduce_kw.get("bcast") == "gather":
            return build_summa_gather_schedule(
                grid.row_axis, grid.col_axis, local_shape=local_shape,
                itemsize=itemsize)
        return build_summa_schedule(
            pr, pc, row_axis=grid.row_axis, col_axis=grid.col_axis,
            empty_steps=empty_steps, local_shape=local_shape,
            itemsize=itemsize)
    axes = ((grid.row_axis, grid.col_axis) if grid.stack_axis is None
            else (grid.stack_axis, grid.row_axis, grid.col_axis))
    return build_ts_schedule(
        algorithm, axes, reduce=reduce_kw.get("reduce", "reduce_scatter"),
        local_shape=local_shape, itemsize=itemsize)


def _schedule_stats(algorithm: str, *, grid, mesh, local_shape, itemsize,
                    lm, densify: bool, pipeline_depth: int,
                    reduce_kw: dict, n_groups: int = 1) -> dict:
    """Per-step comm-vs-compute split of the executed schedule, priced
    with the calibrated hardware constants (host-side observability —
    attached to executed plans as ``schedule_stats`` and emitted as
    schedule-step spans by the telemetry layer).  ``n_groups`` scales
    comm bytes and dense flops for the fused batched dispatch, whose
    every step moves/computes G same-geometry products at once."""
    from repro.planner.calibrate import get_hardware_model

    hw = get_hardware_model()
    empty = getattr(lm, "empty_steps", frozenset())
    sched = _build_meta_schedule(
        algorithm, grid=grid, mesh=mesh, local_shape=local_shape,
        itemsize=itemsize * n_groups, empty_steps=empty,
        reduce_kw=reduce_kw)
    meta = schedule_step_meta(sched)

    ml, kl, nl = local_shape
    dense_flops = 2.0 * ml * kl * nl * n_groups
    step_execs = getattr(lm, "step_executors", None)
    steps = []
    for t in range(meta["n_steps"]):
        comm_bytes = meta["step_comm_bytes"][t]
        plan = None
        if not densify and t not in empty:
            # stepwise executors carry .executor_plan (blocked path) or
            # .batched_plan (fused batched path); both expose
            # n_entries/block_* — enough to price the stack dispatch
            ex = step_execs[t] if step_execs is not None else lm
            plan = (getattr(ex, "executor_plan", None)
                    or getattr(ex, "batched_plan", None))
        if t in empty:
            flops = 0.0
            compute_s = 0.0
        elif plan is not None:
            flops = 2.0 * plan.n_entries * plan.block_m * plan.block_k \
                * plan.block_n
            compute_s = flops / hw.smm_flops_per_s \
                + plan.n_entries * hw.stack_entry_s
        else:
            flops = dense_flops
            compute_s = flops / hw.flops_per_s
        n_dense = getattr(plan, "n_dense_triples", None)
        ranked = plan is not None and hasattr(plan, "rank_entries")
        steps.append({
            "step": t,
            "skipped": t in empty,
            "comm_bytes": comm_bytes,
            "comm_s": comm_bytes / hw.bytes_per_s,
            "flops": flops,
            "compute_s": compute_s,
            "n_entries": None if plan is None else int(plan.n_entries),
            "occupancy": (plan.n_entries / n_dense
                          if plan is not None and n_dense else None),
            # rank-exact steps: the per-rank retained counts behind the
            # busiest-rank n_entries above (None on union/collapsed)
            "rank_entries": (list(map(int, plan.rank_entries))
                             if ranked else None),
            "rank_imbalance": (float(plan.rank_imbalance)
                               if ranked else None),
        })
    comm_s = sum(s["comm_s"] for s in steps)
    compute_s = sum(s["compute_s"] for s in steps)
    # at depth >= 2 the shift/broadcast feeding step t+1 hides behind
    # step t's compute: all but the first step's comm is overlappable
    overlappable = sum(s["comm_s"] for s in steps[:-1]) \
        if meta["algorithm"] in ("cannon", "cannon25d") \
        else sum(s["comm_s"] for s in steps[1:])
    overlap_bound_s = (min(overlappable, compute_s)
                       if pipeline_depth >= 2 and meta["n_steps"] > 1 else 0.0)
    return {
        **meta,
        "pipeline_depth": pipeline_depth,
        "steps": steps,
        "comm_s": comm_s,
        "compute_s": compute_s,
        "prologue_comm_s": meta["prologue_comm_bytes"] / hw.bytes_per_s,
        "epilogue_comm_s": meta["epilogue_comm_bytes"] / hw.bytes_per_s,
        "overlap_bound_s": overlap_bound_s,
    }


def _emit_step_spans(parent, t0: float, total_s: float, ss: dict) -> None:
    """Carve the measured dispatch interval ``[t0, t0+total_s]`` into
    synthetic schedule-step spans (prologue / step[t] {comm, stacks} /
    epilogue), each sized by the cost model's per-step weight from
    ``_schedule_stats`` and scaled so they sum exactly to the measured
    wall time.  The host driver can't time individual shard_map steps
    (one fused device program), so this is the best per-step attribution
    available — attrs carry the *exact* comm-bytes/flops/occupancy."""
    tracer = obs.get_tracer()
    if tracer is None or parent is None or total_s <= 0.0:
        return
    w_pro = ss.get("prologue_comm_s", 0.0)
    w_epi = ss.get("epilogue_comm_s", 0.0)
    steps = ss.get("steps", [])
    w_sum = w_pro + w_epi + sum(s["comm_s"] + s["compute_s"]
                                for s in steps)
    if w_sum <= 0.0:
        return
    scale = total_s / w_sum
    cur = t0
    if w_pro > 0.0:
        tracer.emit("prologue", "comm", t0=cur, dur=w_pro * scale,
                    parent=parent,
                    attrs={"comm_bytes": ss.get("prologue_comm_bytes", 0),
                           "comm_op": ss.get("comm_op")})
        cur += w_pro * scale
    for s in steps:
        sdur = (s["comm_s"] + s["compute_s"]) * scale
        srec = tracer.emit(
            f"step[{s['step']}]", "schedule-step", t0=cur, dur=sdur,
            parent=parent,
            attrs={"step": s["step"], "skipped": s["skipped"],
                   "comm_bytes": s["comm_bytes"], "flops": s["flops"],
                   "occupancy": s.get("occupancy"),
                   "n_entries": s.get("n_entries"),
                   "rank_entries": s.get("rank_entries"),
                   "rank_imbalance": s.get("rank_imbalance")})
        if s["comm_s"] > 0.0:
            tracer.emit("comm", "comm", t0=cur, dur=s["comm_s"] * scale,
                        parent=srec,
                        attrs={"comm_bytes": s["comm_bytes"],
                               "comm_op": ss.get("comm_op")})
        if s["compute_s"] > 0.0:
            tracer.emit("stacks", "compute",
                        t0=cur + s["comm_s"] * scale,
                        dur=s["compute_s"] * scale, parent=srec,
                        attrs={"flops": s["flops"],
                               "occupancy": s.get("occupancy")})
        cur += sdur
    if w_epi > 0.0:
        tracer.emit("epilogue", "comm", t0=cur, dur=w_epi * scale,
                    parent=parent,
                    attrs={"comm_bytes": ss.get("epilogue_comm_bytes", 0),
                           "comm_op": ss.get("comm_op")})


def _verified_result(verify, a, b, c, rerun, *, plan, block_m, block_k,
                     block_n, a_mask, b_mask, a_norms, b_norms, filter_eps,
                     verify_budget, _tele: bool = False):
    """ABFT verification of a raw product (repro.robustness.abft):
    price the checksum overhead against the plan (``verify="auto"``),
    screen the operands with the finite tripwires, apply any installed
    chaos hook (test-only corruption — modelling a soft error between
    compute and verification), then verify / one-shot-repair.  Returns
    ``(c, verification_dict)``; the dict lands on the plan as
    ``plan.verification``."""
    from repro.planner.plan import decide_verify

    m, k = a.shape
    n = b.shape[1]
    itemsize = int(jnp.dtype(jnp.promote_types(a.dtype, b.dtype)).itemsize)
    pricing = decide_verify(plan, m, k, n,
                            blocks=(block_m, block_k, block_n),
                            itemsize=itemsize, budget=verify_budget)
    enabled = verify == "checksum" or (verify == "auto"
                                       and pricing["auto_enabled"])
    if plan is not None and getattr(plan, "trivial", False):
        enabled = False  # empty product: nothing executed to corrupt
    info = {"mode": verify, "enabled": enabled, **pricing, "report": None}
    if not enabled:
        return c, info
    from repro.robustness import abft, chaos, guards

    def _repair_rerun():
        # a detection re-executes the deterministic dispatch once; the
        # repair span makes that second dispatch visible in the trace
        with obs.maybe_span(_tele, "repair", cat="repair"):
            return rerun()

    with obs.maybe_span(_tele, "verify", cat="verify", mode=verify) as vsp:
        guards.assert_finite(a, "A")
        guards.assert_finite(b, "B")
        c = chaos.apply_result_hook(c)
        c, report = abft.verify_and_repair(
            a, b, c, recompute=_repair_rerun,
            block_m=block_m, block_k=block_k, block_n=block_n,
            a_mask=a_mask, b_mask=b_mask, a_norms=a_norms, b_norms=b_norms,
            filter_eps=filter_eps)
        vsp.set(detected=bool(report.detected),
                repaired=bool(report.repaired),
                n_flagged_blocks=len(report.flagged_blocks))
    info["report"] = report
    return jnp.asarray(c), info


def distributed_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec = GridSpec(),
    algorithm: str = "auto",
    densify: Optional[bool] = None,
    block_m: int = 64,
    block_k: int = 64,
    block_n: int = 64,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    local_kernel: Optional[str] = None,
    a_mask: Optional[np.ndarray] = None,
    b_mask: Optional[np.ndarray] = None,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
    filter_eps: Optional[float] = None,
    stack_bins: Optional[int] = None,
    rank_exact: Optional[bool] = None,
    rebalance: Optional[bool] = None,
    precision=jax.lax.Precision.DEFAULT,
    pipeline_depth: Optional[int] = None,
    double_buffer: Optional[bool] = None,
    verify: Optional[str] = None,
    verify_budget: Optional[float] = None,
    return_plan: bool = False,
    **kw,
) -> jax.Array:
    """C = A @ B on the mesh. ``algorithm``:

      auto         — cost-model planner (repro.planner.plan_multiply):
                     cheapest feasible (algorithm, local path) for this
                     (shape, occupancy, mesh)
      cannon       — Cannon's algorithm (square grids)
      cannon25d    — 2.5D Cannon over grid.stack_axis
      ts_k|ts_m|ts_n — tall-and-skinny variants
      summa        — the ScaLAPACK-PDGEMM-style baseline

    ``densify`` picks the local path (True: one big GEMM, False:
    blocked stacks); ``None`` lets the planner decide under ``auto``
    and means True for a fixed algorithm (the legacy default).  For the
    blocked path ``stack_size``/``align`` default to the smm autotune
    winners table for the block geometry and occupancy bin.  ``a_mask``
    / ``b_mask`` are *global* block occupancy masks ((M/block_m,
    K/block_k) / (K/block_k, N/block_n) numpy bool); the blocked path
    then plans only present triples per data-exchange step and skips
    steps whose mask product is empty (see module docstring).  The
    densified path ignores them (absent blocks are zeros, the single
    big GEMM is already correct).

    Norm-based on-the-fly filtering (repro.sparsity): with
    ``filter_eps`` not None, product contributions whose block-norm
    bound ``norm(A_ik) * norm(B_kj)`` falls below eps are dropped
    before they reach a multiplication stack.  ``a_norms`` /
    ``b_norms`` are *global* per-block Frobenius norms (block-grid
    float arrays); when omitted they are computed on the fly from the
    payloads (requires concrete arrays — call outside jit, as with
    ``return_plan``).  Norms ride the same per-shift / per-panel
    slicing machinery as the masks (``cannon_step_norms`` /
    ``summa_step_norms`` / ``ts_step_norms``; SPMD union semantics
    become union-of-max), a step with no retained triple is skipped
    entirely, and the planner's occupancy becomes the norm-predicted
    retained fraction.  ``filter_eps=0.0`` is bit-identical to the
    unfiltered path; the densified local path ignores triple filtering
    (one big GEMM computes everything — filtering there is only the
    caller's post-multiply mask, see dbcsr.multiply).  ``stack_bins``
    caps the stack executor's size-bin count (core/engine.py;
    DBCSR_STACK_BINS env overrides the default 4).

    Rank-exact execution (module docstring): ``rank_exact=None`` (the
    default) runs every masked/filtered blocked multi-rank step from a
    stacked per-rank plan slab — each rank executes exactly its own
    retained triples, selected by ``axis_index`` inside shard_map —
    while ``False`` restores the legacy union-of-ranks plan and
    ``True`` forces per-rank slabs even when auto would collapse.
    Dense and uniform-fill steps collapse to the union executor
    bitwise; with ``filter_eps > 0`` the per-rank norm filter is
    EXACT per rank (the union applies the max norm product over
    ranks, so it under-filters).  ``rebalance`` controls the costed
    block-row/col permutation pass (repro.sparsity.balance): ``None``
    defers to the planner (applied only when the predicted compute
    saved by flattening per-rank load imbalance exceeds the shuffle's
    amortized cost — ``plan.rebalance``), ``True`` forces it,
    ``False`` disables it.  The permutation touches only block rows of
    A/C and block cols of B/C (never K: that would reorder every C
    block's accumulation), and is inverted on C before returning.

    ``pipeline_depth`` (core/schedule.py): 2 = double-buffered
    comm/compute overlap, 1 = serial (bit-identical output), 0 = rolled
    fori_loop ablation; ``None`` takes the plan's depth under ``auto``
    and the overlap default otherwise.  ``double_buffer`` is the legacy
    spelling (True -> 2, False -> 0).

    ``verify`` — ABFT self-verification (repro.robustness.abft):
    ``"checksum"`` verifies the product against independently computed
    Huang–Abraham block checksums (norm-aware tolerances so eps
    filtering and float accumulation never false-positive), localizes
    any corrupted block, and repairs it by one deterministic recompute
    of the flagged blocks; ``"auto"`` enables verification only when
    its priced overhead fits ``verify_budget`` (default 25%) of the
    plan's predicted time; ``None`` (default) is bit-identical to the
    pre-verification dispatcher with zero added work.  The outcome
    lands on the returned plan as ``plan.verification`` (pricing +
    :class:`~repro.robustness.abft.VerificationReport`).  Unrepairable
    corruption raises
    :class:`~repro.robustness.guards.CorruptionDetectedError`.

    ``return_plan=True`` returns ``(C, MultiplyPlan)`` where the plan
    records the planner's decision (with per-candidate predicted costs,
    see ``MultiplyPlan.explain()``) plus the executed blocked-path
    stack statistics (``executor_stats``) and the per-step comm/compute
    split of the executed schedule (``schedule_stats``).  Only usable
    outside jit — the plan is a host-side object.

    Telemetry (repro.obs): with ``obs.enable()`` active — and only
    then — the call records a ``multiply`` span nesting plan ->
    dispatch -> schedule-step -> comm/stacks (plus verify -> repair)
    and logs the plan's predicted-vs-measured cost for the planner
    scoreboard.  Disabled (the default) or under ``jax.jit`` tracing
    this wrapper adds one boolean check and the output is bit
    identical.
    """
    tele = obs.enabled() and not (isinstance(a, jax.core.Tracer)
                                  or isinstance(b, jax.core.Tracer))
    call = dict(
        mesh=mesh, grid=grid, algorithm=algorithm, densify=densify,
        block_m=block_m, block_k=block_k, block_n=block_n,
        stack_size=stack_size, align=align, local_kernel=local_kernel,
        a_mask=a_mask, b_mask=b_mask, a_norms=a_norms, b_norms=b_norms,
        filter_eps=filter_eps, stack_bins=stack_bins,
        rank_exact=rank_exact, rebalance=rebalance, precision=precision,
        pipeline_depth=pipeline_depth, double_buffer=double_buffer,
        verify=verify, verify_budget=verify_budget,
        return_plan=return_plan, **kw)
    if not tele:
        return _distributed_matmul(a, b, **call)
    attrs = {"algorithm": algorithm}
    if getattr(a, "ndim", 0) == 2 and getattr(b, "ndim", 0) == 2:
        attrs.update(m=int(a.shape[0]), k=int(a.shape[1]),
                     n=int(b.shape[1]))
    with obs.span("multiply", cat="multiply", **attrs):
        return _distributed_matmul(a, b, _tele=True, **call)


def _distributed_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec = GridSpec(),
    algorithm: str = "auto",
    densify: Optional[bool] = None,
    block_m: int = 64,
    block_k: int = 64,
    block_n: int = 64,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    local_kernel: Optional[str] = None,
    a_mask: Optional[np.ndarray] = None,
    b_mask: Optional[np.ndarray] = None,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
    filter_eps: Optional[float] = None,
    stack_bins: Optional[int] = None,
    rank_exact: Optional[bool] = None,
    rebalance: Optional[bool] = None,
    precision=jax.lax.Precision.DEFAULT,
    pipeline_depth: Optional[int] = None,
    double_buffer: Optional[bool] = None,
    verify: Optional[str] = None,
    verify_budget: Optional[float] = None,
    return_plan: bool = False,
    _tele: bool = False,
    **kw,
) -> jax.Array:
    """``distributed_matmul`` body (see its docstring); ``_tele`` is
    the per-call telemetry flag resolved by the public wrapper
    (False when telemetry is disabled or under jit tracing)."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims disagree: {a.shape} @ {b.shape}")
    if verify not in (None, "checksum", "auto"):
        raise ValueError(
            f"verify must be None, 'checksum' or 'auto', got {verify!r}")

    filtering = filter_eps is not None
    if filtering and a_norms is None and b_norms is None:
        # on-the-fly: derive the block norms from the payloads (one
        # blockwise reduction each; masked so absent blocks report 0)
        from repro.sparsity.norms import block_norms_of

        a_norms = block_norms_of(a, block_m, block_k, a_mask)
        b_norms = block_norms_of(b, block_k, block_n, b_mask)

    # ---- global mask/norm normalisation + rank-exact resolution -------
    # (hoisted above planning: the per-rank load imbalance of the
    # C-chunk decomposition feeds the planner's rank-exact pricing and
    # its costed rebalance decision)
    pr0, pc0 = grid.grid_shape(mesh)
    n_ranks_all = pr0 * pc0 * (1 if grid.stack_axis is None
                               else grid.stack_size(mesh))
    masked = a_mask is not None or b_mask is not None or filtering
    am = bmk = an_g = bn_g = None
    if masked:
        am, bmk = _block_masks(m, k, n, block_m, block_k, block_n,
                               a_mask, b_mask)
        if filtering:
            # norms ride the same slicing machinery as the masks;
            # mask-absent blocks are forced to norm 0 so one >= eps
            # comparison folds both criteria per rank
            from repro.sparsity.norms import normalize_block_norms

            an_g, bn_g = normalize_block_norms(
                am.shape[0], am.shape[1], bmk.shape[1], a_norms, b_norms)
            an_g = np.where(am, an_g, np.float32(0.0))
            bn_g = np.where(bmk, bn_g, np.float32(0.0))
    use_rank = rank_exact is not False and masked and n_ranks_all > 1
    rank_imb = None
    if use_rank and am.shape[0] % pr0 == 0 and bmk.shape[1] % pc0 == 0:
        from repro.sparsity.balance import (chunk_imbalance,
                                            retained_block_weights)

        rank_imb = chunk_imbalance(
            retained_block_weights(am, bmk, an_g, bn_g, filter_eps),
            pr0, pc0)

    plan = None
    # telemetry forces a plan even for pinned algorithms: the planner
    # scoreboard needs predicted_s for every executed plan
    if algorithm == "auto" or return_plan or verify is not None or _tele:
        from repro.planner.plan import plan_multiply

        with obs.maybe_span(_tele, "plan", cat="plan") as psp:
            mesh_shape = ((pr0, pc0) if grid.stack_axis is None
                          else (pr0, pc0, grid.stack_size(mesh)))
            occ = _global_occupancy(m, k, n, block_m, block_k, block_n,
                                    a_mask, b_mask, a_norms, b_norms,
                                    filter_eps)
            # a pinned summa with the PUMMA broadcast prices through the
            # planner's "summa_gather" model — full-K gathered panels,
            # whose sqrt(P)-fold operand replication the mem feasibility
            # gate must see (auto never enumerates it; only this pin
            # reaches it)
            plan_algorithm = None if algorithm == "auto" else algorithm
            if algorithm == "summa" and kw.get("bcast") == "gather":
                plan_algorithm = "summa_gather"
            plan = plan_multiply(
                m, k, n, blocks=(block_m, block_k, block_n),
                mesh_shape=mesh_shape, occupancy=occ,
                dtype=jnp.promote_types(a.dtype, b.dtype),
                algorithm=plan_algorithm,
                # a fixed algorithm executes the legacy densified
                # default when densify is unset — the plan must describe
                # that, not the planner's own local-path preference
                densify=(densify
                         if algorithm == "auto" or densify is not None
                         else True),
                stack_size=stack_size, align=align,
                rank_imbalance=rank_imb)
            if algorithm == "auto":
                algorithm = plan.algorithm
                if densify is None:
                    densify = plan.densify
                if not densify:
                    if stack_size is None:
                        stack_size = plan.stack_tile
                    if align is None:
                        align = plan.align
                if pipeline_depth is None and double_buffer is None:
                    pipeline_depth = plan.pipeline_depth
            psp.set(algorithm=plan.algorithm, densify=bool(plan.densify),
                    predicted_s=float(plan.predicted_s),
                    occupancy=float(plan.occupancy),
                    trivial=bool(plan.trivial))
    if densify is None:
        densify = True  # legacy default for fixed algorithms
    if algorithm not in ("cannon", "cannon25d", "ts_k", "ts_m", "ts_n",
                        "summa"):
        raise ValueError(f"unknown algorithm {algorithm!r}")
    depth = resolve_pipeline_depth(pipeline_depth, double_buffer)

    # ---- costed rebalance: permute the block distribution -------------
    # The planner arms this only when predicted compute saved by
    # flattening per-rank load imbalance exceeds the shuffle's amortized
    # cost (plan.rebalance); ``rebalance=True/False`` overrides.  Only
    # block rows of A/C and block cols of B/C move — K stays identity so
    # every C block keeps its accumulation order — and the inverse
    # permutation is applied to C inside the re-runnable dispatch
    # closure (ABFT repair re-executions stay self-consistent).
    rb = None
    do_rebalance = (rebalance if rebalance is not None
                    else plan is not None and plan.rebalance)
    if (do_rebalance and not densify and use_rank
            and am.shape[0] % pr0 == 0 and bmk.shape[1] % pc0 == 0):
        from repro.sparsity.balance import plan_rebalance

        cand = plan_rebalance(am, bmk, pr0, pc0, a_norms=an_g,
                              b_norms=bn_g, filter_eps=filter_eps)
        if not cand.identity:
            rb = cand
    a_exec, b_exec = a, b
    if rb is not None:
        from repro.sparsity.balance import (permute_block_cols,
                                            permute_block_rows)

        pm_idx, pn_idx = np.asarray(rb.perm_m), np.asarray(rb.perm_n)
        a_exec = permute_block_rows(a, rb.perm_m, block_m)
        b_exec = permute_block_cols(b, rb.perm_n, block_n)
        am = am[pm_idx]
        bmk = bmk[:, pn_idx]
        if an_g is not None:
            an_g = an_g[pm_idx]
        if bn_g is not None:
            bn_g = bn_g[:, pn_idx]
        obs.counter("planner.rebalance.applied").inc()

    # ---- local multiply geometry (per schedule step) ------------------
    pr, pc = grid.grid_shape(mesh)
    pg = p_all = n_panels = None
    if algorithm.startswith("ts_"):
        p_all = pr * pc * grid.stack_size(mesh)
        shapes = {
            "ts_k": (m, k // p_all, n),
            "ts_m": (m // p_all, k, n),
            "ts_n": (m, k, n // p_all),
        }
        ml, kl, nl = shapes[algorithm]
    elif algorithm in ("cannon", "cannon25d"):
        # Local multiply is (m/pg, k/pg) @ (k/pg, n/pg) on the square
        # grid Cannon requires.  Deriving the inner dim from pc alone
        # (the old ``k // pc``) silently mis-sized B's stack-plan
        # geometry whenever pr != pc: gathers clamp out-of-range
        # block indices instead of failing, producing wrong C.
        pg = grid.validate_square(mesh)
        if (m % pg or k % pg or n % pg) and not densify:
            raise ValueError(
                f"shape ({m},{k},{n}) not divisible by grid side {pg}")
        ml, kl, nl = m // pg, k // pg, n // pg
    elif kw.get("bcast") == "gather":
        # PUMMA-style broadcast: the local multiply sees the
        # all-gathered full-K row of A / column of B — a single
        # stack-plan geometry on any grid shape.
        if (m % pr or n % pc) and not densify:
            raise ValueError(
                f"shape ({m},{n}) not divisible by grid {pr}x{pc}")
        ml, kl, nl = m // pr, k, n // pc
    else:
        # summa psum: every panel's local multiply is
        # (m/pr, k/n_panels) @ (k/n_panels, n/pc) — one per-panel
        # stack-plan geometry shared by all panels, so non-square
        # grids are fine (for square grids k/n_panels == k/pc, the
        # historical full-local-K geometry).
        n_panels = summa_n_panels(pr, pc)
        if (m % pr or n % pc or k % n_panels) and not densify:
            raise ValueError(
                f"shape ({m},{k},{n}) not divisible by summa grid "
                f"{pr}x{pc} with {n_panels} panels")
        ml, kl, nl = m // pr, k // n_panels, n // pc

    # ---- local multiply strategy (densified vs blocked) --------------
    if densify:
        lm = densified_local_matmul(precision, kernel=local_kernel)
    else:
        blocked_kw = dict(
            block_m=block_m, block_k=block_k, block_n=block_n,
            stack_size=stack_size, align=align,
            kernel=local_kernel or "smm", stack_bins=stack_bins)
        if not masked:
            lm = blocked_local_matmul(ml, kl, nl, **blocked_kw)
        elif algorithm in ("cannon", "cannon25d"):
            c_repl = (grid.stack_size(mesh)
                      if algorithm == "cannon25d" else 1)
            if use_rank:
                lm = _stepwise_rank_blocked_lm(
                    ml, kl, nl,
                    rank_steps=cannon_rank_steps(
                        am, bmk, pg, c_repl, a_norms=an_g, b_norms=bn_g),
                    rank_index_fn=_rank_index_fn(algorithm, grid, mesh),
                    filter_eps=filter_eps, **blocked_kw)
            else:
                steps = [{"pair_mask": pm}
                         for pm in cannon_step_masks(am, bmk, pg, c_repl)]
                if filtering:
                    for s, pn in zip(steps, cannon_step_norms(
                            an_g, bn_g, pg, c_repl)):
                        s.update(pair_norms=pn, filter_eps=filter_eps)
                lm = _stepwise_blocked_lm(ml, kl, nl, mask_steps=steps,
                                          **blocked_kw)
        elif algorithm == "summa" and kw.get("bcast") != "gather":
            if use_rank:
                lm = _stepwise_rank_blocked_lm(
                    ml, kl, nl,
                    rank_steps=summa_rank_steps(
                        am, bmk, pr, pc, n_panels,
                        a_norms=an_g, b_norms=bn_g),
                    rank_index_fn=_rank_index_fn(algorithm, grid, mesh),
                    filter_eps=filter_eps, **blocked_kw)
            else:
                steps = [{"a_mask": ua, "b_mask": ub} for ua, ub in
                         summa_step_masks(am, bmk, pr, pc, n_panels)]
                if filtering:
                    for s, (una, unb) in zip(steps, summa_step_norms(
                            an_g, bn_g, pr, pc, n_panels)):
                        s.update(a_norms=una, b_norms=unb,
                                 filter_eps=filter_eps)
                lm = _stepwise_blocked_lm(ml, kl, nl, mask_steps=steps,
                                          **blocked_kw)
        elif algorithm == "summa":
            if use_rank:
                lm = _single_rank_lm(
                    ml, kl, nl,
                    rank_kwargs=summa_gather_rank_steps(
                        am, bmk, pr, pc, a_norms=an_g, b_norms=bn_g),
                    rank_index_fn=_rank_index_fn(algorithm, grid, mesh),
                    filter_eps=filter_eps, **blocked_kw)
            else:
                ua, ub = summa_gather_masks(am, bmk, pr, pc)
                norm_kw = {}
                if filtering:
                    una, unb = summa_gather_norms(an_g, bn_g, pr, pc)
                    norm_kw = dict(a_norms=una, b_norms=unb,
                                   filter_eps=filter_eps)
                lm = blocked_local_matmul(ml, kl, nl, a_mask=ua, b_mask=ub,
                                          **norm_kw, **blocked_kw)
        else:
            if use_rank:
                lm = _single_rank_lm(
                    ml, kl, nl,
                    rank_kwargs=ts_rank_steps(
                        algorithm, am, bmk, p_all,
                        a_norms=an_g, b_norms=bn_g),
                    rank_index_fn=_rank_index_fn(algorithm, grid, mesh),
                    filter_eps=filter_eps, **blocked_kw)
            else:
                norm_kw = {}
                if filtering:
                    norm_kw = dict(ts_step_norms(algorithm, an_g, bn_g,
                                                 p_all),
                                   filter_eps=filter_eps)
                lm = blocked_local_matmul(
                    ml, kl, nl, **ts_step_masks(algorithm, am, bmk, p_all),
                    **norm_kw, **blocked_kw)
    if not densify and obs.enabled():
        imb = _rank_imbalance_of(_rank_totals(lm))
        if imb is not None:
            obs.histogram("executor.rank_imbalance").observe(imb)

    # ---- data-exchange algorithm (all via the schedule engine) --------
    # The dispatch is wrapped in a re-runnable closure: at a fixed
    # config the whole pipeline is deterministic, so the ABFT repair
    # path re-executes it once and splices only the flagged blocks —
    # bitwise equal to a clean run.
    def _run():
        if algorithm == "cannon":
            c = cannon_matmul(
                a_exec, b_exec, mesh=mesh, grid=grid, local_matmul=lm,
                precision=precision, pipeline_depth=depth, **kw)
        elif algorithm == "cannon25d":
            c = cannon25d_matmul(
                a_exec, b_exec, mesh=mesh, grid=grid, local_matmul=lm,
                precision=precision, pipeline_depth=depth, **kw)
        elif algorithm in ("ts_k", "ts_m", "ts_n"):
            c = tall_skinny_matmul(
                a_exec, b_exec, mesh=mesh, grid=grid, mode=algorithm,
                local_matmul=lm, precision=precision, pipeline_depth=depth,
                **kw)
        else:
            c = summa_matmul(
                a_exec, b_exec, mesh=mesh, grid=grid, local_matmul=lm,
                precision=precision, pipeline_depth=depth, **kw)
        if rb is not None:
            from repro.sparsity.balance import (permute_block_cols,
                                                permute_block_rows)

            c = permute_block_rows(c, rb.inv_m, block_m)
            c = permute_block_cols(c, rb.inv_n, block_n)
        return c

    sched_stats_cache = [None]

    def _sched_stats():
        if sched_stats_cache[0] is None:
            itemsize = int(jnp.dtype(
                jnp.promote_types(a.dtype, b.dtype)).itemsize)
            sched_stats_cache[0] = _schedule_stats(
                algorithm, grid=grid, mesh=mesh, local_shape=(ml, kl, nl),
                itemsize=itemsize, lm=lm, densify=densify,
                pipeline_depth=depth, reduce_kw=kw)
        return sched_stats_cache[0]

    dispatch_times: List[float] = []

    def _run_traced():
        # telemetry off: exactly the legacy path — no timing, no sync
        if not _tele:
            return _run()
        with obs.span("dispatch", cat="dispatch", algorithm=algorithm,
                      densify=bool(densify), pipeline_depth=depth) as dsp:
            t0 = time.perf_counter()
            c = jax.block_until_ready(_run())
            dt = time.perf_counter() - t0
        dispatch_times.append(dt)
        try:
            ss = _sched_stats()
        except Exception:
            ss = None  # telemetry must never break the multiply
        if ss is not None:
            dsp.set(comm_bytes=int(ss.get("total_comm_bytes", 0)))
            _emit_step_spans(dsp.rec, t0, dt, ss)
        return c

    c = _run_traced()
    verification = None
    if verify is not None:
        c, verification = _verified_result(
            verify, a, b, c, _run_traced, plan=plan,
            block_m=block_m, block_k=block_k, block_n=block_n,
            a_mask=a_mask, b_mask=b_mask, a_norms=a_norms, b_norms=b_norms,
            filter_eps=filter_eps, verify_budget=verify_budget,
            _tele=_tele)
    if _tele and plan is not None and not plan.trivial and dispatch_times:
        # predicted-vs-actual planner accounting: first dispatch is the
        # clean run (a repair re-execution would re-measure the same
        # deterministic program)
        obs.record_plan_outcome(
            kind="multiply", algorithm=algorithm, densify=bool(densify),
            m=m, k=k, n=n, occupancy=float(plan.occupancy),
            predicted_s=float(plan.predicted_s),
            measured_s=float(dispatch_times[0]),
            pipeline_depth=int(depth))
    if not return_plan:
        return c
    import dataclasses as _dc

    es = _collect_executor_stats(lm, densify)
    if es is not None:
        es["rebalance_applied"] = rb is not None
        if rb is not None:
            es["rebalance_method"] = rb.method
            es["rebalance_imbalance_before"] = rb.imbalance_before
            es["rebalance_imbalance_after"] = rb.imbalance_after
    plan = _dc.replace(
        plan,
        executor_stats=es,
        schedule_stats=_sched_stats(),
        verification=verification)
    return c, plan
