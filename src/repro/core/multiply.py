"""Top-level distributed multiply dispatcher.

Implements DBCSR's algorithm selection (paper section II): with
``algorithm="auto"`` (the default) the cost-model planner
(repro.planner.plan_multiply) evaluates every feasible candidate —
Cannon / SUMMA / 2.5D Cannon / the tall-and-skinny variants, each with
a densified or blocked local path — against calibrated hardware
constants and picks the cheapest, which is the paper's driver
behaviour (the "different sizes and shapes" headline).  A fixed
``algorithm=`` string bypasses the planner entirely.  The local
multiply is either 'densified' (one big GEMM — the paper's section III
optimization) or 'blocked' (stack-of-small-GEMMs via the smm kernel);
``densify=None`` leaves that choice to the planner too.

Occupancy threading (blocked path): ``a_mask`` / ``b_mask`` are the
*global* block-occupancy masks of the operands (host-side numpy bool).
For every data-exchange step of the chosen algorithm — each cannon
shift, each summa panel — this module slices the global masks down to
the block ranges every mesh rank holds at that step and unions them
over ranks (shard_map traces ONE program for all devices, so the
per-step plan must cover every rank's present triples; the union is
the tightest SPMD-uniform plan).  Plans are memoized per shifted-mask
content fingerprint (core/engine.py), and a step whose unioned mask
product is empty skips its ``execute_plan`` — and for summa, the panel
broadcast — entirely.  The densified path ignores the masks: absent
blocks are stored as zeros, so one big GEMM is already correct.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocking import GridSpec
from .cannon import cannon_matmul
from .cannon25d import cannon25d_matmul
from .densify import blocked_local_matmul, densified_local_matmul
from .stacks import normalize_block_masks
from .summa import summa_matmul, summa_n_panels
from .tall_skinny import tall_skinny_matmul

__all__ = ["distributed_matmul"]


# ---------------------------------------------------------------------------
# occupancy-mask slicing: global block masks -> per-step local plans
# ---------------------------------------------------------------------------


def _block_masks(
    m: int, k: int, n: int,
    block_m: int, block_k: int, block_n: int,
    a_mask: Optional[np.ndarray], b_mask: Optional[np.ndarray],
) -> Tuple[np.ndarray, np.ndarray]:
    """Normalise the *global* occupancy masks; a missing mask means the
    operand is dense (all blocks present)."""
    return normalize_block_masks(m // block_m, k // block_k, n // block_n,
                                 a_mask, b_mask)


def _cannon_pair_masks(
    am: np.ndarray, bm: np.ndarray, pg: int, c_repl: int = 1,
) -> List[np.ndarray]:
    """Per-shift-step local pair-presence tensors for (2.5D) Cannon.

    At inner step t, device (i, j) of replica p holds the A chunk
    (i, q) and B chunk (q, j) with q = (i + j + p*spr + t) % pg.  The
    returned (nbr_l, nbk_l, nbc_l) tensor for step t is the union over
    all (p, i, j) of that rank's chunk-product presence — the tightest
    plan every rank can share under SPMD.  Block-structured sparsity
    (banded / block-diagonal operands) makes whole steps empty here,
    which cannon_local_steps then skips.
    """
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if nbr % pg or nbk % pg or nbc % pg:
        raise ValueError(
            f"block grid ({nbr},{nbk},{nbc}) not divisible by cannon grid "
            f"side {pg}")
    if c_repl < 1 or pg % c_repl:
        raise ValueError(f"grid side {pg} not divisible by replication {c_repl}")
    lr, lk, lc = nbr // pg, nbk // pg, nbc // pg
    spr = pg // c_repl  # shift steps each replica executes
    out = []
    for t in range(spr):
        pair = np.zeros((lr, lk, lc), dtype=bool)
        for p in range(c_repl):
            off = t + p * spr
            for i in range(pg):
                for j in range(pg):
                    q = (i + j + off) % pg
                    ac = am[i * lr:(i + 1) * lr, q * lk:(q + 1) * lk]
                    if not ac.any():
                        continue
                    bc = bm[q * lk:(q + 1) * lk, j * lc:(j + 1) * lc]
                    pair |= ac[:, :, None] & bc[None, :, :]
        out.append(pair)
    return out


def _summa_panel_masks(
    am: np.ndarray, bm: np.ndarray, pr: int, pc: int, n_panels: int,
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Per-panel (a_mask, b_mask) unions for psum-broadcast SUMMA.

    Panel p covers the global K block range [p*nbk/n_panels, ...); the
    A-side union runs over the pr row chunks, the B-side over the pc
    column chunks.  Because the row and column ranks vary independently,
    the union of per-rank products equals the product of the factored
    unions — no 3D pair tensor needed.
    """
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if nbr % pr or nbc % pc or nbk % n_panels:
        raise ValueError(
            f"block grid ({nbr},{nbk},{nbc}) not divisible by summa grid "
            f"{pr}x{pc} with {n_panels} panels")
    lr, lc, lkp = nbr // pr, nbc // pc, nbk // n_panels
    out = []
    for p in range(n_panels):
        ksl = slice(p * lkp, (p + 1) * lkp)
        ua = np.zeros((lr, lkp), dtype=bool)
        for i in range(pr):
            ua |= am[i * lr:(i + 1) * lr, ksl]
        ub = np.zeros((lkp, lc), dtype=bool)
        for j in range(pc):
            ub |= bm[ksl, j * lc:(j + 1) * lc]
        out.append((ua, ub))
    return out


def _summa_gather_masks(
    am: np.ndarray, bm: np.ndarray, pr: int, pc: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Factored unions for PUMMA-style (all-gather) SUMMA: the local
    multiply sees the full K extent, so there is a single step whose A
    mask unions over row chunks and B mask over column chunks."""
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if nbr % pr or nbc % pc:
        raise ValueError(
            f"block grid ({nbr},{nbc}) not divisible by grid {pr}x{pc}")
    lr, lc = nbr // pr, nbc // pc
    ua = np.zeros((lr, nbk), dtype=bool)
    for i in range(pr):
        ua |= am[i * lr:(i + 1) * lr]
    ub = np.zeros((nbk, lc), dtype=bool)
    for j in range(pc):
        ub |= bm[:, j * lc:(j + 1) * lc]
    return ua, ub


def _ts_masks(algorithm: str, am: np.ndarray, bm: np.ndarray,
              p_all: int) -> dict:
    """Single-step mask kwargs for the tall-and-skinny variants (the
    contraction/tall dimension is sharded over all p_all devices)."""
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if algorithm == "ts_k":
        if nbk % p_all:
            raise ValueError(f"K block grid {nbk} not divisible by {p_all}")
        lk = nbk // p_all
        pair = np.zeros((nbr, lk, nbc), dtype=bool)
        for d in range(p_all):
            ac = am[:, d * lk:(d + 1) * lk]
            if not ac.any():
                continue
            bc = bm[d * lk:(d + 1) * lk, :]
            pair |= ac[:, :, None] & bc[None, :, :]
        return {"pair_mask": pair}
    if algorithm == "ts_m":
        if nbr % p_all:
            raise ValueError(f"M block grid {nbr} not divisible by {p_all}")
        lr = nbr // p_all
        ua = np.zeros((lr, nbk), dtype=bool)
        for d in range(p_all):
            ua |= am[d * lr:(d + 1) * lr]
        return {"a_mask": ua, "b_mask": bm}
    if nbc % p_all:
        raise ValueError(f"N block grid {nbc} not divisible by {p_all}")
    lc = nbc // p_all
    ub = np.zeros((nbk, lc), dtype=bool)
    for d in range(p_all):
        ub |= bm[:, d * lc:(d + 1) * lc]
    return {"a_mask": am, "b_mask": ub}


def _masks_empty(mask_kwargs: dict) -> bool:
    if "pair_mask" in mask_kwargs:
        return not mask_kwargs["pair_mask"].any()
    ua, ub = mask_kwargs["a_mask"], mask_kwargs["b_mask"]
    return not bool(np.any(ua.any(axis=0) & ub.any(axis=1)))


def _global_occupancy(
    m: int, k: int, n: int,
    block_m: int, block_k: int, block_n: int,
    a_mask: Optional[np.ndarray], b_mask: Optional[np.ndarray],
) -> float:
    """Present-triple fraction of the global dense triple grid — the
    occupancy the planner discounts blocked-path flops by.  An empty
    mask product returns 0.0, which the planner short-circuits to a
    trivial plan (the same contract as ``_masks_empty`` per step: the
    blocked cost model must never divide by zero occupancy)."""
    if a_mask is None and b_mask is None:
        return 1.0
    from .engine import _mask_fill

    return _mask_fill(m // block_m, k // block_k, n // block_n,
                      a_mask, b_mask, None)


def _collect_executor_stats(lm, densify: bool) -> Optional[dict]:
    """Executed-plan stack statistics for plan observability
    (dbcsr.multiply exposes these as ``last_plan.executor_stats``)."""
    if densify:
        return None
    if getattr(lm, "stepwise", False):
        ex = [f.executor_plan for f in lm.step_executors if f is not None]
        n_entries = sum(p.n_entries for p in ex)
        n_dense = sum(p.n_dense_triples for p in ex)
        return {
            "n_steps": len(lm.step_executors),
            "n_empty_steps": len(lm.empty_steps),
            "n_entries": n_entries,
            "n_dense_triples": n_dense,
            "n_skipped_triples": n_dense - n_entries,
            "occupancy": n_entries / n_dense if n_dense else 1.0,
        }
    plan = getattr(lm, "executor_plan", None)
    return None if plan is None else plan.stats()


def _stepwise_blocked_lm(
    ml: int, kl: int, nl: int, *, mask_steps: List[dict], **blocked_kw,
):
    """A stepwise local multiply: one fused stack executor per data-
    exchange step (plans deduplicated by mask fingerprint through the
    engine memo).  Steps whose mask product is empty carry no executor;
    callers (cannon_local_steps / summa_matmul) skip them host-side.
    """
    fns, empty = [], set()
    for t, mask_kwargs in enumerate(mask_steps):
        if _masks_empty(mask_kwargs):
            fns.append(None)
            empty.add(t)
        else:
            fns.append(blocked_local_matmul(ml, kl, nl, **mask_kwargs,
                                            **blocked_kw))

    def lm(a_loc: jax.Array, b_loc: jax.Array, step: int = 0):
        f = fns[step]
        return None if f is None else f(a_loc, b_loc)

    lm.stepwise = True
    lm.empty_steps = frozenset(empty)
    lm.step_executors = fns
    return lm


def distributed_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec = GridSpec(),
    algorithm: str = "auto",
    densify: Optional[bool] = None,
    block_m: int = 64,
    block_k: int = 64,
    block_n: int = 64,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    local_kernel: Optional[str] = None,
    a_mask: Optional[np.ndarray] = None,
    b_mask: Optional[np.ndarray] = None,
    precision=jax.lax.Precision.DEFAULT,
    double_buffer: bool = True,
    return_plan: bool = False,
    **kw,
) -> jax.Array:
    """C = A @ B on the mesh. ``algorithm``:

      auto         — cost-model planner (repro.planner.plan_multiply):
                     cheapest feasible (algorithm, local path) for this
                     (shape, occupancy, mesh)
      cannon       — Cannon's algorithm (square grids)
      cannon25d    — 2.5D Cannon over grid.stack_axis
      ts_k|ts_m|ts_n — tall-and-skinny variants
      summa        — the ScaLAPACK-PDGEMM-style baseline

    ``densify`` picks the local path (True: one big GEMM, False:
    blocked stacks); ``None`` lets the planner decide under ``auto``
    and means True for a fixed algorithm (the legacy default).  For the
    blocked path ``stack_size``/``align`` default to the smm autotune
    winners table for the block geometry and occupancy bin.  ``a_mask``
    / ``b_mask`` are *global* block occupancy masks ((M/block_m,
    K/block_k) / (K/block_k, N/block_n) numpy bool); the blocked path
    then plans only present triples per data-exchange step and skips
    steps whose mask product is empty (see module docstring).  The
    densified path ignores them (absent blocks are zeros, the single
    big GEMM is already correct).

    ``return_plan=True`` returns ``(C, MultiplyPlan)`` where the plan
    records the planner's decision (with per-candidate predicted costs,
    see ``MultiplyPlan.explain()``) plus the executed blocked-path
    stack statistics (``executor_stats``).  Only usable outside jit —
    the plan is a host-side object.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims disagree: {a.shape} @ {b.shape}")

    plan = None
    if algorithm == "auto" or return_plan:
        from repro.planner.plan import plan_multiply

        pr0, pc0 = grid.grid_shape(mesh)
        mesh_shape = ((pr0, pc0) if grid.stack_axis is None
                      else (pr0, pc0, grid.stack_size(mesh)))
        occ = _global_occupancy(m, k, n, block_m, block_k, block_n,
                                a_mask, b_mask)
        plan = plan_multiply(
            m, k, n, blocks=(block_m, block_k, block_n),
            mesh_shape=mesh_shape, occupancy=occ,
            dtype=jnp.promote_types(a.dtype, b.dtype),
            algorithm=None if algorithm == "auto" else algorithm,
            # a fixed algorithm executes the legacy densified default
            # when densify is unset — the plan must describe that, not
            # the planner's own local-path preference
            densify=(densify if algorithm == "auto" or densify is not None
                     else True),
            stack_size=stack_size, align=align)
        if algorithm == "auto":
            algorithm = plan.algorithm
            if densify is None:
                densify = plan.densify
            if not densify:
                if stack_size is None:
                    stack_size = plan.stack_tile
                if align is None:
                    align = plan.align
    if densify is None:
        densify = True  # legacy default for fixed algorithms
    if algorithm not in ("cannon", "cannon25d", "ts_k", "ts_m", "ts_n",
                        "summa"):
        raise ValueError(f"unknown algorithm {algorithm!r}")

    # ---- local multiply strategy (densified vs blocked) --------------
    if densify:
        lm = densified_local_matmul(precision, kernel=local_kernel)
    else:
        pr, pc = grid.grid_shape(mesh)
        pg = p_all = n_panels = None
        if algorithm.startswith("ts_"):
            p_all = pr * pc * grid.stack_size(mesh)
            shapes = {
                "ts_k": (m, k // p_all, n),
                "ts_m": (m // p_all, k, n),
                "ts_n": (m, k, n // p_all),
            }
            ml, kl, nl = shapes[algorithm]
        elif algorithm in ("cannon", "cannon25d"):
            # Local multiply is (m/pg, k/pg) @ (k/pg, n/pg) on the square
            # grid Cannon requires.  Deriving the inner dim from pc alone
            # (the old ``k // pc``) silently mis-sized B's stack-plan
            # geometry whenever pr != pc: gathers clamp out-of-range
            # block indices instead of failing, producing wrong C.
            pg = grid.validate_square(mesh)
            if m % pg or k % pg or n % pg:
                raise ValueError(
                    f"shape ({m},{k},{n}) not divisible by grid side {pg}")
            ml, kl, nl = m // pg, k // pg, n // pg
        elif kw.get("bcast") == "gather":
            # PUMMA-style broadcast: the local multiply sees the
            # all-gathered full-K row of A / column of B — a single
            # stack-plan geometry on any grid shape.
            if m % pr or n % pc:
                raise ValueError(
                    f"shape ({m},{n}) not divisible by grid {pr}x{pc}")
            ml, kl, nl = m // pr, k, n // pc
        else:
            # summa psum: every panel's local multiply is
            # (m/pr, k/n_panels) @ (k/n_panels, n/pc) — one per-panel
            # stack-plan geometry shared by all panels, so non-square
            # grids are fine (for square grids k/n_panels == k/pc, the
            # historical full-local-K geometry).
            n_panels = summa_n_panels(pr, pc)
            if m % pr or n % pc or k % n_panels:
                raise ValueError(
                    f"shape ({m},{k},{n}) not divisible by summa grid "
                    f"{pr}x{pc} with {n_panels} panels")
            ml, kl, nl = m // pr, k // n_panels, n // pc

        blocked_kw = dict(
            block_m=block_m, block_k=block_k, block_n=block_n,
            stack_size=stack_size, align=align,
            kernel=local_kernel or "smm")
        if a_mask is None and b_mask is None:
            lm = blocked_local_matmul(ml, kl, nl, **blocked_kw)
        else:
            am, bmk = _block_masks(m, k, n, block_m, block_k, block_n,
                                   a_mask, b_mask)
            if algorithm in ("cannon", "cannon25d"):
                c_repl = (grid.stack_size(mesh)
                          if algorithm == "cannon25d" else 1)
                steps = [{"pair_mask": pm}
                         for pm in _cannon_pair_masks(am, bmk, pg, c_repl)]
                lm = _stepwise_blocked_lm(ml, kl, nl, mask_steps=steps,
                                          **blocked_kw)
            elif algorithm == "summa" and kw.get("bcast") != "gather":
                steps = [{"a_mask": ua, "b_mask": ub} for ua, ub in
                         _summa_panel_masks(am, bmk, pr, pc, n_panels)]
                lm = _stepwise_blocked_lm(ml, kl, nl, mask_steps=steps,
                                          **blocked_kw)
            elif algorithm == "summa":
                ua, ub = _summa_gather_masks(am, bmk, pr, pc)
                lm = blocked_local_matmul(ml, kl, nl, a_mask=ua, b_mask=ub,
                                          **blocked_kw)
            else:
                lm = blocked_local_matmul(
                    ml, kl, nl, **_ts_masks(algorithm, am, bmk, p_all),
                    **blocked_kw)

    # ---- data-exchange algorithm --------------------------------------
    if algorithm == "cannon":
        c = cannon_matmul(
            a, b, mesh=mesh, grid=grid, local_matmul=lm,
            precision=precision, double_buffer=double_buffer, **kw)
    elif algorithm == "cannon25d":
        c = cannon25d_matmul(
            a, b, mesh=mesh, grid=grid, local_matmul=lm,
            precision=precision, double_buffer=double_buffer, **kw)
    elif algorithm in ("ts_k", "ts_m", "ts_n"):
        c = tall_skinny_matmul(
            a, b, mesh=mesh, grid=grid, mode=algorithm, local_matmul=lm,
            precision=precision, **kw)
    else:
        c = summa_matmul(
            a, b, mesh=mesh, grid=grid, local_matmul=lm,
            precision=precision, **kw)
    if not return_plan:
        return c
    import dataclasses as _dc

    plan = _dc.replace(plan, executor_stats=_collect_executor_stats(
        lm, densify))
    return c, plan
