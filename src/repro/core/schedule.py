"""Pipelined schedule engine — the single step-loop driver behind every
data-exchange algorithm.

The paper's headline GPU win comes from overlapping inter-rank transfer
with local stack processing: the async transfer of the *next* Cannon
shift is issued while the GPU consumes the *current* stacks (MPI/CUDA-
stream double buffering).  The 2.5D companion paper (Lazzaro et al.,
arXiv:1705.10218) and the batched distributed-GPU work of Mijić &
Davidović (arXiv:2203.09353) both show the pipelining structure is
algorithm-independent — so it lives here once, instead of in four
hand-rolled loops.

Contract
--------

Each algorithm module exports a pure *schedule builder* that returns a
``Schedule``: a host-side description of the step sequence

  * ``prologue(a, b) -> carry``      one-time setup comm (Cannon skew,
                                     2.5D replica-offset skew, PUMMA
                                     all-gather); identity by default
  * ``recv(carry, t) -> (a_t, b_t)`` the communication producing step
                                     ``t``'s compute operands (SUMMA's
                                     panel broadcast; identity for
                                     Cannon, whose carry IS the operand
                                     pair)
  * ``shift(carry, t) -> carry``     the carry update feeding step
                                     ``t + 1`` (Cannon's neighbour
                                     ppermute; identity for SUMMA — its
                                     operands stay resident)
  * ``epilogue(c) -> c``             post-loop collective (2.5D stack
                                     reduction, tall-skinny reduce)

plus static metadata: ``n_steps``, the host-static ``empty_steps`` set
(steps whose occupancy-mask product is empty on every rank — SPMD-safe
to skip because it is uniform across devices; under rank-exact
execution this is the ALL-ranks-empty intersection, which equals the
union plan's emptiness because the max norm product over ranks clears
``filter_eps`` iff some rank retains a triple — so the comm schedule
is identical whether the local multiply runs union or per-rank plans),
per-step ``comm_op`` labels and ``step_comm_bytes`` estimates for
observability, and an optional ``rolled`` spec for the fori_loop
ablation form.

``execute_schedule`` runs any schedule with software double-buffering:

  pipeline_depth = 2   the ``shift``/``recv`` for step ``t + 1`` is
                       issued against a second buffer *before* step
                       ``t``'s local multiply, so XLA schedules the
                       collective-permute-start/done (or broadcast)
                       around the compute — the paper's comm/compute
                       overlap.  This is the default.
  pipeline_depth = 1   strictly serial: all communication for step
                       ``t + 1`` is issued after step ``t``'s multiply.
                       Bit-identical output (the same float ops on the
                       same values in the same accumulation order);
                       only the issue order — and therefore the
                       overlap — changes.
  pipeline_depth = 0   rolled ``fori_loop`` form (smaller HLO, no
                       overlap) where the schedule provides a ``rolled``
                       spec; falls back to depth 1 otherwise.  Kept for
                       the HLO-size ablation (the legacy
                       ``double_buffer=False``).

Empty steps: the compute (and, via ``recv`` skipping, the broadcast)
of an empty step is elided, but ``shift`` still runs — later Cannon
steps need the rotated operands.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import pvary

__all__ = [
    "Schedule",
    "RolledSpec",
    "DEFAULT_PIPELINE_DEPTH",
    "execute_schedule",
    "resolve_pipeline_depth",
    "schedule_step_meta",
]

DEFAULT_PIPELINE_DEPTH = 2


def _identity_prologue(a, b):
    return (a, b)


def _identity_recv(carry, t):
    return carry


def _identity_shift(carry, t):
    return carry


def _identity_epilogue(c):
    return c


@dataclasses.dataclass(frozen=True)
class RolledSpec:
    """Step-uniform shift for the ``fori_loop`` ablation form.

    Only schedules whose ``recv`` is the identity and whose ``shift``
    does not depend on the step index can roll (Cannon; not SUMMA,
    whose per-panel slice offsets are host constants).
    """

    shift: Callable  # carry -> carry (step-independent)
    vary_axes: Tuple[str, ...]  # grid axes the accumulator varies over


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Host-side step plan consumed by ``execute_schedule``.

    The callables close over mesh-axis names and host constants only —
    building a Schedule traces nothing and is cheap, so callers may
    rebuild one purely to read its metadata (``multiply.py`` does, for
    the per-step comm/compute report).
    """

    algorithm: str
    n_steps: int
    prologue: Callable = _identity_prologue
    recv: Callable = _identity_recv
    shift: Callable = _identity_shift
    epilogue: Callable = _identity_epilogue
    empty_steps: frozenset = frozenset()
    rolled: Optional[RolledSpec] = None
    # -- observability metadata (host-side, optional) ------------------
    comm_op: str = ""                      # e.g. "ppermute(col,row)"
    prologue_comm_bytes: int = 0
    step_comm_bytes: Tuple[int, ...] = ()  # per-step estimate, len n_steps
    epilogue_comm_bytes: int = 0

    def replace(self, **kw) -> "Schedule":
        return dataclasses.replace(self, **kw)


def resolve_pipeline_depth(pipeline_depth: Optional[int],
                           double_buffer: Optional[bool] = None) -> int:
    """Fold the legacy ``double_buffer`` flag into the depth knob.

    ``pipeline_depth`` wins when given; otherwise ``double_buffer=True``
    (the historical default) maps to depth 2 and ``False`` to the rolled
    form (depth 0), preserving the pre-engine ablation semantics.
    """
    if pipeline_depth is not None:
        d = int(pipeline_depth)
        if d < 0:
            raise ValueError(f"pipeline_depth must be >= 0, got {d}")
        return min(d, 2)
    if double_buffer is None or double_buffer:
        return DEFAULT_PIPELINE_DEPTH
    return 0


def execute_schedule(
    sched: Schedule,
    a_blk: jax.Array,
    b_blk: jax.Array,
    *,
    local_matmul: Callable,
    out_dtype,
    pipeline_depth: int = DEFAULT_PIPELINE_DEPTH,
    accum_dtype=jnp.float32,
) -> jax.Array:
    """Run a schedule's step loop (inside shard_map) and return C.

    ``local_matmul`` may be *stepwise* (``local_matmul.stepwise``
    truthy): it is then called as ``local_matmul(a, b, step=t)`` and may
    return ``None`` for a step whose occupancy-mask product is empty on
    every rank (host-static and uniform across devices, so SPMD-safe to
    skip — the schedule's ``shift`` still runs, later steps need it).
    """
    stepwise = bool(getattr(local_matmul, "stepwise", False))
    empty = sched.empty_steps
    n = sched.n_steps
    depth = pipeline_depth
    if depth == 0 and (stepwise or empty or sched.rolled is None):
        # stepwise plans are distinct host constants a rolled body
        # cannot express; schedules without a rolled spec (per-step
        # recv offsets) cannot roll either
        depth = 1

    carry = sched.prologue(a_blk, b_blk)
    # accumulator shape generalizes over leading batch dims: (m, n) for
    # one product, (G, m, n) for a fused product batch (the batched
    # multiply stacks G local operands as (G, ml, kl) x (G, kl, nl))
    c = jnp.zeros(a_blk.shape[:-1] + b_blk.shape[-1:], dtype=accum_dtype)

    if depth == 0:
        # Rolled (fori_loop): smaller HLO, no overlap.  Kept for the
        # ablation arm (bench_overlap measures the overlap win).
        rolled = sched.rolled

        def body(_, loop_carry):
            inner, c_c = loop_carry
            a_c, b_c = sched.recv(inner, 0)
            c_c = c_c + local_matmul(a_c, b_c).astype(accum_dtype)
            return rolled.shift(inner), c_c

        # the zero-init accumulator must enter the loop already marked
        # varying over the grid axes (its per-step updates are)
        c = pvary(c, rolled.vary_axes)
        _, c = jax.lax.fori_loop(0, n, body, (carry, c))
        return sched.epilogue(c).astype(out_dtype)

    def compute(ops, t):
        a_t, b_t = ops
        part = (local_matmul(a_t, b_t, step=t) if stepwise
                else local_matmul(a_t, b_t))
        return part

    ops = None if 0 in empty else sched.recv(carry, 0)
    for t in range(n):
        nxt_carry = nxt_ops = None
        if depth >= 2 and t + 1 < n:
            # software double buffering: issue step t+1's communication
            # before step t's multiply so XLA overlaps the collective
            # with the compute
            nxt_carry = sched.shift(carry, t)
            if (t + 1) not in empty:
                nxt_ops = sched.recv(nxt_carry, t + 1)
        if t not in empty:
            part = compute(ops, t)
            if part is not None:
                c = c + part.astype(accum_dtype)
        if t + 1 < n:
            if depth < 2:
                # serial: all communication strictly after the multiply
                nxt_carry = sched.shift(carry, t)
                if (t + 1) not in empty:
                    nxt_ops = sched.recv(nxt_carry, t + 1)
            carry, ops = nxt_carry, nxt_ops
    return sched.epilogue(c).astype(out_dtype)


def schedule_step_meta(sched: Schedule) -> dict:
    """Host-side summary of a schedule's communication structure —
    consumed by ``multiply.py`` to build the per-step comm/compute
    report attached to executed plans and by the telemetry layer
    (repro.obs) for dispatch-span comm-bytes attributes."""
    per_step = list(sched.step_comm_bytes) if sched.step_comm_bytes \
        else [0] * sched.n_steps
    return {
        "algorithm": sched.algorithm,
        "n_steps": sched.n_steps,
        "comm_op": sched.comm_op,
        "empty_steps": sorted(sched.empty_steps),
        "prologue_comm_bytes": int(sched.prologue_comm_bytes),
        "step_comm_bytes": [int(x) for x in per_step],
        "epilogue_comm_bytes": int(sched.epilogue_comm_bytes),
        "total_comm_bytes": int(sched.prologue_comm_bytes)
        + sum(int(x) for x in per_step)
        + int(sched.epilogue_comm_bytes),
    }
