"""The paper's primary contribution: distributed blocked matmul.

    from repro.core import dbcsr
    from repro.core.multiply import distributed_matmul
"""
from .blocking import BlockLayout, GridSpec
from .multiply import distributed_matmul
from .cannon import cannon_matmul
from .cannon25d import cannon25d_matmul
from .tall_skinny import (tall_skinny_matmul, classify_shape,
                          ts_classify_ratio, DEFAULT_TS_RATIO)
from .summa import summa_matmul
from .densify import densify, undensify, to_blocks, from_blocks
from .engine import (ExecutorPlan, build_executor_plan, execute_plan,
                     stack_executor)
from .stacks import build_stacks, pad_plans, StackPlan, STACK_SIZE

__all__ = [
    "BlockLayout", "GridSpec", "distributed_matmul", "cannon_matmul",
    "cannon25d_matmul", "tall_skinny_matmul", "classify_shape",
    "ts_classify_ratio", "DEFAULT_TS_RATIO",
    "summa_matmul", "densify", "undensify", "to_blocks", "from_blocks",
    "build_stacks", "pad_plans", "StackPlan", "STACK_SIZE",
    "ExecutorPlan", "build_executor_plan", "execute_plan", "stack_executor",
]
