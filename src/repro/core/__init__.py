"""The paper's primary contribution: distributed blocked matmul.

    from repro.core import dbcsr
    from repro.core.multiply import distributed_matmul
"""
from .blocking import BlockLayout, GridSpec
from .multiply import distributed_matmul
from .cannon import (cannon_matmul, build_cannon_schedule,
                     cannon_step_masks, cannon_step_norms)
from .cannon25d import cannon25d_matmul, build_cannon25d_schedule
from .tall_skinny import (tall_skinny_matmul, build_ts_schedule,
                          ts_step_masks, ts_step_norms, classify_shape,
                          ts_classify_ratio, DEFAULT_TS_RATIO)
from .summa import (summa_matmul, build_summa_schedule,
                    build_summa_gather_schedule, summa_step_masks,
                    summa_gather_masks, summa_step_norms,
                    summa_gather_norms)
from .schedule import (Schedule, execute_schedule, DEFAULT_PIPELINE_DEPTH,
                       resolve_pipeline_depth)
from .densify import densify, undensify, to_blocks, from_blocks
from .engine import (ExecutorPlan, build_executor_plan, execute_plan,
                     stack_executor)
from .stacks import build_stacks, pad_plans, StackPlan, STACK_SIZE

__all__ = [
    "BlockLayout", "GridSpec", "distributed_matmul", "cannon_matmul",
    "cannon25d_matmul", "tall_skinny_matmul", "classify_shape",
    "ts_classify_ratio", "DEFAULT_TS_RATIO",
    "summa_matmul", "densify", "undensify", "to_blocks", "from_blocks",
    "build_stacks", "pad_plans", "StackPlan", "STACK_SIZE",
    "ExecutorPlan", "build_executor_plan", "execute_plan", "stack_executor",
    "Schedule", "execute_schedule", "DEFAULT_PIPELINE_DEPTH",
    "resolve_pipeline_depth", "build_cannon_schedule",
    "build_cannon25d_schedule", "build_summa_schedule",
    "build_summa_gather_schedule", "build_ts_schedule",
    "cannon_step_masks", "summa_step_masks", "summa_gather_masks",
    "ts_step_masks", "cannon_step_norms", "summa_step_norms",
    "summa_gather_norms", "ts_step_norms",
]
