"""SUMMA — the ScaLAPACK PDGEMM-style baseline DBCSR is compared against.

The paper's headline result (section IV-C) is densified DBCSR vs the
PDGEMM of Cray LibSci_acc, a GPU-accelerated ScaLAPACK.  ScaLAPACK's
PDGEMM is SUMMA-like: for each panel k of the contraction dimension,
the owning column of the process grid broadcasts its A panel along
rows, the owning row broadcasts its B panel along columns, and every
process accumulates a local GEMM.

We implement the panel broadcast two ways:

  * ``bcast='psum'``   — masked all-reduce per panel.  One-shot,
    latency-light, but moves ~2x the optimal broadcast volume.  This is
    the *baseline* configuration: its extra volume vs Cannon is what
    the roofline comparison in benchmarks/bench_vs_pgemm.py surfaces
    (the in-framework analogue of the paper's Fig. 4).
  * ``bcast='gather'`` — one all-gather of all panels up front (PUMMA
    style); volume-optimal broadcast, memory cost sqrt(P)x local
    operand size.

Unlike Cannon, SUMMA supports non-square process grids.

The panel loop is the unified schedule engine (core/schedule.py):
``build_summa_schedule`` emits one step per panel whose ``recv`` is the
masked-allreduce broadcast (operands stay resident — ``shift`` is the
identity), so at ``pipeline_depth=2`` the broadcast of panel t+1 is
issued before the local multiply of panel t.
"""
from __future__ import annotations

import math
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .blocking import GridSpec
from .cannon import _default_local_matmul
from .schedule import Schedule, execute_schedule, resolve_pipeline_depth

__all__ = ["summa_matmul", "summa_n_panels", "build_summa_schedule",
           "build_summa_gather_schedule", "summa_step_masks",
           "summa_gather_masks", "summa_step_norms", "summa_gather_norms",
           "summa_rank_steps", "summa_gather_rank_steps"]


def summa_n_panels(pr: int, pc: int) -> int:
    """Contraction panel count of the psum-broadcast SUMMA on a (pr, pc)
    grid: one panel per grid column of A for square grids; the lcm for
    non-square so both the A column owner and the B row owner of every
    panel are well defined.  Exported so the blocked local-multiply
    planner (core/multiply.py) sizes per-panel stack plans consistently.
    """
    return pc if pr == pc else math.lcm(pr, pc)


def build_summa_schedule(
    pr: int,
    pc: int,
    *,
    row_axis: str,
    col_axis: str,
    n_panels: Optional[int] = None,
    empty_steps: frozenset = frozenset(),
    local_shape: Optional[tuple] = None,
    itemsize: int = 4,
) -> Schedule:
    """Schedule for psum-broadcast SUMMA: one step per contraction
    panel; ``recv`` slices the resident local blocks and broadcasts the
    panel pair by masked all-reduce along the perpendicular grid axes.
    """
    n_panels = summa_n_panels(pr, pc) if n_panels is None else n_panels

    def recv(carry, p):
        a_blk, b_blk = carry
        # K is the LAST axis of A and second-to-last of B so the slices
        # are agnostic to leading batch dims ((ml, kl) single product,
        # (G, ml, kl) fused product batch)
        kl_a = a_blk.shape[-1] * pc // n_panels  # A panel width (local)
        kl_b = b_blk.shape[-2] * pr // n_panels  # B panel height (local)
        my_col = jax.lax.axis_index(col_axis)
        my_row = jax.lax.axis_index(row_axis)
        # owner coordinates of panel p
        col_owner = p * pc // n_panels
        row_owner = p * pr // n_panels
        a_off = (p % (n_panels // pc)) * kl_a if n_panels != pc else 0
        b_off = (p % (n_panels // pr)) * kl_b if n_panels != pr else 0
        a_panel = jax.lax.dynamic_slice_in_dim(a_blk, a_off, kl_a,
                                               axis=a_blk.ndim - 1)
        b_panel = jax.lax.dynamic_slice_in_dim(b_blk, b_off, kl_b,
                                               axis=b_blk.ndim - 2)
        # broadcast-by-masked-allreduce along the perpendicular axis
        a_panel = jnp.where(my_col == col_owner, a_panel, 0)
        a_panel = jax.lax.psum(a_panel, col_axis)
        b_panel = jnp.where(my_row == row_owner, b_panel, 0)
        b_panel = jax.lax.psum(b_panel, row_axis)
        return (a_panel, b_panel)

    step_bytes = 0
    if local_shape is not None:
        ml, klp, nl = local_shape  # per-panel local multiply geometry
        # masked all-reduce moves ~2x the optimal broadcast volume
        step_bytes = 2 * (ml * klp + klp * nl) * itemsize

    return Schedule(
        algorithm="summa",
        n_steps=n_panels,
        recv=recv,
        empty_steps=frozenset(empty_steps),
        comm_op=f"bcast-psum(a:{col_axis}, b:{row_axis})",
        step_comm_bytes=tuple(
            0 if t in empty_steps else step_bytes for t in range(n_panels)),
    )


def summa_step_masks(
    am: np.ndarray, bm: np.ndarray, pr: int, pc: int, n_panels: int,
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Per-panel (a_mask, b_mask) unions for psum-broadcast SUMMA — the
    schedule builder's per-step mask slices.

    Panel p covers the global K block range [p*nbk/n_panels, ...); the
    A-side union runs over the pr row chunks, the B-side over the pc
    column chunks.  Because the row and column ranks vary independently,
    the union of per-rank products equals the product of the factored
    unions — no 3D pair tensor needed.
    """
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if nbr % pr or nbc % pc or nbk % n_panels:
        raise ValueError(
            f"block grid ({nbr},{nbk},{nbc}) not divisible by summa grid "
            f"{pr}x{pc} with {n_panels} panels")
    lr, lc, lkp = nbr // pr, nbc // pc, nbk // n_panels
    out = []
    for p in range(n_panels):
        ksl = slice(p * lkp, (p + 1) * lkp)
        ua = np.zeros((lr, lkp), dtype=bool)
        for i in range(pr):
            ua |= am[i * lr:(i + 1) * lr, ksl]
        ub = np.zeros((lkp, lc), dtype=bool)
        for j in range(pc):
            ub |= bm[ksl, j * lc:(j + 1) * lc]
        out.append((ua, ub))
    return out


def summa_step_norms(
    an: np.ndarray, bn: np.ndarray, pr: int, pc: int, n_panels: int,
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Per-panel (a_norms, b_norms) max-unions for psum-broadcast SUMMA
    — the norm twin of ``summa_step_masks`` (repro.sparsity).

    SPMD union-of-max semantics: the A-side takes the elementwise MAX
    over the pr row chunks, the B-side over the pc column chunks.  The
    factored product ``max_i(an) * max_j(bn)`` upper-bounds every
    rank's norm product, so ``filter_eps`` never drops a triple some
    rank still needs — the same conservativeness as the factored mask
    union."""
    nbr, nbk = an.shape
    nbc = bn.shape[1]
    if nbr % pr or nbc % pc or nbk % n_panels:
        raise ValueError(
            f"block grid ({nbr},{nbk},{nbc}) not divisible by summa grid "
            f"{pr}x{pc} with {n_panels} panels")
    an = np.asarray(an, dtype=np.float32)
    bn = np.asarray(bn, dtype=np.float32)
    lr, lc, lkp = nbr // pr, nbc // pc, nbk // n_panels
    out = []
    for p in range(n_panels):
        ksl = slice(p * lkp, (p + 1) * lkp)
        ua = np.zeros((lr, lkp), dtype=np.float32)
        for i in range(pr):
            np.maximum(ua, an[i * lr:(i + 1) * lr, ksl], out=ua)
        ub = np.zeros((lkp, lc), dtype=np.float32)
        for j in range(pc):
            np.maximum(ub, bn[ksl, j * lc:(j + 1) * lc], out=ub)
        out.append((ua, ub))
    return out


def summa_gather_norms(
    an: np.ndarray, bn: np.ndarray, pr: int, pc: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Factored max-unions for PUMMA-style (all-gather) SUMMA — the
    norm twin of ``summa_gather_masks``: one step, A maxed over row
    chunks, B over column chunks."""
    nbr, nbk = an.shape
    nbc = bn.shape[1]
    if nbr % pr or nbc % pc:
        raise ValueError(
            f"block grid ({nbr},{nbc}) not divisible by grid {pr}x{pc}")
    an = np.asarray(an, dtype=np.float32)
    bn = np.asarray(bn, dtype=np.float32)
    lr, lc = nbr // pr, nbc // pc
    ua = np.zeros((lr, nbk), dtype=np.float32)
    for i in range(pr):
        np.maximum(ua, an[i * lr:(i + 1) * lr], out=ua)
    ub = np.zeros((nbk, lc), dtype=np.float32)
    for j in range(pc):
        np.maximum(ub, bn[:, j * lc:(j + 1) * lc], out=ub)
    return ua, ub


def summa_gather_masks(
    am: np.ndarray, bm: np.ndarray, pr: int, pc: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Factored unions for PUMMA-style (all-gather) SUMMA: the local
    multiply sees the full K extent, so there is a single step whose A
    mask unions over row chunks and B mask over column chunks."""
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if nbr % pr or nbc % pc:
        raise ValueError(
            f"block grid ({nbr},{nbc}) not divisible by grid {pr}x{pc}")
    lr, lc = nbr // pr, nbc // pc
    ua = np.zeros((lr, nbk), dtype=bool)
    for i in range(pr):
        ua |= am[i * lr:(i + 1) * lr]
    ub = np.zeros((nbk, lc), dtype=bool)
    for j in range(pc):
        ub |= bm[:, j * lc:(j + 1) * lc]
    return ua, ub


def summa_rank_steps(
    am: np.ndarray, bm: np.ndarray, pr: int, pc: int, n_panels: int,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
) -> List[List[dict]]:
    """Rank-exact twin of ``summa_step_masks``/``summa_step_norms``:
    per panel, per RANK exact local mask (and norm) kwargs.

    ``out[p][r]`` is the kwarg dict for rank ``r = i * pc + j`` at
    panel ``p`` — its own A row chunk against the panel's K slice and
    the panel's K slice against its own B column chunk, no cross-rank
    union and no union-of-max norms.
    """
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if nbr % pr or nbc % pc or nbk % n_panels:
        raise ValueError(
            f"block grid ({nbr},{nbk},{nbc}) not divisible by summa grid "
            f"{pr}x{pc} with {n_panels} panels")
    lr, lc, lkp = nbr // pr, nbc // pc, nbk // n_panels
    if a_norms is not None:
        a_norms = np.asarray(a_norms, dtype=np.float32)
        b_norms = np.asarray(b_norms, dtype=np.float32)
    steps: List[List[dict]] = []
    for p in range(n_panels):
        ksl = slice(p * lkp, (p + 1) * lkp)
        ranks: List[dict] = []
        for i in range(pr):
            rs = slice(i * lr, (i + 1) * lr)
            for j in range(pc):
                cs = slice(j * lc, (j + 1) * lc)
                kw = {"a_mask": am[rs, ksl], "b_mask": bm[ksl, cs]}
                if a_norms is not None:
                    kw["a_norms"] = a_norms[rs, ksl]
                    kw["b_norms"] = b_norms[ksl, cs]
                ranks.append(kw)
        steps.append(ranks)
    return steps


def summa_gather_rank_steps(
    am: np.ndarray, bm: np.ndarray, pr: int, pc: int,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
) -> List[dict]:
    """Rank-exact twin of ``summa_gather_masks``/``summa_gather_norms``
    for the single-step all-gather variant: rank ``r = i * pc + j``
    multiplies its exact A row chunk (full K) by its exact B column
    chunk."""
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if nbr % pr or nbc % pc:
        raise ValueError(
            f"block grid ({nbr},{nbc}) not divisible by grid {pr}x{pc}")
    lr, lc = nbr // pr, nbc // pc
    if a_norms is not None:
        a_norms = np.asarray(a_norms, dtype=np.float32)
        b_norms = np.asarray(b_norms, dtype=np.float32)
    ranks: List[dict] = []
    for i in range(pr):
        rs = slice(i * lr, (i + 1) * lr)
        for j in range(pc):
            cs = slice(j * lc, (j + 1) * lc)
            kw = {"a_mask": am[rs], "b_mask": bm[:, cs]}
            if a_norms is not None:
                kw["a_norms"] = a_norms[rs]
                kw["b_norms"] = b_norms[:, cs]
            ranks.append(kw)
    return ranks


def build_summa_gather_schedule(row_axis: str, col_axis: str,
                           local_shape: Optional[tuple] = None,
                           itemsize: int = 4) -> Schedule:
    """PUMMA-style SUMMA as a single-step schedule: the all-gather of
    the full local row of A / column of B is the prologue, the one
    local multiply is step 0."""

    def prologue(a_blk, b_blk):
        a_row = jax.lax.all_gather(a_blk, col_axis, axis=1, tiled=True)
        b_col = jax.lax.all_gather(b_blk, row_axis, axis=0, tiled=True)
        return (a_row, b_col)

    prologue_bytes = 0
    if local_shape is not None:
        ml, kl, nl = local_shape  # gathered (full-K) local geometry
        prologue_bytes = (ml * kl + kl * nl) * itemsize

    return Schedule(
        algorithm="summa",
        n_steps=1,
        prologue=prologue,
        comm_op=f"all_gather(a:{col_axis}, b:{row_axis})",
        prologue_comm_bytes=prologue_bytes,
    )


def summa_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec = GridSpec(),
    local_matmul: Optional[Callable] = None,
    out_dtype=None,
    precision=jax.lax.Precision.DEFAULT,
    bcast: str = "psum",
    pipeline_depth: Optional[int] = None,
    double_buffer: Optional[bool] = None,
) -> jax.Array:
    """C = A @ B via SUMMA on the (row_axis, col_axis) grid.

    ``pipeline_depth`` follows core/schedule.py semantics: at depth 2
    the panel broadcast for step t+1 overlaps the local multiply of
    step t; depth 1 is strictly serial (bit-identical output).
    """
    pr, pc = grid.grid_shape(mesh)
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    lm = local_matmul or _default_local_matmul(precision)
    depth = resolve_pipeline_depth(pipeline_depth, double_buffer)

    if bcast == "gather":
        # the single gathered dot historically cast straight to
        # out_dtype — accumulate there, not in f32, so f64/int operands
        # keep full precision
        sched = build_summa_gather_schedule(grid.row_axis, grid.col_axis)
        accum = out_dtype
    elif bcast == "psum":
        sched = build_summa_schedule(
            pr, pc, row_axis=grid.row_axis, col_axis=grid.col_axis,
            empty_steps=getattr(lm, "empty_steps", frozenset()))
        accum = jnp.float32  # legacy per-panel f32 accumulation
    else:
        raise ValueError(bcast)

    def body(a_blk, b_blk):
        return execute_schedule(sched, a_blk, b_blk, local_matmul=lm,
                                out_dtype=out_dtype, pipeline_depth=depth,
                                accum_dtype=accum)

    # leading batch dims (a fused product batch (G, m, k)) replicate;
    # the trailing two axes shard over the process grid as always
    spec = P(*([None] * (a.ndim - 2)), grid.row_axis, grid.col_axis)
    fn = shard_map(body, mesh=mesh, in_specs=(spec, spec),
                   out_specs=spec, check_vma=False)
    return fn(a, b)
