"""SUMMA — the ScaLAPACK PDGEMM-style baseline DBCSR is compared against.

The paper's headline result (section IV-C) is densified DBCSR vs the
PDGEMM of Cray LibSci_acc, a GPU-accelerated ScaLAPACK.  ScaLAPACK's
PDGEMM is SUMMA-like: for each panel k of the contraction dimension,
the owning column of the process grid broadcasts its A panel along
rows, the owning row broadcasts its B panel along columns, and every
process accumulates a local GEMM.

We implement the panel broadcast two ways:

  * ``bcast='psum'``   — masked all-reduce per panel.  One-shot,
    latency-light, but moves ~2x the optimal broadcast volume.  This is
    the *baseline* configuration: its extra volume vs Cannon is what
    the roofline comparison in benchmarks/bench_vs_pgemm.py surfaces
    (the in-framework analogue of the paper's Fig. 4).
  * ``bcast='gather'`` — one all-gather of all panels up front (PUMMA
    style); volume-optimal broadcast, memory cost sqrt(P)x local
    operand size.

Unlike Cannon, SUMMA supports non-square process grids.
"""
from __future__ import annotations

import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .blocking import GridSpec
from .cannon import _default_local_matmul

__all__ = ["summa_matmul", "summa_n_panels"]


def summa_n_panels(pr: int, pc: int) -> int:
    """Contraction panel count of the psum-broadcast SUMMA on a (pr, pc)
    grid: one panel per grid column of A for square grids; the lcm for
    non-square so both the A column owner and the B row owner of every
    panel are well defined.  Exported so the blocked local-multiply
    planner (core/multiply.py) sizes per-panel stack plans consistently.
    """
    return pc if pr == pc else math.lcm(pr, pc)


def summa_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec = GridSpec(),
    local_matmul: Optional[Callable] = None,
    out_dtype=None,
    precision=jax.lax.Precision.DEFAULT,
    bcast: str = "psum",
) -> jax.Array:
    """C = A @ B via SUMMA on the (row_axis, col_axis) grid."""
    pr, pc = grid.grid_shape(mesh)
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    lm = local_matmul or _default_local_matmul(precision)
    row_ax, col_ax = grid.row_axis, grid.col_axis

    if bcast == "gather":
        def body_gather(a_blk, b_blk):
            # PUMMA-style: materialise the full local row of A and
            # column of B, then one big local dot.
            a_row = jax.lax.all_gather(a_blk, col_ax, axis=1, tiled=True)
            b_col = jax.lax.all_gather(b_blk, row_ax, axis=0, tiled=True)
            return lm(a_row, b_col).astype(out_dtype)

        spec = P(row_ax, col_ax)
        fn = shard_map(
            body_gather, mesh=mesh, in_specs=(spec, spec), out_specs=spec,
            check_vma=False,
        )
        return fn(a, b)

    if bcast != "psum":
        raise ValueError(bcast)

    # Panel count: one panel per grid column of A (= per grid row of B);
    # the lcm for non-square grids (see summa_n_panels).
    n_panels = summa_n_panels(pr, pc)
    # Stepwise (occupancy-masked) local multiplies carry per-panel stack
    # plans and a host-static set of panels whose mask product is empty
    # on every rank — those skip the broadcast AND the local multiply
    # (uniform across devices, so SPMD-safe).
    stepwise = bool(getattr(lm, "stepwise", False))
    empty_steps = getattr(lm, "empty_steps", frozenset())

    def body(a_blk, b_blk):
        my_col = jax.lax.axis_index(col_ax)
        my_row = jax.lax.axis_index(row_ax)
        kl_a = a_blk.shape[1] * pc // n_panels   # A panel width (local)
        kl_b = b_blk.shape[0] * pr // n_panels   # B panel height (local)
        c = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32)

        for p in range(n_panels):
            if p in empty_steps:
                continue
            # owner coordinates of panel p
            col_owner = p * pc // n_panels
            row_owner = p * pr // n_panels
            a_off = (p % (n_panels // pc)) * kl_a if n_panels != pc else 0
            b_off = (p % (n_panels // pr)) * kl_b if n_panels != pr else 0
            a_panel = jax.lax.dynamic_slice_in_dim(a_blk, a_off, kl_a, axis=1)
            b_panel = jax.lax.dynamic_slice_in_dim(b_blk, b_off, kl_b, axis=0)
            # broadcast-by-masked-allreduce along the perpendicular axis
            a_panel = jnp.where(my_col == col_owner, a_panel, 0)
            a_panel = jax.lax.psum(a_panel, col_ax)
            b_panel = jnp.where(my_row == row_owner, b_panel, 0)
            b_panel = jax.lax.psum(b_panel, row_ax)
            part = (lm(a_panel, b_panel, step=p) if stepwise
                    else lm(a_panel, b_panel))
            if part is not None:
                c = c + part.astype(jnp.float32)
        return c.astype(out_dtype)

    spec = P(row_ax, col_ax)
    fn = shard_map(body, mesh=mesh, in_specs=(spec, spec),
                   out_specs=spec, check_vma=False)
    return fn(a, b)
