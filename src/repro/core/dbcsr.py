"""DBCSRMatrix — user-facing distributed blocked matrix container.

Mirrors the DBCSR API surface (create / multiply / add / trace /
transpose / to-from ScaLAPACK-style layouts) on top of JAX arrays with
NamedSharding.  The payload of a dense DBCSR matrix is simply a 2D
array sharded over the (row_axis, col_axis) process grid; the blocked
structure is metadata (BlockLayout) consumed by the local-multiply
strategies.

Block-sparse matrices carry an additional static block mask (numpy
bool, (nblock_rows, nblock_cols)); absent blocks are stored as zeros in
the dense payload (occupancy handling is metadata-level: the stack
generator skips absent blocks, which is where sparse wins come from in
DBCSR).  This keeps every array shape static — mandatory for pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .blocking import BlockLayout, GridSpec

__all__ = ["DBCSRMatrix", "create", "multiply", "multiply_vector",
           "add", "trace", "transpose"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DBCSRMatrix:
    """A distributed blocked matrix.

    data      : (rows, cols) jax.Array, sharded P(row_axis, col_axis)
    layout    : block structure metadata
    grid      : mesh-axis names of the process grid
    block_mask: optional (nbr, nbc) numpy bool — block-sparse occupancy

    Products returned by ``multiply`` additionally carry the executed
    ``MultiplyPlan`` as a plain ``last_plan`` attribute (host-side
    observability only — not part of the pytree, does not survive jit).
    """

    data: jax.Array
    layout: BlockLayout
    grid: GridSpec
    block_mask: Optional[np.ndarray] = None

    # -- pytree protocol (data is a leaf; the rest is static) ----------
    def tree_flatten(self):
        # the mask rides in aux as (shape, bytes): hashable (jit cache
        # key) AND sufficient to reconstruct the array on unflatten, so
        # block sparsity survives jit/vmap/scan round-trips.
        mask_aux = (None if self.block_mask is None
                    else (self.block_mask.shape, self.block_mask.tobytes()))
        return (self.data,), (self.layout, self.grid, mask_aux)

    @classmethod
    def tree_unflatten(cls, aux, children):
        layout, grid, mask_aux = aux
        mask = None
        if mask_aux is not None:
            shape, raw = mask_aux
            mask = np.frombuffer(raw, dtype=bool).reshape(shape).copy()
        return cls(children[0], layout, grid, mask)

    # -- DBCSR-like API -------------------------------------------------
    @property
    def shape(self):
        return self.data.shape

    @property
    def occupancy(self) -> float:
        if self.block_mask is None:
            return 1.0
        return float(self.block_mask.mean())

    def transpose(self) -> "DBCSRMatrix":
        layout = BlockLayout(self.layout.cols, self.layout.rows,
                             self.layout.block_cols, self.layout.block_rows)
        mask = None if self.block_mask is None else self.block_mask.T.copy()
        return DBCSRMatrix(self.data.T, layout, self.grid, mask)

    def trace(self) -> jax.Array:
        return jnp.trace(self.data)

    def scale(self, alpha) -> "DBCSRMatrix":
        return dataclasses.replace(self, data=self.data * alpha)


def _sharding(mesh: Mesh, grid: GridSpec) -> NamedSharding:
    return NamedSharding(mesh, P(grid.row_axis, grid.col_axis))


def create(
    array,
    *,
    mesh: Mesh,
    grid: GridSpec = GridSpec(),
    block_size: int = 64,
    block_mask: Optional[np.ndarray] = None,
) -> DBCSRMatrix:
    """Create a DBCSR matrix from a host/global array (library owns the
    distribution, like dbcsr_create + dbcsr_put_block)."""
    rows, cols = array.shape
    layout = BlockLayout(rows, cols, block_size, block_size)
    data = jax.device_put(array, _sharding(mesh, grid))
    if block_mask is not None:
        if block_mask.shape != (layout.nblock_rows, layout.nblock_cols):
            raise ValueError("block_mask shape mismatch")
        # zero out absent blocks so dense math matches sparse semantics
        mask_full = np.repeat(np.repeat(block_mask, block_size, 0), block_size, 1)
        data = data * jnp.asarray(mask_full, dtype=data.dtype)
    return DBCSRMatrix(data, layout, grid, block_mask)


def add(a: DBCSRMatrix, b: DBCSRMatrix) -> DBCSRMatrix:
    """C = A + B.  Result occupancy is the union of the operands'.

    A missing mask means *dense* (every block present), so when exactly
    one operand carries a mask the union with the dense operand is
    dense and the result mask is deliberately ``None`` — not a dropped
    mask, but the correct all-present occupancy (contrast multiply(),
    where a one-sided mask does constrain the product's support).
    """
    mask = None
    if a.block_mask is not None and b.block_mask is not None:
        mask = a.block_mask | b.block_mask
    return DBCSRMatrix(a.data + b.data, a.layout, a.grid, mask)


def trace(a: DBCSRMatrix) -> jax.Array:
    return a.trace()


def transpose(a: DBCSRMatrix) -> DBCSRMatrix:
    return a.transpose()


def multiply_vector(a: DBCSRMatrix, x: jax.Array) -> jax.Array:
    """y = A @ x (paper section II lists matrix-vector among the ops).

    The 2D-sharded payload contracts its column-sharded dim against the
    replicated vector; GSPMD reduces the row partials (the degenerate
    N=1 tall-skinny case)."""
    return a.data @ x


def multiply(
    a: DBCSRMatrix,
    b: DBCSRMatrix,
    *,
    mesh: Mesh,
    algorithm: str = "auto",
    densify: Optional[bool] = None,
    return_plan: bool = False,
    **kw,
) -> DBCSRMatrix:
    """C = A @ B — with ``algorithm="auto"`` (the default) the
    cost-model planner (repro.planner.plan_multiply) picks the
    data-exchange algorithm AND the local path for this (shape,
    occupancy, mesh); a fixed ``algorithm=``/``densify=`` pins them
    (``densify=None`` under a fixed algorithm means densified, the
    legacy default).

    Block occupancy flows end to end: the operands' masks are handed to
    the distributed dispatcher (the blocked path plans only present
    triples and skips empty shift/panel steps), and the result carries
    the symbolic product mask ``(a_mask @ b_mask) > 0`` — with a missing
    operand mask treated as all-present, so a single masked operand
    still constrains the product's support.

    The executed plan is observable without re-deriving it: the product
    carries it as ``C.last_plan`` (a ``MultiplyPlan`` with per-candidate
    predicted costs via ``.explain()`` and the executed blocked-path
    stack statistics as ``.executor_stats``), and ``return_plan=True``
    additionally returns ``(C, plan)``.  ``last_plan`` is a plain
    host-side attribute — it does not survive pytree flatten/jit
    round-trips (only ``data``/``layout``/``grid``/``block_mask`` do).
    """
    from .multiply import distributed_matmul

    c_data, plan = distributed_matmul(
        a.data, b.data, mesh=mesh, grid=a.grid,
        algorithm=algorithm, densify=densify,
        block_m=a.layout.block_rows, block_k=a.layout.block_cols,
        block_n=b.layout.block_cols,
        a_mask=a.block_mask, b_mask=b.block_mask, return_plan=True, **kw,
    )
    c_layout = BlockLayout(a.layout.rows, b.layout.cols,
                           a.layout.block_rows, b.layout.block_cols)
    mask = None
    if a.block_mask is not None or b.block_mask is not None:
        from .stacks import normalize_block_masks

        am, bm = normalize_block_masks(
            a.layout.nblock_rows, a.layout.nblock_cols,
            b.layout.nblock_cols, a.block_mask, b.block_mask)
        mask = (am.astype(np.int64) @ bm.astype(np.int64)) > 0
    c = DBCSRMatrix(c_data, c_layout, a.grid, mask)
    c.last_plan = plan
    return (c, plan) if return_plan else c
