"""DBCSRMatrix — user-facing distributed blocked matrix container.

Mirrors the DBCSR API surface (create / multiply / add / trace /
transpose / to-from ScaLAPACK-style layouts) on top of JAX arrays with
NamedSharding.  The payload of a dense DBCSR matrix is simply a 2D
array sharded over the (row_axis, col_axis) process grid; the blocked
structure is metadata (BlockLayout) consumed by the local-multiply
strategies.

Block-sparse matrices carry an additional static block mask (numpy
bool, (nblock_rows, nblock_cols)); absent blocks are stored as zeros in
the dense payload (occupancy handling is metadata-level: the stack
generator skips absent blocks, which is where sparse wins come from in
DBCSR).  This keeps every array shape static — mandatory for pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .blocking import BlockLayout, GridSpec

__all__ = ["DBCSRMatrix", "create", "multiply", "multiply_vector",
           "add", "trace", "transpose"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DBCSRMatrix:
    """A distributed blocked matrix.

    data       : (rows, cols) jax.Array, sharded P(row_axis, col_axis)
    layout     : block structure metadata
    grid       : mesh-axis names of the process grid
    block_mask : optional (nbr, nbc) numpy bool — block-sparse occupancy
    block_norms: optional (nbr, nbc) numpy float32 — per-block Frobenius
                 norms (repro.sparsity), lazily computed/cached by
                 ``norms()`` and consumed by the ``filter_eps`` multiply
                 path and ``filter()``

    Products returned by ``multiply`` additionally carry the executed
    ``MultiplyPlan`` as a plain ``last_plan`` attribute (host-side
    observability only — not part of the pytree, does not survive jit).
    """

    data: jax.Array
    layout: BlockLayout
    grid: GridSpec
    block_mask: Optional[np.ndarray] = None
    block_norms: Optional[np.ndarray] = None

    # -- pytree protocol (data is a leaf; the rest is static) ----------
    def tree_flatten(self):
        # mask AND norms ride in aux as (shape, bytes): hashable (jit
        # cache key) AND sufficient to reconstruct the arrays on
        # unflatten, so block sparsity — and its norms — survive
        # jit/vmap/scan round-trips.
        mask_aux = (None if self.block_mask is None
                    else (self.block_mask.shape, self.block_mask.tobytes()))
        norms_aux = None
        if self.block_norms is not None:
            norms = np.ascontiguousarray(self.block_norms, dtype=np.float32)
            norms_aux = (norms.shape, norms.tobytes())
        return (self.data,), (self.layout, self.grid, mask_aux, norms_aux)

    @classmethod
    def tree_unflatten(cls, aux, children):
        layout, grid, mask_aux, norms_aux = aux
        mask = None
        if mask_aux is not None:
            shape, raw = mask_aux
            mask = np.frombuffer(raw, dtype=bool).reshape(shape).copy()
        norms = None
        if norms_aux is not None:
            shape, raw = norms_aux
            norms = np.frombuffer(raw, dtype=np.float32).reshape(shape).copy()
        return cls(children[0], layout, grid, mask, norms)

    # -- DBCSR-like API -------------------------------------------------
    @property
    def shape(self):
        return self.data.shape

    @property
    def occupancy(self) -> float:
        if self.block_mask is None:
            return 1.0
        return float(self.block_mask.mean())

    def norms(self, recompute: bool = False) -> np.ndarray:
        """Per-block Frobenius norms ((nbr, nbc) float32 numpy), cached
        on the matrix after the first call (one blockwise device
        reduction per geometry — repro.sparsity.norms).  Mask-absent
        blocks report 0.  Pass ``recompute=True`` after mutating
        ``data`` through a non-DBCSR op (the cache cannot observe
        that)."""
        if self.block_norms is None or recompute:
            from repro.sparsity.norms import block_norms_of

            self.block_norms = block_norms_of(
                self.data, self.layout.block_rows, self.layout.block_cols,
                self.block_mask)
        return self.block_norms

    def filter(self, eps: float) -> "DBCSRMatrix":
        """DBCSR's post-multiply filtering pass: re-derive the
        occupancy from the *actual* block norms, dropping every block
        with ``norm < eps`` (blocks exactly at eps survive, matching
        the triple-filter contract), zeroing the dropped blocks'
        payload so dense math keeps matching sparse semantics.  Never
        resurrects a block the current mask declares absent."""
        norms = self.norms()
        mask = norms >= float(eps)
        if self.block_mask is not None:
            mask &= self.block_mask
        bs_r, bs_c = self.layout.block_rows, self.layout.block_cols
        full = np.repeat(np.repeat(mask, bs_r, 0), bs_c, 1)
        data = self.data * jnp.asarray(full, dtype=self.data.dtype)
        new_norms = np.where(mask, norms, np.float32(0.0)).astype(np.float32)
        return DBCSRMatrix(data, self.layout, self.grid, mask, new_norms)

    def transpose(self) -> "DBCSRMatrix":
        layout = BlockLayout(self.layout.cols, self.layout.rows,
                             self.layout.block_cols, self.layout.block_rows)
        mask = None if self.block_mask is None else self.block_mask.T.copy()
        norms = (None if self.block_norms is None
                 else self.block_norms.T.copy())
        return DBCSRMatrix(self.data.T, layout, self.grid, mask, norms)

    def trace(self) -> jax.Array:
        return jnp.trace(self.data)

    def scale(self, alpha) -> "DBCSRMatrix":
        norms = None
        if self.block_norms is not None:
            try:
                # |alpha| rescales Frobenius norms exactly — but only a
                # concrete scalar can update the host-side cache; under
                # a tracer the cache is dropped (recomputed lazily)
                norms = (self.block_norms
                         * np.float32(abs(float(alpha)))).astype(np.float32)
            except Exception:  # traced alpha cannot reach host numpy
                norms = None
        return dataclasses.replace(self, data=self.data * alpha,
                                   block_norms=norms)


def _sharding(mesh: Mesh, grid: GridSpec) -> NamedSharding:
    return NamedSharding(mesh, P(grid.row_axis, grid.col_axis))


def create(
    array,
    *,
    mesh: Mesh,
    grid: GridSpec = GridSpec(),
    block_size: int = 64,
    block_mask: Optional[np.ndarray] = None,
    compute_norms: bool = False,
) -> DBCSRMatrix:
    """Create a DBCSR matrix from a host/global array (library owns the
    distribution, like dbcsr_create + dbcsr_put_block).
    ``compute_norms=True`` eagerly populates the per-block Frobenius
    norm cache (otherwise ``norms()`` computes it on first use)."""
    rows, cols = array.shape
    layout = BlockLayout(rows, cols, block_size, block_size)
    data = jax.device_put(array, _sharding(mesh, grid))
    if block_mask is not None:
        if block_mask.shape != (layout.nblock_rows, layout.nblock_cols):
            raise ValueError("block_mask shape mismatch")
        # zero out absent blocks so dense math matches sparse semantics
        mask_full = np.repeat(np.repeat(block_mask, block_size, 0), block_size, 1)
        data = data * jnp.asarray(mask_full, dtype=data.dtype)
    out = DBCSRMatrix(data, layout, grid, block_mask)
    if compute_norms:
        out.norms()
    return out


def add(a: DBCSRMatrix, b: DBCSRMatrix) -> DBCSRMatrix:
    """C = A + B.  Result occupancy is the union of the operands'.

    A missing mask means *dense* (every block present), so when exactly
    one operand carries a mask the union with the dense operand is
    dense and the result mask is deliberately ``None`` — not a dropped
    mask, but the correct all-present occupancy (contrast multiply(),
    where a one-sided mask does constrain the product's support).

    Norms are NOT propagated: ``||A + B||_F`` per block is not
    derivable from the operands' norms (only bounded), and the cache
    must never hold a bound where ``filter()`` expects the truth — the
    result recomputes lazily via ``norms()``.
    """
    mask = None
    if a.block_mask is not None and b.block_mask is not None:
        mask = a.block_mask | b.block_mask
    return DBCSRMatrix(a.data + b.data, a.layout, a.grid, mask)


def trace(a: DBCSRMatrix) -> jax.Array:
    return a.trace()


def transpose(a: DBCSRMatrix) -> DBCSRMatrix:
    return a.transpose()


def multiply_vector(a: DBCSRMatrix, x: jax.Array) -> jax.Array:
    """y = A @ x (paper section II lists matrix-vector among the ops).

    The 2D-sharded payload contracts its column-sharded dim against the
    replicated vector; GSPMD reduces the row partials (the degenerate
    N=1 tall-skinny case)."""
    return a.data @ x


def multiply(
    a: DBCSRMatrix,
    b: DBCSRMatrix,
    *,
    mesh: Mesh,
    algorithm: str = "auto",
    densify: Optional[bool] = None,
    filter_eps: Optional[float] = None,
    return_plan: bool = False,
    **kw,
) -> DBCSRMatrix:
    """C = A @ B — with ``algorithm="auto"`` (the default) the
    cost-model planner (repro.planner.plan_multiply) picks the
    data-exchange algorithm AND the local path for this (shape,
    occupancy, mesh); a fixed ``algorithm=``/``densify=`` pins them
    (``densify=None`` under a fixed algorithm means densified, the
    legacy default).

    Block occupancy flows end to end: the operands' masks are handed to
    the distributed dispatcher (the blocked path plans only present
    triples and skips empty shift/panel steps), and the result carries
    the symbolic product mask ``(a_mask @ b_mask) > 0`` — with a missing
    operand mask treated as all-present, so a single masked operand
    still constrains the product's support.

    ``filter_eps`` — norm-based on-the-fly filtering (repro.sparsity),
    the interaction with ``block_mask`` being strictly *subtractive*:

      * the binary masks still decide which blocks exist at all; on top
        of them, product contributions with ``norm(A_ik) * norm(B_kj) <
        filter_eps`` are dropped before they reach a multiplication
        stack (operand norms come from ``norms()``, computed on the fly
        when not already cached),
      * the result's ``block_mask`` is the *retained* support — C
        blocks with at least one surviving contribution — which is a
        subset of the symbolic mask product, and the payload is zeroed
        outside it (so the mask/zeros invariant holds on the densified
        path too, whose single big GEMM does not drop triples),
      * ``filter_eps=0.0`` retains everything: identical result, mask
        and payload to the unfiltered path; ``None`` (default) disables
        the norm machinery entirely,
      * per-block truncation error is bounded by ``nbk * filter_eps``
        (at most nbk dropped contributions, each below eps).

    The executed plan is observable without re-deriving it: the product
    carries it as ``C.last_plan`` (a ``MultiplyPlan`` with per-candidate
    predicted costs via ``.explain()``, the executed blocked-path stack
    statistics as ``.executor_stats`` — including retained-vs-filtered
    triple counts under eps), and ``return_plan=True`` additionally
    returns ``(C, plan)``.  ``last_plan`` is a plain host-side
    attribute — it does not survive pytree flatten/jit round-trips
    (only ``data``/``layout``/``grid``/``block_mask``/``block_norms``
    do).
    """
    from .multiply import distributed_matmul

    an = bn = None
    if filter_eps is not None:
        an, bn = a.norms(), b.norms()
    c_data, plan = distributed_matmul(
        a.data, b.data, mesh=mesh, grid=a.grid,
        algorithm=algorithm, densify=densify,
        block_m=a.layout.block_rows, block_k=a.layout.block_cols,
        block_n=b.layout.block_cols,
        a_mask=a.block_mask, b_mask=b.block_mask,
        a_norms=an, b_norms=bn, filter_eps=filter_eps,
        return_plan=True, **kw,
    )
    c_layout = BlockLayout(a.layout.rows, b.layout.cols,
                           a.layout.block_rows, b.layout.block_cols)
    mask = None
    if (a.block_mask is not None or b.block_mask is not None
            or filter_eps is not None):
        from .stacks import normalize_block_masks

        am, bm = normalize_block_masks(
            a.layout.nblock_rows, a.layout.nblock_cols,
            b.layout.nblock_cols, a.block_mask, b.block_mask)
        if filter_eps is not None:
            from repro.sparsity.filter import product_mask

            mask = product_mask(am, bm, an, bn, filter_eps)
            # enforce the mask/zeros invariant — load-bearing on BOTH
            # local paths: the densified GEMM computes sub-eps blocks
            # the retained mask excludes, and the blocked path's SPMD
            # union-of-max steps let a rank deposit small contributions
            # into blocks outside the global retained support
            full = np.repeat(np.repeat(mask, a.layout.block_rows, 0),
                             b.layout.block_cols, 1)
            c_data = c_data * jnp.asarray(full, dtype=c_data.dtype)
        else:
            mask = (am.astype(np.int64) @ bm.astype(np.int64)) > 0
    c = DBCSRMatrix(c_data, c_layout, a.grid, mask)
    c.last_plan = plan
    return (c, plan) if return_plan else c
