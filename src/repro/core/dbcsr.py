"""DBCSRMatrix — user-facing distributed blocked matrix container.

Mirrors the DBCSR API surface (create / multiply / add / trace /
transpose / to-from ScaLAPACK-style layouts) on top of JAX arrays with
NamedSharding.  The payload of a dense DBCSR matrix is simply a 2D
array sharded over the (row_axis, col_axis) process grid; the blocked
structure is metadata (BlockLayout) consumed by the local-multiply
strategies.

Block-sparse matrices carry an additional static block mask (numpy
bool, (nblock_rows, nblock_cols)); absent blocks are stored as zeros in
the dense payload (occupancy handling is metadata-level: the stack
generator skips absent blocks, which is where sparse wins come from in
DBCSR).  This keeps every array shape static — mandatory for pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs

from .blocking import BlockLayout, GridSpec

__all__ = ["DBCSRMatrix", "create", "multiply", "multiply_batched",
           "multiply_vector", "add", "trace", "transpose",
           "contract", "create_tensor"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DBCSRMatrix:
    """A distributed blocked matrix.

    data       : (rows, cols) jax.Array, sharded P(row_axis, col_axis)
    layout     : block structure metadata
    grid       : mesh-axis names of the process grid
    block_mask : optional (nbr, nbc) numpy bool — block-sparse occupancy
    block_norms: optional (nbr, nbc) numpy float32 — per-block Frobenius
                 norms (repro.sparsity), lazily computed/cached by
                 ``norms()`` and consumed by the ``filter_eps`` multiply
                 path and ``filter()``

    Products returned by ``multiply`` additionally carry the executed
    ``MultiplyPlan`` as a plain ``last_plan`` attribute (host-side
    observability only — not part of the pytree, does not survive jit).
    """

    data: jax.Array
    layout: BlockLayout
    grid: GridSpec
    block_mask: Optional[np.ndarray] = None
    block_norms: Optional[np.ndarray] = None

    # -- pytree protocol (data is a leaf; the rest is static) ----------
    def tree_flatten(self):
        # mask AND norms ride in aux as (shape, bytes): hashable (jit
        # cache key) AND sufficient to reconstruct the arrays on
        # unflatten, so block sparsity — and its norms — survive
        # jit/vmap/scan round-trips.
        mask_aux = (None if self.block_mask is None
                    else (self.block_mask.shape, self.block_mask.tobytes()))
        norms_aux = None
        if self.block_norms is not None:
            norms = np.ascontiguousarray(self.block_norms, dtype=np.float32)
            norms_aux = (norms.shape, norms.tobytes())
        return (self.data,), (self.layout, self.grid, mask_aux, norms_aux)

    @classmethod
    def tree_unflatten(cls, aux, children):
        layout, grid, mask_aux, norms_aux = aux
        mask = None
        if mask_aux is not None:
            shape, raw = mask_aux
            mask = np.frombuffer(raw, dtype=bool).reshape(shape).copy()
        norms = None
        if norms_aux is not None:
            shape, raw = norms_aux
            norms = np.frombuffer(raw, dtype=np.float32).reshape(shape).copy()
        return cls(children[0], layout, grid, mask, norms)

    # -- DBCSR-like API -------------------------------------------------
    @property
    def shape(self):
        return self.data.shape

    @property
    def occupancy(self) -> float:
        if self.block_mask is None:
            return 1.0
        return float(self.block_mask.mean())

    def norms(self, recompute: bool = False) -> np.ndarray:
        """Per-block Frobenius norms ((nbr, nbc) float32 numpy), cached
        on the matrix after the first call (one blockwise device
        reduction per geometry — repro.sparsity.norms).  Mask-absent
        blocks report 0.  Pass ``recompute=True`` after mutating
        ``data`` through a non-DBCSR op (the cache cannot observe
        that)."""
        if self.block_norms is None or recompute:
            from repro.sparsity.norms import block_norms_of

            self.block_norms = block_norms_of(
                self.data, self.layout.block_rows, self.layout.block_cols,
                self.block_mask)
        return self.block_norms

    def filter(self, eps: float) -> "DBCSRMatrix":
        """DBCSR's post-multiply filtering pass: re-derive the
        occupancy from the *actual* block norms, dropping every block
        with ``norm < eps`` (blocks exactly at eps survive, matching
        the triple-filter contract), zeroing the dropped blocks'
        payload so dense math keeps matching sparse semantics.  Never
        resurrects a block the current mask declares absent."""
        norms = self.norms()
        mask = norms >= float(eps)
        if self.block_mask is not None:
            mask &= self.block_mask
        bs_r, bs_c = self.layout.block_rows, self.layout.block_cols
        full = np.repeat(np.repeat(mask, bs_r, 0), bs_c, 1)
        data = self.data * jnp.asarray(full, dtype=self.data.dtype)
        new_norms = np.where(mask, norms, np.float32(0.0)).astype(np.float32)
        return DBCSRMatrix(data, self.layout, self.grid, mask, new_norms)

    def transpose(self) -> "DBCSRMatrix":
        layout = BlockLayout(self.layout.cols, self.layout.rows,
                             self.layout.block_cols, self.layout.block_rows)
        mask = None if self.block_mask is None else self.block_mask.T.copy()
        norms = (None if self.block_norms is None
                 else self.block_norms.T.copy())
        return DBCSRMatrix(self.data.T, layout, self.grid, mask, norms)

    def trace(self) -> jax.Array:
        return jnp.trace(self.data)

    def scale(self, alpha) -> "DBCSRMatrix":
        norms = None
        if self.block_norms is not None:
            try:
                # |alpha| rescales Frobenius norms exactly — but only a
                # concrete scalar can update the host-side cache; under
                # a tracer the cache is dropped (recomputed lazily)
                norms = (self.block_norms
                         * np.float32(abs(float(alpha)))).astype(np.float32)
            except Exception:  # traced alpha cannot reach host numpy
                norms = None
        return dataclasses.replace(self, data=self.data * alpha,
                                   block_norms=norms)


def _sharding(mesh: Mesh, grid: GridSpec) -> NamedSharding:
    return NamedSharding(mesh, P(grid.row_axis, grid.col_axis))


def create(
    array,
    *,
    mesh: Mesh,
    grid: GridSpec = GridSpec(),
    block_size: int = 64,
    block_mask: Optional[np.ndarray] = None,
    compute_norms: bool = False,
) -> DBCSRMatrix:
    """Create a DBCSR matrix from a host/global array (library owns the
    distribution, like dbcsr_create + dbcsr_put_block).
    ``compute_norms=True`` eagerly populates the per-block Frobenius
    norm cache (otherwise ``norms()`` computes it on first use)."""
    rows, cols = array.shape
    layout = BlockLayout(rows, cols, block_size, block_size)
    data = jax.device_put(array, _sharding(mesh, grid))
    if block_mask is not None:
        if block_mask.shape != (layout.nblock_rows, layout.nblock_cols):
            raise ValueError("block_mask shape mismatch")
        # zero out absent blocks so dense math matches sparse semantics
        mask_full = np.repeat(np.repeat(block_mask, block_size, 0), block_size, 1)
        data = data * jnp.asarray(mask_full, dtype=data.dtype)
    out = DBCSRMatrix(data, layout, grid, block_mask)
    if compute_norms:
        out.norms()
    return out


def add(a: DBCSRMatrix, b: DBCSRMatrix,
        recompute_norms: bool = False) -> DBCSRMatrix:
    """C = A + B.  Result occupancy is the union of the operands'.

    A missing mask means *dense* (every block present), so when exactly
    one operand carries a mask the union with the dense operand is
    dense and the result mask is deliberately ``None`` — not a dropped
    mask, but the correct all-present occupancy (contrast multiply(),
    where a one-sided mask does constrain the product's support).

    Norms are NOT propagated: ``||A + B||_F`` per block is not
    derivable from the operands' norms (only bounded), and the cache
    must never hold a bound where ``filter()`` expects the truth — so
    by default the result's norm cache is empty and recomputes lazily
    via ``norms()``.  ``recompute_norms=True`` is a convenience that
    eagerly computes the sum's true norms from its payload before
    returning (one blockwise reduction — exactly what the first
    ``norms()`` call would do; handy when the caller filters or
    eps-multiplies the sum immediately, e.g. purification iterations).
    """
    mask = None
    if a.block_mask is not None and b.block_mask is not None:
        mask = a.block_mask | b.block_mask
    out = DBCSRMatrix(a.data + b.data, a.layout, a.grid, mask)
    if recompute_norms:
        out.norms()
    return out


def trace(a: DBCSRMatrix) -> jax.Array:
    return a.trace()


def transpose(a: DBCSRMatrix) -> DBCSRMatrix:
    return a.transpose()


def multiply_vector(a: DBCSRMatrix, x: jax.Array) -> jax.Array:
    """y = A @ x (paper section II lists matrix-vector among the ops).

    The 2D-sharded payload contracts its column-sharded dim against the
    replicated vector; GSPMD reduces the row partials (the degenerate
    N=1 tall-skinny case)."""
    return a.data @ x


def _product_mask(a: DBCSRMatrix, b: DBCSRMatrix, an, bn,
                  filter_eps: Optional[float]):
    """The result support of C = A @ B, shared by ``multiply`` and
    ``multiply_batched``: ``(mask, needs_zeroing)`` where ``mask`` is
    the symbolic product support ``(a_mask @ b_mask) > 0`` (None when
    both operands are dense and no filter applies) or, under
    ``filter_eps``, the eps-*retained* support — in which case the
    payload outside it must be zeroed (``needs_zeroing``) to keep the
    mask/zeros invariant on both local paths."""
    if (a.block_mask is None and b.block_mask is None
            and filter_eps is None):
        return None, False
    from .stacks import normalize_block_masks

    am, bm = normalize_block_masks(
        a.layout.nblock_rows, a.layout.nblock_cols,
        b.layout.nblock_cols, a.block_mask, b.block_mask)
    if filter_eps is not None:
        from repro.sparsity.filter import product_mask

        return product_mask(am, bm, an, bn, filter_eps), True
    return (am.astype(np.int64) @ bm.astype(np.int64)) > 0, False


def _apply_result_mask(c_data: jax.Array, mask: Optional[np.ndarray],
                       needs_zeroing: bool, block_rows: int,
                       block_cols: int) -> jax.Array:
    """Zero the payload outside the retained support (eps path only —
    the symbolic-product mask never needs it, absent blocks are already
    exact zeros)."""
    if mask is None or not needs_zeroing:
        return c_data
    full = np.repeat(np.repeat(mask, block_rows, 0), block_cols, 1)
    return c_data * jnp.asarray(full, dtype=c_data.dtype)


def multiply(
    a: DBCSRMatrix,
    b: DBCSRMatrix,
    *,
    mesh: Mesh,
    algorithm: str = "auto",
    densify: Optional[bool] = None,
    filter_eps: Optional[float] = None,
    verify: Optional[str] = None,
    return_plan: bool = False,
    **kw,
) -> DBCSRMatrix:
    """C = A @ B — with ``algorithm="auto"`` (the default) the
    cost-model planner (repro.planner.plan_multiply) picks the
    data-exchange algorithm AND the local path for this (shape,
    occupancy, mesh); a fixed ``algorithm=``/``densify=`` pins them
    (``densify=None`` under a fixed algorithm means densified, the
    legacy default).

    Block occupancy flows end to end: the operands' masks are handed to
    the distributed dispatcher (the blocked path plans only present
    triples and skips empty shift/panel steps), and the result carries
    the symbolic product mask ``(a_mask @ b_mask) > 0`` — with a missing
    operand mask treated as all-present, so a single masked operand
    still constrains the product's support.

    ``filter_eps`` — norm-based on-the-fly filtering (repro.sparsity),
    the interaction with ``block_mask`` being strictly *subtractive*:

      * the binary masks still decide which blocks exist at all; on top
        of them, product contributions with ``norm(A_ik) * norm(B_kj) <
        filter_eps`` are dropped before they reach a multiplication
        stack (operand norms come from ``norms()``, computed on the fly
        when not already cached),
      * the result's ``block_mask`` is the *retained* support — C
        blocks with at least one surviving contribution — which is a
        subset of the symbolic mask product, and the payload is zeroed
        outside it (so the mask/zeros invariant holds on the densified
        path too, whose single big GEMM does not drop triples),
      * ``filter_eps=0.0`` retains everything: identical result, mask
        and payload to the unfiltered path; ``None`` (default) disables
        the norm machinery entirely,
      * per-block truncation error is bounded by ``nbk * filter_eps``
        (at most nbk dropped contributions, each below eps).

    The executed plan is observable without re-deriving it: the product
    carries it as ``C.last_plan`` (a ``MultiplyPlan`` with per-candidate
    predicted costs via ``.explain()``, the executed blocked-path stack
    statistics as ``.executor_stats`` — including retained-vs-filtered
    triple counts under eps), and ``return_plan=True`` additionally
    returns ``(C, plan)``.  ``last_plan`` is a plain host-side
    attribute — it does not survive pytree flatten/jit round-trips
    (only ``data``/``layout``/``grid``/``block_mask``/``block_norms``
    do).

    ``verify`` — ABFT self-verification (repro.robustness):

      * ``"checksum"`` verifies the raw product against independently
        computed Huang–Abraham block checksums *before* the result mask
        is applied; detection tolerances scale with the PR 5 norm cache
        (``||A_ik||_F * ||B_kj||_F`` bounds plus the eps-filtered
        dropped mass), so float accumulation order and ``filter_eps``
        dropping never false-positive.  A detected corruption is
        localized to its exact block coordinates, repaired by ONE
        deterministic recompute of the flagged blocks (bitwise equal to
        a clean run), and reported; corruption that survives repair
        raises ``repro.robustness.guards.CorruptionDetectedError``.
        Operands are screened by the NaN/Inf tripwires first
        (``NonFiniteOperandError`` — poison inputs are not a checksum
        problem).
      * ``"auto"`` enables verification only when the planner prices
        its checksum overhead (extra flops + comm for the augmented
        row/column — ``cost_model.verify_overhead_s``) within
        ``verify_budget`` (default 25%) of the plan's predicted time.
      * ``None`` (default) adds zero work — bit-identical to the
        unverified multiply.

    The outcome is observable as ``C.verification`` (and
    ``plan.verification``): a dict with the pricing decision and the
    ``VerificationReport`` (``detected``, flagged ``(i, j)`` blocks,
    residuals vs tolerances, ``repaired``) when verification ran.

    Many small products?  See ``multiply_batched``: it fuses
    same-geometry requests into one dispatch, amortizing the per-call
    trace/launch cost that dominates small multiplies.  Batching and
    filtering compose — a fused bucket is (geometry, occupancy-bin,
    eps)-uniform by construction, so ``filter_eps`` semantics inside a
    batch are identical to this single-product path.
    """
    from .multiply import distributed_matmul

    an = bn = None
    if filter_eps is not None:
        an, bn = a.norms(), b.norms()
    c_data, plan = distributed_matmul(
        a.data, b.data, mesh=mesh, grid=a.grid,
        algorithm=algorithm, densify=densify,
        block_m=a.layout.block_rows, block_k=a.layout.block_cols,
        block_n=b.layout.block_cols,
        a_mask=a.block_mask, b_mask=b.block_mask,
        a_norms=an, b_norms=bn, filter_eps=filter_eps,
        verify=verify, return_plan=True, **kw,
    )
    c_layout = BlockLayout(a.layout.rows, b.layout.cols,
                           a.layout.block_rows, b.layout.block_cols)
    # the eps path zeroes the payload outside the retained support —
    # load-bearing on BOTH local paths: the densified GEMM computes
    # sub-eps blocks the retained mask excludes, and the blocked path's
    # SPMD union-of-max steps let a rank deposit small contributions
    # into blocks outside the global retained support
    mask, zero = _product_mask(a, b, an, bn, filter_eps)
    c_data = _apply_result_mask(c_data, mask, zero, a.layout.block_rows,
                                b.layout.block_cols)
    c = DBCSRMatrix(c_data, c_layout, a.grid, mask)
    c.last_plan = plan
    c.verification = plan.verification
    return (c, plan) if return_plan else c


def create_tensor(array, *, mesh, grid=GridSpec(), block_sizes,
                  block_mask=None, compute_norms=False):
    """Create a blocked N-d ``DBCSRTensor`` (repro.tensor) — the tensor
    analogue of ``create``: uniform per-axis blocking, an optional N-d
    block occupancy mask (absent blocks' payload zeroed) and a lazily
    cached per-block Frobenius norm tensor.  Tensors are contracted
    with ``contract``."""
    from repro.tensor import create_tensor as _create_tensor

    return _create_tensor(array, mesh=mesh, grid=grid,
                          block_sizes=block_sizes, block_mask=block_mask,
                          compute_norms=compute_norms)


def contract(
    spec: str,
    a,
    b,
    *,
    mesh: Mesh,
    algorithm: str = "auto",
    layout="auto",
    densify: Optional[bool] = None,
    filter_eps: Optional[float] = None,
    verify: Optional[str] = None,
    rank_exact: Optional[bool] = None,
    return_plan: bool = False,
    **kw,
):
    """C = contraction of two blocked tensors per an einsum ``spec``
    (``"ijk,kl->ijl"``) — the N-d sibling of ``multiply`` /
    ``multiply_batched`` (repro.tensor, after arXiv:1910.13555): the
    spec is parsed into (contracted, A-free, B-free) index groups, the
    tensors are MATRICIZED — each group fused into one blocked matrix
    dimension at the block level, so masks lower by a pure block-grid
    transpose (an N-d block is retained iff its 2D image is) and the
    Frobenius norm cache lowers exactly (norms are invariant to the
    intra-block permutation) — the 2D product runs through the ordinary
    ``multiply``, and the result folds back into the spec's output
    frame as a ``DBCSRTensor`` carrying the retained N-d mask.

    ``layout`` — the matricization is a COSTED choice, not a
    convention: every legal layout (fusion orders of the three index
    groups x the transposed variant) is priced by the planner as its
    own 2D multiply plan (per-layout occupancy and rank-imbalance from
    the matricized masks) plus its unfold/refold copy cost
    (``cost_model.matricize_cost_s``).  ``"auto"`` (default) lets
    ``planner.plan_contract`` pick — LRU-cached on the contraction
    signature, so a repeated contraction replans for free; a
    ``Layout`` instance or its label string (e.g. ``"(ij|k)@(k|l)"``)
    pins it.  The decision is observable: the result carries the
    executed ``ContractionPlan`` as ``C.last_plan``, whose
    ``explain()`` prints the per-layout table above the winning
    layout's per-candidate multiply breakdown.

    ``algorithm`` / ``densify`` / ``filter_eps`` / ``verify`` /
    ``rank_exact`` and any further kwargs thread through to the
    underlying ``multiply`` with identical semantics — eps filtering
    uses the lowered norms (same subtractive contract, ``filter_eps=0``
    bit-identical to unfiltered), ABFT verification detects/localizes/
    repairs corruption before the refold (so the guarantee lands in the
    tensor frame, reported as ``C.verification``), and rank-exact
    per-rank plans see the matricized masks.

    At a FIXED layout the result is bitwise equal to hand-matricizing
    the operands and calling ``multiply`` directly (the fold is a pure
    element permutation); different layouts change the fused
    accumulation order and agree to float tolerance only.

    ``return_plan=True`` returns ``(C, ContractionPlan)``.
    """
    from repro.tensor import contract as _contract

    return _contract(spec, a, b, mesh=mesh, algorithm=algorithm,
                     layout=layout, densify=densify,
                     filter_eps=filter_eps, verify=verify,
                     rank_exact=rank_exact, return_plan=return_plan, **kw)


def _bucket_key(a: DBCSRMatrix, b: DBCSRMatrix,
                filter_eps: Optional[float]) -> tuple:
    """The batching bucket contract: requests fuse only when they agree
    on (geometry, occupancy-bin, eps).

      geometry       operand shapes + block sizes + grid axis names —
                     everything the traced dispatch program's shape
                     depends on
      occupancy-bin  ``fill_bin`` of each operand's block-mask fill
                     (the autotune table's log-spaced bins): requests in
                     one bin share stack params and pad little against
                     each other; finer distinctions stay per-request
                     via the content-fingerprinted plan memo
      eps            the norm-filter threshold — it shapes the
                     per-group plans, so it must be bucket-uniform

    This is the same key contract the serving layer
    (repro.serve.multiply_service) buckets queued requests by.
    """
    from repro.kernels.smm.autotune import fill_bin

    return (
        tuple(a.shape), tuple(b.shape),
        a.layout.block_rows, a.layout.block_cols, b.layout.block_cols,
        a.grid.row_axis, a.grid.col_axis,
        fill_bin(a.occupancy), fill_bin(b.occupancy),
        None if filter_eps is None else float(filter_eps),
    )


def _execute_bucket(group, *, mesh, algorithm, densify, filter_eps,
                    fused, verify=None, **kw):
    """Run one bucket of same-key requests: fused (one batched
    dispatch) or looped (per-request ``multiply``), per the planner's
    fuse-or-loop pricing unless ``fused`` pins it.

    ``verify`` forces the looped path: ABFT checksums verify one
    product at a time (verification of the fused batched dispatch is an
    open ROADMAP item), so a verified bucket trades the fusion win for
    per-request detection/repair."""
    from .multiply_batched import BATCHED_ALGORITHMS

    if verify is not None:
        if fused:
            raise ValueError(
                "verify= requires the looped path (ABFT on the fused "
                "batched dispatch is not implemented); drop fused=True")
        fused = False
    a0, b0 = group[0]
    g = len(group)
    an = bn = None
    if filter_eps is not None:
        an = [a.norms() for a, _ in group]
        bn = [b.norms() for _, b in group]

    batchable = (algorithm in ("auto",) + BATCHED_ALGORITHMS
                 and kw.get("bcast") != "gather")
    if fused and not batchable:
        raise ValueError(
            f"fused=True requires a batch-capable algorithm "
            f"{BATCHED_ALGORITHMS}, got {algorithm!r}"
            + (" with bcast='gather'" if kw.get("bcast") == "gather"
               else ""))
    plan = None
    fuse = fused
    if fuse is None:
        fuse = batchable and g > 1
        if fuse:
            from repro.planner.plan import plan_multiply_batched

            from .multiply import _global_occupancy

            pr, pc = a0.grid.grid_shape(mesh)
            occs = [
                _global_occupancy(
                    a.layout.rows, a.layout.cols, b.layout.cols,
                    a.layout.block_rows, a.layout.block_cols,
                    b.layout.block_cols, a.block_mask, b.block_mask,
                    an[i] if an else None, bn[i] if bn else None,
                    filter_eps)
                for i, (a, b) in enumerate(group)
            ]
            occ = sum(occs) / len(occs)
            occ_max = max(occs)
            plan = plan_multiply_batched(
                g, a0.layout.rows, a0.layout.cols, b0.layout.cols,
                blocks=(a0.layout.block_rows, a0.layout.block_cols,
                        b0.layout.block_cols),
                mesh_shape=(pr, pc), occupancy=occ,
                dtype=a0.data.dtype,
                algorithm=None if algorithm == "auto" else algorithm,
                densify=densify,
                padding_frac=(1.0 - occ / occ_max if occ_max > 0 else 0.0))
            fuse = plan.fuse

    if not fuse:
        out = [multiply(a, b, mesh=mesh, algorithm=algorithm,
                        densify=densify, filter_eps=filter_eps,
                        verify=verify, **kw)
               for a, b in group]
        return out, {"fused": False, "plan": plan}

    from .multiply_batched import distributed_matmul_batched

    a_masks = [a.block_mask for a, _ in group]
    b_masks = [b.block_mask for _, b in group]
    if all(x is None for x in a_masks):
        a_masks = None
    if all(x is None for x in b_masks):
        b_masks = None
    c_data, bplan = distributed_matmul_batched(
        jnp.stack([a.data for a, _ in group]),
        jnp.stack([b.data for _, b in group]),
        mesh=mesh, grid=a0.grid, algorithm=algorithm, densify=densify,
        block_m=a0.layout.block_rows, block_k=a0.layout.block_cols,
        block_n=b0.layout.block_cols,
        a_masks=a_masks, b_masks=b_masks, a_norms=an, b_norms=bn,
        filter_eps=filter_eps, return_plan=True, **kw)
    c_layout = BlockLayout(a0.layout.rows, b0.layout.cols,
                           a0.layout.block_rows, b0.layout.block_cols)
    out = []
    for gi, (a, b) in enumerate(group):
        mask, zero = _product_mask(
            a, b, an[gi] if an else None, bn[gi] if bn else None,
            filter_eps)
        cd = _apply_result_mask(c_data[gi], mask, zero,
                                a.layout.block_rows, b.layout.block_cols)
        c = DBCSRMatrix(cd, c_layout, a.grid, mask)
        c.last_plan = bplan
        out.append(c)
    return out, {"fused": True, "plan": bplan}


def multiply_batched(
    requests,
    *,
    mesh: Mesh,
    algorithm: str = "auto",
    densify: Optional[bool] = None,
    filter_eps: Optional[float] = None,
    fused: Optional[bool] = None,
    verify: Optional[str] = None,
    return_plan: bool = False,
    **kw,
):
    """Many products, one dispatch: ``requests`` is a sequence of
    ``(A, B)`` DBCSRMatrix pairs; returns their products in input
    order.

    Requests are bucketed by the ``(geometry, occupancy-bin, eps)``
    key (see ``_bucket_key``) and each bucket executes either FUSED —
    operands stacked ``(G, m, k)``, ONE schedule / ONE fused stack
    dispatch for the whole bucket
    (core/multiply_batched.distributed_matmul_batched) — or LOOPED
    (per-request ``multiply``), whichever the planner prices cheaper
    (``plan_multiply_batched``: amortized trace/launch/latency vs
    cross-request padding waste).  ``fused=True``/``False`` pins the
    choice; ``None`` (default) lets the planner decide per bucket.

    Semantics match per-request ``multiply`` exactly: per-request
    product masks, eps-retained support and payload zeroing, and each
    result carries its bucket's executed ``BatchedMultiplyPlan`` as
    ``last_plan``.  At ``pipeline_depth=1`` with ``filter_eps`` in
    {None, 0.0} the fused blocked path is bit-identical to the looped
    one (core/multiply_batched bit-identity contract).

    ``verify`` (repro.robustness): per-request ABFT verification with
    the same semantics as ``multiply(verify=...)``; it forces the
    looped path (checksums on the fused batched dispatch are an open
    ROADMAP item), so a verified bucket trades the fusion win for
    per-request corruption detection and repair.

    ``return_plan=True`` returns ``(results, report)`` where the
    report carries per-bucket fusion stats: request count, the
    fuse-or-loop decision, and the executed plan (padding fractions,
    cross-request plan sharing, predicted fused-vs-looped times).
    """
    requests = list(requests)
    if not requests:
        return ([], {"n_requests": 0, "n_buckets": 0, "buckets": []}) \
            if return_plan else []
    buckets: dict = {}
    for i, (a, b) in enumerate(requests):
        buckets.setdefault(_bucket_key(a, b, filter_eps), []).append(i)
    results: list = [None] * len(requests)
    bucket_reports = []
    for key, idxs in buckets.items():
        out, rep = _execute_bucket(
            [requests[i] for i in idxs], mesh=mesh, algorithm=algorithm,
            densify=densify, filter_eps=filter_eps, fused=fused,
            verify=verify, **kw)
        for i, c in zip(idxs, out):
            results[i] = c
        if obs.enabled():
            # fuse-or-loop decision accounting (planner or pinned)
            obs.counter("batched.requests_fused" if rep["fused"]
                        else "batched.requests_looped").inc(len(idxs))
            obs.counter("batched.buckets").inc()
        bucket_reports.append({
            "key": key, "n_requests": len(idxs), "request_indices": idxs,
            **rep})
    if not return_plan:
        return results
    report = {
        "n_requests": len(requests),
        "n_buckets": len(buckets),
        "n_fused_requests": sum(r["n_requests"] for r in bucket_reports
                                if r["fused"]),
        "buckets": bucket_reports,
    }
    return results, report
