"""Block layout descriptors for DBCSR-style blocked matrices.

DBCSR stores matrices as a grid of small dense blocks, block-cyclic
distributed over a 2D process grid.  On TPU we keep the same *logical*
layout but the per-device payload is a contiguous array; the block
structure is static metadata used by the stack scheduler (stacks.py)
and the densification pass (densify.py).

Everything in this module is host-side / static: plain ints and numpy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

__all__ = [
    "BlockLayout",
    "GridSpec",
    "ceil_div",
    "pad_to_multiple",
]


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pad_to_multiple(n: int, m: int) -> int:
    return ceil_div(n, m) * m


@dataclasses.dataclass(frozen=True)
class BlockLayout:
    """Uniform-block layout of a (rows x cols) matrix.

    The paper uses square blocks of size 22 / 64 (and 4 in one test);
    we support any uniform (block_rows x block_cols).
    """

    rows: int
    cols: int
    block_rows: int
    block_cols: int

    def __post_init__(self):
        if self.rows % self.block_rows:
            raise ValueError(
                f"rows={self.rows} not divisible by block_rows={self.block_rows}"
            )
        if self.cols % self.block_cols:
            raise ValueError(
                f"cols={self.cols} not divisible by block_cols={self.block_cols}"
            )

    @property
    def nblock_rows(self) -> int:
        return self.rows // self.block_rows

    @property
    def nblock_cols(self) -> int:
        return self.cols // self.block_cols

    @property
    def nblocks(self) -> int:
        return self.nblock_rows * self.nblock_cols

    def block_shape(self) -> Tuple[int, int]:
        return (self.block_rows, self.block_cols)

    def local(self, grid_rows: int, grid_cols: int) -> "BlockLayout":
        """Layout of one device's shard under an even 2D split."""
        if self.nblock_rows % grid_rows or self.nblock_cols % grid_cols:
            raise ValueError(
                f"block grid {self.nblock_rows}x{self.nblock_cols} not divisible "
                f"by process grid {grid_rows}x{grid_cols}"
            )
        return BlockLayout(
            self.rows // grid_rows,
            self.cols // grid_cols,
            self.block_rows,
            self.block_cols,
        )


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Names the mesh axes used as the DBCSR 2D process grid.

    ``stack_axis`` (optional) is the 2.5D replication axis (the "pod"
    axis of the production mesh) used by cannon25d.
    """

    row_axis: str = "data"
    col_axis: str = "model"
    stack_axis: str | None = None

    def grid_shape(self, mesh) -> Tuple[int, int]:
        return mesh.shape[self.row_axis], mesh.shape[self.col_axis]

    def stack_size(self, mesh) -> int:
        if self.stack_axis is None:
            return 1
        return mesh.shape[self.stack_axis]

    def validate_square(self, mesh) -> int:
        pr, pc = self.grid_shape(mesh)
        if pr != pc:
            raise ValueError(
                f"Cannon requires a square process grid, got {pr}x{pc}. "
                "Use summa/tall_skinny for non-square grids."
            )
        return pr


def block_cyclic_owner(
    block_row: int, block_col: int, grid_rows: int, grid_cols: int
) -> Tuple[int, int]:
    """ScaLAPACK-style block-cyclic owner of a block (paper section IV:
    matrices are 'block-cycling distributed a la Scalapack')."""
    return block_row % grid_rows, block_col % grid_cols


def morton_order(n_rows: int, n_cols: int) -> np.ndarray:
    """Cache-oblivious (Z-Morton) traversal order over a block grid.

    DBCSR uses a cache-oblivious matrix traversal to fix the order in
    which blocks are multiplied (Traversal phase, Fig. 1).  Returns an
    (n_rows*n_cols, 2) int32 array of (row, col) pairs in Z-order.
    """
    side = 1 << max(n_rows - 1, n_cols - 1, 1).bit_length()
    coords = []
    for z in range(side * side):
        # de-interleave bits of z into (row, col)
        r = c = 0
        for bit in range(side.bit_length()):
            c |= ((z >> (2 * bit)) & 1) << bit
            r |= ((z >> (2 * bit + 1)) & 1) << bit
        if r < n_rows and c < n_cols:
            coords.append((r, c))
    out = np.asarray(coords, dtype=np.int32)
    assert out.shape == (n_rows * n_cols, 2)
    return out
