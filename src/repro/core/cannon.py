"""Cannon's algorithm on a square mesh grid via shard_map + ppermute.

This is DBCSR's data-exchange algorithm for general matrix shapes
(paper section II): per-process communicated data scales O(1/sqrt(P)).

TPU adaptation notes (see DESIGN.md §2):
  * MPI async point-to-point sends -> ``jax.lax.ppermute`` neighbour
    shifts.  The TPU ICI is a torus, so Cannon's row/col shifts map to
    contention-free single-hop collective-permutes.
  * The initial Cannon skew (device (i,j) must start from A(i, (i+j)%P)
    and B((i+j)%P, j)) is one joint-axis ppermute over the flattened
    (row, col) axes.
  * Communication/computation overlap (paper: MPI/CUDA-stream double
    buffering) is owned by the schedule engine (core/schedule.py): at
    ``pipeline_depth=2`` the ppermute for step t+1 is issued against a
    second buffer *before* the local multiply of step t, and XLA
    schedules the collective-permute-start/done pair around the dot.

This module is a pure *schedule builder* plus the shard_map wrapper:
``build_cannon_schedule`` emits the step sequence (skew prologue,
identity recv, neighbour-shift carry update), ``cannon_step_masks``
emits the per-step occupancy-mask slices, and the unified driver
(``schedule.execute_schedule``) runs the loop.

The local multiply is pluggable (``local_matmul``): ``densified`` uses a
single large dot (paper section III — the cuBLAS path), ``blocked``
dispatches the stack-of-small-blocks path (kernels/smm, LIBCUSMM
analogue).
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .blocking import GridSpec
from .schedule import (RolledSpec, Schedule, execute_schedule,
                       resolve_pipeline_depth)

__all__ = ["cannon_matmul", "build_cannon_schedule", "cannon_step_masks",
           "cannon_step_norms", "cannon_rank_steps"]


def _skew_perm(pg: int, which: str):
    """Joint-axis permutation realising the Cannon pre-skew.

    A: device (i, j) receives A block (i, (i+j) % P)  [row i shifted left i]
    B: device (i, j) receives B block ((i+j) % P, j)  [col j shifted up  j]
    Expressed as (source, destination) pairs over the row-major flattened
    (row, col) index space.
    """
    pairs = []
    for i in range(pg):
        for j in range(pg):
            if which == "a":  # (i, j) sends to (i, (j - i) % P)
                pairs.append((i * pg + j, i * pg + ((j - i) % pg)))
            else:  # b: (i, j) sends to ((i - j) % P, j)
                pairs.append((i * pg + j, ((i - j) % pg) * pg + j))
    return pairs


def _shift_perm(pg: int):
    """Single-axis circular shift by one (left/up)."""
    return [(k, (k - 1) % pg) for k in range(pg)]


def build_cannon_schedule(
    pg: int,
    *,
    row_axis: str,
    col_axis: str,
    skew: bool = True,
    steps: Optional[int] = None,
    step_offset: int = 0,
    empty_steps: frozenset = frozenset(),
    local_shape: Optional[tuple] = None,
    itemsize: int = 4,
) -> Schedule:
    """Schedule for Cannon's algorithm on a ``pg`` x ``pg`` grid.

    ``steps`` / ``step_offset`` support the 2.5D variant (cannon25d.py)
    where each replica executes a strided/offset subset of the shifts.
    ``local_shape`` = (ml, kl, nl) of the per-device multiply fills the
    observability byte counts (the callables never need it).
    """
    n_steps = pg if steps is None else steps
    shift_a = _shift_perm(pg)
    shift_b = _shift_perm(pg)

    def prologue(a_blk, b_blk):
        if skew:
            a_blk = jax.lax.ppermute(a_blk, (row_axis, col_axis),
                                     _skew_perm(pg, "a"))
            b_blk = jax.lax.ppermute(b_blk, (row_axis, col_axis),
                                     _skew_perm(pg, "b"))
        if step_offset:
            # jump the k-phase forward by step_offset (2.5D replica offset)
            off_a = [(j, (j - step_offset) % pg) for j in range(pg)]
            off_b = [(i, (i - step_offset) % pg) for i in range(pg)]
            a_blk = jax.lax.ppermute(a_blk, col_axis, off_a)
            b_blk = jax.lax.ppermute(b_blk, row_axis, off_b)
        return (a_blk, b_blk)

    def shift(carry, t):
        a_blk, b_blk = carry
        return (jax.lax.ppermute(a_blk, col_axis, shift_a),
                jax.lax.ppermute(b_blk, row_axis, shift_b))

    def rolled_shift(carry):
        return shift(carry, 0)

    step_bytes = 0
    prologue_bytes = 0
    if local_shape is not None:
        ml, kl, nl = local_shape
        step_bytes = (ml * kl + kl * nl) * itemsize
        prologue_bytes = step_bytes if (skew or step_offset) else 0

    return Schedule(
        algorithm="cannon",
        n_steps=n_steps,
        prologue=prologue,
        shift=shift,
        empty_steps=frozenset(empty_steps),
        rolled=RolledSpec(shift=rolled_shift,
                          vary_axes=(row_axis, col_axis)),
        comm_op=f"ppermute(a:{col_axis}, b:{row_axis})",
        prologue_comm_bytes=prologue_bytes,
        # the final step receives no shift: n_steps - 1 shifts total
        step_comm_bytes=tuple(
            step_bytes if t + 1 < n_steps else 0 for t in range(n_steps)),
    )


def cannon_step_masks(
    am: np.ndarray, bm: np.ndarray, pg: int, c_repl: int = 1,
) -> List[np.ndarray]:
    """Per-shift-step local pair-presence tensors for (2.5D) Cannon —
    the schedule builder's per-step mask slices.

    At inner step t, device (i, j) of replica p holds the A chunk
    (i, q) and B chunk (q, j) with q = (i + j + p*spr + t) % pg.  The
    returned (nbr_l, nbk_l, nbc_l) tensor for step t is the union over
    all (p, i, j) of that rank's chunk-product presence — the tightest
    plan every rank can share under SPMD.  Block-structured sparsity
    (banded / block-diagonal operands) makes whole steps empty here,
    which the schedule driver then skips.
    """
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if nbr % pg or nbk % pg or nbc % pg:
        raise ValueError(
            f"block grid ({nbr},{nbk},{nbc}) not divisible by cannon grid "
            f"side {pg}")
    if c_repl < 1 or pg % c_repl:
        raise ValueError(f"grid side {pg} not divisible by replication {c_repl}")
    lr, lk, lc = nbr // pg, nbk // pg, nbc // pg
    spr = pg // c_repl  # shift steps each replica executes
    out = []
    for t in range(spr):
        pair = np.zeros((lr, lk, lc), dtype=bool)
        for p in range(c_repl):
            off = t + p * spr
            for i in range(pg):
                for j in range(pg):
                    q = (i + j + off) % pg
                    ac = am[i * lr:(i + 1) * lr, q * lk:(q + 1) * lk]
                    if not ac.any():
                        continue
                    bc = bm[q * lk:(q + 1) * lk, j * lc:(j + 1) * lc]
                    pair |= ac[:, :, None] & bc[None, :, :]
        out.append(pair)
    return out


def cannon_step_norms(
    an: np.ndarray, bn: np.ndarray, pg: int, c_repl: int = 1,
) -> List[np.ndarray]:
    """Per-shift-step local pair NORM-PRODUCT tensors for (2.5D) Cannon
    — the norm twin of ``cannon_step_masks`` for the on-the-fly filter
    (repro.sparsity).

    Where the mask builder unions per-rank *presence* (SPMD: the step
    plan must cover every rank), the norm builder takes the per-rank
    MAX of ``norm(A_ik) * norm(B_kj)`` — union-of-max.  A triple is
    then dropped by ``filter_eps`` only when it falls below eps on
    EVERY rank sharing the traced program: the tightest SPMD-uniform
    filter, conservative in exactly the way the mask union is.
    """
    nbr, nbk = an.shape
    nbc = bn.shape[1]
    if nbr % pg or nbk % pg or nbc % pg:
        raise ValueError(
            f"block grid ({nbr},{nbk},{nbc}) not divisible by cannon grid "
            f"side {pg}")
    if c_repl < 1 or pg % c_repl:
        raise ValueError(f"grid side {pg} not divisible by replication {c_repl}")
    an = np.asarray(an, dtype=np.float32)
    bn = np.asarray(bn, dtype=np.float32)
    lr, lk, lc = nbr // pg, nbk // pg, nbc // pg
    spr = pg // c_repl
    out = []
    for t in range(spr):
        pair = np.zeros((lr, lk, lc), dtype=np.float32)
        for p in range(c_repl):
            off = t + p * spr
            for i in range(pg):
                for j in range(pg):
                    q = (i + j + off) % pg
                    ac = an[i * lr:(i + 1) * lr, q * lk:(q + 1) * lk]
                    if not ac.any():
                        continue
                    bc = bn[q * lk:(q + 1) * lk, j * lc:(j + 1) * lc]
                    np.maximum(pair, ac[:, :, None] * bc[None, :, :],
                               out=pair)
        out.append(pair)
    return out


def cannon_rank_steps(
    am: np.ndarray, bm: np.ndarray, pg: int, c_repl: int = 1,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
) -> List[List[dict]]:
    """Rank-exact twin of ``cannon_step_masks``/``cannon_step_norms``:
    per step, per RANK local mask (and norm) kwargs instead of the
    union over ranks.

    ``out[t][r]`` is the mask/norm kwarg dict for the rank with flat
    index ``r = (p * pg + i) * pg + j`` (stack-major, matching
    ``cannon25d._skew25d_perm``; plain Cannon is the ``c_repl == 1``
    slice ``r = i * pg + j``) at inner shift step ``t`` — the exact A
    chunk ``(i, q)`` x B chunk ``(q, j)`` with
    ``q = (i + j + t + p*spr) % pg``.  The factored ``a_mask``/
    ``b_mask`` form is exact per rank (no cross-rank union), and the
    norms are the rank's own chunk norms — eps filtering against them
    is DBCSR's true local filter rather than the union-of-max bound.
    """
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if nbr % pg or nbk % pg or nbc % pg:
        raise ValueError(
            f"block grid ({nbr},{nbk},{nbc}) not divisible by cannon grid "
            f"side {pg}")
    if c_repl < 1 or pg % c_repl:
        raise ValueError(f"grid side {pg} not divisible by replication {c_repl}")
    lr, lk, lc = nbr // pg, nbk // pg, nbc // pg
    spr = pg // c_repl
    if a_norms is not None:
        a_norms = np.asarray(a_norms, dtype=np.float32)
        b_norms = np.asarray(b_norms, dtype=np.float32)
    steps: List[List[dict]] = []
    for t in range(spr):
        ranks: List[dict] = []
        for p in range(c_repl):
            for i in range(pg):
                rs = slice(i * lr, (i + 1) * lr)
                for j in range(pg):
                    q = (i + j + t + p * spr) % pg
                    ks = slice(q * lk, (q + 1) * lk)
                    cs = slice(j * lc, (j + 1) * lc)
                    kw = {"a_mask": am[rs, ks], "b_mask": bm[ks, cs]}
                    if a_norms is not None:
                        kw["a_norms"] = a_norms[rs, ks]
                        kw["b_norms"] = b_norms[ks, cs]
                    ranks.append(kw)
        steps.append(ranks)
    return steps


def _default_local_matmul(precision):
    def f(a, b):
        return jax.lax.dot(a, b, precision=precision,
                           preferred_element_type=jnp.float32)

    return f


def cannon_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec = GridSpec(),
    local_matmul: Optional[Callable] = None,
    out_dtype=None,
    precision=jax.lax.Precision.DEFAULT,
    pipeline_depth: Optional[int] = None,
    double_buffer: Optional[bool] = None,
    skew: bool = True,
) -> jax.Array:
    """C = A @ B with Cannon's algorithm on a square (row, col) grid.

    A: (M, K) sharded P(row_axis, col_axis)
    B: (K, N) sharded P(row_axis, col_axis)
    C: (M, N) sharded P(row_axis, col_axis)

    Per-device communication volume: (M*K + K*N) / P * sqrt(P) total
    over sqrt(P) steps == O(1/sqrt(P)) of the matrix size, the paper's
    scaling for general shapes.

    ``pipeline_depth`` (see core/schedule.py): 2 = double-buffered
    comm/compute overlap (default), 1 = serial, 0 = rolled fori_loop
    ablation.  ``double_buffer`` is the legacy spelling (True -> 2,
    False -> 0); ``pipeline_depth`` wins when both are given.
    """
    pg = grid.validate_square(mesh)
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    lm = local_matmul or _default_local_matmul(precision)
    depth = resolve_pipeline_depth(pipeline_depth, double_buffer)
    sched = build_cannon_schedule(
        pg, row_axis=grid.row_axis, col_axis=grid.col_axis, skew=skew,
        empty_steps=getattr(lm, "empty_steps", frozenset()))

    def body(a_blk, b_blk):
        return execute_schedule(sched, a_blk, b_blk, local_matmul=lm,
                                out_dtype=out_dtype, pipeline_depth=depth)

    # leading batch dims (a fused product batch (G, m, k)) replicate;
    # the ppermute skew/shift callables are shape-agnostic, so the same
    # schedule drives single products and batches alike
    spec = P(*([None] * (a.ndim - 2)), grid.row_axis, grid.col_axis)
    fn = shard_map(body, mesh=mesh, in_specs=(spec, spec),
                   out_specs=spec, check_vma=False)
    return fn(a, b)
