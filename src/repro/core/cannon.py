"""Cannon's algorithm on a square mesh grid via shard_map + ppermute.

This is DBCSR's data-exchange algorithm for general matrix shapes
(paper section II): per-process communicated data scales O(1/sqrt(P)).

TPU adaptation notes (see DESIGN.md §2):
  * MPI async point-to-point sends -> ``jax.lax.ppermute`` neighbour
    shifts.  The TPU ICI is a torus, so Cannon's row/col shifts map to
    contention-free single-hop collective-permutes.
  * The initial Cannon skew (device (i,j) must start from A(i, (i+j)%P)
    and B((i+j)%P, j)) is one joint-axis ppermute over the flattened
    (row, col) axes.
  * Communication/computation overlap (paper: MPI/CUDA-stream double
    buffering) is expressed by issuing the ppermute for step t+1
    *before* the local dot of step t; XLA schedules the
    collective-permute-start/done pair around the dot.

The local multiply is pluggable (``local_matmul``): ``densified`` uses a
single large dot (paper section III — the cuBLAS path), ``blocked``
dispatches the stack-of-small-blocks path (kernels/smm, LIBCUSMM
analogue).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import pvary, shard_map

from .blocking import GridSpec

__all__ = ["cannon_matmul", "cannon_local_steps"]


def _skew_perm(pg: int, which: str):
    """Joint-axis permutation realising the Cannon pre-skew.

    A: device (i, j) receives A block (i, (i+j) % P)  [row i shifted left i]
    B: device (i, j) receives B block ((i+j) % P, j)  [col j shifted up  j]
    Expressed as (source, destination) pairs over the row-major flattened
    (row, col) index space.
    """
    pairs = []
    for i in range(pg):
        for j in range(pg):
            if which == "a":  # (i, j) sends to (i, (j - i) % P)
                pairs.append((i * pg + j, i * pg + ((j - i) % pg)))
            else:  # b: (i, j) sends to ((i - j) % P, j)
                pairs.append((i * pg + j, ((i - j) % pg) * pg + j))
    return pairs


def _shift_perm(pg: int):
    """Single-axis circular shift by one (left/up)."""
    return [(k, (k - 1) % pg) for k in range(pg)]


def cannon_local_steps(
    a_blk: jax.Array,
    b_blk: jax.Array,
    *,
    pg: int,
    row_axis: str,
    col_axis: str,
    local_matmul: Callable[[jax.Array, jax.Array], jax.Array],
    out_dtype,
    skew: bool = True,
    double_buffer: bool = True,
    steps: Optional[int] = None,
    step_offset: int = 0,
):
    """Body of Cannon's algorithm (runs inside shard_map).

    ``steps``/``step_offset`` support the 2.5D variant (cannon25d.py)
    where each replica executes a strided/offset subset of the shifts.

    ``local_matmul`` may be *stepwise* (``local_matmul.stepwise`` is
    truthy): it is then called as ``local_matmul(a, b, step=t)`` with
    the 0-based shift index, and may return ``None`` to signal that the
    step's occupancy-mask product is empty on every rank — the partial
    accumulation is skipped (host-static and uniform across devices, so
    SPMD-safe; the shifts themselves still run, later steps need them).
    """
    if skew:
        a_blk = jax.lax.ppermute(a_blk, (row_axis, col_axis), _skew_perm(pg, "a"))
        b_blk = jax.lax.ppermute(b_blk, (row_axis, col_axis), _skew_perm(pg, "b"))
    if step_offset:
        # jump the k-phase forward by step_offset (2.5D replica offset)
        shift_a = [(j, (j - step_offset) % pg) for j in range(pg)]
        shift_b = [(i, (i - step_offset) % pg) for i in range(pg)]
        a_blk = jax.lax.ppermute(a_blk, col_axis, shift_a)
        b_blk = jax.lax.ppermute(b_blk, row_axis, shift_b)

    n_steps = pg if steps is None else steps
    c_blk = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), dtype=out_dtype)
    shift_a = _shift_perm(pg)
    shift_b = _shift_perm(pg)
    stepwise = bool(getattr(local_matmul, "stepwise", False))

    if double_buffer or stepwise:
        # Unrolled: issue step t+1's permutes before step t's dot so XLA
        # overlaps collective-permute with the local matmul.  Stepwise
        # (occupancy-masked) local multiplies force this form: per-step
        # plans are distinct host constants the rolled fori_loop body
        # cannot express.
        for t in range(n_steps):
            if t < n_steps - 1:
                a_nxt = jax.lax.ppermute(a_blk, col_axis, shift_a)
                b_nxt = jax.lax.ppermute(b_blk, row_axis, shift_b)
            part = (local_matmul(a_blk, b_blk, step=t) if stepwise
                    else local_matmul(a_blk, b_blk))
            if part is not None:
                c_blk = c_blk + part.astype(out_dtype)
            if t < n_steps - 1:
                a_blk, b_blk = a_nxt, b_nxt
    else:
        # Rolled (fori_loop): smaller HLO, no overlap. Kept for ablation
        # (EXPERIMENTS.md §Perf measures the overlap win from the HLO).
        def body(_, carry):
            a_c, b_c, c_c = carry
            c_c = c_c + local_matmul(a_c, b_c).astype(out_dtype)
            a_c = jax.lax.ppermute(a_c, col_axis, shift_a)
            b_c = jax.lax.ppermute(b_c, row_axis, shift_b)
            return a_c, b_c, c_c

        # the zero-init accumulator must enter the loop already marked
        # varying over the grid axes (its per-step updates are)
        c_blk = pvary(c_blk, (row_axis, col_axis))
        _, _, c_blk = jax.lax.fori_loop(0, n_steps, body, (a_blk, b_blk, c_blk))
    return c_blk


def _default_local_matmul(precision):
    def f(a, b):
        return jax.lax.dot(a, b, precision=precision,
                           preferred_element_type=jnp.float32)

    return f


def cannon_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec = GridSpec(),
    local_matmul: Optional[Callable] = None,
    out_dtype=None,
    precision=jax.lax.Precision.DEFAULT,
    double_buffer: bool = True,
    skew: bool = True,
) -> jax.Array:
    """C = A @ B with Cannon's algorithm on a square (row, col) grid.

    A: (M, K) sharded P(row_axis, col_axis)
    B: (K, N) sharded P(row_axis, col_axis)
    C: (M, N) sharded P(row_axis, col_axis)

    Per-device communication volume: (M*K + K*N) / P * sqrt(P) total
    over sqrt(P) steps == O(1/sqrt(P)) of the matrix size, the paper's
    scaling for general shapes.
    """
    pg = grid.validate_square(mesh)
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    lm = local_matmul or _default_local_matmul(precision)

    def body(a_blk, b_blk):
        c = cannon_local_steps(
            a_blk,
            b_blk,
            pg=pg,
            row_axis=grid.row_axis,
            col_axis=grid.col_axis,
            local_matmul=lm,
            out_dtype=jnp.float32,
            skew=skew,
            double_buffer=double_buffer,
        )
        return c.astype(out_dtype)

    spec = P(grid.row_axis, grid.col_axis)
    fn = shard_map(body, mesh=mesh, in_specs=(spec, spec),
                   out_specs=spec, check_vma=False)
    return fn(a, b)
