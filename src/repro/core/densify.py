"""Densification — the paper's core optimization (section III).

DBCSR stores operands as many small blocks.  For *dense* inputs the
per-thread blocks are coalesced ("densified") into one large dense
block, so that:
  1. the Generation phase has fewer blocks to organise into stacks,
  2. the Scheduler phase has fewer stacks to handle (stack size -> 1),
  3. the local multiply becomes a single large GEMM executed by the
     vendor library (cuBLAS there, the MXU dot / tiled_matmul Pallas
     kernel here), which is where large-block throughput saturates.

The cost is the densify/undensify copy of the payload (the paper's
measured overhead).  On TPU the copies are pure layout transforms
((nbr, nbc, bm, bn) <-> (nbr*bm, nbc*bn) reshuffles) that XLA fuses
into surrounding ops; the *performance* content of the trade-off
(many small dots vs one big dot) is identical and is what
benchmarks/bench_densify.py measures.

This module provides the layout transforms plus the two local-multiply
strategies consumed by cannon/summa/tall_skinny's ``local_matmul`` hook:

  * ``densified_local_matmul`` — densify, one big dot, undensify.
  * ``blocked_local_matmul``   — keep blocks, run the stack plans
    through the smm kernel (LIBCUSMM analogue) or its jnp reference.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "to_blocks",
    "from_blocks",
    "to_blocks_batched",
    "from_blocks_batched",
    "densify",
    "undensify",
    "blocked_local_matmul",
    "densified_local_matmul",
    "grouped_densified_local_matmul",
]


def to_blocks(x: jax.Array, bm: int, bn: int) -> jax.Array:
    """(R, C) -> (nbr*nbc, bm, bn) stacked blocks, row-major block order.

    This is the 'blocked' storage: the DBCSR payload of a dense matrix.
    """
    r, c = x.shape
    if r % bm or c % bn:
        raise ValueError(f"shape {x.shape} not divisible by block ({bm},{bn})")
    nbr, nbc = r // bm, c // bn
    return (
        x.reshape(nbr, bm, nbc, bn).transpose(0, 2, 1, 3).reshape(nbr * nbc, bm, bn)
    )


def from_blocks(blocks: jax.Array, nbr: int, nbc: int) -> jax.Array:
    """Inverse of to_blocks."""
    _, bm, bn = blocks.shape
    return (
        blocks.reshape(nbr, nbc, bm, bn).transpose(0, 2, 1, 3).reshape(nbr * bm, nbc * bn)
    )


def to_blocks_batched(x: jax.Array, bm: int, bn: int) -> jax.Array:
    """(G, R, C) -> (G, nbr*nbc, bm, bn): ``to_blocks`` over a leading
    product/group dimension (the fused batched multiply's payload)."""
    g, r, c = x.shape
    if r % bm or c % bn:
        raise ValueError(f"shape {x.shape} not divisible by block ({bm},{bn})")
    nbr, nbc = r // bm, c // bn
    return (
        x.reshape(g, nbr, bm, nbc, bn)
        .transpose(0, 1, 3, 2, 4)
        .reshape(g, nbr * nbc, bm, bn)
    )


def from_blocks_batched(blocks: jax.Array, nbr: int, nbc: int) -> jax.Array:
    """Inverse of to_blocks_batched."""
    g, _, bm, bn = blocks.shape
    return (
        blocks.reshape(g, nbr, nbc, bm, bn)
        .transpose(0, 1, 3, 2, 4)
        .reshape(g, nbr * bm, nbc * bn)
    )


def densify(blocks: jax.Array, nbr: int, nbc: int) -> jax.Array:
    """Coalesce a blocked payload into one dense block (paper eq. 1/2).

    In DBCSR this is a copy into fresh memory-pool buffers; here it is
    the layout transform from block-stacked to contiguous row-major.
    """
    return from_blocks(blocks, nbr, nbc)


def undensify(dense: jax.Array, bm: int, bn: int) -> jax.Array:
    """Decompose the densified C back into the original block sizes."""
    return to_blocks(dense, bm, bn)


def densified_local_matmul(precision=jax.lax.Precision.DEFAULT,
                           kernel: Optional[str] = None):
    """Local multiply for the densified path: one large GEMM.

    kernel=None     -> jax.lax.dot (XLA's MXU path; the 'vendor' GEMM)
    kernel='pallas' -> kernels/tiled_matmul (explicit VMEM tiling)
    """
    if kernel == "pallas":
        from repro.kernels.tiled_matmul.ops import tiled_matmul

        def f(a, b):
            return tiled_matmul(a, b)

        return f

    def f(a, b):
        return jax.lax.dot(a, b, precision=precision,
                           preferred_element_type=jnp.float32)

    return f


def grouped_densified_local_matmul(precision=jax.lax.Precision.DEFAULT,
                                   kernel: Optional[str] = None):
    """Local multiply for the densified path of a fused product batch:
    one grouped GEMM over ``(G, ml, kl) @ (G, kl, nl)``.

    kernel=None     -> batched jax.lax.dot_general (XLA's MXU path)
    kernel='pallas' -> kernels/grouped_gemm (one Pallas dispatch for
                       all G products — the grouped-GEMM unification)
    """
    if kernel == "pallas":
        from repro.kernels.grouped_gemm.ops import grouped_gemm

        def f(a, b):
            return grouped_gemm(a, b)

        return f

    def f(a, b):
        return jax.lax.dot_general(
            a, b, (((a.ndim - 1,), (1,)), ((0,), (0,))),
            precision=precision, preferred_element_type=jnp.float32)

    return f


def blocked_local_matmul(
    m: int,
    k: int,
    n: int,
    *,
    block_m: int,
    block_k: int,
    block_n: int,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    kernel: str = "smm",
    a_mask=None,
    b_mask=None,
    pair_mask=None,
    a_norms=None,
    b_norms=None,
    pair_norms=None,
    filter_eps: Optional[float] = None,
    stack_bins: Optional[int] = None,
):
    """Local multiply for the blocked path.

    Delegates to the fused stack executor (core/engine.py): one memoized
    plan build per geometry (and per occupancy-mask/norm fingerprint),
    one ``lax.scan`` over padded stacks, one smm trace per block
    geometry.  ``stack_size`` / ``align`` default to the autotune
    winners table for this block geometry and occupancy bin;
    ``stack_bins`` caps the executor's size-bin count (None: the
    DBCSR_STACK_BINS env or 4).  Occupancy masks
    (``a_mask``/``b_mask``/``pair_mask``, host-side numpy bool) restrict
    the plan to present triples — see the sparse planning contract in
    core/engine.py — and block norms + ``filter_eps`` apply DBCSR's
    norm-product on-the-fly filter on top (repro.sparsity).

    kernel='smm'  -> Pallas LIBCUSMM-analogue (interpret-mode on CPU)
    kernel='ref'  -> pure-jnp gather/segment-sum oracle (same math)
    """
    from .engine import stack_executor

    return stack_executor(
        m, k, n, block_m=block_m, block_k=block_k, block_n=block_n,
        stack_size=stack_size, align=align, kernel=kernel,
        a_mask=a_mask, b_mask=b_mask, pair_mask=pair_mask,
        a_norms=a_norms, b_norms=b_norms, pair_norms=pair_norms,
        filter_eps=filter_eps, stack_bins=stack_bins,
    )
