"""Product-batched distributed multiply: N block-sparse products, ONE
fused dispatch.

Many workloads (density-matrix purification over k-point batches,
ensemble propagation, batched NEGF) issue MANY independent block-sparse
products of the same block geometry.  Dispatching them through
``distributed_matmul`` one by one pays the per-product dispatch price N
times over: each call traces its own shard_map program, builds its own
stack plans, and launches its own scan — and on small products the
host-side dispatch dominates the device time (the batched-GPU
observation of Mijić & Davidović, arXiv:2203.09353).

``distributed_matmul_batched`` stacks the G operand pairs as
``(G, m, k) @ (G, k, n)`` and runs ONE schedule over them:

  * the data-exchange schedule (Cannon shifts / SUMMA panel broadcasts)
    is shape-agnostic over leading batch dims, so the G products ride
    one ppermute/psum sequence — G times the payload per message, same
    message count (latency amortization);
  * the blocked local path fuses the per-group stack plans into one
    group-offset stack tensor (core/engine.py
    ``BatchedExecutorPlan``) executed by a single ``lax.scan`` through
    ``grouped_process_stack`` — one trace for the whole batch;
  * the densified local path becomes one grouped GEMM
    ``(G, ml, kl) @ (G, kl, nl)`` (kernels/grouped_gemm).

Supported data-exchange algorithms: ``cannon`` and ``summa`` (psum
broadcast) — the two whose schedules are batch-shape-agnostic.  The
tall-skinny and 2.5D variants reshape over mesh axes in ways that are
not worth generalizing for the batched service (their target regimes —
one huge skinny product, one huge square product — are not
many-small-products regimes).

Per-product occupancy masks and norms are accepted as *sequences*
(``a_masks[g]`` etc.); the fused plan covers every group's present
triples and a data-exchange step is skipped only when it is empty for
EVERY group.

Bit-identity contract: at ``pipeline_depth=1`` (serial) with
``filter_eps`` in {None, 0.0}, the blocked path of the fused batch is
bit-identical to G sequential ``distributed_matmul`` calls — stack
fusion never reorders any C block's k-run and padding rows only touch
the global scratch block (see ``execute_batched_plan``).  The densified
path is numerically equivalent but not bitwise-guaranteed (the grouped
Pallas GEMM may tile differently from per-product ``lax.dot``).
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .blocking import GridSpec
from .cannon import cannon_matmul, cannon_step_masks, cannon_step_norms
from .densify import grouped_densified_local_matmul
from .engine import batched_stack_executor
from .multiply import (_block_masks, _emit_step_spans, _global_occupancy,
                       _masks_empty, _schedule_stats)
from .schedule import resolve_pipeline_depth
from .summa import (summa_matmul, summa_n_panels, summa_step_masks,
                    summa_step_norms)
# canonical definition lives with the cost model (the planner restricts
# its batched enumeration by it; cost_model imports nothing from core)
from repro.planner.cost_model import BATCHED_ALGORITHMS

__all__ = ["distributed_matmul_batched", "BATCHED_ALGORITHMS"]


def _per_group(seq: Optional[Sequence], g: int, n_groups: int, name: str):
    """Normalise an optional per-group sequence argument."""
    if seq is None:
        return None
    if len(seq) != n_groups:
        raise ValueError(f"{name} has {len(seq)} entries for {n_groups} "
                         f"products")
    return seq[g]


def _stepwise_batched_lm(
    n_groups: int, ml: int, kl: int, nl: int, *,
    group_mask_steps: List[List[dict]],
    filter_eps: Optional[float] = None,
    **batched_kw,
):
    """A stepwise *batched* local multiply: one fused batched executor
    per data-exchange step (``group_mask_steps[t][g]`` is group ``g``'s
    mask/norm kwargs at step ``t``).  A step is empty — and host-side
    skipped by the schedule driver — only when every group's mask/norm
    product is empty at that step; a group that is individually empty at
    a non-empty step contributes zero stacks to the fused tensor."""
    fns, empty = [], set()
    for t, gms in enumerate(group_mask_steps):
        if all(_masks_empty(dict(gm, filter_eps=filter_eps)) for gm in gms):
            fns.append(None)
            empty.add(t)
        else:
            fns.append(batched_stack_executor(
                n_groups, ml, kl, nl, group_masks=gms,
                filter_eps=filter_eps, **batched_kw))

    def lm(a_loc: jax.Array, b_loc: jax.Array, step: int = 0):
        f = fns[step]
        return None if f is None else f(a_loc, b_loc)

    lm.stepwise = True
    lm.empty_steps = frozenset(empty)
    lm.step_executors = fns
    return lm


def _collect_batched_executor_stats(lm, densify: bool) -> Optional[dict]:
    """Aggregate the executed fused dispatch's padding / cross-request
    fusion statistics (attached to executed plans as
    ``executor_stats``)."""
    if densify:
        return None
    if getattr(lm, "stepwise", False):
        plans = [f.batched_plan for f in lm.step_executors if f is not None]
        n_steps = len(lm.step_executors)
    else:
        plan = getattr(lm, "batched_plan", None)
        plans = [] if plan is None else [plan]
        n_steps = 1
    if not plans:
        return None
    n_entries = sum(p.n_entries for p in plans)
    n_padding = sum(p.n_padding for p in plans)
    total = sum(p.n_stacks * p.stack_tile for p in plans)
    return {
        "n_groups": plans[0].n_groups,
        "n_steps": n_steps,
        "n_empty_steps": len(getattr(lm, "empty_steps", frozenset())),
        "n_fused_dispatches": len(plans),
        # groups whose per-step plan hit another group's memo entry —
        # the cross-request plan-sharing win of bucketing by content
        "n_shared_plans": sum(p.n_shared_plans for p in plans),
        "n_entries": n_entries,
        "n_padding": n_padding,
        "padding_frac": n_padding / total if total else 0.0,
        "per_step": [p.stats() for p in plans],
    }


def distributed_matmul_batched(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec = GridSpec(),
    algorithm: str = "auto",
    densify: Optional[bool] = None,
    block_m: int = 64,
    block_k: int = 64,
    block_n: int = 64,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    local_kernel: Optional[str] = None,
    a_masks: Optional[Sequence[Optional[np.ndarray]]] = None,
    b_masks: Optional[Sequence[Optional[np.ndarray]]] = None,
    a_norms: Optional[Sequence[Optional[np.ndarray]]] = None,
    b_norms: Optional[Sequence[Optional[np.ndarray]]] = None,
    filter_eps: Optional[float] = None,
    precision=jax.lax.Precision.DEFAULT,
    pipeline_depth: Optional[int] = None,
    double_buffer: Optional[bool] = None,
    return_plan: bool = False,
    **kw,
):
    """C[g] = A[g] @ B[g] for every product ``g`` of a fused batch.

    With telemetry on (``obs.enable()``), records a
    ``multiply_batched`` span nesting plan -> dispatch ->
    schedule-step children (G-scaled comm bytes / flops) and logs the
    batched plan's predicted-vs-measured fused cost; disabled or under
    jit tracing the call is bit-identical with one boolean of
    overhead.  See ``_distributed_matmul_batched`` for semantics.
    """
    tele = obs.enabled() and not (isinstance(a, jax.core.Tracer)
                                  or isinstance(b, jax.core.Tracer))
    call = dict(
        mesh=mesh, grid=grid, algorithm=algorithm, densify=densify,
        block_m=block_m, block_k=block_k, block_n=block_n,
        stack_size=stack_size, align=align, local_kernel=local_kernel,
        a_masks=a_masks, b_masks=b_masks, a_norms=a_norms, b_norms=b_norms,
        filter_eps=filter_eps, precision=precision,
        pipeline_depth=pipeline_depth, double_buffer=double_buffer,
        return_plan=return_plan, **kw)
    if not tele:
        return _distributed_matmul_batched(a, b, **call)
    attrs = {"algorithm": algorithm}
    if getattr(a, "ndim", 0) == 3 and getattr(b, "ndim", 0) == 3:
        attrs.update(n_groups=int(a.shape[0]), m=int(a.shape[1]),
                     k=int(a.shape[2]), n=int(b.shape[2]))
    with obs.span("multiply_batched", cat="multiply", **attrs):
        return _distributed_matmul_batched(a, b, _tele=True, **call)


def _distributed_matmul_batched(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec = GridSpec(),
    algorithm: str = "auto",
    densify: Optional[bool] = None,
    block_m: int = 64,
    block_k: int = 64,
    block_n: int = 64,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    local_kernel: Optional[str] = None,
    a_masks: Optional[Sequence[Optional[np.ndarray]]] = None,
    b_masks: Optional[Sequence[Optional[np.ndarray]]] = None,
    a_norms: Optional[Sequence[Optional[np.ndarray]]] = None,
    b_norms: Optional[Sequence[Optional[np.ndarray]]] = None,
    filter_eps: Optional[float] = None,
    precision=jax.lax.Precision.DEFAULT,
    pipeline_depth: Optional[int] = None,
    double_buffer: Optional[bool] = None,
    return_plan: bool = False,
    _tele: bool = False,
    **kw,
):
    """C[g] = A[g] @ B[g] for every product ``g`` of a fused batch.

    ``a``: (G, M, K) and ``b``: (G, K, N), both sharded over the
    trailing two axes exactly like the single-product
    ``distributed_matmul`` operands (the leading product dim is
    replicated).  ``algorithm`` is ``"auto"`` (planner-resolved,
    restricted to the batch-capable set), ``"cannon"`` or ``"summa"``
    (psum broadcast; ``bcast="gather"`` is not supported batched).

    Per-product sparsity: ``a_masks`` / ``b_masks`` / ``a_norms`` /
    ``b_norms`` are length-G sequences (entries may be None = dense);
    ``filter_eps`` is shared by the whole batch — the batching service
    buckets requests by eps, so a fused batch is eps-uniform by
    construction.  When filtering without explicit norms they are
    derived per product from the payloads (outside jit only).

    ``return_plan=True`` returns ``(C, BatchedMultiplyPlan)`` with the
    planner's fuse-vs-loop pricing and the executed fused dispatch's
    padding / plan-sharing statistics (``executor_stats``).

    See the module docstring for the bit-identity contract vs G looped
    ``distributed_matmul`` calls.
    """
    if a.ndim != 3 or b.ndim != 3:
        raise ValueError(f"batched operands must be (G, M, K) x (G, K, N), "
                         f"got {a.shape} x {b.shape}")
    g_count, m, k = a.shape
    gb, k2, n = b.shape
    if gb != g_count or k != k2:
        raise ValueError(f"batched operands disagree: {a.shape} @ {b.shape}")
    if g_count < 1:
        raise ValueError("batched multiply needs at least one product")
    if kw.get("bcast") == "gather":
        raise ValueError("bcast='gather' is not supported for batched "
                         "dispatch (the all-gathered full-K row would be "
                         "replicated per product)")

    filtering = filter_eps is not None
    if filtering and a_norms is None and b_norms is None:
        from repro.sparsity.norms import block_norms_of

        a_norms = [block_norms_of(a[gi], block_m, block_k,
                                  _per_group(a_masks, gi, g_count, "a_masks"))
                   for gi in range(g_count)]
        b_norms = [block_norms_of(b[gi], block_k, block_n,
                                  _per_group(b_masks, gi, g_count, "b_masks"))
                   for gi in range(g_count)]

    plan = None
    # telemetry forces a plan even for pinned algorithms (scoreboard
    # needs the predicted fused cost)
    if algorithm == "auto" or return_plan or _tele:
        from repro.planner.plan import plan_multiply_batched

        with obs.maybe_span(_tele, "plan", cat="plan") as psp:
            pr0, pc0 = grid.grid_shape(mesh)
            occs = [
                _global_occupancy(
                    m, k, n, block_m, block_k, block_n,
                    _per_group(a_masks, gi, g_count, "a_masks"),
                    _per_group(b_masks, gi, g_count, "b_masks"),
                    _per_group(a_norms, gi, g_count, "a_norms"),
                    _per_group(b_norms, gi, g_count, "b_norms"),
                    filter_eps)
                for gi in range(g_count)
            ]
            occ = sum(occs) / len(occs)
            occ_max = max(occs)
            # groups pad to the largest group's stack shape: the
            # mean/max occupancy spread estimates the fused dispatch's
            # padding waste
            pad_est = 1.0 - occ / occ_max if occ_max > 0 else 0.0
            plan = plan_multiply_batched(
                g_count, m, k, n, blocks=(block_m, block_k, block_n),
                mesh_shape=(pr0, pc0), occupancy=occ,
                dtype=jnp.promote_types(a.dtype, b.dtype),
                algorithm=None if algorithm == "auto" else algorithm,
                densify=(densify
                         if algorithm == "auto" or densify is not None
                         else True),
                padding_frac=pad_est, stack_size=stack_size, align=align)
            if algorithm == "auto":
                algorithm = plan.algorithm
                if densify is None:
                    densify = plan.densify
                if not densify:
                    if stack_size is None:
                        stack_size = plan.stack_tile
                    if align is None:
                        align = plan.align
                if pipeline_depth is None and double_buffer is None:
                    pipeline_depth = plan.pipeline_depth
            psp.set(algorithm=plan.algorithm, fuse=bool(plan.fuse),
                    densify=bool(plan.densify),
                    predicted_fused_s=float(plan.predicted_fused_s),
                    predicted_looped_s=float(plan.predicted_looped_s),
                    occupancy=float(occ), trivial=bool(plan.trivial))
    if densify is None:
        densify = True  # mirror distributed_matmul's fixed-algorithm default
    if algorithm not in BATCHED_ALGORITHMS:
        raise ValueError(
            f"batched dispatch supports {BATCHED_ALGORITHMS}, got "
            f"{algorithm!r} (the tall-skinny / 2.5D schedules are not "
            f"batch-shape-agnostic)")
    depth = resolve_pipeline_depth(pipeline_depth, double_buffer)

    # ---- local multiply geometry ------------------------------------
    pr, pc = grid.grid_shape(mesh)
    pg = n_panels = None
    if algorithm == "cannon":
        pg = grid.validate_square(mesh)
        if (m % pg or k % pg or n % pg) and not densify:
            raise ValueError(
                f"shape ({m},{k},{n}) not divisible by grid side {pg}")
        ml, kl, nl = m // pg, k // pg, n // pg
    else:
        n_panels = summa_n_panels(pr, pc)
        if (m % pr or n % pc or k % n_panels) and not densify:
            raise ValueError(
                f"shape ({m},{k},{n}) not divisible by summa grid "
                f"{pr}x{pc} with {n_panels} panels")
        ml, kl, nl = m // pr, k // n_panels, n // pc

    # ---- local multiply strategy ------------------------------------
    no_masks = a_masks is None and b_masks is None
    if densify:
        lm = grouped_densified_local_matmul(precision, kernel=local_kernel)
    else:
        batched_kw = dict(
            block_m=block_m, block_k=block_k, block_n=block_n,
            stack_size=stack_size, align=align,
            kernel=local_kernel or "smm")
        if no_masks and not filtering:
            lm = batched_stack_executor(g_count, ml, kl, nl, **batched_kw)
        else:
            group_ab = []
            for gi in range(g_count):
                am, bmk = _block_masks(
                    m, k, n, block_m, block_k, block_n,
                    _per_group(a_masks, gi, g_count, "a_masks"),
                    _per_group(b_masks, gi, g_count, "b_masks"))
                an_g = bn_g = None
                if filtering:
                    from repro.sparsity.norms import normalize_block_norms

                    an_g, bn_g = normalize_block_norms(
                        am.shape[0], am.shape[1], bmk.shape[1],
                        _per_group(a_norms, gi, g_count, "a_norms"),
                        _per_group(b_norms, gi, g_count, "b_norms"))
                    an_g = np.where(am, an_g, np.float32(0.0))
                    bn_g = np.where(bmk, bn_g, np.float32(0.0))
                group_ab.append((am, bmk, an_g, bn_g))
            if algorithm == "cannon":
                n_steps = pg
                per_group = [cannon_step_masks(am, bmk, pg)
                             for am, bmk, _, _ in group_ab]
                steps = [[{"pair_mask": per_group[gi][t]}
                          for gi in range(g_count)] for t in range(n_steps)]
                if filtering:
                    per_group_n = [cannon_step_norms(an_g, bn_g, pg)
                                   for _, _, an_g, bn_g in group_ab]
                    for t in range(n_steps):
                        for gi in range(g_count):
                            steps[t][gi]["pair_norms"] = per_group_n[gi][t]
            else:
                n_steps = n_panels
                per_group = [summa_step_masks(am, bmk, pr, pc, n_panels)
                             for am, bmk, _, _ in group_ab]
                steps = [[dict(zip(("a_mask", "b_mask"), per_group[gi][t]))
                          for gi in range(g_count)] for t in range(n_steps)]
                if filtering:
                    per_group_n = [summa_step_norms(an_g, bn_g, pr, pc,
                                                    n_panels)
                                   for _, _, an_g, bn_g in group_ab]
                    for t in range(n_steps):
                        for gi in range(g_count):
                            una, unb = per_group_n[gi][t]
                            steps[t][gi].update(a_norms=una, b_norms=unb)
            lm = _stepwise_batched_lm(
                g_count, ml, kl, nl, group_mask_steps=steps,
                filter_eps=filter_eps, **batched_kw)

    # ---- data exchange (one schedule for the whole batch) ------------
    def _run():
        if algorithm == "cannon":
            return cannon_matmul(
                a, b, mesh=mesh, grid=grid, local_matmul=lm,
                precision=precision, pipeline_depth=depth, **kw)
        return summa_matmul(
            a, b, mesh=mesh, grid=grid, local_matmul=lm,
            precision=precision, pipeline_depth=depth, **kw)

    if not _tele:
        c = _run()
    else:
        with obs.span("dispatch", cat="dispatch", algorithm=algorithm,
                      densify=bool(densify), pipeline_depth=depth,
                      n_groups=g_count) as dsp:
            t0 = time.perf_counter()
            c = jax.block_until_ready(_run())
            dt = time.perf_counter() - t0
        try:
            # per-step spans from the single-product schedule model,
            # G-scaled (comm bytes and dense flops multiply by the
            # group count on the fused batch)
            itemsize = int(jnp.dtype(
                jnp.promote_types(a.dtype, b.dtype)).itemsize)
            ss = _schedule_stats(
                algorithm, grid=grid, mesh=mesh, local_shape=(ml, kl, nl),
                itemsize=itemsize, lm=lm, densify=densify,
                pipeline_depth=depth, reduce_kw=kw, n_groups=g_count)
        except Exception:
            ss = None  # telemetry must never break the multiply
        if ss is not None:
            dsp.set(comm_bytes=int(ss.get("total_comm_bytes", 0)))
            _emit_step_spans(dsp.rec, t0, dt, ss)
        if plan is not None and not plan.trivial:
            obs.record_plan_outcome(
                kind="multiply_batched", algorithm=algorithm,
                densify=bool(densify), n_groups=g_count, m=m, k=k, n=n,
                fuse=bool(plan.fuse),
                predicted_s=float(plan.predicted_fused_s),
                measured_s=float(dt), pipeline_depth=int(depth))
    if not return_plan:
        return c
    import dataclasses as _dc

    plan = _dc.replace(
        plan, executor_stats=_collect_batched_executor_stats(lm, densify))
    return c, plan
