"""Tall-and-skinny multiplication: O(1) per-process communication.

DBCSR's second data-exchange algorithm (paper section II, ref [13]):
when one matrix dimension is much larger than the others, Cannon's
O(1/sqrt(P)) volume is beaten by an algorithm whose per-process
communication is *independent of P*.

The paper's rectangular benchmark is M = N = 1'408, K = 1'982'464:
only the contraction dimension is large.  The TPU-native formulation:

  * shard K over *all* P devices (both mesh axes flattened),
  * replicate the small M and N dimensions,
  * local dot:  (M, K/P) @ (K/P, N) -> full (M, N) partial product,
  * one reduction over the flattened axis.

With ``reduce='all_reduce'`` every device receives the full (M, N)
result: communicated data per process ~ 2 * M * N bytes — O(1) in P,
matching the paper's claim.  ``reduce='reduce_scatter'`` leaves C
row-sharded and moves (P-1)/P * M*N per device, strictly less.

Two degenerate variants are provided for the other tall-skinny shapes:
  * M large (A tall): shard M, replicate B — **zero** communication.
  * N large (B wide): shard N, replicate A — zero communication.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .blocking import GridSpec
from .cannon import _default_local_matmul
from .schedule import Schedule, execute_schedule, resolve_pipeline_depth

__all__ = ["tall_skinny_matmul", "build_ts_schedule", "ts_step_masks",
           "ts_step_norms", "ts_rank_steps", "classify_shape",
           "ts_classify_ratio", "DEFAULT_TS_RATIO"]

# The historical hardcoded tall/skinny threshold.  The live threshold
# is planner-owned (the cost-model crossover where tall-skinny's O(1)
# communication beats Cannon's O(1/sqrt(P)) — see
# repro.planner.cost_model.ts_crossover_ratio); this constant is the
# fallback when the planner cannot produce one.
DEFAULT_TS_RATIO = 8.0

_RATIO_CACHE: float | None = None


def ts_classify_ratio(refresh: bool = False) -> float:
    """The dominance ratio at which ``classify_shape`` switches from
    Cannon to a tall-skinny variant.

    Exported so callers can inspect *why* a shape was classified
    tall/skinny: a shape is ``ts_<dim>`` iff its largest dimension is at
    least ``ts_classify_ratio()`` times each other dimension.  Computed
    once per process from the planner's cost-model crossover (hardware
    constants from repro.planner.calibrate), falling back to the legacy
    ``DEFAULT_TS_RATIO`` when the planner is unavailable.
    """
    global _RATIO_CACHE
    if _RATIO_CACHE is None or refresh:
        try:
            from repro.planner.cost_model import ts_crossover_ratio

            _RATIO_CACHE = float(ts_crossover_ratio())
        except Exception:
            _RATIO_CACHE = DEFAULT_TS_RATIO
    return _RATIO_CACHE


def classify_shape(m: int, k: int, n: int,
                   ratio: float | None = None) -> str:
    """Pick the data-exchange algorithm from the global shape.

    Mirrors DBCSR's dispatch: 'cannon' for general matrices,
    'ts_k' / 'ts_m' / 'ts_n' when one dimension dominates by at least
    ``ratio`` (default: the planner-owned ``ts_classify_ratio()``).

    Note: ``distributed_matmul(algorithm="auto")`` no longer dispatches
    through this shape heuristic alone — it evaluates the full
    cost-model candidate space (repro.planner.plan_multiply), which
    also accounts for occupancy, local path, and mesh geometry.  This
    classifier remains the shape-only view of that decision.
    """
    if ratio is None:
        ratio = ts_classify_ratio()
    dims = {"m": m, "k": k, "n": n}
    big = max(dims, key=dims.get)
    others = [v for kk, v in dims.items() if kk != big]
    if dims[big] >= ratio * max(others):
        return f"ts_{big}"
    return "cannon"


def build_ts_schedule(
    mode: str,
    axes,
    *,
    reduce: str = "reduce_scatter",
    local_shape: Optional[tuple] = None,
    itemsize: int = 4,
) -> Schedule:
    """Schedule for the tall-and-skinny variants: a single compute step
    (operands arrive pre-sharded over ``axes``), with the O(1)-in-P
    reduction of the (m, n) partial product as the epilogue (ts_k) or
    no communication at all (ts_m / ts_n)."""
    if mode not in ("ts_k", "ts_m", "ts_n"):
        raise ValueError(mode)
    epilogue_bytes = 0

    if mode == "ts_k":
        if reduce == "all_reduce":
            def epilogue(c):
                return jax.lax.psum(c, axes)   # O(1): ~2*M*N per device
        elif reduce == "reduce_scatter":
            def epilogue(c):
                return jax.lax.psum_scatter(
                    c, axes, scatter_dimension=0, tiled=True
                )                              # (P-1)/P * M*N per device
        else:
            raise ValueError(reduce)
        comm_op = f"psum{'_scatter' if reduce == 'reduce_scatter' else ''}"
        if local_shape is not None:
            ml, _, nl = local_shape
            epilogue_bytes = 2 * ml * nl * 4   # f32 partial both ways
    else:
        epilogue = None
        comm_op = "none (operand pre-replicated)"

    kw = {} if epilogue is None else {"epilogue": epilogue}
    return Schedule(
        algorithm=mode,
        n_steps=1,
        comm_op=comm_op,
        epilogue_comm_bytes=epilogue_bytes,
        **kw,
    )


def ts_step_masks(mode: str, am: np.ndarray, bm: np.ndarray,
                  p_all: int) -> dict:
    """Single-step mask kwargs for the tall-and-skinny variants (the
    contraction/tall dimension is sharded over all ``p_all`` devices) —
    the schedule builder's per-step mask slice, as a union over ranks."""
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if mode == "ts_k":
        if nbk % p_all:
            raise ValueError(f"K block grid {nbk} not divisible by {p_all}")
        lk = nbk // p_all
        pair = np.zeros((nbr, lk, nbc), dtype=bool)
        for d in range(p_all):
            ac = am[:, d * lk:(d + 1) * lk]
            if not ac.any():
                continue
            bc = bm[d * lk:(d + 1) * lk, :]
            pair |= ac[:, :, None] & bc[None, :, :]
        return {"pair_mask": pair}
    if mode == "ts_m":
        if nbr % p_all:
            raise ValueError(f"M block grid {nbr} not divisible by {p_all}")
        lr = nbr // p_all
        ua = np.zeros((lr, nbk), dtype=bool)
        for d in range(p_all):
            ua |= am[d * lr:(d + 1) * lr]
        return {"a_mask": ua, "b_mask": bm}
    if nbc % p_all:
        raise ValueError(f"N block grid {nbc} not divisible by {p_all}")
    lc = nbc // p_all
    ub = np.zeros((nbk, lc), dtype=bool)
    for d in range(p_all):
        ub |= bm[:, d * lc:(d + 1) * lc]
    return {"a_mask": am, "b_mask": ub}


def ts_step_norms(mode: str, an: np.ndarray, bn: np.ndarray,
                  p_all: int) -> dict:
    """Single-step norm kwargs for the tall-and-skinny variants — the
    norm twin of ``ts_step_masks`` under SPMD union-of-max semantics
    (repro.sparsity): where the mask builder unions presence over the
    ``p_all`` shards, the norm builder takes the elementwise MAX, so
    ``filter_eps`` never drops a triple some shard still needs."""
    nbr, nbk = an.shape
    nbc = bn.shape[1]
    an = np.asarray(an, dtype=np.float32)
    bn = np.asarray(bn, dtype=np.float32)
    if mode == "ts_k":
        if nbk % p_all:
            raise ValueError(f"K block grid {nbk} not divisible by {p_all}")
        lk = nbk // p_all
        pair = np.zeros((nbr, lk, nbc), dtype=np.float32)
        for d in range(p_all):
            ac = an[:, d * lk:(d + 1) * lk]
            if not ac.any():
                continue
            bc = bn[d * lk:(d + 1) * lk, :]
            np.maximum(pair, ac[:, :, None] * bc[None, :, :], out=pair)
        return {"pair_norms": pair}
    if mode == "ts_m":
        if nbr % p_all:
            raise ValueError(f"M block grid {nbr} not divisible by {p_all}")
        lr = nbr // p_all
        ua = np.zeros((lr, nbk), dtype=np.float32)
        for d in range(p_all):
            np.maximum(ua, an[d * lr:(d + 1) * lr], out=ua)
        return {"a_norms": ua, "b_norms": bn}
    if nbc % p_all:
        raise ValueError(f"N block grid {nbc} not divisible by {p_all}")
    lc = nbc // p_all
    ub = np.zeros((nbk, lc), dtype=np.float32)
    for d in range(p_all):
        np.maximum(ub, bn[:, d * lc:(d + 1) * lc], out=ub)
    return {"a_norms": an, "b_norms": ub}


def ts_rank_steps(mode: str, am: np.ndarray, bm: np.ndarray, p_all: int,
                  a_norms: Optional[np.ndarray] = None,
                  b_norms: Optional[np.ndarray] = None) -> List[dict]:
    """Rank-exact twin of ``ts_step_masks``/``ts_step_norms``: one
    exact mask/norm kwarg dict per device ``d`` (the joint-axes
    flattened shard index), instead of the union over shards.

    ts_k shards K: device ``d`` multiplies its A column chunk by its B
    row chunk.  ts_m shards M (its A row chunk x full B); ts_n shards
    N (full A x its B column chunk).
    """
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if a_norms is not None:
        a_norms = np.asarray(a_norms, dtype=np.float32)
        b_norms = np.asarray(b_norms, dtype=np.float32)
    ranks: List[dict] = []
    if mode == "ts_k":
        if nbk % p_all:
            raise ValueError(f"K block grid {nbk} not divisible by {p_all}")
        lk = nbk // p_all
        for d in range(p_all):
            ks = slice(d * lk, (d + 1) * lk)
            kw = {"a_mask": am[:, ks], "b_mask": bm[ks, :]}
            if a_norms is not None:
                kw["a_norms"] = a_norms[:, ks]
                kw["b_norms"] = b_norms[ks, :]
            ranks.append(kw)
        return ranks
    if mode == "ts_m":
        if nbr % p_all:
            raise ValueError(f"M block grid {nbr} not divisible by {p_all}")
        lr = nbr // p_all
        for d in range(p_all):
            rs = slice(d * lr, (d + 1) * lr)
            kw = {"a_mask": am[rs], "b_mask": bm}
            if a_norms is not None:
                kw["a_norms"] = a_norms[rs]
                kw["b_norms"] = b_norms
            ranks.append(kw)
        return ranks
    if nbc % p_all:
        raise ValueError(f"N block grid {nbc} not divisible by {p_all}")
    lc = nbc // p_all
    for d in range(p_all):
        cs = slice(d * lc, (d + 1) * lc)
        kw = {"a_mask": am, "b_mask": bm[:, cs]}
        if a_norms is not None:
            kw["a_norms"] = a_norms
            kw["b_norms"] = b_norms[:, cs]
        ranks.append(kw)
    return ranks


def tall_skinny_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    grid: GridSpec = GridSpec(),
    mode: str = "ts_k",
    reduce: str = "reduce_scatter",
    local_matmul: Optional[Callable] = None,
    out_dtype=None,
    precision=jax.lax.Precision.DEFAULT,
    pipeline_depth: Optional[int] = None,
) -> jax.Array:
    """C = A @ B with the tall-and-skinny algorithm.

    mode='ts_k': A (M,K) sharded P(None, (row,col)), B (K,N) sharded
      P((row,col), None); C replicated or row-sharded.
    mode='ts_m': A sharded P((row,col), None), B replicated; C row-sharded.
    mode='ts_n': A replicated, B sharded P(None, (row,col)); C col-sharded.

    The single compute step routes through the schedule engine for
    uniformity; ``pipeline_depth`` is accepted but has no overlap to
    express on a one-step schedule.
    """
    axes = (grid.row_axis, grid.col_axis) if grid.stack_axis is None else (
        grid.stack_axis, grid.row_axis, grid.col_axis)
    if out_dtype is None:
        out_dtype = jnp.promote_types(a.dtype, b.dtype)
    lm = local_matmul or _default_local_matmul(precision)
    depth = resolve_pipeline_depth(pipeline_depth)
    sched = build_ts_schedule(mode, axes, reduce=reduce)
    # ts_k reduces f32 partials (legacy semantics); the zero-comm
    # ts_m/ts_n variants historically cast the single local dot straight
    # to out_dtype — accumulate there, not in f32, so f64/int operands
    # keep full precision
    accum = jnp.float32 if mode == "ts_k" else out_dtype

    def body(a_blk, b_blk):
        return execute_schedule(sched, a_blk, b_blk, local_matmul=lm,
                                out_dtype=out_dtype, pipeline_depth=depth,
                                accum_dtype=accum)

    if mode == "ts_m":
        # zero-communication: shard the tall output dimension
        in_specs = (P(axes, None), P(None, None))
        out_spec = P(axes, None)
    elif mode == "ts_n":
        in_specs = (P(None, None), P(None, axes))
        out_spec = P(None, axes)
    else:  # ts_k
        in_specs = (P(None, axes), P(axes, None))
        out_spec = P(None, None) if reduce == "all_reduce" else P(axes, None)
    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=out_spec, check_vma=False)
    return fn(a, b)
