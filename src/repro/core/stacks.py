"""Stack generation: the Traversal / Generation / Scheduler phases.

DBCSR organises the local block-pair multiplications into *stacks*
(batches of at most ``STACK_SIZE`` = 30'000 multiplications, paper
section II).  The order of multiplications follows a cache-oblivious
(Z-Morton) traversal of the C block grid; within the Scheduler phase,
stacks are grouped so that all entries of a stack share C row-blocks
(the paper statically assigns batches with a given A row-block to one
OpenMP thread to avoid data races — on TPU the analogue is that the
Pallas ``smm`` kernel requires each C block's updates to be contiguous
in the stack so the accumulator can stay resident in VMEM).

All outputs are host-side numpy; they parameterise the smm kernel.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .blocking import BlockLayout, morton_order

STACK_SIZE = 30_000  # paper: "each batch consists of maximum 30'000"

__all__ = ["StackPlan", "build_stacks", "pad_plans", "stack_statistics",
           "STACK_SIZE"]


@dataclasses.dataclass(frozen=True)
class StackPlan:
    """A batch of small-GEMM triples: C[c] += A[a] @ B[b].

    ``triples`` is (S, 3) int32 with columns (a_block, b_block, c_block);
    block indices are flat indices into the row-major (nbr, nbk) /
    (nbk, nbc) / (nbr, nbc) block grids of the local operands.
    Sorted so that equal c_block entries are contiguous (see module doc).
    """

    triples: np.ndarray
    n_c_blocks: int
    block_m: int
    block_k: int
    block_n: int

    @property
    def size(self) -> int:
        return int(self.triples.shape[0])

    def flops(self) -> int:
        return 2 * self.size * self.block_m * self.block_k * self.block_n


def build_stacks(
    a_layout: BlockLayout,
    b_layout: BlockLayout,
    stack_size: int = STACK_SIZE,
) -> List[StackPlan]:
    """Generation phase: enumerate all (a, b, c) block triples of the
    local (dense) multiply, in cache-oblivious traversal order over the
    C block grid, then split into stacks of at most ``stack_size``.

    For the dense case every block is present, so the triple count is
    nbr * nbk * nbc — this is exactly the "~8 million stacks for block
    size 22" regime the paper measures for the 63'360^2 matrices.
    """
    if a_layout.block_cols != b_layout.block_rows:
        raise ValueError("inner block dims disagree")
    if a_layout.cols != b_layout.rows:
        raise ValueError("inner dims disagree")

    nbr = a_layout.nblock_rows
    nbk = a_layout.nblock_cols
    nbc = b_layout.nblock_cols

    # Traversal phase: Z-Morton over the C block grid for locality.
    c_order = morton_order(nbr, nbc)

    # Generation phase: for each C block (i, j), the k-loop of updates.
    i = c_order[:, 0].astype(np.int64)
    j = c_order[:, 1].astype(np.int64)
    ks = np.arange(nbk, dtype=np.int64)
    # (n_c, nbk) index grids, flattened C-major so each C block's k-run
    # is contiguous => accumulator-friendly for the smm kernel.
    a_idx = (i[:, None] * nbk + ks[None, :]).reshape(-1)
    b_idx = (ks[None, :] * nbc + j[:, None]).reshape(-1)
    c_idx = np.repeat(i * nbc + j, nbk)
    triples = np.stack([a_idx, b_idx, c_idx], axis=1).astype(np.int32)

    # Scheduler phase: split into stacks; never split a C block's k-run
    # across stacks (keeps revisit-contiguity inside every stack).
    run = nbk
    runs_per_stack = max(1, stack_size // run)
    step = runs_per_stack * run
    plans = []
    for start in range(0, triples.shape[0], step):
        plans.append(
            StackPlan(
                triples=triples[start : start + step],
                n_c_blocks=nbr * nbc,
                block_m=a_layout.block_rows,
                block_k=a_layout.block_cols,
                block_n=b_layout.block_cols,
            )
        )
    return plans


def pad_plans(
    plans: List[StackPlan],
    stack_tile: int | None = None,
    sentinel_c: int | None = None,
) -> np.ndarray:
    """Pad ragged stack plans into one ``(n_stacks, stack_tile, 4)`` tensor.

    The fused executor (core/engine.py) runs all stacks through a single
    ``lax.scan``, which needs every stack to have the same static length.
    Output columns are ``(a_idx, b_idx, c_idx, valid)``; padding rows
    carry ``(0, 0, sentinel_c, 0)``:

      * ``valid == 0`` lets the kernel zero the padding entry's product,
      * ``c_idx == sentinel_c`` (default: one past the last real C block,
        the executor appends a scratch block there) keeps the padding
        writes off the real C blocks AND preserves the run-contiguity
        invariant inside every padded stack — the padding rows form one
        trailing run of their own.
    """
    if not plans:
        raise ValueError("no stack plans to pad")
    n_c = plans[0].n_c_blocks
    sentinel = n_c if sentinel_c is None else sentinel_c
    tile = max(p.size for p in plans) if stack_tile is None else stack_tile
    out = np.zeros((len(plans), tile, 4), dtype=np.int32)
    out[:, :, 2] = sentinel
    for i, p in enumerate(plans):
        if p.size > tile:
            raise ValueError(f"plan of size {p.size} exceeds stack_tile {tile}")
        out[i, : p.size, :3] = p.triples
        out[i, : p.size, 3] = 1
    return out


def stack_statistics(plans: List[StackPlan],
                     stack_tile: int | None = None) -> dict:
    """Summary used by benchmarks (paper quotes stack counts directly).

    With ``stack_tile`` given, also reports the padding the fused
    executor introduces (mask fill ratio of the padded stack tensor).
    """
    sizes = [p.size for p in plans]
    stats = {
        "n_stacks": len(plans),
        "n_multiplications": int(np.sum(sizes)),
        "max_stack": int(np.max(sizes)) if sizes else 0,
        "flops": int(np.sum([p.flops() for p in plans])),
    }
    if stack_tile is None and sizes:
        stack_tile = stats["max_stack"]
    if stack_tile:
        padded_total = len(plans) * stack_tile
        stats["stack_tile"] = stack_tile
        stats["n_padding"] = padded_total - stats["n_multiplications"]
        stats["fill"] = stats["n_multiplications"] / padded_total
    return stats
