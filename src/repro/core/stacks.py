"""Stack generation: the Traversal / Generation / Scheduler phases.

DBCSR organises the local block-pair multiplications into *stacks*
(batches of at most ``STACK_SIZE`` = 30'000 multiplications, paper
section II).  The order of multiplications follows a cache-oblivious
(Z-Morton) traversal of the C block grid; within the Scheduler phase,
stacks are grouped so that all entries of a stack share C row-blocks
(the paper statically assigns batches with a given A row-block to one
OpenMP thread to avoid data races — on TPU the analogue is that the
Pallas ``smm`` kernel requires each C block's updates to be contiguous
in the stack so the accumulator can stay resident in VMEM).

All outputs are host-side numpy; they parameterise the smm kernel.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .blocking import BlockLayout, morton_order

STACK_SIZE = 30_000  # paper: "each batch consists of maximum 30'000"

__all__ = ["StackPlan", "build_stacks", "normalize_block_masks",
           "pad_plans", "stack_rank_slab", "stack_statistics",
           "STACK_SIZE"]


def normalize_block_masks(
    nbr: int,
    nbk: int,
    nbc: int,
    a_mask: "Optional[np.ndarray]" = None,
    b_mask: "Optional[np.ndarray]" = None,
):
    """Canonical occupancy-mask normalization, shared by every layer
    (stacks / engine / multiply / dbcsr): ``None`` means dense (all
    blocks present), anything else must be a bool-coercible array of
    exactly the block-grid shape."""
    am = (np.ones((nbr, nbk), dtype=bool) if a_mask is None
          else np.asarray(a_mask, dtype=bool))
    bm = (np.ones((nbk, nbc), dtype=bool) if b_mask is None
          else np.asarray(b_mask, dtype=bool))
    if am.shape != (nbr, nbk):
        raise ValueError(f"a_mask shape {am.shape} != block grid {(nbr, nbk)}")
    if bm.shape != (nbk, nbc):
        raise ValueError(f"b_mask shape {bm.shape} != block grid {(nbk, nbc)}")
    return am, bm


@dataclasses.dataclass(frozen=True)
class StackPlan:
    """A batch of small-GEMM triples: C[c] += A[a] @ B[b].

    ``triples`` is (S, 3) int32 with columns (a_block, b_block, c_block);
    block indices are flat indices into the row-major (nbr, nbk) /
    (nbk, nbc) / (nbr, nbc) block grids of the local operands.
    Sorted so that equal c_block entries are contiguous (see module doc).
    """

    triples: np.ndarray
    n_c_blocks: int
    block_m: int
    block_k: int
    block_n: int

    @property
    def size(self) -> int:
        return int(self.triples.shape[0])

    def flops(self) -> int:
        return 2 * self.size * self.block_m * self.block_k * self.block_n


def _pair_presence(
    nbr: int,
    nbk: int,
    nbc: int,
    i: np.ndarray,
    j: np.ndarray,
    a_mask: Optional[np.ndarray],
    b_mask: Optional[np.ndarray],
    pair_mask: Optional[np.ndarray],
) -> np.ndarray:
    """(n_c, nbk) bool: which k-updates exist for each C block, with
    rows ordered by the Morton traversal (i, j)."""
    if pair_mask is not None:
        if a_mask is not None or b_mask is not None:
            raise ValueError("pass either pair_mask or a_mask/b_mask, not both")
        pair_mask = np.asarray(pair_mask, dtype=bool)
        if pair_mask.shape != (nbr, nbk, nbc):
            raise ValueError(
                f"pair_mask shape {pair_mask.shape} != {(nbr, nbk, nbc)}")
        return pair_mask[i, :, j]
    am, bm = normalize_block_masks(nbr, nbk, nbc, a_mask, b_mask)
    return am[i] & bm[:, j].T


def _norm_keep(
    nbr: int,
    nbk: int,
    nbc: int,
    i: np.ndarray,
    j: np.ndarray,
    a_norms: Optional[np.ndarray],
    b_norms: Optional[np.ndarray],
    pair_norms: Optional[np.ndarray],
    filter_eps: float,
) -> np.ndarray:
    """(n_c, nbk) bool: which k-updates clear the norm-product threshold
    (``norm(A_ik) * norm(B_kj) >= filter_eps`` — the on-the-fly filter;
    see repro.sparsity).  Rows follow the same Morton traversal as
    ``_pair_presence``, so the two AND together elementwise.  At eps 0
    every product (``>= 0``) passes, keeping the filtered enumeration
    bit-identical to the mask-only one."""
    eps = float(filter_eps)
    if pair_norms is not None:
        if a_norms is not None or b_norms is not None:
            raise ValueError(
                "pass either pair_norms or a_norms/b_norms, not both")
        pair_norms = np.asarray(pair_norms, dtype=np.float32)
        if pair_norms.shape != (nbr, nbk, nbc):
            raise ValueError(
                f"pair_norms shape {pair_norms.shape} != {(nbr, nbk, nbc)}")
        return pair_norms.astype(np.float64)[i, :, j] >= eps
    from repro.sparsity.norms import normalize_block_norms

    an, bn = normalize_block_norms(nbr, nbk, nbc, a_norms, b_norms)
    return (an.astype(np.float64)[i] * bn.astype(np.float64)[:, j].T) >= eps


def build_stacks(
    a_layout: BlockLayout,
    b_layout: BlockLayout,
    stack_size: int = STACK_SIZE,
    a_mask: Optional[np.ndarray] = None,
    b_mask: Optional[np.ndarray] = None,
    pair_mask: Optional[np.ndarray] = None,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
    pair_norms: Optional[np.ndarray] = None,
    filter_eps: Optional[float] = None,
) -> List[StackPlan]:
    """Generation phase: enumerate the *present* (a, b, c) block triples
    of the local multiply, in cache-oblivious traversal order over the C
    block grid, then split into stacks of at most ``stack_size``.

    Occupancy filtering — where the block-sparse speedup comes from
    (paper section II): with ``a_mask`` ((nbr, nbk) bool) and/or
    ``b_mask`` ((nbk, nbc) bool) given, C block (i, j) only receives the
    updates k where ``a_mask[i, k] & b_mask[k, j]``; its k-run becomes
    *ragged* (possibly empty).  ``pair_mask`` ((nbr, nbk, nbc) bool)
    states the k-updates per C block directly, for callers whose
    presence structure is not a product of two factors (the distributed
    layer's shifted-union plans, multiply.py).  With no masks every
    block is present and the triple count is nbr * nbk * nbc — exactly
    the "~8 million stacks for block size 22" regime the paper measures
    for the 63'360^2 matrices; masked output with all-true masks is
    bit-identical to the dense enumeration.

    Norm filtering — DBCSR's on-the-fly filter (repro.sparsity): with
    ``filter_eps`` not None and block norms given (``a_norms`` /
    ``b_norms`` (float, block-grid shapes) or a direct ``pair_norms``
    ((nbr, nbk, nbc), the distributed layer's per-step union-of-max
    products), a mask-present triple is additionally dropped when
    ``norm(A_ik) * norm(B_kj) < filter_eps``.  ``filter_eps=0.0``
    retains everything — bit-identical to the mask-only enumeration —
    while ``filter_eps=None`` skips the predicate entirely.
    """
    if a_layout.block_cols != b_layout.block_rows:
        raise ValueError("inner block dims disagree")
    if a_layout.cols != b_layout.rows:
        raise ValueError("inner dims disagree")

    nbr = a_layout.nblock_rows
    nbk = a_layout.nblock_cols
    nbc = b_layout.nblock_cols

    # Traversal phase: Z-Morton over the C block grid for locality.
    c_order = morton_order(nbr, nbc)

    # Generation phase: for each C block (i, j), the k-run of *present*
    # updates.  np.nonzero walks the (n_c, nbk) presence grid row-major,
    # so each C block's k-run stays contiguous => accumulator-friendly
    # for the smm kernel.
    i = c_order[:, 0].astype(np.int64)
    j = c_order[:, 1].astype(np.int64)
    pair = _pair_presence(nbr, nbk, nbc, i, j, a_mask, b_mask, pair_mask)
    if filter_eps is not None and (a_norms is not None or b_norms is not None
                                   or pair_norms is not None):
        pair = pair & _norm_keep(nbr, nbk, nbc, i, j, a_norms, b_norms,
                                 pair_norms, filter_eps)
    rows, ks = np.nonzero(pair)
    a_idx = i[rows] * nbk + ks
    b_idx = ks * nbc + j[rows]
    c_idx = i[rows] * nbc + j[rows]
    triples = np.stack([a_idx, b_idx, c_idx], axis=1).astype(np.int32)

    # Scheduler phase: greedily pack whole (now possibly ragged) k-runs
    # into stacks of at most ``stack_size``; never split a C block's
    # k-run across stacks (keeps revisit-contiguity inside every stack).
    # A run longer than ``stack_size`` gets a stack of its own.
    run_lens = pair.sum(axis=1).astype(np.int64)
    total = int(triples.shape[0])
    plan_slices = []
    if total and (run_lens == run_lens[0]).all():
        # uniform runs (the dense regime — millions of C blocks for the
        # paper's 63'360^2 matrices): fixed-step split, no Python loop
        # over runs, bit-identical to the historical dense scheduler.
        run = int(run_lens[0])
        step = max(1, stack_size // run) * run
        plan_slices = [(s, min(s + step, total))
                       for s in range(0, total, step)]
    elif total:
        # ragged runs: greedy packing over the non-empty run *end*
        # boundaries, O(n_stacks) iterations (not O(n_runs)) — each
        # stack takes the longest run prefix fitting stack_size, or a
        # single oversized run.
        bounds = np.concatenate([[0], np.cumsum(run_lens)])
        ends = bounds[1:][run_lens > 0]
        start = 0
        while start < total:
            fit = np.searchsorted(ends, start + stack_size, side="right") - 1
            first = np.searchsorted(ends, start, side="right")
            stop = int(ends[max(fit, first)])
            plan_slices.append((start, stop))
            start = stop

    return [
        StackPlan(
            triples=triples[start:stop],
            n_c_blocks=nbr * nbc,
            block_m=a_layout.block_rows,
            block_k=a_layout.block_cols,
            block_n=b_layout.block_cols,
        )
        for start, stop in plan_slices
    ]


def pad_plans(
    plans: List[StackPlan],
    stack_tile: int | None = None,
    sentinel_c: int | None = None,
) -> np.ndarray:
    """Pad ragged stack plans into one ``(n_stacks, stack_tile, 4)`` tensor.

    The fused executor (core/engine.py) runs all stacks through a single
    ``lax.scan``, which needs every stack to have the same static length.
    Output columns are ``(a_idx, b_idx, c_idx, valid)``; padding rows
    carry ``(0, 0, sentinel_c, 0)``:

      * ``valid == 0`` lets the kernel zero the padding entry's product,
      * ``c_idx == sentinel_c`` (default: one past the last real C block,
        the executor appends a scratch block there) keeps the padding
        writes off the real C blocks AND preserves the run-contiguity
        invariant inside every padded stack — the padding rows form one
        trailing run of their own.
    """
    if not plans:
        raise ValueError("no stack plans to pad")
    n_c = plans[0].n_c_blocks
    sentinel = n_c if sentinel_c is None else sentinel_c
    tile = max(p.size for p in plans) if stack_tile is None else stack_tile
    out = np.zeros((len(plans), tile, 4), dtype=np.int32)
    out[:, :, 2] = sentinel
    for i, p in enumerate(plans):
        if p.size > tile:
            raise ValueError(f"plan of size {p.size} exceeds stack_tile {tile}")
        out[i, : p.size, :3] = p.triples
        out[i, : p.size, 3] = 1
    return out


def stack_rank_slab(
    rank_triples: List[np.ndarray],
    n_c_blocks: int,
) -> np.ndarray:
    """Stack per-rank padded triple tensors into one ``(R, S, T, 4)`` slab.

    Rank-exact execution (core/engine.py) traces ONE program for every
    rank of an SPMD mesh, so every rank's plan must share a single
    static shape: each rank's ``(S_r, T_r, 4)`` padded tensor (the
    single-tensor view of its own plan) is grown to the across-rank
    maxima ``S = max(S_r)`` / ``T = max(T_r)`` with the same padding
    rows ``pad_plans`` uses — ``(0, 0, n_c_blocks, 0)`` pointing at the
    executor's scratch block with ``valid == 0``.  A rank whose plan is
    empty contributes an all-padding slab slice; inside ``shard_map``
    each rank selects its slice by ``axis_index`` and executes only its
    own retained triples.
    """
    if not rank_triples:
        raise ValueError("no per-rank triple tensors to stack")
    n_stacks = max(int(t.shape[0]) for t in rank_triples)
    tile = max((int(t.shape[1]) for t in rank_triples
                if t.shape[0]), default=1)
    tile = max(tile, 1)
    out = np.zeros((len(rank_triples), max(n_stacks, 0), tile, 4),
                   dtype=np.int32)
    out[:, :, :, 2] = n_c_blocks
    for r, t in enumerate(rank_triples):
        s, w = int(t.shape[0]), int(t.shape[1])
        if w > tile or s > n_stacks:
            raise ValueError(
                f"rank {r} tensor {t.shape} exceeds slab ({n_stacks}, {tile})")
        out[r, :s, :w, :] = t
    return out


def stack_statistics(plans: List[StackPlan],
                     stack_tile: int | None = None) -> dict:
    """Summary used by benchmarks (paper quotes stack counts directly).

    With ``stack_tile`` given, also reports the padding the fused
    executor introduces (mask fill ratio of the padded stack tensor).
    """
    sizes = [p.size for p in plans]
    stats = {
        "n_stacks": len(plans),
        "n_multiplications": int(np.sum(sizes)),
        "max_stack": int(np.max(sizes)) if sizes else 0,
        "flops": int(np.sum([p.flops() for p in plans])),
    }
    if stack_tile is None and sizes:
        stack_tile = stats["max_stack"]
    if stack_tile:
        padded_total = len(plans) * stack_tile
        stats["stack_tile"] = stack_tile
        stats["n_padding"] = padded_total - stats["n_multiplications"]
        stats["fill"] = stats["n_multiplications"] / padded_total
    return stats
