"""Unified stack executor — fused dispatch of DBCSR stack plans.

The paper's Generation/Scheduler phases organise the local block
multiplications into stacks and batch them onto the accelerator
(LIBCUSMM processes whole stacks per kernel launch).  The seed's
blocked path instead dispatched each ``StackPlan`` through a separate
jit call in a Python loop: one trace/compile per distinct stack length
(the ragged tail always differs), one dispatch per stack, and a fresh
host->device transfer of every stack's triples on every multiply.

This module replaces that loop with a single fused executor:

  * all plans are padded into one ``(n_stacks, stack_tile, 4)`` masked
    triple tensor (``stacks.pad_plans`` — padding rows are ``valid=0``
    and write to a scratch C block appended past the real blocks),
  * the whole multiply runs as one ``jax.lax.scan`` over stacks around
    ``smm_process_stack``, so the smm kernel is traced/compiled ONCE
    per block geometry, never once per stack,
  * host-side plan construction is memoized on
    ``(m, k, n, block_m, block_k, block_n, stack_size)`` so repeated
    multiplies (training steps, benchmark reps) reuse the numpy plans,
  * when the caller doesn't pin ``align`` / ``stack_size``, they are
    resolved from the autotune winners table
    (``repro.kernels.smm.autotune.best_params_for``), closing the loop
    the paper's LIBCUSMM tuner closes on CUDA.

``execute_plans_looped`` keeps the legacy per-plan dispatch alive for
the before/after comparison in benchmarks/bench_kernels.py.

Sparse planning contract (occupancy-aware stacks)
-------------------------------------------------

Block occupancy is *host-side static metadata* (numpy bool masks),
never traced data — the whole point is that absent blocks are excluded
at Generation time, so the executor dispatches fewer small-GEMMs
instead of multiplying zeros (the paper's block-sparse regime):

  * ``build_executor_plan`` / ``stack_executor`` accept
    ``a_mask`` ((nbr, nbk)), ``b_mask`` ((nbk, nbc)) or a direct
    ``pair_mask`` ((nbr, nbk, nbc)); the plan then contains only the
    triples with ``a_mask[i, k] & b_mask[k, j]`` (ragged k-runs, runs
    never split across stacks).  All-true masks are bit-identical to
    the dense enumeration.
  * Masks are unhashable numpy, so memoization keys on a content
    fingerprint ``(shape, sha1(bytes))`` — identical mask *content*
    hits the same cached plan regardless of array object identity.
    The distributed layer (core/multiply.py) exploits this: one plan
    per distinct shifted-mask fingerprint across cannon shifts / summa
    panels.
  * The operand payloads stay dense (absent blocks stored as zeros,
    see core/dbcsr.py), so array shapes remain static for pjit; only
    the triple tensor shrinks.  A plan whose mask product is empty has
    ``n_stacks == 0`` and ``execute_plan`` returns C unchanged.
  * ``ExecutorPlan.stats()`` reports ``n_dense_triples``,
    ``n_skipped_triples`` and effective ``occupancy`` so benchmarks
    (benchmarks/bench_sparse.py) can attribute the win.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import os
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .blocking import BlockLayout
from .densify import from_blocks, to_blocks
from .stacks import (StackPlan, build_stacks, pad_plans, stack_rank_slab,
                     STACK_SIZE)

__all__ = [
    "BatchedExecutorPlan",
    "ExecutorPlan",
    "RankExecutorPlan",
    "batched_stack_executor",
    "build_batched_executor_plan",
    "build_executor_plan",
    "build_rank_executor_plan",
    "execute_batched_plan",
    "execute_plan",
    "execute_plans_looped",
    "execute_rank_plan",
    "rank_stack_executor",
    "resolve_stack_bins",
    "stack_executor",
]


def _resolve_process(kernel: str):
    """Normalise the two stack processors to one call signature."""
    if kernel == "smm":
        from repro.kernels.smm.ops import smm_process_stack

        def process(a, b, c, t, align=False):
            return smm_process_stack(a, b, c, t, align=align)

    elif kernel == "ref":
        from repro.kernels.smm.ref import smm_process_stack_ref

        def process(a, b, c, t, align=False):
            return smm_process_stack_ref(a, b, c, t)

    else:
        raise ValueError(f"unknown stack kernel {kernel!r}")
    return process


@dataclasses.dataclass(frozen=True)
class ExecutorPlan:
    """Static (host-side) description of one fused stack execution.

    ``bin_triples`` holds one padded ``(n_stacks_b, tile_b, 4)`` int32
    tensor of ``(a_idx, b_idx, c_idx, valid)`` rows per *stack-length
    bin* (see ``stacks.pad_plans`` for the padding contract).  Dense
    plans have uniform stack sizes and collapse to a single bin —
    bit-identical to the historical single-tensor layout.  Ragged
    (low-fill) plans are size-binned so short stacks stop being padded
    to the longest stack: the executor runs one ``lax.scan`` per bin,
    cutting padding FLOPs at low occupancy (the ROADMAP stack-executor
    item).  ``plans`` keeps the original ragged ``StackPlan``s for
    statistics and the legacy looped dispatch.
    """

    bin_triples: Tuple[np.ndarray, ...]
    n_c_blocks: int
    block_m: int
    block_k: int
    block_n: int
    nbr: int
    nbk: int
    nbc: int
    plans: Tuple[StackPlan, ...]
    # -- norm filtering (repro.sparsity): eps the plan was built under
    # and the triple count the binary masks ALONE would have dispatched
    # (None when no norm filter was applied) --------------------------
    filter_eps: Optional[float] = None
    n_unfiltered_entries: Optional[int] = None

    @property
    def triples(self) -> np.ndarray:
        """Legacy single-tensor view: the padded ``(n_stacks,
        stack_tile, 4)`` layout the executor used before size-binning
        (and still uses whenever stack sizes are uniform)."""
        if len(self.bin_triples) == 1:
            return self.bin_triples[0]
        return pad_plans(list(self.plans))

    @property
    def n_bins(self) -> int:
        return len(self.bin_triples)

    @property
    def n_stacks(self) -> int:
        return sum(int(t.shape[0]) for t in self.bin_triples)

    @property
    def stack_tile(self) -> int:
        return max(int(t.shape[1]) for t in self.bin_triples)

    @property
    def n_entries(self) -> int:
        return sum(p.size for p in self.plans)

    @property
    def n_padding(self) -> int:
        """Padding rows actually dispatched (size-binned layout)."""
        return sum(int(t.shape[0] * t.shape[1])
                   for t in self.bin_triples) - self.n_entries

    @property
    def n_padding_unbinned(self) -> int:
        """Padding rows the pre-binning layout (every stack padded to
        the longest) would have dispatched — the baseline the
        size-binned savings are measured against."""
        return self.n_stacks * self.stack_tile - self.n_entries

    @property
    def n_dense_triples(self) -> int:
        """Triple count of the dense (mask-free) enumeration."""
        return self.nbr * self.nbk * self.nbc

    @property
    def n_skipped_triples(self) -> int:
        return self.n_dense_triples - self.n_entries

    @property
    def occupancy(self) -> float:
        """Fraction of the dense triple grid the plan dispatches."""
        dense = self.n_dense_triples
        return self.n_entries / dense if dense else 1.0

    @property
    def n_norm_filtered_triples(self) -> int:
        """Mask-present triples the norm filter dropped (0 when the
        plan was built without norms)."""
        if self.n_unfiltered_entries is None:
            return 0
        return self.n_unfiltered_entries - self.n_entries

    def stats(self) -> dict:
        from .stacks import stack_statistics

        s = stack_statistics(
            list(self.plans),
            stack_tile=self.stack_tile if self.plans else None)
        s["n_entries"] = self.n_entries
        s["n_dense_triples"] = self.n_dense_triples
        s["n_skipped_triples"] = self.n_skipped_triples
        s["occupancy"] = self.occupancy
        # size-binned padding accounting: the per-entry flop cost is
        # identical for every (padding or real) row, so saved triples
        # translate directly into saved padding FLOPs
        flop_per_entry = 2 * self.block_m * self.block_k * self.block_n
        s["n_bins"] = self.n_bins
        s["n_padding"] = self.n_padding
        s["n_padding_unbinned"] = self.n_padding_unbinned
        s["padding_triples_saved"] = self.n_padding_unbinned - self.n_padding
        s["padding_flops_saved"] = s["padding_triples_saved"] * flop_per_entry
        if self.plans:
            padded_total = self.n_entries + self.n_padding
            s["fill"] = self.n_entries / padded_total if padded_total else 1.0
        # norm-filter accounting (repro.sparsity): retained vs filtered
        # triples and the FLOPs the on-the-fly filter removed
        s["filter_eps"] = self.filter_eps
        if self.n_unfiltered_entries is not None:
            filtered = self.n_norm_filtered_triples
            s["n_unfiltered_triples"] = self.n_unfiltered_entries
            s["n_norm_filtered_triples"] = filtered
            s["norm_filtered_flops"] = filtered * flop_per_entry
            s["norm_retained_fraction"] = (
                self.n_entries / self.n_unfiltered_entries
                if self.n_unfiltered_entries else 1.0)
        if obs.enabled():
            # publish into the process-wide registry (gated: the
            # disabled path must add zero registry entries)
            obs.counter("executor.stats_reports").inc()
            obs.counter("executor.entries").inc(self.n_entries)
            obs.counter("executor.padding_triples_saved").inc(
                s["padding_triples_saved"])
            obs.counter("executor.norm_filtered_triples").inc(
                self.n_norm_filtered_triples)
            obs.histogram("executor.occupancy").observe(self.occupancy)
        return s


# Masks and norms are numpy arrays — unhashable, so the plan memo keys
# on a content fingerprint (shape, dtype, sha1(bytes)).  The arrays
# themselves are staged here only for the duration of a
# build_executor_plan call (the cached builder reads them on a memo
# miss); nothing retains the caller's arrays afterwards, and plan
# retention is bounded by the LRU below rather than growing with every
# distinct mask/norm pattern ever seen.
_STAGED_MASKS: dict = {}

# Distinct dense geometries are few, but masked keys are open-ended
# (one per occupancy pattern per shift/panel); bound the memo so a
# long-running job with evolving sparsity cannot accumulate plans
# without eviction.
_PLAN_CACHE_SIZE = 1024


def _array_fingerprint(arr: Optional[np.ndarray], dtype):
    """Fingerprint a *private copy* of a host array — the caller's
    array is never retained or frozen, so callers may mutate their
    masks/norms between multiplies (each content change simply
    fingerprints anew)."""
    if arr is None:
        return None
    m = np.array(arr, dtype=dtype, order="C")  # always a fresh copy
    fp = (m.shape, str(m.dtype), hashlib.sha1(m.tobytes()).hexdigest())
    _STAGED_MASKS.setdefault(fp, m)
    return fp


def _mask_fingerprint(mask: Optional[np.ndarray]):
    return _array_fingerprint(mask, bool)


def _norm_fingerprint(norms: Optional[np.ndarray]):
    # norms always fingerprint as float32 (the dtype sparsity/norms.py
    # computes) so equal content hits one plan regardless of input dtype
    return _array_fingerprint(norms, np.float32)


# One lax.scan (and one traced kernel body) runs per stack-length bin,
# so the bin count is capped.  4 bins (the default) bounds the extra
# traces while capturing most of the padding win (stack sizes within a
# bin differ by at most 2x); ``stack_bins=`` / DBCSR_STACK_BINS
# override it — benchmarks/bench_sparse.py sweeps the cap.
_MAX_SIZE_BINS = 4


def resolve_stack_bins(stack_bins: Optional[int] = None) -> int:
    """The executor's size-bin cap: explicit kwarg > DBCSR_STACK_BINS
    env > the default (4).  1 disables binning (the pre-PR4 single
    padded tensor); higher values trade extra scan traces for less
    padding at low fill."""
    if stack_bins is None:
        stack_bins = int(os.environ.get("DBCSR_STACK_BINS", _MAX_SIZE_BINS))
    stack_bins = int(stack_bins)
    if stack_bins < 1:
        raise ValueError(f"stack_bins must be >= 1, got {stack_bins}")
    return stack_bins


def build_executor_plan(
    m: int,
    k: int,
    n: int,
    block_m: int,
    block_k: int,
    block_n: int,
    stack_size: int = STACK_SIZE,
    a_mask: Optional[np.ndarray] = None,
    b_mask: Optional[np.ndarray] = None,
    pair_mask: Optional[np.ndarray] = None,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
    pair_norms: Optional[np.ndarray] = None,
    filter_eps: Optional[float] = None,
    stack_bins: Optional[int] = None,
) -> ExecutorPlan:
    """Generation + Scheduler phases for the local (m, k) x (k, n)
    multiply, memoized: repeated multiplies of the same geometry
    (training steps, benchmark reps, repeated cannon shifts with the
    same occupancy pattern) never rebuild the numpy plans.  Occupancy
    masks AND block norms participate in the memo key by content
    fingerprint (see module docstring: sparse planning contract);
    ``filter_eps`` follows the repro.sparsity contract (triples whose
    norm product is < eps are dropped; None disables filtering,
    0.0 is bit-identical to the mask-only plan).
    """
    eps = None if filter_eps is None else float(filter_eps)
    bins_cap = resolve_stack_bins(stack_bins)
    fps = (_mask_fingerprint(a_mask), _mask_fingerprint(b_mask),
           _mask_fingerprint(pair_mask), _norm_fingerprint(a_norms),
           _norm_fingerprint(b_norms), _norm_fingerprint(pair_norms))
    try:
        return _build_executor_plan_cached(
            m, k, n, block_m, block_k, block_n, stack_size, *fps,
            eps, bins_cap)
    finally:
        for fp in fps:
            if fp is not None:
                _STAGED_MASKS.pop(fp, None)


def _size_binned(plans: List[StackPlan],
                 max_bins: int = _MAX_SIZE_BINS) -> Tuple[np.ndarray, ...]:
    """Group stack plans into <= ``max_bins`` power-of-two length bins
    and pad each bin to its own longest stack (ragged-aware stack_tile).

    Uniform stack sizes (the dense regime) collapse to a single bin
    whose tensor is bit-identical to the historical ``pad_plans`` of
    the whole plan list.  Binning never reorders entries *within* a
    stack and never splits k-runs, and each C block lives in exactly
    one stack, so cross-bin execution order cannot change any result.
    """
    sizes = [p.size for p in plans]
    if len(set(sizes)) <= 1 or max_bins <= 1:
        return (pad_plans(plans),)
    # engage binning only when the single-tile layout wastes >= 25% of
    # its dispatched rows on padding: a dense plan's short final stack
    # is not worth a second scan trace, the low-fill regime (wildly
    # ragged run lengths, oversized-run stacks) is
    total_unbinned = len(plans) * max(sizes)
    if 4 * (total_unbinned - sum(sizes)) < total_unbinned:
        return (pad_plans(plans),)
    keys = [max(s, 1).bit_length() for s in sizes]
    shift = 0
    while len(set(k >> shift for k in keys)) > max_bins:
        # halve the log-resolution until the bin count fits the cap
        shift += 1
    keys = [k >> shift for k in keys]
    out = []
    for key in sorted(set(keys)):
        members = [p for p, kk in zip(plans, keys) if kk == key]
        out.append(pad_plans(members))
    return tuple(out)


@functools.lru_cache(maxsize=_PLAN_CACHE_SIZE)
def _build_executor_plan_cached(
    m: int,
    k: int,
    n: int,
    block_m: int,
    block_k: int,
    block_n: int,
    stack_size: int,
    a_fp,
    b_fp,
    pair_fp,
    an_fp,
    bn_fp,
    pn_fp,
    filter_eps: Optional[float],
    stack_bins: int,
) -> ExecutorPlan:
    a_layout = BlockLayout(m, k, block_m, block_k)
    b_layout = BlockLayout(k, n, block_k, block_n)
    staged = lambda fp: None if fp is None else _STAGED_MASKS[fp]
    a_mask, b_mask, pair_mask = staged(a_fp), staged(b_fp), staged(pair_fp)
    a_norms, b_norms, pair_norms = staged(an_fp), staged(bn_fp), staged(pn_fp)
    filtering = filter_eps is not None and (
        a_norms is not None or b_norms is not None or pair_norms is not None)
    plans = build_stacks(
        a_layout, b_layout, stack_size,
        a_mask=a_mask, b_mask=b_mask, pair_mask=pair_mask,
        a_norms=a_norms, b_norms=b_norms, pair_norms=pair_norms,
        filter_eps=filter_eps)
    if plans:
        bins = _size_binned(plans, stack_bins)
    else:
        # empty mask/filter product: zero stacks, execute_plan is a no-op
        bins = (np.zeros((0, 1, 4), dtype=np.int32),)
    for t in bins:
        t.setflags(write=False)  # memoized => shared; guard against mutation
    n_unfiltered = None
    if filtering:
        # what the binary masks alone would have dispatched, so stats()
        # can attribute the norm filter's extra skips
        if pair_mask is not None:
            n_unfiltered = int(np.count_nonzero(pair_mask))
        else:
            from .stacks import normalize_block_masks

            am, bm = normalize_block_masks(
                a_layout.nblock_rows, a_layout.nblock_cols,
                b_layout.nblock_cols, a_mask, b_mask)
            n_unfiltered = int(
                (am.astype(np.int64) @ bm.astype(np.int64)).sum())
    return ExecutorPlan(
        bin_triples=bins,
        n_c_blocks=a_layout.nblock_rows * b_layout.nblock_cols,
        block_m=block_m,
        block_k=block_k,
        block_n=block_n,
        nbr=a_layout.nblock_rows,
        nbk=a_layout.nblock_cols,
        nbc=b_layout.nblock_cols,
        plans=tuple(plans),
        filter_eps=filter_eps if filtering else None,
        n_unfiltered_entries=n_unfiltered,
    )


def execute_plan(
    plan: ExecutorPlan,
    a_blocks: jax.Array,
    b_blocks: jax.Array,
    c_blocks: jax.Array,
    *,
    kernel: str = "smm",
    align: bool = False,
) -> jax.Array:
    """Run every stack of ``plan`` through ``lax.scan`` — one scan per
    stack-length bin (dense plans have one bin), so the stack processor
    is traced once per (block geometry, bin tile), never once per stack.

    A scratch C block is appended at index ``n_c_blocks`` to absorb the
    padding rows' (masked, zero) writes, and stripped from the result.

    An empty plan (fully-absent mask product) returns ``c_blocks``
    unchanged without dispatching anything.
    """
    if plan.n_stacks == 0:
        return c_blocks
    process = _resolve_process(kernel)
    bm, bn = c_blocks.shape[1], c_blocks.shape[2]
    if align and kernel == "smm":
        # Hoist the MXU alignment out of the scan: pad A/B/C once here
        # instead of letting every scan step re-pad the (loop-invariant)
        # block arrays and round-trip the whole C accumulator.
        from repro.kernels.smm.ops import mxu_pad_shape

        bk = a_blocks.shape[2]
        pm, pk, pn = mxu_pad_shape(bm, bk, bn, True)
        if (pm, pk, pn) != (bm, bk, bn):
            a_blocks = jnp.pad(a_blocks, ((0, 0), (0, pm - bm), (0, pk - bk)))
            b_blocks = jnp.pad(b_blocks, ((0, 0), (0, pk - bk), (0, pn - bn)))
            c_blocks = jnp.pad(c_blocks, ((0, 0), (0, pm - bm), (0, pn - bn)))
        align = False  # blocks are pre-aligned; steps run the raw kernel
    scratch = jnp.zeros((1,) + c_blocks.shape[1:], c_blocks.dtype)
    c = jnp.concatenate([c_blocks, scratch], axis=0)

    def step(c_carry, stack_triples):
        return process(a_blocks, b_blocks, c_carry, stack_triples,
                       align=align), None

    # each C block's k-run lives in exactly one stack, so bin order
    # cannot change any accumulation order (engine bit-identity)
    for tensor in plan.bin_triples:
        c, _ = jax.lax.scan(step, c, jnp.asarray(tensor))
    c = c[:-1]
    if c.shape[1:] != (bm, bn):
        c = c[:, :bm, :bn]
    return c


def execute_plans_looped(
    plans: List[StackPlan],
    a_blocks: jax.Array,
    b_blocks: jax.Array,
    c_blocks: jax.Array,
    *,
    kernel: str = "smm",
    align: bool = False,
) -> jax.Array:
    """The seed's per-plan Python-loop dispatch (one jit call per stack).

    Kept as the baseline arm of the fused-vs-looped benchmark and the
    trace-count regression test; production paths use ``execute_plan``.
    """
    process = _resolve_process(kernel)
    c = c_blocks
    for p in plans:
        c = process(a_blocks, b_blocks, c, jnp.asarray(p.triples),
                    align=align)
    return c


# ---------------------------------------------------------------------------
# Product-batched execution: N same-geometry products, one dispatch
# ---------------------------------------------------------------------------


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class BatchedExecutorPlan:
    """``ExecutorPlan``'s batched variant: one fused stack tensor for a
    *group* of N same-block-geometry products.

    Per-group plans are built through the ordinary memoized
    ``build_executor_plan`` (so two requests with identical mask/norm
    content share ONE cached plan — that is the cross-request plan
    sharing ``n_shared_plans`` counts), then their single-tensor views
    are padded to a shared ``(n_groups, stack_pad, tile_pad)`` shape and
    fused by folding the group index into the block indices: group
    ``g``'s rows are offset by ``(g*n_a_blocks, g*n_b_blocks,
    g*n_c_blocks)`` and EVERY padding row — a group's own stack padding
    and the cross-group shape padding alike — points at the single
    global scratch block ``n_groups * n_c_blocks`` with ``valid=0``.

    ``stack_pad`` / ``tile_pad`` are rounded up to powers of two, so the
    fused tensor's shape — the only thing the traced dispatch program
    depends on — is quantized: batches whose per-group occupancies land
    in the same power-of-two bin (and whose eps bucket matches, since
    eps shapes the per-group plans) replay one trace.  This is the
    batched memo-key contract: (geometry, occupancy-bin, eps-bin),
    shared across requests, while per-group triple *values* still come
    from the content-fingerprint memo.
    """

    triples: np.ndarray            # (n_groups*stack_pad, tile_pad, 4) fused
    n_groups: int
    n_a_blocks: int                # per-group block counts
    n_b_blocks: int
    n_c_blocks: int
    block_m: int
    block_k: int
    block_n: int
    group_plans: Tuple[ExecutorPlan, ...]
    n_shared_plans: int            # groups that hit another group's memo entry
    filter_eps: Optional[float] = None

    @property
    def scratch_index(self) -> int:
        return self.n_groups * self.n_c_blocks

    @property
    def n_stacks(self) -> int:
        return int(self.triples.shape[0])

    @property
    def stack_tile(self) -> int:
        return int(self.triples.shape[1])

    @property
    def n_entries(self) -> int:
        return sum(p.n_entries for p in self.group_plans)

    @property
    def n_padding(self) -> int:
        """Padding rows of the fused dispatch — per-group stack padding
        PLUS the cross-group power-of-two shape padding."""
        return self.n_stacks * self.stack_tile - self.n_entries

    @property
    def padding_frac(self) -> float:
        total = self.n_stacks * self.stack_tile
        return self.n_padding / total if total else 0.0

    def stats(self) -> dict:
        """Per-group padding and cross-request fusion accounting."""
        flop_per_entry = 2 * self.block_m * self.block_k * self.block_n
        per_group = []
        for p in self.group_plans:
            per_group.append({
                "n_entries": p.n_entries,
                "n_stacks": p.n_stacks,
                "occupancy": p.occupancy,
            })
        s = {
            "n_groups": self.n_groups,
            "n_shared_plans": self.n_shared_plans,
            "n_entries": self.n_entries,
            "n_stacks": self.n_stacks,
            "stack_tile": self.stack_tile,
            "n_padding": self.n_padding,
            "padding_frac": self.padding_frac,
            "padding_flops": self.n_padding * flop_per_entry,
            "filter_eps": self.filter_eps,
            "per_group": per_group,
        }
        if obs.enabled():
            obs.counter("executor.batched_stats_reports").inc()
            obs.counter("executor.batched_shared_plans").inc(
                self.n_shared_plans)
            obs.histogram("executor.batched_padding_frac").observe(
                self.padding_frac)
        return s


def build_batched_executor_plan(
    m: int,
    k: int,
    n: int,
    block_m: int,
    block_k: int,
    block_n: int,
    group_masks,
    stack_size: int = STACK_SIZE,
    filter_eps: Optional[float] = None,
) -> BatchedExecutorPlan:
    """Fuse one ``ExecutorPlan`` per group into a single group-offset
    stack tensor (see ``BatchedExecutorPlan``).

    ``group_masks`` is a sequence of per-group mask/norm kwargs dicts
    (``a_mask`` / ``b_mask`` / ``pair_mask`` / ``a_norms`` / ``b_norms``
    / ``pair_norms``; an empty dict means a dense group).  Per-group
    plans are built with ``stack_bins=1`` — within a batch the shape
    binning happens ACROSS groups (the power-of-two padded fused shape),
    not within one group's stack list.
    """
    group_masks = list(group_masks)
    if not group_masks:
        raise ValueError("batched plan needs at least one group")
    plans = [
        build_executor_plan(m, k, n, block_m, block_k, block_n, stack_size,
                            filter_eps=filter_eps, stack_bins=1, **gm)
        for gm in group_masks
    ]
    g_total = len(plans)
    base = plans[0]
    n_a = base.nbr * base.nbk
    n_b = base.nbk * base.nbc
    n_c = base.n_c_blocks
    seen, shared = set(), 0
    for p in plans:
        if id(p) in seen:
            shared += 1
        else:
            seen.add(id(p))
    views = [p.triples for p in plans]
    s_max = max(v.shape[0] for v in views)
    t_max = max(v.shape[1] for v in views)
    if s_max == 0:
        fused = np.zeros((0, 1, 4), dtype=np.int32)
    else:
        s_pad, t_pad = _next_pow2(s_max), _next_pow2(t_max)
        scratch = g_total * n_c
        fused = np.zeros((g_total, s_pad, t_pad, 4), dtype=np.int32)
        fused[..., 2] = scratch
        for g, v in enumerate(views):
            s, t = int(v.shape[0]), int(v.shape[1])
            if not s:
                continue
            valid = v[:, :, 3] != 0
            sub = fused[g, :s, :t]
            sub[:, :, 0] = np.where(valid, v[:, :, 0] + g * n_a, 0)
            sub[:, :, 1] = np.where(valid, v[:, :, 1] + g * n_b, 0)
            sub[:, :, 2] = np.where(valid, v[:, :, 2] + g * n_c, scratch)
            sub[:, :, 3] = v[:, :, 3]
        fused = fused.reshape(g_total * s_pad, t_pad, 4)
    fused.setflags(write=False)
    return BatchedExecutorPlan(
        triples=fused,
        n_groups=g_total,
        n_a_blocks=n_a,
        n_b_blocks=n_b,
        n_c_blocks=n_c,
        block_m=block_m,
        block_k=block_k,
        block_n=block_n,
        group_plans=tuple(plans),
        n_shared_plans=shared,
        filter_eps=filter_eps,
    )


def execute_batched_plan(
    plan: BatchedExecutorPlan,
    a_blocks: jax.Array,   # (n_groups, n_a_blocks, bm, bk)
    b_blocks: jax.Array,   # (n_groups, n_b_blocks, bk, bn)
    c_blocks: jax.Array,   # (n_groups, n_c_blocks, bm, bn)
    *,
    kernel: str = "smm",
    align: bool = False,
) -> jax.Array:
    """Run every group's stacks in ONE fused dispatch (one ``lax.scan``
    through ``grouped_process_stack``) and return the accumulated
    ``(n_groups, n_c_blocks, bm, bn)`` C blocks.

    Bit-identity with the per-group ``execute_plan`` loop: each C
    block's k-run lives in exactly one stack of exactly one group, group
    offsetting never reorders entries within a stack, and padding rows
    only touch the global scratch block — so the per-block accumulation
    order is identical to the looped dispatch.
    """
    if plan.n_stacks == 0:
        return c_blocks
    g = plan.n_groups
    bm, bn = int(c_blocks.shape[-2]), int(c_blocks.shape[-1])
    a = a_blocks.reshape((g * plan.n_a_blocks,) + tuple(a_blocks.shape[-2:]))
    b = b_blocks.reshape((g * plan.n_b_blocks,) + tuple(b_blocks.shape[-2:]))
    c = c_blocks.reshape((g * plan.n_c_blocks,) + tuple(c_blocks.shape[-2:]))
    if align and kernel == "smm":
        # same MXU-alignment hoist as execute_plan: pad once out here
        from repro.kernels.smm.ops import mxu_pad_shape

        bk = int(a.shape[2])
        pm, pk, pn = mxu_pad_shape(bm, bk, bn, True)
        if (pm, pk, pn) != (bm, bk, bn):
            a = jnp.pad(a, ((0, 0), (0, pm - bm), (0, pk - bk)))
            b = jnp.pad(b, ((0, 0), (0, pk - bk), (0, pn - bn)))
            c = jnp.pad(c, ((0, 0), (0, pm - bm), (0, pn - bn)))
        align = False
    from repro.kernels.grouped_gemm.ops import grouped_process_stack

    scratch = jnp.zeros((1,) + tuple(c.shape[1:]), c.dtype)
    c = jnp.concatenate([c, scratch], axis=0)
    c = grouped_process_stack(a, b, c, jnp.asarray(plan.triples),
                              kernel=kernel, align=align)
    c = c[:-1]
    if c.shape[1:] != (bm, bn):
        c = c[:, :bm, :bn]
    return c.reshape((g, plan.n_c_blocks, bm, bn))


def batched_stack_executor(
    n_groups: int,
    m: int,
    k: int,
    n: int,
    *,
    block_m: int,
    block_k: int,
    block_n: int,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    kernel: str = "smm",
    group_masks=None,
    filter_eps: Optional[float] = None,
):
    """Build the fused batched blocked local multiply
    ``((G, m, k), (G, k, n)) -> (G, m, n)``.

    The batched twin of ``stack_executor``: autotune params are
    resolved ONCE per batch from the mean group fill (the bucket's
    occupancy bin — requests in one bucket share stack params by
    contract), the per-group plans go through the shared engine memo,
    and the whole batch executes as one fused dispatch.  Note stack
    splitting and ``align`` padding never change per-block accumulation
    order (runs are never split; zero-padding adds exact 0.0 terms), so
    differing tuned params between this and a looped oracle cannot
    break bit-identity.
    """
    from repro.kernels.smm.autotune import best_params_for

    from .densify import from_blocks_batched, to_blocks_batched

    if group_masks is None:
        group_masks = [{}] * n_groups
    group_masks = list(group_masks)
    if len(group_masks) != n_groups:
        raise ValueError(
            f"{len(group_masks)} mask groups for {n_groups} groups")
    nbr, nbk, nbc = m // block_m, k // block_k, n // block_n
    fills = [
        _mask_fill(nbr, nbk, nbc,
                   gm.get("a_mask"), gm.get("b_mask"), gm.get("pair_mask"),
                   gm.get("a_norms"), gm.get("b_norms"),
                   gm.get("pair_norms"), filter_eps)
        for gm in group_masks
    ]
    fill = sum(fills) / len(fills)
    tuned_align, tuned_tile = best_params_for(block_m, block_k, block_n,
                                              fill=fill)
    if align is None:
        align = tuned_align
    if stack_size is None:
        stack_size = tuned_tile
    plan = build_batched_executor_plan(
        m, k, n, block_m, block_k, block_n, group_masks,
        stack_size=stack_size, filter_eps=filter_eps)

    def f(a: jax.Array, b: jax.Array) -> jax.Array:
        if a.shape != (n_groups, m, k) or b.shape != (n_groups, k, n):
            raise ValueError(
                f"batched executor built for ({n_groups},{m},{k}) x "
                f"({n_groups},{k},{n}), got {a.shape} x {b.shape}")
        a_blocks = to_blocks_batched(a, block_m, block_k)
        b_blocks = to_blocks_batched(b, block_k, block_n)
        c_blocks = jnp.zeros((n_groups, nbr * nbc, block_m, block_n),
                             jnp.float32)
        c_blocks = execute_batched_plan(plan, a_blocks, b_blocks, c_blocks,
                                        kernel=kernel, align=align)
        return from_blocks_batched(c_blocks, nbr, nbc)

    f.batched_plan = plan
    f.align = align
    f.stack_size = stack_size
    f.n_groups = n_groups
    return f


def _mask_fill(
    nbr: int,
    nbk: int,
    nbc: int,
    a_mask: Optional[np.ndarray],
    b_mask: Optional[np.ndarray],
    pair_mask: Optional[np.ndarray],
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
    pair_norms: Optional[np.ndarray] = None,
    filter_eps: Optional[float] = None,
) -> float:
    """Retained-triple fraction of the dense grid (cheap, plan-free —
    needed *before* plan construction to pick the occupancy-binned
    autotune winner, whose stack_tile shapes the plan itself).  With
    norms and a ``filter_eps`` this is the NORM-PREDICTED fraction
    (mask-present triples clearing the eps product bound), which is
    also what the planner discounts blocked-path flops by."""
    filtering = filter_eps is not None and (
        a_norms is not None or b_norms is not None or pair_norms is not None)
    size = nbr * nbk * nbc
    if pair_norms is not None and filtering:
        keep = pair_norms.astype(np.float64) >= float(filter_eps)
        if pair_mask is not None:
            keep &= pair_mask
        return float(np.count_nonzero(keep)) / size
    if pair_mask is not None:
        return float(np.count_nonzero(pair_mask)) / size
    if a_mask is None and b_mask is None and not filtering:
        return 1.0
    from .stacks import normalize_block_masks

    am, bm = normalize_block_masks(nbr, nbk, nbc, a_mask, b_mask)
    if filtering:
        from repro.sparsity.filter import count_retained_triples

        return count_retained_triples(am, bm, a_norms, b_norms,
                                      filter_eps) / size
    return float((am.astype(np.int64) @ bm.astype(np.int64)).sum()) / size


def stack_executor(
    m: int,
    k: int,
    n: int,
    *,
    block_m: int,
    block_k: int,
    block_n: int,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    kernel: str = "smm",
    a_mask: Optional[np.ndarray] = None,
    b_mask: Optional[np.ndarray] = None,
    pair_mask: Optional[np.ndarray] = None,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
    pair_norms: Optional[np.ndarray] = None,
    filter_eps: Optional[float] = None,
    stack_bins: Optional[int] = None,
):
    """Build the fused blocked local multiply ``(a, b) -> c``.

    ``stack_size`` / ``align`` default to the autotune winners table for
    this block geometry *and* occupancy bin (falling back to its
    heuristic when no sweep has been recorded); pass explicit values to
    pin them.  Occupancy masks follow the sparse planning contract
    (module docstring): the executor dispatches only present triples;
    operands still arrive as full dense arrays with absent blocks
    zeroed.  Block norms + ``filter_eps`` additionally drop triples by
    the norm-product bound (repro.sparsity) — the fill the autotune bin
    is resolved against is then the norm-predicted retained fraction.
    ``stack_bins`` caps the executor's size bins (``resolve_stack_bins``).
    """
    from repro.kernels.smm.autotune import best_params_for

    fill = _mask_fill(m // block_m, k // block_k, n // block_n,
                      a_mask, b_mask, pair_mask,
                      a_norms, b_norms, pair_norms, filter_eps)
    tuned_align, tuned_tile = best_params_for(block_m, block_k, block_n,
                                              fill=fill)
    if align is None:
        align = tuned_align
    if stack_size is None:
        stack_size = tuned_tile
    plan = build_executor_plan(m, k, n, block_m, block_k, block_n, stack_size,
                               a_mask=a_mask, b_mask=b_mask,
                               pair_mask=pair_mask, a_norms=a_norms,
                               b_norms=b_norms, pair_norms=pair_norms,
                               filter_eps=filter_eps, stack_bins=stack_bins)

    def f(a: jax.Array, b: jax.Array) -> jax.Array:
        if a.shape != (m, k) or b.shape != (k, n):
            # loud failure: shapes that happen to divide into the blocks
            # would otherwise execute with wrong block indexing (gathers
            # clamp out-of-range indices instead of raising)
            raise ValueError(
                f"stack executor built for ({m},{k}) x ({k},{n}), "
                f"got {a.shape} x {b.shape}")
        a_blocks = to_blocks(a, block_m, block_k)
        b_blocks = to_blocks(b, block_k, block_n)
        c_blocks = jnp.zeros((plan.nbr * plan.nbc, block_m, block_n),
                             jnp.float32)
        c_blocks = execute_plan(plan, a_blocks, b_blocks, c_blocks,
                                kernel=kernel, align=align)
        return from_blocks(c_blocks, plan.nbr, plan.nbc)

    f.executor_plan = plan
    f.plans = list(plan.plans)  # legacy attribute (benchmarks/stats)
    f.align = align
    f.stack_size = stack_size
    return f


# ---------------------------------------------------------------------------
# Rank-exact execution: one padded plan slab per rank, selected by
# axis_index inside shard_map (ISSUE 9 / ROADMAP "Rank-exact execution")
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RankExecutorPlan:
    """Stacked per-rank plans for one SPMD local multiply.

    ``slab`` is the host-constant ``(R, S, T, 4)`` tensor
    ``stacks.stack_rank_slab`` builds from R per-rank ``ExecutorPlan``
    single-tensor views: every rank's retained triples, padded to one
    traced shape.  Inside ``shard_map`` each rank selects its slice
    with ``lax.dynamic_index_in_dim(slab, rank_index)`` — the traced
    program is identical on every rank (SPMD-safe), but a rank executes
    only ITS mask/norm-retained triples instead of the union plan.

    The union-compatible statistics properties (``n_entries`` etc.)
    report the MAX over ranks — the busiest rank bounds the step's wall
    time, which is what schedule pricing and the planner consume.
    Per-rank detail lives in ``rank_entries`` / ``rank_imbalance``.
    """

    slab: np.ndarray               # (R, S, T, 4) int32, read-only
    n_c_blocks: int
    block_m: int
    block_k: int
    block_n: int
    nbr: int
    nbk: int
    nbc: int
    rank_plans: Tuple[ExecutorPlan, ...]
    filter_eps: Optional[float] = None

    @property
    def n_ranks(self) -> int:
        return int(self.slab.shape[0])

    @property
    def n_stacks(self) -> int:
        return int(self.slab.shape[1])

    @property
    def stack_tile(self) -> int:
        return int(self.slab.shape[2])

    @property
    def rank_entries(self) -> Tuple[int, ...]:
        """Retained (non-padding) triples each rank executes."""
        return tuple(p.n_entries for p in self.rank_plans)

    @property
    def n_entries(self) -> int:
        """Busiest rank's retained triples (the wall-time bound)."""
        return max(self.rank_entries, default=0)

    @property
    def n_entries_mean(self) -> float:
        e = self.rank_entries
        return float(np.mean(e)) if e else 0.0

    @property
    def rank_imbalance(self) -> float:
        """max/mean retained triples over ranks (1.0 = balanced)."""
        mean = self.n_entries_mean
        return float(self.n_entries) / mean if mean > 0 else 1.0

    @property
    def n_dense_triples(self) -> int:
        return self.nbr * self.nbk * self.nbc

    @property
    def n_skipped_triples(self) -> int:
        return self.n_dense_triples - self.n_entries

    @property
    def occupancy(self) -> float:
        """Busiest rank's fraction of the dense local triple grid."""
        dense = self.n_dense_triples
        return self.n_entries / dense if dense else 1.0

    @property
    def n_padding(self) -> int:
        """Padding rows the busiest-rank slab slice dispatches."""
        return self.n_stacks * self.stack_tile - self.n_entries

    @property
    def n_padding_unbinned(self) -> int:
        return self.n_padding

    @property
    def n_unfiltered_entries(self) -> Optional[int]:
        vals = [p.n_unfiltered_entries for p in self.rank_plans]
        if any(v is not None for v in vals):
            return max(v if v is not None else p.n_entries
                       for v, p in zip(vals, self.rank_plans))
        return None

    @property
    def n_norm_filtered_triples(self) -> int:
        return max((p.n_norm_filtered_triples for p in self.rank_plans),
                   default=0)

    @property
    def uniform(self) -> bool:
        """True when every rank's slab slice is content-identical —
        the dense / uniform-fill regime where rank-exact execution
        degenerates to the union plan."""
        return bool((self.slab == self.slab[:1]).all())

    def stats(self) -> dict:
        s = {
            "n_ranks": self.n_ranks,
            "n_stacks": self.n_stacks,
            "stack_tile": self.stack_tile,
            "n_entries": self.n_entries,
            "rank_entries": list(self.rank_entries),
            "rank_entries_mean": self.n_entries_mean,
            "rank_imbalance": self.rank_imbalance,
            "n_dense_triples": self.n_dense_triples,
            "occupancy": self.occupancy,
            "n_padding": self.n_padding,
            "filter_eps": self.filter_eps,
        }
        if obs.enabled():
            obs.histogram("executor.rank_imbalance").observe(
                self.rank_imbalance)
        return s


def build_rank_executor_plan(
    m: int,
    k: int,
    n: int,
    *,
    block_m: int,
    block_k: int,
    block_n: int,
    rank_masks,
    stack_size: int = STACK_SIZE,
    filter_eps: Optional[float] = None,
) -> RankExecutorPlan:
    """Build R per-rank plans (memoized individually — identical ranks
    share one cached ``ExecutorPlan``) and stack their padded triple
    tensors into the rank slab.  ``rank_masks`` is a sequence of
    per-rank mask/norm kwarg dicts (``a_mask``/``b_mask``/``pair_mask``
    /``a_norms``/``b_norms``/``pair_norms``) on the LOCAL geometry, in
    mesh-flattened rank order (the order the caller's rank_index
    computes inside shard_map).

    Per-rank plans are built with ``stack_bins=1``: size-binning would
    give each rank a private bin structure, breaking the single traced
    shape the slab requires.
    """
    plans = tuple(
        build_executor_plan(m, k, n, block_m, block_k, block_n, stack_size,
                            filter_eps=filter_eps, stack_bins=1, **rm)
        for rm in rank_masks)
    n_c = plans[0].n_c_blocks
    slab = stack_rank_slab([p.triples for p in plans], n_c)
    slab.setflags(write=False)
    return RankExecutorPlan(
        slab=slab,
        n_c_blocks=n_c,
        block_m=block_m,
        block_k=block_k,
        block_n=block_n,
        nbr=plans[0].nbr,
        nbk=plans[0].nbk,
        nbc=plans[0].nbc,
        rank_plans=plans,
        filter_eps=filter_eps,
    )


def execute_rank_plan(
    plan: RankExecutorPlan,
    rank_index,
    a_blocks: jax.Array,
    b_blocks: jax.Array,
    c_blocks: jax.Array,
    *,
    kernel: str = "smm",
    align: bool = False,
) -> jax.Array:
    """``execute_plan``'s rank-exact twin: select this rank's slab slice
    by the traced ``rank_index`` and scan only those stacks.

    The program is shape-identical on every rank; only the gathered
    triple VALUES differ, so the dispatch stays SPMD-safe under
    ``shard_map``.  An all-empty slab (every rank's product absent)
    returns ``c_blocks`` untouched.
    """
    if plan.n_stacks == 0 or max(plan.rank_entries, default=0) == 0:
        return c_blocks
    process = _resolve_process(kernel)
    bm, bn = c_blocks.shape[1], c_blocks.shape[2]
    if align and kernel == "smm":
        from repro.kernels.smm.ops import mxu_pad_shape

        bk = a_blocks.shape[2]
        pm, pk, pn = mxu_pad_shape(bm, bk, bn, True)
        if (pm, pk, pn) != (bm, bk, bn):
            a_blocks = jnp.pad(a_blocks, ((0, 0), (0, pm - bm), (0, pk - bk)))
            b_blocks = jnp.pad(b_blocks, ((0, 0), (0, pk - bk), (0, pn - bn)))
            c_blocks = jnp.pad(c_blocks, ((0, 0), (0, pm - bm), (0, pn - bn)))
        align = False
    scratch = jnp.zeros((1,) + c_blocks.shape[1:], c_blocks.dtype)
    c = jnp.concatenate([c_blocks, scratch], axis=0)
    mine = jax.lax.dynamic_index_in_dim(
        jnp.asarray(plan.slab), jnp.asarray(rank_index, jnp.int32),
        axis=0, keepdims=False)

    def step(c_carry, stack_triples):
        return process(a_blocks, b_blocks, c_carry, stack_triples,
                       align=align), None

    c, _ = jax.lax.scan(step, c, mine)
    c = c[:-1]
    if c.shape[1:] != (bm, bn):
        c = c[:, :bm, :bn]
    return c


def rank_stack_executor(
    m: int,
    k: int,
    n: int,
    *,
    block_m: int,
    block_k: int,
    block_n: int,
    rank_masks,
    rank_index_fn,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    kernel: str = "smm",
    filter_eps: Optional[float] = None,
    stack_bins: Optional[int] = None,
):
    """``stack_executor``'s rank-exact twin for use inside ``shard_map``.

    ``rank_index_fn`` is a zero-arg callable evaluated at trace time
    inside the shard_map body, returning this rank's flat index into
    ``rank_masks`` order (built from ``jax.lax.axis_index`` over the
    mesh axes).  ``stack_size``/``align`` default to the autotune
    winners resolved at the BUSIEST rank's fill, so every rank runs the
    same tuned tile.

    ``stack_bins`` is accepted for signature parity but rank slabs are
    always single-bin (see ``build_rank_executor_plan``).
    """
    from repro.kernels.smm.autotune import best_params_for

    nbr, nbk, nbc = m // block_m, k // block_k, n // block_n
    fill = max(
        _mask_fill(nbr, nbk, nbc,
                   rm.get("a_mask"), rm.get("b_mask"), rm.get("pair_mask"),
                   rm.get("a_norms"), rm.get("b_norms"),
                   rm.get("pair_norms"), filter_eps)
        for rm in rank_masks)
    tuned_align, tuned_tile = best_params_for(block_m, block_k, block_n,
                                              fill=fill)
    if align is None:
        align = tuned_align
    if stack_size is None:
        stack_size = tuned_tile
    plan = build_rank_executor_plan(
        m, k, n, block_m=block_m, block_k=block_k, block_n=block_n,
        rank_masks=rank_masks, stack_size=stack_size,
        filter_eps=filter_eps)

    def f(a: jax.Array, b: jax.Array) -> jax.Array:
        if a.shape != (m, k) or b.shape != (k, n):
            raise ValueError(
                f"rank stack executor built for ({m},{k}) x ({k},{n}), "
                f"got {a.shape} x {b.shape}")
        a_blocks = to_blocks(a, block_m, block_k)
        b_blocks = to_blocks(b, block_k, block_n)
        c_blocks = jnp.zeros((plan.nbr * plan.nbc, block_m, block_n),
                             jnp.float32)
        c_blocks = execute_rank_plan(plan, rank_index_fn(), a_blocks,
                                     b_blocks, c_blocks, kernel=kernel,
                                     align=align)
        return from_blocks(c_blocks, plan.nbr, plan.nbc)

    f.executor_plan = plan
    f.rank_plan = plan
    f.align = align
    f.stack_size = stack_size
    return f
