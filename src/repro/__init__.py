"""repro — DBCSR distributed matmul, reproduced as a TPU/JAX framework.

Public API:
    repro.core        the paper's engine (Cannon / tall-skinny / 2.5D /
                      densification / SUMMA baseline / DBCSRMatrix)
    repro.kernels     Pallas TPU kernels (smm, tiled_matmul, grouped_gemm)
    repro.models      the 10-architecture LM zoo
    repro.train       optimizer / train step / checkpointing / elasticity
    repro.serve       prefill + decode engine
    repro.launch      meshes, multi-pod dry-run, roofline analysis
    repro.configs     architecture configs (get_config / ARCHS / SHAPES)
"""

__version__ = "1.0.0"
