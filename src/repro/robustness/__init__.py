"""Robustness subsystem: algorithm-based fault tolerance (ABFT) and
chaos engineering for the multiply stack.

Long-running electronic-structure campaigns multiply their outputs
back into themselves for dozens of iterations (the McWeeny
purification workload), so a single silently corrupted block poisons
everything downstream.  At service scale (serve/multiply_service.py)
soft errors, kernel miscompiles and poison requests are operating
conditions.  This package is the defense layer:

  * ``abft``   — Huang–Abraham-style block checksums: verify a product
                 per block from independently computed checksum rows /
                 columns, localize corrupted blocks, and repair them by
                 a one-shot recompute-and-splice.  Exposed as
                 ``verify=`` on ``distributed_matmul`` /
                 ``dbcsr.multiply``.
  * ``guards`` — cheap jitted NaN/Inf tripwires and structural input
                 validation raising a typed ``DbcsrValidationError``
                 taxonomy (instead of shape explosions deep in jit).
  * ``chaos``  — deterministic seeded fault injection (bit-flips, NaN,
                 scale/zero corruption, transient dispatch failures)
                 driving the chaos test battery and the CI chaos gate
                 (``python -m repro.robustness.chaos --report``).

The serving layer (serve/multiply_service.py) builds its retry /
degradation ladder on top of these pieces.
"""
from . import abft, chaos, guards  # noqa: F401

__all__ = ["abft", "chaos", "guards"]
