"""Huang–Abraham-style block checksums for block-sparse products (ABFT).

The classical ABFT construction augments A with a column-sum checksum
row and B with a row-sum checksum column; the product of the augmented
matrices then carries checksum rows/columns that verify C.  We keep the
block-granular version of exactly that invariant, computed on an
*independent* arithmetic path from the multiply itself (plain jnp
contractions — never the Pallas smm stack executor), so a kernel
miscompile or an in-flight soft error shows up as a checksum residual:

  column checksums (localize the block COLUMN):
      S_A = sum_i A[i-th block row]          (block_m, k)
      sum_i C[i-th block row]  ==  S_A @ B   (block_m, n)

  row checksums (localize the block ROW):
      T_B = sum_j B[j-th block col]          (k, block_n)
      sum_j C[j-th block col]  ==  A @ T_B   (m, block_n)

A corrupted block (i, j) perturbs block row i of the row residual and
block column j of the column residual; the intersection localizes it
exactly (for multi-block corruption the cross product is a superset,
which is safe for repair — splicing a clean block over a clean block is
the identity).

**Norm-aware tolerance.**  Checksums compare two float accumulations
with different orders, and the eps-filtered blocked path deliberately
drops sub-eps triples from C that the checksum reference still
contains.  The detection threshold therefore scales with what the PR 5
norm cache knows:

    tol = atol + rtol * sum ||A_ik||_F * ||B_kj||_F          (roundoff)
               + sum_{dropped triples} ||A_ik||_F * ||B_kj||_F  (eps)

summed over the block row/column being tested.  The dropped-mass term
is exact for the union-of-max SPMD filter (every triple the executor
actually dropped is norm-predicted dropped, so the discrepancy it can
introduce is bounded by the predicted mass).  This is why clean dense,
sparse, eps-filtered, and purification-style iterated multiplies never
false-positive, while NaN / exponent bit-flips / scale corruption land
orders of magnitude above the threshold.  NaN residuals are flagged via
``~(res <= tol)`` so NaN never slips through a comparison.

**Repair.**  The multiply pipeline is deterministic at a fixed config,
so a transient fault is repaired by re-running the same closure once
and splicing only the flagged blocks — the result is bitwise equal to a
clean run (unflagged blocks keep their original bits, flagged blocks
get the recomputed ones).  If the recheck still fails, the fault is
persistent (poison input, deterministic miscompile) and
:class:`~repro.robustness.guards.CorruptionDetectedError` is raised.

Scope: checksums verify that C is consistent with the *given* A and B.
Corruption of the inputs themselves before the multiply produces a
correct product of corrupted inputs and is invisible here — that is the
domain of ``guards`` (finite tripwires, structural validation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

from repro import obs

import jax
import jax.numpy as jnp
import numpy as np

from repro.robustness import guards
from repro.sparsity.norms import block_norms_of, normalize_block_norms

__all__ = [
    "DEFAULT_RTOL",
    "VerificationReport",
    "checksum_residuals",
    "verification_tolerances",
    "verify_product",
    "splice_blocks",
    "verify_and_repair",
]

# Margin over float32 accumulation roundoff relative to the (loose)
# norm-product bound.  The bound overestimates typical residual
# magnitudes by orders of magnitude, so 1e-5 x bound sits far above
# honest roundoff while staying far below any exponent-level corruption
# (measured margins in tests/test_robustness.py are >10x on both sides).
DEFAULT_RTOL = 1e-5

# Exact dropped-mass accounting builds an (nbr, nbk, nbc) tensor; above
# this entry count fall back to the conservative per-block bound
# nbk * eps (every dropped triple is < eps by definition).
_EXACT_DROP_LIMIT = 64_000_000


@dataclasses.dataclass(frozen=True)
class VerificationReport:
    """Outcome of one ABFT verification (and optional repair) pass."""

    detected: bool
    flagged_rows: Tuple[int, ...]
    flagged_cols: Tuple[int, ...]
    flagged_blocks: Tuple[Tuple[int, int], ...]
    row_residual: np.ndarray
    col_residual: np.ndarray
    row_tol: np.ndarray
    col_tol: np.ndarray
    repair_attempted: bool = False
    repaired: bool = False
    n_recomputed_blocks: int = 0


@functools.lru_cache(maxsize=None)
def _residual_reduction(block_m: int, block_n: int):
    """Jitted checksum residual for one block geometry: returns the
    per-block-row and per-block-column max-abs discrepancy between C's
    checksums and the independently contracted references."""

    @jax.jit
    def residuals(a, b, c):
        m, k = a.shape
        n = b.shape[1]
        nbr, nbc = m // block_m, n // block_n
        # column checksums: sum of C's block rows vs S_A @ B
        s_a = a.reshape(nbr, block_m, k).sum(axis=0)
        col_ref = s_a @ b
        col_sum = c.reshape(nbr, block_m, n).sum(axis=0)
        d_col = jnp.abs(col_sum - col_ref).reshape(block_m, nbc, block_n)
        col_res = d_col.max(axis=(0, 2))
        # row checksums: sum of C's block columns vs A @ T_B
        t_b = b.reshape(k, nbc, block_n).sum(axis=1)
        row_ref = a @ t_b
        row_sum = c.reshape(m, nbc, block_n).sum(axis=1)
        d_row = jnp.abs(row_sum - row_ref).reshape(nbr, block_m, block_n)
        row_res = d_row.max(axis=(1, 2))
        return row_res, col_res

    return residuals


def checksum_residuals(a, b, c, block_m: int,
                       block_n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host numpy ``(row_residual (nbr,), col_residual (nbc,))`` of the
    block checksum discrepancies of ``c`` against ``a @ b``."""
    row, col = _residual_reduction(block_m, block_n)(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    return (np.asarray(jax.device_get(row), dtype=np.float64),
            np.asarray(jax.device_get(col), dtype=np.float64))


def _dropped_mass(an: np.ndarray, bn: np.ndarray,
                  filter_eps: Optional[float]) -> np.ndarray:
    """(nbr, nbc) norm mass of triples the eps filter may drop from C
    but which the checksum reference still contains."""
    nbr, nbk = an.shape
    nbc = bn.shape[1]
    if filter_eps is None or filter_eps <= 0.0:
        return np.zeros((nbr, nbc), dtype=np.float64)
    if nbr * nbk * nbc <= _EXACT_DROP_LIMIT:
        prod = (an.astype(np.float64)[:, :, None]
                * bn.astype(np.float64)[None, :, :])
        return np.where(prod < filter_eps, prod, 0.0).sum(axis=1)
    return np.full((nbr, nbc), float(nbk) * float(filter_eps))


def verification_tolerances(
    a_norms: np.ndarray,
    b_norms: np.ndarray,
    *,
    rtol: float = DEFAULT_RTOL,
    atol: float = 0.0,
    filter_eps: Optional[float] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-block-row / per-block-column detection thresholds from the
    norm cache: roundoff scaled by the norm-product bound plus the
    eps-filtered dropped mass."""
    bound = a_norms.astype(np.float64) @ b_norms.astype(np.float64)
    dropped = _dropped_mass(a_norms, b_norms, filter_eps)
    row_tol = atol + rtol * bound.sum(axis=1) + dropped.sum(axis=1)
    col_tol = atol + rtol * bound.sum(axis=0) + dropped.sum(axis=0)
    return row_tol, col_tol


def verify_product(
    a,
    b,
    c,
    *,
    block_m: int,
    block_k: int,
    block_n: int,
    a_mask: Optional[np.ndarray] = None,
    b_mask: Optional[np.ndarray] = None,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
    filter_eps: Optional[float] = None,
    rtol: float = DEFAULT_RTOL,
    atol: float = 0.0,
) -> VerificationReport:
    """Verify ``c == a @ b`` blockwise via checksum residuals.

    Norms are taken from the PR 5 cache when supplied and recomputed
    from the payloads (mask-applied) otherwise.  Returns a
    :class:`VerificationReport`; ``flagged_blocks`` is the cross product
    of flagged rows and columns (exact for single-block corruption).
    """
    m, k = a.shape
    n = b.shape[1]
    nbr, nbk, nbc = m // block_m, k // block_k, n // block_n
    if a_norms is None:
        a_norms = block_norms_of(a, block_m, block_k, a_mask)
    if b_norms is None:
        b_norms = block_norms_of(b, block_k, block_n, b_mask)
    a_norms, b_norms = normalize_block_norms(
        nbr, nbk, nbc, a_norms, b_norms)
    row_res, col_res = checksum_residuals(a, b, c, block_m, block_n)
    row_tol, col_tol = verification_tolerances(
        a_norms, b_norms, rtol=rtol, atol=atol, filter_eps=filter_eps)
    # ~(res <= tol) instead of (res > tol): NaN residuals must flag.
    row_bad = ~(row_res <= row_tol)
    col_bad = ~(col_res <= col_tol)
    rows = tuple(int(i) for i in np.nonzero(row_bad)[0])
    cols = tuple(int(j) for j in np.nonzero(col_bad)[0])
    if rows and cols:
        blocks = tuple((i, j) for i in rows for j in cols)
    elif rows:  # conservative: residual cancelled in one direction
        blocks = tuple((i, j) for i in rows for j in range(nbc))
    elif cols:
        blocks = tuple((i, j) for i in range(nbr) for j in cols)
    else:
        blocks = ()
    if obs.enabled():
        # gated telemetry counters: the disabled path publishes nothing
        obs.counter("abft.verifications").inc()
        if blocks:
            obs.counter("abft.detections").inc()
    return VerificationReport(
        detected=bool(blocks),
        flagged_rows=rows,
        flagged_cols=cols,
        flagged_blocks=blocks,
        row_residual=row_res,
        col_residual=col_res,
        row_tol=row_tol,
        col_tol=col_tol,
    )


def splice_blocks(c, c_fresh, blocks, block_m: int, block_n: int):
    """Replace only the flagged blocks of ``c`` with ``c_fresh``'s.

    Unflagged blocks keep their original bits — together with a
    deterministic recompute this makes repair bitwise-exact."""
    if not blocks:
        return c
    m, n = c.shape
    nbr, nbc = m // block_m, n // block_n
    sel = np.zeros((nbr, nbc), dtype=bool)
    for i, j in blocks:
        sel[i, j] = True
    full = np.repeat(np.repeat(sel, block_m, axis=0), block_n, axis=1)
    return jnp.where(jnp.asarray(full), jnp.asarray(c_fresh),
                     jnp.asarray(c))


def verify_and_repair(
    a,
    b,
    c,
    *,
    recompute: Callable[[], "jax.Array"],
    block_m: int,
    block_k: int,
    block_n: int,
    a_mask: Optional[np.ndarray] = None,
    b_mask: Optional[np.ndarray] = None,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
    filter_eps: Optional[float] = None,
    rtol: float = DEFAULT_RTOL,
    atol: float = 0.0,
):
    """Verify ``c``; on detection recompute once, splice the flagged
    blocks, and recheck.  Returns ``(c, VerificationReport)``.

    Raises :class:`~repro.robustness.guards.CorruptionDetectedError`
    when the spliced result still fails — the one-shot repair budget is
    exhausted and the fault is persistent.
    """
    kw = dict(block_m=block_m, block_k=block_k, block_n=block_n,
              a_mask=a_mask, b_mask=b_mask,
              a_norms=a_norms, b_norms=b_norms,
              filter_eps=filter_eps, rtol=rtol, atol=atol)
    report = verify_product(a, b, c, **kw)
    if not report.detected:
        return c, report
    fresh = recompute()
    c = splice_blocks(c, fresh, report.flagged_blocks, block_m, block_n)
    recheck = verify_product(a, b, c, **kw)
    report = dataclasses.replace(
        report,
        repair_attempted=True,
        repaired=not recheck.detected,
        n_recomputed_blocks=len(report.flagged_blocks),
    )
    if obs.enabled():
        obs.counter("abft.repairs" if report.repaired
                    else "abft.repair_failures").inc()
    if recheck.detected:
        raise guards.CorruptionDetectedError(
            f"corruption persisted after one-shot repair: blocks "
            f"{recheck.flagged_blocks} still exceed tolerance",
            report=dataclasses.replace(
                recheck, repair_attempted=True, repaired=False,
                n_recomputed_blocks=len(report.flagged_blocks)))
    return c, report
