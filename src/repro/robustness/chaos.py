"""Deterministic seeded fault injection for the multiply stack.

Chaos engineering in the style of ``train/elastic.py``'s
``FailureInjector``, aimed at the multiply engine instead of the train
loop.  Everything is seeded and reproducible; nothing here imports jax
at module scope, so the ``--report`` CLI can pin ``XLA_FLAGS`` before
the backend initializes and ``core/multiply.py`` can import the hook
machinery lazily at zero cost.

Three fault families:

* **Block payload corruption** (``corrupt_block`` / ``FaultInjector``):
  flip a high exponent bit, write a NaN, rescale, or zero one block of
  a payload.  Applied to a *result* it models a soft error anywhere
  inside the multiply pipeline (kernel output, a corrupted shift step's
  payload) as observed at C — exactly what ABFT checksums must catch.
  Applied to an *operand* it models poison input — invisible to
  checksums by construction (the product is then a correct product of
  corrupted inputs) and the job of ``guards``' tripwires instead.

* **Result-corruption hook** (``result_corruption`` context manager):
  installs a process-global callable that ``distributed_matmul``
  applies to the raw product *before* verification (and only when
  ``verify=`` is active — ``verify=None`` never looks at the hook).
  ``FaultInjector.one_shot_result_hook`` corrupts on the first call and
  is the identity afterwards, so the repair recompute sees a clean
  pipeline — the transient-soft-error model.

* **Dispatch faults** (``DispatchFaultInjector``): raises
  ``TransientDispatchError`` from inside ``MultiplyService._dispatch``
  to drive the retry/backoff and degradation-ladder paths under test.

CLI (the CI chaos gate)::

    PYTHONPATH=src python -m repro.robustness.chaos --report

runs the injection matrix {cannon, summa} x {dense, 5% fill} x
{bitflip, nan, scale} on 1x1 and 2x2 meshes plus clean / eps-filtered
false-positive checks, prints a scorecard, and writes
``artifacts/bench/chaos_smoke.json``; exits nonzero unless every
injection is detected, localized to the exact block, repaired, and
bitwise equal to the clean result, with zero false positives.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, List, Optional

import numpy as np

__all__ = [
    "FAULT_MODES",
    "corrupt_block",
    "FaultInjector",
    "result_corruption",
    "apply_result_hook",
    "TransientDispatchError",
    "DispatchFaultInjector",
    "run_injection_matrix",
]

FAULT_MODES = ("bitflip", "nan", "scale", "zero")


def _flip_exponent_bit(x: np.ndarray) -> np.ndarray:
    """XOR the high exponent bit of every element (float32 bit 30,
    float64 bit 62) — the classic soft-error model: a one-bit upset
    that changes the value by many orders of magnitude."""
    if x.dtype == np.float32:
        return (x.view(np.int32) ^ np.int32(1 << 30)).view(np.float32)
    if x.dtype == np.float64:
        return (x.view(np.int64) ^ np.int64(1 << 62)).view(np.float64)
    raise ValueError(f"unsupported dtype for bitflip: {x.dtype}")


def corrupt_block(
    array,
    i: int,
    j: int,
    *,
    block_m: int,
    block_n: int,
    mode: str = "bitflip",
    rng: Optional[np.random.RandomState] = None,
) -> np.ndarray:
    """Return a host copy of ``array`` with block (i, j) corrupted.

    Modes: ``bitflip`` flips the high exponent bit of one element
    (rng-chosen), ``nan`` writes NaN into one element, ``scale``
    multiplies the block by 1000, ``zero`` zeroes it.
    """
    if mode not in FAULT_MODES:
        raise ValueError(f"unknown fault mode {mode!r}; one of {FAULT_MODES}")
    rng = rng or np.random.RandomState(0)
    out = np.array(array, copy=True)
    r0, c0 = i * block_m, j * block_n
    blk = out[r0:r0 + block_m, c0:c0 + block_n]
    if mode == "bitflip":
        r = int(rng.randint(block_m))
        c = int(rng.randint(block_n))
        blk[r, c] = _flip_exponent_bit(blk[r:r + 1, c:c + 1])[0, 0]
    elif mode == "nan":
        r = int(rng.randint(block_m))
        c = int(rng.randint(block_n))
        blk[r, c] = np.nan
    elif mode == "scale":
        blk *= np.asarray(1000.0, dtype=blk.dtype)
    else:  # zero
        blk[...] = 0
    out[r0:r0 + block_m, c0:c0 + block_n] = blk
    return out


class FaultInjector:
    """Deterministic seeded block-fault injector with an audit log."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self.rng = np.random.RandomState(seed)
        self.log: List[dict] = []

    def corrupt_block(self, array, i: int, j: int, *, block_m: int,
                      block_n: int, mode: str = "bitflip") -> np.ndarray:
        out = corrupt_block(array, i, j, block_m=block_m, block_n=block_n,
                            mode=mode, rng=self.rng)
        self.log.append({"target": "payload", "block": (i, j),
                         "mode": mode})
        return out

    def one_shot_result_hook(self, i: int, j: int, *, block_m: int,
                             block_n: int,
                             mode: str = "bitflip") -> Callable:
        """A hook for ``result_corruption`` that corrupts block (i, j)
        on its first invocation only — later calls (the repair
        recompute) pass through untouched."""
        injector = self

        class _OneShot:
            fired = False

            def __call__(self, c):
                if self.fired:
                    return c
                self.fired = True
                injector.log.append({"target": "result", "block": (i, j),
                                     "mode": mode})
                return corrupt_block(c, i, j, block_m=block_m,
                                     block_n=block_n, mode=mode,
                                     rng=injector.rng)

        return _OneShot()


# --- result-corruption hook -------------------------------------------
# Installed by tests / the chaos CLI; consulted by distributed_matmul
# only when verify= is active. verify=None never reads it, preserving
# the zero-overhead / bit-identity contract for unverified multiplies.
_RESULT_HOOK: Optional[Callable] = None


@contextlib.contextmanager
def result_corruption(hook: Callable):
    """Install ``hook(c) -> c'`` as the process-global result
    corruption for the duration of the context."""
    global _RESULT_HOOK
    prev = _RESULT_HOOK
    _RESULT_HOOK = hook
    try:
        yield hook
    finally:
        _RESULT_HOOK = prev


def apply_result_hook(c):
    """Apply the installed corruption hook to a raw product (identity
    when no hook is installed)."""
    hook = _RESULT_HOOK
    return c if hook is None else hook(c)


# --- dispatch faults ---------------------------------------------------
class TransientDispatchError(RuntimeError):
    """Injected dispatch failure (models OOM blips, preempted donated
    buffers, transient backend errors)."""


class DispatchFaultInjector:
    """Raises ``TransientDispatchError`` from service dispatch attempts.

    ``fail_first`` makes the first N checks fail (transient — retries
    then succeed); ``fail_stages`` makes every check at those ladder
    stages fail (persistent — forces degradation past the stage).
    """

    def __init__(self, fail_first: int = 0, fail_stages=()):
        self.fail_first = int(fail_first)
        self.fail_stages = frozenset(fail_stages)
        self.n_checks = 0
        self.n_raised = 0

    def check(self, stage: Optional[str] = None, **meta) -> None:
        self.n_checks += 1
        if stage in self.fail_stages:
            self.n_raised += 1
            raise TransientDispatchError(
                f"injected persistent failure at stage {stage!r}")
        if self.n_raised < self.fail_first:
            self.n_raised += 1
            raise TransientDispatchError(
                f"injected transient failure #{self.n_raised}")


# --- injection matrix (shared by tests and the CLI) --------------------
@dataclasses.dataclass
class _Case:
    mesh_name: str
    algorithm: str
    fill: float
    mode: str  # a FAULT_MODES entry, or "clean" / "clean_eps"


def _make_operand(rng, m, n, block, fill, mesh):
    """Build a DBCSRMatrix with the requested block fill (1.0 = dense)."""
    from repro.core import dbcsr

    nbr, nbc = m // block, n // block
    mask = None
    if fill < 1.0:
        mask = rng.rand(nbr, nbc) < fill
        mask[0, 0] = True  # never fully empty
    data = rng.randn(m, n).astype(np.float32)
    return dbcsr.create(data, mesh=mesh, block_size=block, block_mask=mask)


def run_injection_matrix(
    mesh,
    mesh_name: str,
    *,
    algorithms=("cannon", "summa"),
    fills=(1.0, 0.05),
    modes=("bitflip", "nan", "scale"),
    geometry=(128, 128, 128),
    block: int = 32,
    seed: int = 0,
    filter_eps_clean: float = 1e-2,
) -> List[dict]:
    """Run the chaos matrix on one mesh; returns one row per cell.

    Each injection cell: compute the clean product, corrupt the
    max-norm result block through the one-shot hook, re-run with
    ``verify="checksum"``, and record detection / exact localization /
    repair / bitwise equality with the clean result.  Clean cells
    (``mode == "clean"`` / ``"clean_eps"``) record false positives.
    """
    from repro.core import dbcsr
    from repro.sparsity.norms import compute_block_norms

    m, k, n = geometry
    exec_kw = dict(mesh=mesh, densify=False, local_kernel="ref",
                   pipeline_depth=1)
    rows: List[dict] = []
    rng = np.random.RandomState(seed)
    for algorithm in algorithms:
        for fill in fills:
            a = _make_operand(rng, m, k, block, fill, mesh)
            b = _make_operand(rng, k, n, block, fill, mesh)
            c_clean = dbcsr.multiply(a, b, algorithm=algorithm, **exec_kw)
            c_norms = compute_block_norms(c_clean.data, block, block)
            i0, j0 = np.unravel_index(int(np.argmax(c_norms)),
                                      c_norms.shape)
            i0, j0 = int(i0), int(j0)
            for mode in modes:
                injector = FaultInjector(seed=seed)
                hook = injector.one_shot_result_hook(
                    i0, j0, block_m=block, block_n=block, mode=mode)
                with result_corruption(hook):
                    c_v, plan = dbcsr.multiply(
                        a, b, algorithm=algorithm, verify="checksum",
                        return_plan=True, **exec_kw)
                rep = plan.verification["report"]
                rows.append({
                    "mesh": mesh_name, "algorithm": algorithm,
                    "fill": fill, "mode": mode,
                    "injected_block": [i0, j0],
                    "detected": bool(rep.detected),
                    "localized_exact":
                        rep.flagged_blocks == ((i0, j0),),
                    "repaired": bool(rep.repaired),
                    "bitwise_clean": bool(np.array_equal(
                        np.asarray(c_v.data),
                        np.asarray(c_clean.data))),
                    "ok": bool(rep.detected
                               and rep.flagged_blocks == ((i0, j0),)
                               and rep.repaired
                               and np.array_equal(
                                   np.asarray(c_v.data),
                                   np.asarray(c_clean.data))),
                })
            # false-positive checks: clean run, and eps-filtered clean run
            for clean_mode, eps in (("clean", None),
                                    ("clean_eps", filter_eps_clean)):
                c_v, plan = dbcsr.multiply(
                    a, b, algorithm=algorithm, verify="checksum",
                    filter_eps=eps, return_plan=True, **exec_kw)
                rep = plan.verification["report"]
                rows.append({
                    "mesh": mesh_name, "algorithm": algorithm,
                    "fill": fill, "mode": clean_mode,
                    "injected_block": None,
                    "detected": bool(rep.detected),
                    "localized_exact": True,
                    "repaired": False,
                    "bitwise_clean": True,
                    "ok": not rep.detected,
                })
    return rows


def _main(argv=None) -> int:
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser(
        description="chaos gate: injection matrix scorecard")
    ap.add_argument("--report", action="store_true",
                    help="run the injection matrix and write the scorecard")
    ap.add_argument("--out", default="artifacts/bench/chaos_smoke.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=4)
    args = ap.parse_args(argv)
    if not args.report:
        ap.error("nothing to do: pass --report")

    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")
    import jax

    from repro.compat import make_mesh
    # under ``python -m`` this file executes as __main__, so OUR
    # result-corruption hook global would live in a different module
    # instance than the repro.robustness.chaos that core/multiply.py
    # consults — dispatch through the canonical import instead
    from repro.robustness import chaos as _canonical

    meshes = [("1x1", make_mesh((1, 1), ("data", "model")))]
    if len(jax.devices()) >= 4:
        meshes.append(("2x2", make_mesh((2, 2), ("data", "model"))))

    rows: List[dict] = []
    for mesh_name, mesh in meshes:
        rows.extend(_canonical.run_injection_matrix(
            mesh, mesh_name, seed=args.seed))

    injected = [r for r in rows if r["injected_block"] is not None]
    clean = [r for r in rows if r["injected_block"] is None]
    scorecard = {
        "n_cases": len(rows),
        "n_injected": len(injected),
        "n_detected": sum(r["detected"] for r in injected),
        "n_localized_exact": sum(r["localized_exact"] for r in injected),
        "n_repaired": sum(r["repaired"] for r in injected),
        "n_bitwise_clean": sum(r["bitwise_clean"] for r in injected),
        "n_clean_runs": len(clean),
        "n_false_positives": sum(r["detected"] for r in clean),
        "all_ok": all(r["ok"] for r in rows),
        "rows": rows,
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(scorecard, f, indent=2)

    print(f"{'mesh':>4} {'algo':>7} {'fill':>5} {'mode':>9} "
          f"{'det':>4} {'loc':>4} {'rep':>4} {'bit':>4} ok")
    for r in rows:
        print(f"{r['mesh']:>4} {r['algorithm']:>7} {r['fill']:>5} "
              f"{r['mode']:>9} {str(r['detected']):>4} "
              f"{str(r['localized_exact']):>4} {str(r['repaired']):>4} "
              f"{str(r['bitwise_clean']):>4} "
              f"{'PASS' if r['ok'] else 'FAIL'}")
    print(f"\nchaos scorecard: {scorecard['n_detected']}/"
          f"{scorecard['n_injected']} detected, "
          f"{scorecard['n_localized_exact']} localized, "
          f"{scorecard['n_repaired']} repaired, "
          f"{scorecard['n_bitwise_clean']} bitwise-clean; "
          f"{scorecard['n_false_positives']} false positives on "
          f"{scorecard['n_clean_runs']} clean runs -> {args.out}")
    return 0 if scorecard["all_ok"] else 1


if __name__ == "__main__":
    raise SystemExit(_main())
