"""Cheap tripwires and structural validation for the multiply stack.

Two kinds of defense live here:

* **Structural validation** (``validate_matrix`` /
  ``validate_multiply_request``): host-side checks of block geometry,
  grid compatibility, and mask/norm-cache consistency that raise a
  *typed* ``DbcsrValidationError`` subclass with a readable message —
  instead of a shape-mismatch explosion deep inside jit, minutes after
  the bad request was accepted.  The batched service runs these at
  ``submit()`` time so a malformed request is rejected synchronously.

* **Finite tripwires** (``all_finite`` / ``assert_finite``): a single
  jitted ``isfinite(x).all()`` reduction (one pass over the payload,
  retraced per shape/dtype by jax's own cache) used to screen operands
  before a verified multiply and results before ticket delivery.  A
  NaN that enters a purification loop is amplified forever; one
  reduction per multiply is cheap insurance, and the planner prices it
  as part of the verification overhead.

Exception taxonomy::

    DbcsrValidationError(ValueError)
      +-- ShapeMismatchError      payload/layout/inner-dim/block geometry
      +-- GridMismatchError       operands live on incompatible grids
      +-- MaskConsistencyError    block_mask shape/dtype vs layout
      +-- NormConsistencyError    block_norms shape/negativity/NaN
      +-- NonFiniteOperandError   NaN/Inf in an input payload
      +-- NonFiniteResultError    NaN/Inf in a computed result
    CorruptionDetectedError(RuntimeError)   ABFT detected corruption that
                                            repair could not clear
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DbcsrValidationError",
    "ShapeMismatchError",
    "GridMismatchError",
    "MaskConsistencyError",
    "NormConsistencyError",
    "NonFiniteOperandError",
    "NonFiniteResultError",
    "CorruptionDetectedError",
    "all_finite",
    "assert_finite",
    "validate_matrix",
    "validate_multiply_request",
]


class DbcsrValidationError(ValueError):
    """Base class for typed validation failures in the multiply stack."""


class ShapeMismatchError(DbcsrValidationError):
    """Payload/layout/inner-dimension/block-geometry inconsistency."""


class GridMismatchError(DbcsrValidationError):
    """Operands are distributed over incompatible process grids."""


class MaskConsistencyError(DbcsrValidationError):
    """block_mask does not describe the payload's block grid."""


class NormConsistencyError(DbcsrValidationError):
    """block_norms cache is inconsistent (shape, sign, or NaN)."""


class NonFiniteOperandError(DbcsrValidationError):
    """An input payload contains NaN/Inf."""


class NonFiniteResultError(DbcsrValidationError):
    """A computed result contains NaN/Inf."""


class CorruptionDetectedError(RuntimeError):
    """ABFT verification detected corruption that repair did not clear.

    Carries the final :class:`repro.robustness.abft.VerificationReport`
    as ``.report`` — the flagged blocks survived a recompute-and-splice,
    so the fault is persistent (poison input, deterministic miscompile)
    rather than a transient soft error.
    """

    def __init__(self, message: str, report=None):
        super().__init__(message)
        self.report = report


@functools.lru_cache(maxsize=None)
def _finite_reduction():
    # One jitted reduction shared by every tripwire; jax's trace cache
    # handles per-shape/dtype specialization.
    return jax.jit(lambda x: jnp.isfinite(x).all())


def all_finite(x) -> bool:
    """True iff every element of ``x`` is finite (single jitted pass)."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.floating) and not jnp.issubdtype(
            x.dtype, jnp.complexfloating):
        return True
    return bool(_finite_reduction()(x))


def assert_finite(x, name: str = "array", *, kind: str = "operand") -> None:
    """Raise ``NonFinite{Operand,Result}Error`` if ``x`` has NaN/Inf."""
    if all_finite(x):
        return
    exc = NonFiniteOperandError if kind == "operand" else NonFiniteResultError
    raise exc(f"{name} contains NaN/Inf ({kind} tripwire)")


def _layout_shape(mat):
    layout = mat.layout
    return (layout.rows, layout.cols,
            layout.block_rows, layout.block_cols,
            layout.nblock_rows, layout.nblock_cols)


def validate_matrix(mat, name: str = "operand") -> None:
    """Structural validation of one DBCSRMatrix-like operand.

    Checks payload-vs-layout shape, block divisibility, block_mask
    shape/dtype, and block_norms shape/sign/finiteness.  Raises a typed
    :class:`DbcsrValidationError` subclass; never touches device data
    beyond reading ``.shape`` (masks and norms are host metadata).
    """
    rows, cols, bm, bn, nbr, nbc = _layout_shape(mat)
    shape = tuple(mat.data.shape)
    if shape != (rows, cols):
        raise ShapeMismatchError(
            f"{name}: payload shape {shape} != layout ({rows}, {cols})")
    if rows % bm or cols % bn:
        raise ShapeMismatchError(
            f"{name}: shape ({rows}, {cols}) not divisible by blocks "
            f"({bm}, {bn})")
    mask = getattr(mat, "block_mask", None)
    if mask is not None:
        mask = np.asarray(mask)
        if mask.shape != (nbr, nbc):
            raise MaskConsistencyError(
                f"{name}: block_mask shape {mask.shape} != block grid "
                f"({nbr}, {nbc})")
        if mask.dtype != np.bool_:
            raise MaskConsistencyError(
                f"{name}: block_mask dtype {mask.dtype} is not bool")
    norms = getattr(mat, "block_norms", None)
    if norms is not None:
        norms = np.asarray(norms)
        if norms.shape != (nbr, nbc):
            raise NormConsistencyError(
                f"{name}: block_norms shape {norms.shape} != block grid "
                f"({nbr}, {nbc})")
        if not np.isfinite(norms).all():
            raise NormConsistencyError(
                f"{name}: block_norms cache contains NaN/Inf")
        if (norms < 0).any():
            raise NormConsistencyError(
                f"{name}: block_norms cache contains negative entries")
        if mask is not None and norms[~mask].any():
            raise NormConsistencyError(
                f"{name}: block_norms nonzero outside block_mask support")


def validate_multiply_request(a, b) -> None:
    """Validate a multiply pair (A, B) structurally, pre-dispatch.

    Raises a typed :class:`DbcsrValidationError` subclass on payload /
    layout mismatch, incompatible inner dimension or block-k geometry,
    or operands living on different process grids.
    """
    validate_matrix(a, "A")
    validate_matrix(b, "B")
    if a.layout.cols != b.layout.rows:
        raise ShapeMismatchError(
            f"inner dimension mismatch: A is {a.layout.rows}x{a.layout.cols},"
            f" B is {b.layout.rows}x{b.layout.cols}")
    if a.layout.block_cols != b.layout.block_rows:
        raise ShapeMismatchError(
            f"block-k mismatch: A block_cols={a.layout.block_cols}, "
            f"B block_rows={b.layout.block_rows}")
    ga, gb = a.grid, b.grid
    if (ga.row_axis, ga.col_axis, ga.stack_axis) != (
            gb.row_axis, gb.col_axis, gb.stack_axis):
        raise GridMismatchError(
            f"A on grid axes ({ga.row_axis}, {ga.col_axis}, "
            f"stack={ga.stack_axis}); B on grid axes ({gb.row_axis}, "
            f"{gb.col_axis}, stack={gb.stack_axis})")
