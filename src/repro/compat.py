"""Version-tolerant JAX API shims.

The codebase is written against the modern JAX surface (``jax.shard_map``
with ``check_vma``, ``jax.sharding.AxisType``, ``jax.set_mesh``,
``jax.lax.pvary``).  Deployment environments pin older releases (this
container ships 0.4.37, where none of those exist yet), so every use of
a moved/renamed API goes through this module instead of ``jax`` directly:

  * ``shard_map``  — ``jax.shard_map(check_vma=...)`` on new JAX,
    ``jax.experimental.shard_map.shard_map(check_rep=...)`` on old.
  * ``pvary``      — varying-axes annotation; a data no-op, so the old-JAX
    fallback is the identity (old shard_map with ``check_rep=False`` does
    not track varying axes at all).
  * ``make_mesh``  — drops the ``axis_types`` kwarg when unsupported.
  * ``set_mesh``   — falls back to ``jax.sharding.use_mesh`` or the plain
    ``Mesh`` context manager.
  * ``AxisType``   — stand-in enum when ``jax.sharding.AxisType`` is absent.

Keep this module dependency-free (jax only) so anything may import it.
"""
from __future__ import annotations

import jax

__all__ = ["AxisType", "HAS_AXIS_TYPES", "make_mesh", "shard_map",
           "pvary", "set_mesh", "axis_size"]


try:  # JAX >= 0.5: axis types are real (Auto/Explicit/Manual sharding)
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_AXIS_TYPES = True
except ImportError:  # old JAX: every mesh axis behaves like Auto
    HAS_AXIS_TYPES = False

    class AxisType:  # minimal stand-in so call sites can still name them
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes, axis_names, axis_types=None):
    """``jax.make_mesh`` that tolerates missing ``axis_types`` support."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if HAS_AXIS_TYPES:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    return jax.make_mesh(axis_shapes, axis_names)


if hasattr(jax, "shard_map"):  # JAX >= 0.6 public API

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

else:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:

    def axis_size(axis_name):
        # classic idiom: psum of a Python scalar folds to the axis size
        return jax.lax.psum(1, axis_name)


if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:

    def pvary(x, axis_name):
        return x


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
elif hasattr(jax.sharding, "use_mesh"):
    set_mesh = jax.sharding.use_mesh  # type: ignore[attr-defined]
else:
    def set_mesh(mesh):
        # old JAX: Mesh is itself a context manager (global resource env)
        return mesh
