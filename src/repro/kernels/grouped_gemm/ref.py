"""Oracle for the grouped (per-expert) GEMM kernel."""
import jax
import jax.numpy as jnp


def grouped_gemm_ref(tokens: jax.Array, weights: jax.Array) -> jax.Array:
    """tokens (E, C, d) @ weights (E, d, f) -> (E, C, f), f32 accum."""
    return jnp.einsum(
        "ecd,edf->ecf",
        tokens.astype(jnp.float32),
        weights.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
