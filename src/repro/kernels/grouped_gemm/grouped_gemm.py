"""Pallas grouped GEMM — the densified MoE expert multiply.

MoE expert computation is a block-sparse matrix multiply: the
(token x expert) dispatch pattern selects which (token-block, expert)
pairs exist.  *Densification* in the DBCSR sense is the grouped-GEMM
trick: gather each expert's tokens into a contiguous capacity buffer
(E, C, d) so the expert dimension becomes a batch of dense GEMMs — one
large multiply per expert instead of many small per-token-block ones.

The kernel is a batched VMEM-tiled matmul with the expert index as the
outermost grid dimension; each expert's weight tile streams through
VMEM while the float32 accumulator persists across the contraction
steps (same revisit pattern as tiled_matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["grouped_gemm_pallas"]


def _gg_kernel(t_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        t_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bc", "bf", "bk", "out_dtype", "interpret")
)
def grouped_gemm_pallas(
    tokens: jax.Array,    # (E, C, d)
    weights: jax.Array,   # (E, d, f)
    *,
    bc: int = 128,
    bf: int = 256,
    bk: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = tokens.shape
    e2, d2, f = weights.shape
    assert e == e2 and d == d2
    bc, bf, bk = min(bc, c), min(bf, f), min(bk, d)
    if c % bc or f % bf or d % bk:
        raise ValueError(f"({e},{c},{d},{f}) not divisible by ({bc},{bk},{bf})")
    k_steps = d // bk
    return pl.pallas_call(
        functools.partial(_gg_kernel, k_steps=k_steps),
        grid=(e, c // bc, f // bf, k_steps),
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda ei, i, j, kk: (ei, i, kk)),
            pl.BlockSpec((1, bk, bf), lambda ei, i, j, kk: (ei, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda ei, i, j, kk: (ei, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), out_dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(tokens, weights)
