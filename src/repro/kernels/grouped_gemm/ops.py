"""Public wrappers for the grouped (product-batched) kernels.

Two entry points serve the batched multiply stack (core/engine.py
``execute_batched_plan`` / core/multiply_batched.py):

  * ``grouped_gemm``          — the Pallas batched dense GEMM
    ``(E, C, d) @ (E, d, f)``: the *densified* local path of a fused
    product batch (every group's local multiply is one slab of the
    batched dot).
  * ``grouped_process_stack`` — the *blocked* local path: one fused
    ``lax.scan`` dispatch of a group-offset stack-triple tensor over
    the flattened block arrays of all groups.  This is the smm stack
    executor (kernels/smm) with a leading product/group dimension
    folded into the block indices — N same-block-geometry products run
    in ONE scan instead of N traces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .grouped_gemm import grouped_gemm_pallas

__all__ = ["grouped_gemm", "grouped_process_stack"]


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bk", "interpret"))
def grouped_gemm(
    tokens: jax.Array,   # (E, C, d)
    weights: jax.Array,  # (E, d, f)
    *,
    bc: int = 128,
    bf: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    e, c, d = tokens.shape
    _, _, f = weights.shape
    bc_, bf_, bk_ = min(bc, c), min(bf, f), min(bk, d)
    pad = lambda x, t: (-x) % t
    pc, pk, pf = pad(c, bc_), pad(d, bk_), pad(f, bf_)
    t_p = jnp.pad(tokens, ((0, 0), (0, pc), (0, pk))) if (pc or pk) else tokens
    w_p = jnp.pad(weights, ((0, 0), (0, pk), (0, pf))) if (pk or pf) else weights
    out = grouped_gemm_pallas(t_p, w_p, bc=bc_, bf=bf_, bk=bk_,
                              interpret=interpret)
    return out[:, :c, :f] if (pc or pf) else out


def grouped_process_stack(
    a_blocks: jax.Array,   # (G*Na, bm, bk) flattened group block arrays
    b_blocks: jax.Array,   # (G*Nb, bk, bn)
    c_blocks: jax.Array,   # (G*Nc + 1, bm, bn) — scratch block appended
    triples: jax.Array,    # (S, T, 4) group-offset (a, b, c, valid) rows
    *,
    kernel: str = "smm",
    align: bool = False,
) -> jax.Array:
    """Run a fused (multi-product) stack tensor through the smm stack
    processor in one ``lax.scan``.

    The caller (core/engine.py ``execute_batched_plan``) has already
    folded the group dimension into the block indices: group ``g``'s
    triples are offset by ``(g*Na, g*Nb, g*Nc)`` and every padding row
    points at the single global scratch block ``G*Nc`` with
    ``valid=0``.  The smm kernel therefore needs no group awareness at
    all — this IS the unification of the grouped-GEMM dispatch with the
    stack executor: one trace per (block geometry, stack shape bin),
    amortized across every product in the batch.
    """
    if kernel == "smm":
        from repro.kernels.smm.ops import smm_process_stack

        def process(c, t):
            return smm_process_stack(a_blocks, b_blocks, c, t,
                                     align=align), None
    elif kernel == "ref":
        from repro.kernels.smm.ref import smm_process_stack_ref

        def process(c, t):
            return smm_process_stack_ref(a_blocks, b_blocks, c, t), None
    else:
        raise ValueError(f"unknown stack kernel {kernel!r}")
    c, _ = jax.lax.scan(process, c_blocks, triples)
    return c
