"""Public wrapper for the grouped GEMM kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .grouped_gemm import grouped_gemm_pallas

__all__ = ["grouped_gemm"]


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bk", "interpret"))
def grouped_gemm(
    tokens: jax.Array,   # (E, C, d)
    weights: jax.Array,  # (E, d, f)
    *,
    bc: int = 128,
    bf: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    e, c, d = tokens.shape
    _, _, f = weights.shape
    bc_, bf_, bk_ = min(bc, c), min(bf, f), min(bk, d)
    pad = lambda x, t: (-x) % t
    pc, pk, pf = pad(c, bc_), pad(d, bk_), pad(f, bf_)
    t_p = jnp.pad(tokens, ((0, 0), (0, pc), (0, pk))) if (pc or pk) else tokens
    w_p = jnp.pad(weights, ((0, 0), (0, pk), (0, pf))) if (pk or pf) else weights
    out = grouped_gemm_pallas(t_p, w_p, bc=bc_, bf=bf_, bk=bk_,
                              interpret=interpret)
    return out[:, :c, :f] if (pc or pf) else out
