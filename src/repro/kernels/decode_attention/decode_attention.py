"""Pallas split-KV decode attention (FlashDecoding on TPU).

The single-token decode read of a long KV cache is the serving
roofline's dominant memory stream (see EXPERIMENTS.md §Roofline: every
decode cell is memory-bound on exactly this).  The kernel streams the
cache through VMEM in blocks along the sequence axis with an
online-softmax accumulator held in VMEM scratch — one HBM pass over
K/V at Hkv width (GQA stays grouped: queries enter as (Hkv, R) so the
cache is never expanded to H heads).

Grid: (B, Hkv, S/block_k) — the kv axis is innermost, so the output
block (b, h) is revisited across consecutive steps and the scratch
accumulator stays resident (the same revisit contract as tiled_matmul).
``cur_len`` arrives via scalar prefetch and masks the tail block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

__all__ = ["decode_attention_pallas"]


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_k: int, n_kv: int, scale: float):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (R, Dh)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (block_k, Dh)
    v = v_ref[0, :, 0].astype(jnp.float32)       # (block_k, Dh)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (R, bk)
    kv_pos = kv_i * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1)
    s = jnp.where(kv_pos < len_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_pallas(
    q: jax.Array,        # (B, Hkv, R, Dh)
    k_cache: jax.Array,  # (B, S, Hkv, Dh)
    v_cache: jax.Array,  # (B, S, Hkv, Dh)
    cur_len: jax.Array,  # () int32 — number of valid cache entries
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, hkv, r, dh = q.shape
    s = k_cache.shape[1]
    block_k = min(block_k, s)
    assert s % block_k == 0, (s, block_k)
    n_kv = s // block_k
    scale = dh ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, r, dh), lambda bi, hi, ki, L: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda bi, hi, ki, L: (bi, ki, hi, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda bi, hi, ki, L: (bi, ki, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, r, dh),
                               lambda bi, hi, ki, L: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((r, 1), jnp.float32),    # running max
            pltpu.VMEM((r, 1), jnp.float32),    # running denominator
            pltpu.VMEM((r, dh), jnp.float32),   # accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, n_kv=n_kv, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, r, dh), jnp.float32),
        interpret=interpret,
    )(cur_len.reshape(1), q, k_cache, v_cache)
