"""Oracle for the decode-attention kernel (grouped GQA form)."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, cur_len):
    """q (B, Hkv, R, Dh); caches (B, S, Hkv, Dh); cur_len () int32.

    Returns (B, Hkv, R, Dh) — attention of each grouped query head over
    the first cur_len cache entries.
    """
    s = jnp.einsum("bhrd,bkhd->bhrk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32))
    scale = q.shape[-1] ** -0.5
    s = s * scale
    valid = jnp.arange(k_cache.shape[1]) < cur_len
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrk,bkhd->bhrd", p, v_cache.astype(jnp.float32))
    return out
