"""Public wrapper: (B, 1, H, Dh) query layout <-> grouped kernel layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_pallas

__all__ = ["decode_attention"]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jax.Array,        # (B, 1, H, Dh) — the model-layer layout
    k_cache: jax.Array,  # (B, S, Hkv, Dh)
    v_cache: jax.Array,
    cur_len: jax.Array,  # () int32
    *,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, one, h, dh = q.shape
    hkv = k_cache.shape[2]
    r = h // hkv
    qg = q.reshape(b, hkv, r, dh)
    out = decode_attention_pallas(
        qg, k_cache, v_cache, jnp.asarray(cur_len, jnp.int32),
        block_k=block_k, interpret=interpret)
    return out.reshape(b, one, h, dh).astype(q.dtype)
