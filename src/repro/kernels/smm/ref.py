"""Pure-jnp oracle for the smm (small-matrix-multiply stack) kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["smm_process_stack_ref"]


def smm_process_stack_ref(
    a_blocks: jax.Array,  # (Na, bm, bk)
    b_blocks: jax.Array,  # (Nb, bk, bn)
    c_blocks: jax.Array,  # (Nc, bm, bn) float32 accumulator
    triples: jax.Array,   # (S, 3|4) int32: (a_idx, b_idx, c_idx[, valid])
) -> jax.Array:
    """C[c] += A[a] @ B[b] for every stack entry — gather / batched
    matmul / scatter-add formulation.  An optional 4th triples column is
    a validity mask (the fused executor's stack padding): masked entries
    contribute zero."""
    a = a_blocks[triples[:, 0]]
    b = b_blocks[triples[:, 1]]
    prod = jnp.einsum(
        "smk,skn->smn", a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if triples.shape[1] > 3:
        prod = prod * triples[:, 3].astype(jnp.float32)[:, None, None]
    return c_blocks.at[triples[:, 2]].add(prod)
