"""jit'd public wrapper for the smm kernel.

Handles MXU alignment: DBCSR block sizes (4 / 22 / 64 in the paper) are
mostly hostile to the TPU systolic array, which wants the trailing two
dims in multiples of (8, 128) for f32.  ``smm_process_stack`` pads the
block arrays once per stack batch (amortised over the whole stack) and
strips the padding from C — the TPU equivalent of LIBCUSMM generating a
kernel per (m, n, k) with internal padding registers.

On CPU (this container) the kernel runs in interpret mode; on TPU the
same code lowers natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .smm import smm_pallas_call

__all__ = ["smm_process_stack", "mxu_pad_shape"]

_SUBLANE = 8
_LANE = 128


def mxu_pad_shape(bm: int, bk: int, bn: int, align: bool):
    if not align:
        return bm, bk, bn
    pad = lambda x, m: -(-x // m) * m
    return pad(bm, _SUBLANE), pad(bk, _LANE), pad(bn, _LANE)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("align", "interpret"))
def smm_process_stack(
    a_blocks: jax.Array,
    b_blocks: jax.Array,
    c_blocks: jax.Array,
    triples: jax.Array,
    *,
    align: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """C[c] += A[a] @ B[b] over a stack; returns updated C blocks.

    ``triples`` is (S, 3) or (S, 4) int32 — the optional 4th column is
    the validity mask of the fused executor's padded stacks (see
    smm.py); masked entries accumulate nothing.
    """
    if triples.ndim != 2 or triples.shape[1] not in (3, 4):
        raise ValueError(f"triples must be (S, 3|4), got {triples.shape}")
    if interpret is None:
        interpret = _on_cpu()
    _, bm, bk = a_blocks.shape
    _, _, bn = b_blocks.shape
    pm, pk, pn = mxu_pad_shape(bm, bk, bn, align)
    if (pm, pk, pn) != (bm, bk, bn):
        a_blocks = jnp.pad(a_blocks, ((0, 0), (0, pm - bm), (0, pk - bk)))
        b_blocks = jnp.pad(b_blocks, ((0, 0), (0, pk - bk), (0, pn - bn)))
        c_blocks_p = jnp.pad(c_blocks, ((0, 0), (0, pm - bm), (0, pn - bn)))
    else:
        c_blocks_p = c_blocks
    out = smm_pallas_call(a_blocks, b_blocks, c_blocks_p, triples,
                          interpret=interpret)
    if (pm, pn) != (bm, bn):
        out = out[:, :bm, :bn]
    return out
