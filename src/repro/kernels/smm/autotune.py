"""smm kernel parameter sweep — the LIBCUSMM autotuner's TPU analogue.

LIBCUSMM explores ~30k-150k CUDA parameter combinations per (m, n, k)
with an ML performance model (paper section II).  The TPU parameter
space is BlockSpec-level and small enough to sweep directly:

  * MXU alignment on/off (pad blocks to (8, 128) multiples),
  * stack tile (how many stack entries per kernel launch chunk),

measured per (m, n, k) block size and *occupancy bin* and cached to a
JSON winners table.  Occupancy binning matters because the best
stack_tile for a sparse workload is not the dense winner: at 10% fill
the ragged k-runs pack into far fewer entries per C-run, so a 30'000
tile is almost all padding while a small tile wins — the sweep records
a winner per FILL_BINS bin (dense entries keep their legacy un-suffixed
key; sparse entries are keyed ``"<block>@<bin>"``).
On this CPU container the sweep times interpret-mode execution (a
correctness vehicle, so the *absolute* numbers are not TPU truth —
the harness and cache format are what transfer; on real hardware the
same sweep runs the compiled kernel).

    PYTHONPATH=src python -m repro.kernels.smm.autotune --blocks 22 64 \
        --fills 1.0 0.5 0.2 0.05
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.densify import to_blocks
from repro.core.engine import build_executor_plan, execute_plan
from .ops import mxu_pad_shape

DEFAULT_CACHE = os.path.join("artifacts", "smm_autotune.json")

# the sweep space: (align, stack_tile)
SPACE: List[Tuple[bool, int]] = [
    (False, 1024), (False, 4096), (False, 30000),
    (True, 1024), (True, 4096), (True, 30000),
]

# occupancy bins of the winners table (present-triple fraction of the
# dense grid); the sweep in benchmarks/bench_sparse.py uses the same
# grid.  Lookups snap to the nearest bin in log space.
FILL_BINS: Tuple[float, ...] = (1.0, 0.5, 0.2, 0.05)


def fill_bin(fill: float) -> float:
    """Snap an effective occupancy to the nearest winners-table bin
    (log-space nearest: 0.08 is closer to 0.05 than to 0.2)."""
    f = min(max(float(fill), 1e-9), 1.0)
    return min(FILL_BINS, key=lambda b: abs(math.log(f / b)))


def _cache_key(block: int, bin_: float) -> str:
    # dense keeps the legacy key so existing winners tables stay valid
    return str(block) if bin_ >= 1.0 else f"{block}@{bin_:g}"


def _bench(fn, *args, reps: int = 3) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def tune_block(block: int, *, n_blocks: int = 8,
               use_kernel: bool = False, fill: float = 1.0) -> Dict:
    """Sweep SPACE for a (block x block x block) stack workload at the
    given *effective triple occupancy* ``fill``.

    The bin must mean the same thing the dispatch-side lookup computes
    (engine._mask_fill: present-triple fraction of the dense grid), so
    the sweep uses a one-sided A mask with exactly
    ``round(fill * n_cells)`` present blocks — the plan's triple
    occupancy then equals ``fill`` (two independent rate-``fill`` masks
    would give ~fill^2 and record winners an order of magnitude sparser
    than the workloads their bin serves).
    """
    m = k = n = block * n_blocks
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(m, k).astype(np.float32))
    b = jnp.asarray(rng.randn(k, n).astype(np.float32))
    a_mask = b_mask = None
    if fill < 1.0:
        mask_rng = np.random.RandomState(1)
        n_cells = n_blocks * n_blocks
        n_true = max(1, round(fill * n_cells))  # never tune the empty plan
        a_mask = np.zeros(n_cells, dtype=bool)
        a_mask[mask_rng.choice(n_cells, n_true, replace=False)] = True
        a_mask = a_mask.reshape(n_blocks, n_blocks)
        mask_a_full = np.repeat(np.repeat(a_mask, block, 0), block, 1)
        a = a * jnp.asarray(mask_a_full, jnp.float32)
    a_blocks = to_blocks(a, block, block)
    b_blocks = to_blocks(b, block, block)

    # the sweep measures the SAME dispatch path production uses: the
    # fused scan executor (core/engine.py), per (align, stack_tile)
    kernel = "smm" if use_kernel else "ref"
    if use_kernel:
        space = SPACE
    else:
        # the ref oracle ignores align — sweeping it would record a
        # coin-flip align bit into the winners table; pin it from the
        # MXU-padding heuristic and sweep stack_tile only
        heur_align = mxu_pad_shape(block, block, block, True) != \
            (block, block, block)
        space = [(heur_align, t) for t in sorted({t for _, t in SPACE})]
    rows = []
    for align, stack_tile in space:
        plan = build_executor_plan(m, k, n, block, block, block, stack_tile,
                                   a_mask=a_mask, b_mask=b_mask)
        c = jnp.zeros((n_blocks * n_blocks, block, block), jnp.float32)

        def run(c0=c, plan=plan, align=align):
            return execute_plan(plan, a_blocks, b_blocks, c0,
                                kernel=kernel, align=align)

        dt = _bench(jax.jit(run))
        # useful flops only: absent triples are skipped, not multiplied
        flops = plan.n_entries * 2 * block ** 3
        rows.append({"align": align, "stack_tile": stack_tile,
                     "time_s": dt, "gflops": flops / dt / 1e9,
                     "n_stacks": plan.n_stacks,
                     "n_entries": plan.n_entries})
    best = min(rows, key=lambda r: r["time_s"])
    return {"block": block, "fill": fill, "rows": rows, "best": best}


def load_cache(path: str | None = None) -> Dict:
    # path resolves at call time so tests / tools can repoint
    # DEFAULT_CACHE after import
    path = DEFAULT_CACHE if path is None else path
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def best_params_meta(block_m: int, block_k: int, block_n: int,
                     path: str | None = None, *,
                     fill: float = 1.0) -> Dict:
    """Winner lookup WITH provenance — the planner-facing entry point.

    Returns ``{"align", "stack_tile", "source", "bin", "gflops"}``:
    ``source`` records where the params came from
    (``"winners[<key>]"`` — an occupancy-binned sweep entry,
    ``"winners[<block>]"`` — dense-entry fallback for an unswept sparse
    bin, or ``"heuristic"`` / ``"heuristic-nonuniform"``), and
    ``gflops`` carries the sweep's measured throughput when recorded so
    the planner's cost model (repro.planner.cost_model) can use the
    per-geometry rate instead of a global constant.

    The winners table is keyed on uniform block sizes (the paper's
    regime); non-uniform geometries fall back to the heuristic: align
    iff MXU padding would change the block shape.
    """
    b = fill_bin(fill)
    if block_m == block_k == block_n:
        cache = load_cache(path)
        keys = [_cache_key(block_m, b)]
        if b < 1.0:
            keys.append(str(block_m))
        for key in keys:
            entry = cache.get(key)
            if entry:
                best = entry["best"]
                return {"align": best["align"],
                        "stack_tile": best["stack_tile"],
                        "source": f"winners[{key}]", "bin": b,
                        "gflops": best.get("gflops")}
        return {"align": block_m % 8 != 0 or block_m % 128 != 0,
                "stack_tile": 30000, "source": "heuristic", "bin": b,
                "gflops": None}
    align = mxu_pad_shape(block_m, block_k, block_n, True) != \
        (block_m, block_k, block_n)
    return {"align": align, "stack_tile": 30000,
            "source": "heuristic-nonuniform", "bin": b, "gflops": None}


def best_params(block: int, path: str | None = None, *,
                fill: float = 1.0) -> Tuple[bool, int]:
    """Winner lookup used by callers; falls back through the dense
    entry (a sparse bin with no recorded sweep) to a sane default."""
    meta = best_params_meta(block, block, block, path, fill=fill)
    return meta["align"], meta["stack_tile"]


def best_params_for(block_m: int, block_k: int, block_n: int,
                    path: str | None = None, *,
                    fill: float = 1.0) -> Tuple[bool, int]:
    """Winner lookup for a (possibly non-uniform) block geometry and
    occupancy — the dispatch-path entry point (core/engine.py resolves
    ``align`` / ``stack_tile`` through this when the caller doesn't pin
    them, passing the plan's effective fill so sparse workloads get the
    occupancy-binned winner).  See ``best_params_meta`` for provenance.
    """
    meta = best_params_meta(block_m, block_k, block_n, path, fill=fill)
    return meta["align"], meta["stack_tile"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--blocks", type=int, nargs="+", default=[22, 64])
    ap.add_argument("--fills", type=float, nargs="+", default=[1.0],
                    help="occupancy bins to sweep (see FILL_BINS)")
    ap.add_argument("--cache", default=DEFAULT_CACHE)
    ap.add_argument("--kernel", action="store_true",
                    help="sweep the interpret-mode Pallas kernel itself")
    args = ap.parse_args()

    cache = load_cache(args.cache)
    for block in args.blocks:
        for fill in args.fills:
            bin_ = fill_bin(fill)
            result = tune_block(block, use_kernel=args.kernel, fill=bin_)
            cache[_cache_key(block, bin_)] = result
            b = result["best"]
            print(f"block {block:3d} fill {bin_:4g}: best align={b['align']} "
                  f"stack_tile={b['stack_tile']} ({b['gflops']:.2f} GF/s)")
    os.makedirs(os.path.dirname(args.cache) or ".", exist_ok=True)
    with open(args.cache, "w") as f:
        json.dump(cache, f, indent=1)
    print("cached ->", args.cache)


if __name__ == "__main__":
    main()
