"""smm kernel parameter sweep — the LIBCUSMM autotuner's TPU analogue.

LIBCUSMM explores ~30k-150k CUDA parameter combinations per (m, n, k)
with an ML performance model (paper section II).  The TPU parameter
space is BlockSpec-level and small enough to sweep directly:

  * MXU alignment on/off (pad blocks to (8, 128) multiples),
  * stack tile (how many stack entries per kernel launch chunk),

measured per (m, n, k) block size and cached to a JSON winners table.
On this CPU container the sweep times interpret-mode execution (a
correctness vehicle, so the *absolute* numbers are not TPU truth —
the harness and cache format are what transfer; on real hardware the
same sweep runs the compiled kernel).

    PYTHONPATH=src python -m repro.kernels.smm.autotune --blocks 22 64
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.densify import to_blocks
from repro.core.engine import build_executor_plan, execute_plan
from .ops import mxu_pad_shape

DEFAULT_CACHE = os.path.join("artifacts", "smm_autotune.json")

# the sweep space: (align, stack_tile)
SPACE: List[Tuple[bool, int]] = [
    (False, 1024), (False, 4096), (False, 30000),
    (True, 1024), (True, 4096), (True, 30000),
]


def _bench(fn, *args, reps: int = 3) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def tune_block(block: int, *, n_blocks: int = 8,
               use_kernel: bool = False) -> Dict:
    """Sweep SPACE for a (block x block x block) stack workload."""
    m = k = n = block * n_blocks
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(m, k).astype(np.float32))
    b = jnp.asarray(rng.randn(k, n).astype(np.float32))
    a_blocks = to_blocks(a, block, block)
    b_blocks = to_blocks(b, block, block)

    # the sweep measures the SAME dispatch path production uses: the
    # fused scan executor (core/engine.py), per (align, stack_tile)
    kernel = "smm" if use_kernel else "ref"
    if use_kernel:
        space = SPACE
    else:
        # the ref oracle ignores align — sweeping it would record a
        # coin-flip align bit into the winners table; pin it from the
        # MXU-padding heuristic and sweep stack_tile only
        heur_align = mxu_pad_shape(block, block, block, True) != \
            (block, block, block)
        space = [(heur_align, t) for t in sorted({t for _, t in SPACE})]
    rows = []
    for align, stack_tile in space:
        plan = build_executor_plan(m, k, n, block, block, block, stack_tile)
        c = jnp.zeros((n_blocks * n_blocks, block, block), jnp.float32)

        def run(c0=c, plan=plan, align=align):
            return execute_plan(plan, a_blocks, b_blocks, c0,
                                kernel=kernel, align=align)

        dt = _bench(jax.jit(run))
        flops = 2 * m * k * n
        rows.append({"align": align, "stack_tile": stack_tile,
                     "time_s": dt, "gflops": flops / dt / 1e9,
                     "n_stacks": plan.n_stacks})
    best = min(rows, key=lambda r: r["time_s"])
    return {"block": block, "rows": rows, "best": best}


def load_cache(path: str = DEFAULT_CACHE) -> Dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def best_params(block: int, path: str = DEFAULT_CACHE) -> Tuple[bool, int]:
    """Winner lookup used by callers; falls back to a sane default."""
    cache = load_cache(path)
    entry = cache.get(str(block))
    if entry:
        return entry["best"]["align"], entry["best"]["stack_tile"]
    return (block % 8 != 0 or block % 128 != 0), 30000


def best_params_for(block_m: int, block_k: int, block_n: int,
                    path: str = DEFAULT_CACHE) -> Tuple[bool, int]:
    """Winner lookup for a (possibly non-uniform) block geometry — the
    dispatch-path entry point (core/engine.py resolves ``align`` /
    ``stack_tile`` through this when the caller doesn't pin them).

    The winners table is keyed on uniform block sizes (the paper's
    regime); non-uniform geometries fall back to the heuristic: align
    iff MXU padding would change the block shape.
    """
    if block_m == block_k == block_n:
        return best_params(block_m, path)
    align = mxu_pad_shape(block_m, block_k, block_n, True) != \
        (block_m, block_k, block_n)
    return align, 30000


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--blocks", type=int, nargs="+", default=[22, 64])
    ap.add_argument("--cache", default=DEFAULT_CACHE)
    ap.add_argument("--kernel", action="store_true",
                    help="sweep the interpret-mode Pallas kernel itself")
    args = ap.parse_args()

    cache = load_cache(args.cache)
    for block in args.blocks:
        result = tune_block(block, use_kernel=args.kernel)
        cache[str(block)] = result
        b = result["best"]
        print(f"block {block:3d}: best align={b['align']} "
              f"stack_tile={b['stack_tile']} ({b['gflops']:.2f} GF/s)")
    os.makedirs(os.path.dirname(args.cache) or ".", exist_ok=True)
    with open(args.cache, "w") as f:
        json.dump(cache, f, indent=1)
    print("cached ->", args.cache)


if __name__ == "__main__":
    main()
