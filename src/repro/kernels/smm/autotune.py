"""smm kernel parameter sweep — the LIBCUSMM autotuner's TPU analogue.

LIBCUSMM explores ~30k-150k CUDA parameter combinations per (m, n, k)
with an ML performance model (paper section II).  The TPU parameter
space is BlockSpec-level and small enough to sweep directly:

  * MXU alignment on/off (pad blocks to (8, 128) multiples),
  * stack tile (how many stack entries per kernel launch chunk),

measured per (m, n, k) block size and cached to a JSON winners table.
On this CPU container the sweep times interpret-mode execution (a
correctness vehicle, so the *absolute* numbers are not TPU truth —
the harness and cache format are what transfer; on real hardware the
same sweep runs the compiled kernel).

    PYTHONPATH=src python -m repro.kernels.smm.autotune --blocks 22 64
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocking import BlockLayout
from repro.core.stacks import build_stacks
from repro.core.densify import to_blocks
from .ops import smm_process_stack
from .ref import smm_process_stack_ref

DEFAULT_CACHE = os.path.join("artifacts", "smm_autotune.json")

# the sweep space: (align, stack_tile)
SPACE: List[Tuple[bool, int]] = [
    (False, 1024), (False, 4096), (False, 30000),
    (True, 1024), (True, 4096), (True, 30000),
]


def _bench(fn, *args, reps: int = 3) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def tune_block(block: int, *, n_blocks: int = 8,
               use_kernel: bool = False) -> Dict:
    """Sweep SPACE for a (block x block x block) stack workload."""
    m = k = n = block * n_blocks
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(m, k).astype(np.float32))
    b = jnp.asarray(rng.randn(k, n).astype(np.float32))
    a_blocks = to_blocks(a, block, block)
    b_blocks = to_blocks(b, block, block)

    rows = []
    for align, stack_tile in SPACE:
        plans = build_stacks(BlockLayout(m, k, block, block),
                             BlockLayout(k, n, block, block),
                             stack_size=stack_tile)
        c = jnp.zeros((n_blocks * n_blocks, block, block), jnp.float32)

        if use_kernel:  # interpret-mode Pallas (slow on CPU, true on TPU)
            def run(c0=c, plans=plans, align=align):
                out = c0
                for p in plans:
                    out = smm_process_stack(a_blocks, b_blocks, out,
                                            jnp.asarray(p.triples),
                                            align=align)
                return out
        else:           # jnp oracle path (CPU-meaningful proxy)
            def run(c0=c, plans=plans):
                out = c0
                for p in plans:
                    out = smm_process_stack_ref(a_blocks, b_blocks, out,
                                                jnp.asarray(p.triples))
                return out

        dt = _bench(jax.jit(run))
        flops = 2 * m * k * n
        rows.append({"align": align, "stack_tile": stack_tile,
                     "time_s": dt, "gflops": flops / dt / 1e9,
                     "n_stacks": len(plans)})
    best = min(rows, key=lambda r: r["time_s"])
    return {"block": block, "rows": rows, "best": best}


def load_cache(path: str = DEFAULT_CACHE) -> Dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def best_params(block: int, path: str = DEFAULT_CACHE) -> Tuple[bool, int]:
    """Winner lookup used by callers; falls back to a sane default."""
    cache = load_cache(path)
    entry = cache.get(str(block))
    if entry:
        return entry["best"]["align"], entry["best"]["stack_tile"]
    return (block % 8 != 0 or block % 128 != 0), 30000


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--blocks", type=int, nargs="+", default=[22, 64])
    ap.add_argument("--cache", default=DEFAULT_CACHE)
    ap.add_argument("--kernel", action="store_true",
                    help="sweep the interpret-mode Pallas kernel itself")
    args = ap.parse_args()

    cache = load_cache(args.cache)
    for block in args.blocks:
        result = tune_block(block, use_kernel=args.kernel)
        cache[str(block)] = result
        b = result["best"]
        print(f"block {block:3d}: best align={b['align']} "
              f"stack_tile={b['stack_tile']} ({b['gflops']:.2f} GF/s)")
    os.makedirs(os.path.dirname(args.cache) or ".", exist_ok=True)
    with open(args.cache, "w") as f:
        json.dump(cache, f, indent=1)
    print("cached ->", args.cache)


if __name__ == "__main__":
    main()
