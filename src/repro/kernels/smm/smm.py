"""Pallas small-matrix-multiply stack kernel — LIBCUSMM's TPU analogue.

LIBCUSMM processes *stacks* of small-block multiplications
C[c] += A[a] @ B[b] with JIT-generated CUDA kernels parametrised over
threads/block, per-thread work, and tiling (paper section II).  None of
those CUDA dimensions exist on TPU; the TPU-native parameter space is:

  * BlockSpec block shapes (how much of each operand lives in VMEM),
  * MXU alignment padding (the systolic array wants multiples of
    (8, 128) lanes; small DBCSR blocks of 22/64 are padded by ops.py),
  * the grid layout (one grid step per stack entry, scalar-prefetched
    indices).

The stack's (a, b, c) indices are data: they drive *which* blocks each
grid step touches.  That requires scalar prefetch
(pltpu.PrefetchScalarGridSpec) so the index_map can read them before
the DMA of the corresponding blocks is issued.

Triples may carry an optional 4th column (validity mask).  The fused
stack executor (core/engine.py) pads ragged stacks to a uniform tile;
padding rows have mask 0 and point ``c_idx`` at a scratch block one
past the real C blocks, so their (zeroed) products never touch real
output.

Accumulation correctness relies on the stack invariant established by
stacks.py: entries with equal c_idx are contiguous, so each C block is
resident in VMEM for exactly one run of consecutive grid steps (the
TPU output-revisit rule).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["smm_pallas_call"]


def _smm_kernel(triples_ref, a_ref, b_ref, c_in_ref, c_out_ref):
    s = pl.program_id(0)
    # first grid step of this C block's contiguous run?
    prev_same = jnp.where(
        s > 0, triples_ref[jnp.maximum(s - 1, 0), 2] == triples_ref[s, 2], False
    )
    prod = jnp.dot(
        a_ref[0].astype(jnp.float32),
        b_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if triples_ref.shape[1] > 3:
        # masked triples (fused-executor stack padding): column 3 is a
        # validity flag — zero the padding entries' product so their
        # accumulation into the scratch C block is a no-op.
        prod = prod * triples_ref[s, 3].astype(jnp.float32)

    @pl.when(jnp.logical_not(prev_same))
    def _init():  # start of run: seed with the incoming C block
        c_out_ref[0] = c_in_ref[0] + prod

    @pl.when(prev_same)
    def _accum():  # same C block as previous step: VMEM-resident add
        c_out_ref[0] = c_out_ref[0] + prod


@functools.partial(jax.jit, static_argnames=("interpret",))
def smm_pallas_call(
    a_blocks: jax.Array,  # (Na, bm, bk)
    b_blocks: jax.Array,  # (Nb, bk, bn)
    c_blocks: jax.Array,  # (Nc, bm, bn) float32
    triples: jax.Array,   # (S, 3) int32, c-runs contiguous
    *,
    interpret: bool = False,
) -> jax.Array:
    s_len = triples.shape[0]
    _, bm, bk = a_blocks.shape
    _, bk2, bn = b_blocks.shape
    assert bk == bk2, (a_blocks.shape, b_blocks.shape)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(s_len,),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda s, t: (t[s, 0], 0, 0)),
            pl.BlockSpec((1, bk, bn), lambda s, t: (t[s, 1], 0, 0)),
            pl.BlockSpec((1, bm, bn), lambda s, t: (t[s, 2], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda s, t: (t[s, 2], 0, 0)),
    )
    return pl.pallas_call(
        _smm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(c_blocks.shape, jnp.float32),
        input_output_aliases={3: 0},  # c_blocks buffer is donated to out
        interpret=interpret,
    )(triples, a_blocks, b_blocks, c_blocks)
