"""Oracle for the tiled dense matmul kernel."""
import jax
import jax.numpy as jnp


def tiled_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
