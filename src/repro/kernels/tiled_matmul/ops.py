"""Public wrapper for the tiled matmul kernel: pads to tile multiples,
auto-selects interpret mode on CPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .tiled_matmul import tiled_matmul_pallas

__all__ = ["tiled_matmul"]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def tiled_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, k = a.shape
    _, n = b.shape
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    pad = lambda x, t: (-x) % t
    pm, pk, pn = pad(m, bm_), pad(k, bk_), pad(n, bn_)
    a_p = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    b_p = jnp.pad(b, ((0, pk), (0, pn))) if (pk or pn) else b
    out = tiled_matmul_pallas(
        a_p, b_p, bm=bm_, bn=bn_, bk=bk_, interpret=interpret
    )
    return out[:m, :n] if (pm or pn) else out
