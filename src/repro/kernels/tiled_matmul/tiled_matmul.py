"""Pallas VMEM-tiled dense matmul — the 'vendor GEMM' of the densified
path (cuBLAS analogue on TPU).

Classic three-level tiling for the TPU memory hierarchy:
HBM -> (BlockSpec DMA) -> VMEM tiles -> MXU (128x128 systolic) with a
float32 VMEM scratch accumulator that persists across the contraction
grid dimension (output-revisit: k is the innermost grid axis, so the C
tile is written exactly once, at k == k_steps-1).

Tile sizes are parameters; defaults (256, 256, 512) keep the working
set (a_tile + b_tile + acc ≈ 0.9 MiB at bf16) comfortably inside the
~16 MiB VMEM while giving the MXU 128-aligned operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["tiled_matmul_pallas"]


def _matmul_kernel(a_ref, b_ref, c_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _flush():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret")
)
def tiled_matmul_pallas(
    a: jax.Array,   # (M, K)
    b: jax.Array,   # (K, N)
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{k},{n}) not divisible by tile "
                         f"({bm},{bk},{bn}); ops.py pads first")
    k_steps = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
