"""Pallas TPU kernels for the compute hot-spots the paper optimizes:

    smm/          LIBCUSMM analogue — stack-driven batched small GEMM
                  (+ autotune.py, the parameter-sweep tuner)
    tiled_matmul/ cuBLAS analogue — VMEM-tiled dense matmul
    grouped_gemm/ densified-MoE grouped GEMM

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, CPU interpret-mode autoselect), ref.py (pure-jnp oracle).
"""
