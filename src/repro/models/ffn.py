"""Dense feed-forward blocks (GLU and plain), tensor-parallel over 'model'.

Column-parallel up/gate, row-parallel down; the combining psum is left
to GSPMD (emitted from the sharding constraints on the weights).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamDef, act_fn

__all__ = ["ffn_defs", "ffn_apply"]


def ffn_defs(cfg, d_ff: int | None = None) -> Dict[str, ParamDef]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.glu:
        defs = {
            "w_gate": ParamDef((d, f), P(None, "model")),
            "w_up": ParamDef((d, f), P(None, "model")),
            "w_down": ParamDef((f, d), P("model", None)),
        }
    else:
        defs = {
            "w_up": ParamDef((d, f), P(None, "model")),
            "w_down": ParamDef((f, d), P("model", None)),
        }
    if cfg.mlp_bias:
        defs["b_up"] = ParamDef((f,), P("model"), "zeros")
        defs["b_down"] = ParamDef((d,), P(None), "zeros")
    return defs


def ffn_apply(params: Dict, x: jax.Array, cfg) -> jax.Array:
    act = act_fn(cfg.act)
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    if cfg.mlp_bias:
        u = u + params["b_up"].astype(x.dtype)
    if cfg.glu:
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
        h = act(g) * u
    else:
        h = act(u)
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))
    if cfg.mlp_bias:
        out = out + params["b_down"].astype(x.dtype)
    return out
