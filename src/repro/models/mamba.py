"""Mamba (selective SSM) mixer — for the Jamba hybrid architecture.

Training/prefill uses a chunked parallel scan: the sequence is cut into
chunks processed by an associative scan (log-depth, TPU-friendly) with
a sequential lax.scan carrying the inter-chunk state, bounding the
materialised (B, chunk, d_inner, N) decay tensors.  The inner dimension
is sharded over the 'model' axis, so the big intermediates are TP-sharded
too (GSPMD propagates from the weight specs).

Decode is the O(1) recurrent step with (conv_state, ssm_state) carried
in the serve cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamDef, rms_norm

__all__ = ["mamba_defs", "mamba_apply"]


def _dims(cfg):
    d_in = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, cfg.d_model // 16)
    return d_in, dt_rank, cfg.mamba_d_state, cfg.mamba_conv


def mamba_defs(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    d_in, dt_rank, n, k = _dims(cfg)
    return {
        "in_proj": ParamDef((d, 2 * d_in), P(None, "model")),
        "conv_w": ParamDef((k, d_in), P(None, "model")),
        "conv_b": ParamDef((d_in,), P("model"), "zeros"),
        "x_proj": ParamDef((d_in, dt_rank + 2 * n), P("model", None)),
        "dt_proj": ParamDef((dt_rank, d_in), P(None, "model")),
        "dt_bias": ParamDef((d_in,), P("model"), "zeros"),
        "a_log": ParamDef((d_in, n), P("model", None), "ones"),
        "d_skip": ParamDef((d_in,), P("model"), "ones"),
        "out_proj": ParamDef((d_in, d), P("model", None)),
    }


def _ssm_chunked(u, dt, a, b, c, *, chunk: int):
    """h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t ;  y_t = C_t . h_t

    u, dt: (B, T, D); a: (D, N); b, c: (B, T, N).  Returns y (B, T, D)
    and the final state (B, D, N).
    """
    bsz, t, dd = u.shape
    n = a.shape[1]
    chunk = min(chunk, t)
    if t % chunk:
        pad = chunk - t % chunk
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        t_orig = t
        t = t + pad
    else:
        t_orig = t
    nchunks = t // chunk
    u = u.reshape(bsz, nchunks, chunk, dd)
    dt = dt.reshape(bsz, nchunks, chunk, dd)
    b = b.reshape(bsz, nchunks, chunk, n)
    c = c.reshape(bsz, nchunks, chunk, n)

    def chunk_step(h0, args):
        u_c, dt_c, b_c, c_c = args            # (B, chunk, ...)
        decay = jnp.exp(dt_c[..., None] * a)  # (B, chunk, D, N)
        inp = (dt_c * u_c)[..., None] * b_c[:, :, None, :]

        def combine(x, y):
            d1, s1 = x
            d2, s2 = y
            return d1 * d2, s1 * d2 + s2

        dec_cum, s_cum = jax.lax.associative_scan(
            combine, (decay, inp), axis=1)
        h = dec_cum * h0[:, None] + s_cum      # (B, chunk, D, N)
        y_c = jnp.einsum("btdn,btn->btd", h, c_c)
        return h[:, -1], y_c

    h_final, y = jax.lax.scan(
        chunk_step,
        jnp.zeros((bsz, dd, n), u.dtype),
        (u.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2, 3),
         b.transpose(1, 0, 2, 3), c.transpose(1, 0, 2, 3)),
    )
    y = y.transpose(1, 0, 2, 3).reshape(bsz, t, dd)[:, :t_orig]
    return y, h_final


def mamba_apply(
    params: Dict,
    x: jax.Array,                   # (B, S, d)
    cfg,
    *,
    cache: Optional[Tuple] = None,  # (conv_state (B,k-1,D), ssm_state (B,D,N))
    chunk: int = 128,
):
    """Returns (out (B,S,d), new_cache)."""
    bsz, s, d = x.shape
    d_in, dt_rank, n, k = _dims(cfg)
    compute_dtype = x.dtype

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    u, z = jnp.split(xz, 2, axis=-1)           # (B, S, D) each

    conv_w = params["conv_w"].astype(x.dtype)  # (k, D)
    if cache is None:
        u_pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
        conv_out = sum(
            u_pad[:, i : i + s] * conv_w[i] for i in range(k)
        ) + params["conv_b"].astype(x.dtype)
        new_conv_state = u_pad[:, -(k - 1):] if k > 1 else None
    else:
        conv_state, ssm_state = cache
        window = jnp.concatenate([conv_state.astype(x.dtype), u], axis=1)
        conv_out = jnp.einsum("bkd,kd->bd", window, conv_w)[:, None]
        conv_out = conv_out + params["conv_b"].astype(x.dtype)
        new_conv_state = window[:, 1:]
    u = jax.nn.silu(conv_out)

    proj = jnp.einsum("bsd,de->bse", u, params["x_proj"].astype(x.dtype))
    dt_lr, b_t, c_t = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_lr, params["dt_proj"].astype(x.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))     # (D, N)

    if cache is None:
        y, h_last = _ssm_chunked(
            u.astype(jnp.float32), dt, a,
            b_t.astype(jnp.float32), c_t.astype(jnp.float32), chunk=chunk)
        new_cache = (new_conv_state, h_last)
    else:
        _, ssm_state = cache
        decay = jnp.exp(dt[:, 0, :, None] * a)             # (B, D, N)
        h = ssm_state * decay + (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] \
            * b_t[:, 0, None, :].astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, c_t[:, 0].astype(jnp.float32))[:, None]
        new_cache = (new_conv_state, h)

    y = y.astype(compute_dtype)
    y = y + u * params["d_skip"].astype(compute_dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(x.dtype)), new_cache
