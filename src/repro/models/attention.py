"""Attention mixers: GQA/MHA/MQA with RoPE, QK-norm, bias options;
memory-efficient blockwise causal attention for long sequences; KV-cache
prefill and decode paths.

Sharding convention: Q heads and KV heads are sharded over the 'model'
mesh axis (KV heads replicated when num_kv_heads < model-axis size);
activations are data-sharded over ('pod', 'data').
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamDef, apply_rope, norm_defs, apply_norm

NEG_INF = -1e30


def pick_blocks(sq: int, skv: int, block_q: int, block_kv: int):
    """Adaptive blocking: ~16 q-blocks keeps the unrolled q loop small
    while bounding the per-block score tile."""
    bq = min(block_q, max(512, sq // 16))
    while sq % bq:
        bq //= 2
    bkv = min(block_kv, max(512, bq))
    while skv % bkv:
        bkv //= 2
    return max(bq, 1), max(bkv, 1)


# ---------------------------------------------------------------------------
# parameter declarations
# ---------------------------------------------------------------------------


def effective_heads(cfg):
    """(q, kv) head counts after TP padding.

    head_pad_factor=c scales BOTH counts by the integer c, appending
    zero-masked heads: the real-head -> kv-group mapping j*hkv/h is
    invariant under a common factor, so the padded model computes
    exactly the original attention (pad-head outputs are hard-masked in
    attention_apply, so no gradient ever flows into them).  Purpose:
    h=12/24 cannot shard over a 16-way model axis — c in {2, 4} makes
    them shardable instead of fully replicated (EXPERIMENTS.md §Perf).
    """
    c = max(1, cfg.head_pad_factor)
    return cfg.num_heads * c, cfg.num_kv_heads * c


def attention_defs(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    dh = cfg.head_dim or d // cfg.num_heads
    h, hkv = effective_heads(cfg)
    defs = {
        "wq": ParamDef((d, h, dh), P(None, "model", None)),
        "wk": ParamDef((d, hkv, dh), P(None, "model", None)),
        "wv": ParamDef((d, hkv, dh), P(None, "model", None)),
        "wo": ParamDef((h, dh, d), P("model", None, None)),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, dh), P("model", None), "zeros")
        defs["bk"] = ParamDef((hkv, dh), P("model", None), "zeros")
        defs["bv"] = ParamDef((hkv, dh), P("model", None), "zeros")
    if cfg.qk_norm:
        defs["q_norm"] = {"scale": ParamDef((dh,), P(None), "ones")}
        defs["k_norm"] = {"scale": ParamDef((dh,), P(None), "ones")}
    return defs


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Hkv, Dh) -> (B, S, Hkv*n_rep, Dh)"""
    if n_rep == 1:
        return k
    b, s, hkv, dh = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, s, hkv, n_rep, dh)
    ).reshape(b, s, hkv * n_rep, dh)


def full_causal_attention(q, k, v, *, scale: float) -> jax.Array:
    """Naive O(S^2)-memory attention — reference / short sequences.

    q (B, Sq, H, Dh); k, v (B, Skv, H, Dh); causal with Sq == Skv.
    """
    b, sq, h, dh = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def blockwise_causal_attention(
    q, k, v, *, scale: float, block_q: int = 512, block_kv: int = 512
) -> jax.Array:
    """Flash-style online-softmax attention in pure JAX.

    Memory is O(S * block) instead of O(S^2); the causal structure is
    exploited with a traced-upper-bound fori_loop so no flops are spent
    on fully-masked KV blocks (the usual 2x waste of mask-only impls).
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    block_q, block_kv = pick_blocks(sq, skv, block_q, block_kv)
    nq, nkv = sq // block_q, skv // block_kv
    q_pos0 = skv - sq  # alignment offset (prefill continuation)

    q_blocks = q.reshape(b, nq, block_q, h, dh)

    def one_q_block(qi: int, q_blk):
        # positions of this q block (qi is a python int: the q loop is
        # unrolled so every kv fori_loop below has a *static* trip
        # count — flop-optimal causality and statically-analyzable HLO
        # for the roofline pass, vs. the masked-full-scan variant that
        # wastes ~2x flops)
        q_pos = q_pos0 + qi * block_q + jnp.arange(block_q)

        def kv_step(ki, carry):
            acc, m, l = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * block_kv, block_kv, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * block_kv, block_kv, 1)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            kv_pos = ki * block_kv + jnp.arange(block_kv)
            causal = q_pos[:, None] >= kv_pos[None, :]
            s = jnp.where(causal[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return acc, m_new, l

        acc0 = jnp.zeros((b, h, block_q, dh), jnp.float32)
        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        # only kv blocks that intersect the causal triangle (static)
        hi = min((q_pos0 + (qi + 1) * block_q + block_kv - 1) // block_kv, nkv)
        acc, m, l = jax.lax.fori_loop(0, hi, kv_step, (acc0, m0, l0))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # cast to the compute dtype per block: concatenating f32 blocks
        # would materialise a 2x-sized tensor before the cast
        return out.transpose(0, 2, 1, 3).astype(q.dtype)

    outs = [one_q_block(qi, q_blocks[:, qi]) for qi in range(nq)]
    return jnp.concatenate(outs, axis=1) if nq > 1 else outs[0]


def decode_attention(q, k_cache, v_cache, cur_len, *, scale: float):
    """Single-token decode: q (B, 1, H, Dh); caches (B, Smax, Hkv, Dh);
    cur_len (int32 scalar) — number of valid cache entries.

    GQA is computed in grouped form (q reshaped to (Hkv, n_rep)) so the
    KV cache is never materialised at H heads — for MQA/GQA decode the
    cache read is the roofline-dominant memory stream and must stay at
    Hkv width.
    """
    b, one, h, dh = q.shape
    hkv = k_cache.shape[2]
    n_rep = h // hkv
    qg = q.reshape(b, one, hkv, n_rep, dh)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(k_cache.shape[1]) < cur_len
    s = jnp.where(valid[None, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", p, v_cache)
    return out.reshape(b, one, h, dh)


# ---------------------------------------------------------------------------
# the attention block (projections + mixer + cache plumbing)
# ---------------------------------------------------------------------------


def attention_apply(
    params: Dict,
    x: jax.Array,                    # (B, S, d)
    positions: jax.Array,            # (B, S)
    cfg,
    *,
    cache: Optional[Tuple] = None,   # (k_cache, v_cache, cur_len) for decode
    block_q: int = 512,
    block_kv: int = 512,
    long_seq_threshold: int = 8192,
):
    """Returns (out (B, S, d), new_cache)."""
    d = cfg.d_model
    dh = cfg.head_dim or d // cfg.num_heads
    h, hkv = effective_heads(cfg)
    scale = dh ** -0.5

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        from .common import rms_norm
        q = rms_norm(q, params["q_norm"]["scale"])
        k = rms_norm(k, params["k_norm"]["scale"])
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    n_rep = h // hkv
    if cache is None:
        k_full = _repeat_kv(k, n_rep)
        v_full = _repeat_kv(v, n_rep)
        if x.shape[1] > long_seq_threshold:
            out = blockwise_causal_attention(
                q, k_full, v_full, scale=scale,
                block_q=block_q, block_kv=block_kv)
        else:
            out = full_causal_attention(q, k_full, v_full, scale=scale)
        new_cache = (k, v)  # pre-repeat KV (what a prefill would store)
    else:
        k_cache, v_cache, cur_len = cache
        # write the new token at cur_len
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, cur_len, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, cur_len, 1)
        out = decode_attention(q, k_cache, v_cache, cur_len + 1, scale=scale)
        new_cache = (k_cache, v_cache)

    if cfg.head_pad_factor > 1:
        # hard-mask padded heads: keeps the padded model *exactly* the
        # original (and blocks gradient flow into pad parameters)
        head_mask = (jnp.arange(h) < cfg.num_heads).astype(out.dtype)
        out = out * head_mask[None, None, :, None]
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, new_cache
