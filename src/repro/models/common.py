"""Shared model substrate: declarative params, norms, RoPE, activations.

Params are declared once (shape + init + PartitionSpec) through
``ParamDef``; both the initializer and the sharding-spec pytree derive
from the same declaration so they can never drift.  Mesh axis
conventions (see launch/mesh.py):

  batch / sequence  -> ("pod", "data")   (data parallel)
  heads / ff hidden / experts / vocab -> "model"  (TP / EP)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# declarative parameters
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: P
    init: str = "normal"      # normal | zeros | ones | scaled
    scale: float = 1.0
    dtype: Any = jnp.float32


def init_params(defs, key, dtype_override=None):
    """Materialise a pytree of ParamDef into arrays (smoke tests / examples)."""
    flat, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(flat))
    out = []
    for d, k in zip(flat, keys):
        dt = dtype_override or d.dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            fan_in = d.shape[0] if len(d.shape) > 1 else max(d.shape[-1], 1)
            std = d.scale / math.sqrt(fan_in)
            out.append((jax.random.normal(k, d.shape, jnp.float32) * std).astype(dt))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shapes(defs, dtype_override=None):
    """ShapeDtypeStruct pytree (for eval_shape / the dry-run)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype_override or d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def param_specs(defs):
    """PartitionSpec pytree with the same structure."""
    return jax.tree_util.tree_map(
        lambda d: d.spec, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def resolve_spec(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Drop mesh axes from dims they don't evenly divide.

    E.g. KV-head dims of 2/4/12/24 cannot shard over a 16-way 'model'
    axis — those tensors fall back to replication on that dim (noted in
    DESIGN.md §5; the TP win there moves to the FFN/vocab matmuls).
    """
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        axes = tuple(a for a in axes if a in mesh.shape)  # drop absent axes
        if not axes:
            out.append(None)
            continue
        extent = 1
        for a in axes:
            extent *= mesh.shape[a]
        if dim % extent != 0:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def resolve_specs(spec_tree, shape_tree, mesh):
    """resolve_spec over a (specs, shapes) pytree pair."""
    return jax.tree_util.tree_map(
        lambda sp, sh: resolve_spec(sp, sh.shape, mesh),
        spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P))


def stack_defs(defs, n: int):
    """Prepend a scan (layer) dimension of size n to every ParamDef."""
    def f(d: ParamDef) -> ParamDef:
        spec = P(*((None,) + tuple(d.spec)))
        return dataclasses.replace(d, shape=(n,) + tuple(d.shape), spec=spec)
    return jax.tree_util.tree_map(
        f, defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(x, params, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])


def norm_defs(d: int, kind: str) -> Dict[str, ParamDef]:
    if kind == "rmsnorm":
        return {"scale": ParamDef((d,), P(None), "ones")}
    return {"scale": ParamDef((d,), P(None), "ones"),
            "bias": ParamDef((d,), P(None), "zeros")}


def act_fn(name: str):
    return {
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, Dh) ; positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d: int) -> jax.Array:
    """MusicGen-style sinusoidal position embeddings, computed pointwise
    from position ids (works for both prefill ranges and decode steps).

    positions (..., S) int32 -> (..., S, d) float32.
    """
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    angle = pos / jnp.power(10000.0, dim / d)          # (..., S, d/2)
    pe = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
    return pe


# ---------------------------------------------------------------------------
# sharded embedding / lm head / loss (the tall-skinny corner of the paper)
# ---------------------------------------------------------------------------


def embed_lookup(tokens, embedding):
    """tokens (B, S) int32; embedding (V, d) sharded P('model', None).

    The gather is a one-hot x embedding matmul in disguise: with the
    vocabulary sharded over 'model', each device gathers only its own
    rows (out-of-range -> 0) and the partials are summed — GSPMD emits
    exactly this from the take + sharding constraint.
    """
    return jnp.take(embedding, tokens, axis=0)


def cross_entropy_logits_sharded(logits, labels, *, valid_mask=None):
    """logits (B, S, V) — V may be sharded over 'model'; numerically
    stable CE computed in f32.  Returns mean nll over valid tokens."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(
        logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    if valid_mask is None:
        return jnp.mean(nll)
    valid = valid_mask.astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
