"""Model assembly: embeddings, layer-pattern segments (scan-stacked),
LM head/loss, KV/state caches, MTP.

Layer patterns (uniform, DeepSeek dense-prefix+MoE, Jamba 1:7
Mamba/attention interleave with alternating MoE, RWKV) are normalised
into *segments*: (n_repeats, [period of layer kinds]).  Parameters of
each period position are stacked over n_repeats and the segment runs
under one ``jax.lax.scan`` — compile time and HLO size are O(period),
not O(num_layers), which is what keeps 61-88-layer dry-runs cheap.

The same segment structure carries the serve cache (KV / latent-KV /
conv+ssm state / rwkv state), scanned alongside the params.
"""
from __future__ import annotations

from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (ParamDef, apply_norm, norm_defs, init_params,
                     param_shapes, param_specs, stack_defs, resolve_specs,
                     sinusoidal_positions, cross_entropy_logits_sharded)
from .attention import attention_defs, attention_apply, effective_heads
from .mla import mla_defs, mla_apply
from .ffn import ffn_defs, ffn_apply
from .moe import moe_defs, moe_apply
from .mamba import mamba_defs, mamba_apply, _dims as mamba_dims
from .rwkv6 import rwkv6_defs, rwkv6_time_mix, rwkv6_channel_mix

DP_AXES = ("pod", "data")


def dp_axes(mesh) -> tuple:
    """Data-parallel axes present in this mesh (single-pod has no 'pod')."""
    return tuple(a for a in DP_AXES if a in mesh.shape)

# ---------------------------------------------------------------------------
# segment planning
# ---------------------------------------------------------------------------


def segment_plan(cfg) -> List[Tuple[int, List[Tuple[str, str]]]]:
    kinds = [cfg.layer_kind(l) for l in range(cfg.num_layers)]
    segments = []
    start = 0
    if cfg.first_dense_layers:
        n = cfg.first_dense_layers
        assert all(k == kinds[0] for k in kinds[:n])
        segments.append((n, [kinds[0]]))
        start = n
    rest = kinds[start:]
    if rest:
        period = len(rest)
        for p in range(1, len(rest) + 1):
            if len(rest) % p == 0 and rest == rest[:p] * (len(rest) // p):
                period = p
                break
        segments.append((len(rest) // period, rest[:period]))
    return segments


# ---------------------------------------------------------------------------
# parameter declaration
# ---------------------------------------------------------------------------


def _mixer_defs(kind: str, cfg):
    if kind == "attention":
        return attention_defs(cfg)
    if kind == "mla":
        return mla_defs(cfg)
    if kind == "mamba":
        return mamba_defs(cfg)
    if kind == "rwkv6":
        return rwkv6_defs(cfg)       # includes channel-mix params
    raise ValueError(kind)


def _ffn_defs(kind: str, cfg):
    if kind == "dense":
        return ffn_defs(cfg)
    if kind == "moe":
        return moe_defs(cfg)
    if kind == "rwkv_cm":
        return {}                    # lives inside rwkv6_defs
    raise ValueError(kind)


def _layer_defs(kind: Tuple[str, str], cfg) -> Dict[str, Any]:
    mix, ff = kind
    defs = {
        "norm1": norm_defs(cfg.d_model, cfg.norm),
        "norm2": norm_defs(cfg.d_model, cfg.norm),
        "mixer": _mixer_defs(mix, cfg),
    }
    ffd = _ffn_defs(ff, cfg)
    if ffd:
        defs["ffn"] = ffd
    return defs


def model_defs(cfg) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    defs: Dict[str, Any] = {
        "embed": ParamDef((v, d), P("model", None), "normal"),
        "final_norm": norm_defs(d, cfg.norm),
    }
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((d, v), P(None, "model"))
    segments = []
    for n_rep, period in segment_plan(cfg):
        seg = [stack_defs(_layer_defs(kind, cfg), n_rep) for kind in period]
        segments.append(seg)
    defs["segments"] = segments
    if cfg.mtp:
        defs["mtp"] = {
            "proj": ParamDef((2 * d, d), P(None, None)),
            "norm_h": norm_defs(d, cfg.norm),
            "norm_e": norm_defs(d, cfg.norm),
            "block": _layer_defs((("mla" if cfg.mixer == "mla"
                                   else "attention"), "dense"), cfg),
        }
    return defs


def model_param_specs(cfg, mesh=None):
    specs = param_specs(model_defs(cfg))
    if mesh is not None:
        specs = resolve_specs(specs, model_param_shapes(cfg), mesh)
    return specs


def model_param_shapes(cfg, dtype=None):
    import jax.numpy as jnp
    dt = dtype or getattr(jnp, cfg.dtype)
    return param_shapes(model_defs(cfg), dtype_override=dt)


def model_init(cfg, key, dtype=None):
    import jax.numpy as jnp
    dt = dtype or getattr(jnp, cfg.dtype)
    return init_params(model_defs(cfg), key, dtype_override=dt)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _layer_cache_shape(kind: Tuple[str, str], cfg, batch: int, max_len: int):
    """ShapeDtypeStructs of one layer's serve cache."""
    mix, _ = kind
    dt = getattr(jnp, cfg.dtype)
    dh = cfg.resolved_head_dim
    if mix == "attention":
        _, hkv_eff = effective_heads(cfg)
        kv = (batch, max_len, hkv_eff, dh)
        return (jax.ShapeDtypeStruct(kv, dt), jax.ShapeDtypeStruct(kv, dt))
    if mix == "mla":
        return (jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dt),
                jax.ShapeDtypeStruct((batch, max_len, cfg.qk_rope_dim), dt))
    if mix == "mamba":
        d_in, _, n, k = mamba_dims(cfg)
        return (jax.ShapeDtypeStruct((batch, k - 1, d_in), dt),
                jax.ShapeDtypeStruct((batch, d_in, n), jnp.float32))
    if mix == "rwkv6":
        d = cfg.d_model
        h = d // cfg.rwkv_head_size
        return (jax.ShapeDtypeStruct((batch, d), dt),
                jax.ShapeDtypeStruct((batch, h, cfg.rwkv_head_size,
                                      cfg.rwkv_head_size), jnp.float32),
                jax.ShapeDtypeStruct((batch, d), dt))  # cm shift
    raise ValueError(mix)


def _cache_spec_one(kind: Tuple[str, str], cfg, dp=DP_AXES,
                    seq_axes=("model",)):
    mix, _ = kind
    tp = "model"
    DP_AXES_ = dp
    seq = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    if mix == "attention":
        # split-KV: sequence dim sharded over 'model' (KV heads rarely
        # divide a 16-way axis); GSPMD partitions the softmax reductions
        # into the FlashDecoding-style combine automatically.  When the
        # batch cannot cover the data axes (long_500k: batch 1) the data
        # axes also move onto the sequence dim.
        s = P(DP_AXES_, seq, None, None)
        return (s, s)
    if mix == "mla":
        return (P(DP_AXES_, seq, None), P(DP_AXES_, seq, None))
    if mix == "mamba":
        return (P(DP_AXES_, None, tp), P(DP_AXES_, tp, None))
    if mix == "rwkv6":
        return (P(DP_AXES_, None), P(DP_AXES_, tp, None, None),
                P(DP_AXES_, None))
    raise ValueError(mix)


def cache_shapes(cfg, batch: int, max_len: int):
    out = []
    for n_rep, period in segment_plan(cfg):
        seg = []
        for kind in period:
            shapes = _layer_cache_shape(kind, cfg, batch, max_len)
            seg.append(tuple(
                jax.ShapeDtypeStruct((n_rep,) + s.shape, s.dtype)
                for s in shapes))
        out.append(seg)
    return out


def cache_specs(cfg, mesh=None, batch=None):
    dp = dp_axes(mesh) if mesh is not None else DP_AXES
    seq_axes = ("model",)
    if batch is not None and mesh is not None:
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        if batch % max(n_dp, 1) != 0:
            # batch can't shard over the data axes: put them on the
            # sequence dim instead (long_500k single-sequence decode)
            seq_axes = dp + ("model",)
            dp = ()
    out = []
    for n_rep, period in segment_plan(cfg):
        seg = []
        for kind in period:
            seg.append(tuple(P(*((None,) + tuple(s)))
                             for s in _cache_spec_one(kind, cfg, dp, seq_axes)))
        out.append(seg)
    return out


def cache_init(cfg, batch: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        cache_shapes(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _apply_layer(kind, lp, x, positions, cfg, mesh, cache, cur_len,
                 collect=False):
    """One layer. cache is None (train/prefill) or this layer's cache
    slice (decode).  With collect=True (prefill) the cache the layer
    *would have written* is returned even when none was passed in."""
    mix, ff = kind
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(x, lp["norm1"], cfg.norm)
    if mix == "attention":
        c = None if cache is None else (cache[0], cache[1], cur_len)
        out, new_c = attention_apply(
            lp["mixer"], h, positions, cfg, cache=c,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            long_seq_threshold=cfg.long_seq_threshold)
    elif mix == "mla":
        c = None if cache is None else (cache[0], cache[1], cur_len)
        out, new_c = mla_apply(
            lp["mixer"], h, positions, cfg, cache=c,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            long_seq_threshold=cfg.long_seq_threshold)
    elif mix == "mamba":
        c = None if cache is None else (cache[0], cache[1])
        out, new_c = mamba_apply(lp["mixer"], h, cfg, cache=c)
    elif mix == "rwkv6":
        c = None if cache is None else (cache[0], cache[1])
        out, new_c = rwkv6_time_mix(lp["mixer"], h, cfg, cache=c)
    else:
        raise ValueError(mix)
    x = x + out

    h = apply_norm(x, lp["norm2"], cfg.norm)
    if ff == "dense":
        x = x + ffn_apply(lp["ffn"], h, cfg)
    elif ff == "moe":
        out, aux = moe_apply(lp["ffn"], h, cfg, mesh=mesh)
        out = _checkpoint_name(out, "moe_out")
        x = x + out
    elif ff == "rwkv_cm":
        cm_cache = None if cache is None else cache[2]
        out, cm_state = rwkv6_channel_mix(lp["mixer"], h, cfg, cache=cm_cache)
        x = x + out
        if cache is not None or collect:
            new_c = new_c + (cm_state,)
    else:
        raise ValueError(ff)

    if cache is None and not collect:
        new_c = None
    return x, aux, new_c


def _remat_wrap(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "save_moe":
        # save the (cheap, small) MoE layer outputs so the backward pass
        # never recomputes the expert FFN — recompute would re-gather
        # the FSDP expert weights: ~1.4 GB/layer/microbatch of pure
        # collective traffic at DeepSeek scale (EXPERIMENTS.md §Perf)
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "moe_out"))
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def forward(
    params: Dict,
    inputs: jax.Array,              # (B, S) int32 or (B, S, d) embeddings
    cfg,
    mesh,
    *,
    positions: Optional[jax.Array] = None,
    cache=None,                     # segment-structured cache or None
    cur_len=None,                   # int32 scalar (decode)
    collect_cache: bool = False,    # prefill: return would-be caches
):
    """Returns (logits, hidden, aux_loss, new_cache)."""
    dt = getattr(jnp, cfg.dtype)
    if cfg.input_mode == "embeddings" or inputs.ndim == 3:
        x = inputs.astype(dt)
    else:
        x = jnp.take(params["embed"], inputs, axis=0).astype(dt)
    b, s = x.shape[:2]
    if positions is None:
        if cur_len is not None:
            positions = jnp.broadcast_to(cur_len, (b, s)).astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(dt)

    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(dp_axes(mesh), None, None)))

    aux_total = jnp.zeros((), jnp.float32)
    new_cache = [] if (cache is not None or collect_cache) else None
    plan = segment_plan(cfg)

    for si, (n_rep, period) in enumerate(plan):
        seg_params = params["segments"][si]
        seg_cache = cache[si] if cache is not None else None

        # sequence-parallel residual stream: keep x sharded over the TP
        # axis between layers (checkpointed carries shrink by the TP
        # degree and GSPMD turns the TP all-reduces into AG+RS pairs)
        sp_on = (cfg.sequence_parallel and cache is None
                 and s % mesh.shape.get("model", 1) == 0
                 and mesh.shape.get("model", 1) > 1)

        def sp_constraint(v):
            if not sp_on:
                return v
            return jax.lax.with_sharding_constraint(
                v, jax.sharding.NamedSharding(
                    mesh, P(dp_axes(mesh), "model", None)))

        def seg_body(carry, xs, _period=period):
            xc, auxc = carry
            lps, cslices = xs
            new_cslices = []
            for pi, kind in enumerate(_period):
                cslice = None if cslices is None else cslices[pi]

                def layer_fn(lp, xin, _kind=kind, _cslice=cslice):
                    return _apply_layer(
                        _kind, lp, xin, positions, cfg, mesh, _cslice,
                        cur_len, collect=collect_cache)

                if (len(_period) > 1 and cfg.remat != "none"
                        and cslices is None and not collect_cache):
                    # nested remat: periods with several sub-layers
                    # (jamba's 8) would otherwise keep every sub-layer's
                    # internals alive during the period's backward
                    layer_fn = jax.checkpoint(
                        layer_fn,
                        policy=jax.checkpoint_policies.nothing_saveable)
                xc, aux, nc = layer_fn(lps[pi], xc)
                xc = sp_constraint(xc)
                auxc = auxc + aux
                new_cslices.append(nc)
            ys = (tuple(new_cslices)
                  if (cslices is not None or collect_cache) else None)
            return (xc, auxc), ys

        seg_body = _remat_wrap(seg_body, cfg)
        xs = (seg_params, tuple(seg_cache) if seg_cache is not None else None)
        (x, aux_total), ys = jax.lax.scan(
            seg_body, (x, aux_total), xs, length=n_rep)
        if new_cache is not None:
            new_cache.append(list(ys))

    hidden = apply_norm(x, params["final_norm"], cfg.norm)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", hidden,
                            params["embed"].astype(hidden.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", hidden,
                            params["head"].astype(hidden.dtype))
    logits = jax.lax.with_sharding_constraint(
        logits,
        jax.sharding.NamedSharding(mesh, P(dp_axes(mesh), None, "model")))
    return logits, hidden, aux_total, new_cache


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def lm_loss(params, batch, cfg, mesh) -> Tuple[jax.Array, Dict]:
    """batch: {"inputs": (B,S) or (B,S,d), "labels": (B,S)}."""
    logits, hidden, aux, _ = forward(params, batch["inputs"], cfg, mesh)
    labels = batch["labels"]
    loss = cross_entropy_logits_sharded(logits, labels)
    metrics = {"nll": loss, "aux": aux}
    if cfg.moe:
        loss = loss + 0.01 * aux
    if cfg.mtp:
        mtp_loss = _mtp_loss(params, hidden, batch, cfg, mesh)
        metrics["mtp"] = mtp_loss
        loss = loss + cfg.mtp_weight * mtp_loss
    return loss, metrics


def _mtp_loss(params, hidden, batch, cfg, mesh):
    """DeepSeek-V3 multi-token prediction (depth 1, dense-FFN block)."""
    mp = params["mtp"]
    tokens = batch["labels"]            # next tokens (t+1) at each position
    dt = hidden.dtype
    emb_next = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    h = jnp.concatenate(
        [apply_norm(hidden, mp["norm_h"], cfg.norm),
         apply_norm(emb_next, mp["norm_e"], cfg.norm)], axis=-1)
    h = jnp.einsum("bse,ed->bsd", h, mp["proj"].astype(dt))
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kind = ("mla" if cfg.mixer == "mla" else "attention", "dense")
    h, _, _ = _apply_layer(kind, mp["block"], h, positions, cfg, mesh,
                           None, None)
    h = apply_norm(h, params["final_norm"], cfg.norm)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(dt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["head"].astype(dt))
    # predict t+2: labels shifted one more step
    labels2 = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    valid = jnp.concatenate(
        [jnp.ones((b, s - 1), bool), jnp.zeros((b, 1), bool)], axis=1)
    return cross_entropy_logits_sharded(logits, labels2, valid_mask=valid)
