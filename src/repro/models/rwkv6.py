"""RWKV-6 "Finch" mixer — attention-free, data-dependent decay.

Time-mix: token-shift with data-dependent (LoRA) interpolation feeding
r/k/v/gate/decay projections; per-head WKV state recurrence
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)
with w_t = exp(-exp(w_base + lora(x))) per channel.  Heads are sharded
over the 'model' axis.

Training runs the recurrence with a rolled lax.scan over time (state is
a few MB; per-step flops are outer products — RWKV's design point is
exactly that this is cheap).  Decode carries (shift_tm, shift_cm, S)
through the serve cache: O(1) state — this is why rwkv6 runs the
long_500k shape that full-attention models skip.

Channel-mix: squared-ReLU MLP with token shift and receptance gate.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamDef

__all__ = ["rwkv6_defs", "rwkv6_time_mix", "rwkv6_channel_mix"]

_LORA_R = 32
_DECAY_R = 64


def rwkv6_defs(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    h = d // hs
    return {
        "tm": {
            # base lerp coefficients for (w, k, v, r, g) shifts
            "mix_base": ParamDef((5, d), P(None, None), "zeros"),
            "mix_lora_a": ParamDef((d, 5 * _LORA_R), P(None, None)),
            "mix_lora_b": ParamDef((5, _LORA_R, d), P(None, None, None), "zeros"),
            "w_base": ParamDef((d,), P(None), "zeros"),
            "w_lora_a": ParamDef((d, _DECAY_R), P(None, None)),
            "w_lora_b": ParamDef((_DECAY_R, d), P(None, None), "zeros"),
            "u": ParamDef((h, hs), P("model", None), "zeros"),
            "wr": ParamDef((d, h, hs), P(None, "model", None)),
            "wk": ParamDef((d, h, hs), P(None, "model", None)),
            "wv": ParamDef((d, h, hs), P(None, "model", None)),
            "wg": ParamDef((d, h, hs), P(None, "model", None)),
            "ln_x": {"scale": ParamDef((h, hs), P("model", None), "ones"),
                     "bias": ParamDef((h, hs), P("model", None), "zeros")},
            "wo": ParamDef((h, hs, d), P("model", None, None)),
        },
        "cm": {
            "mix_k": ParamDef((d,), P(None), "zeros"),
            "mix_r": ParamDef((d,), P(None), "zeros"),
            "wk": ParamDef((d, cfg.d_ff), P(None, "model")),
            "wr": ParamDef((d, d), P(None, None)),
            "wv": ParamDef((cfg.d_ff, d), P("model", None)),
        },
    }


def _token_shift(x, shift_state):
    """x (B,S,d) -> previous-token stream; shift_state (B,d) is x_{-1}."""
    prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
    return prev


def rwkv6_time_mix(
    params: Dict,
    x: jax.Array,                      # (B, S, d)
    cfg,
    *,
    cache: Optional[Tuple] = None,     # (shift_state (B,d), wkv_state (B,H,hs,hs))
):
    p = params["tm"]
    bsz, s, d = x.shape
    hs = cfg.rwkv_head_size
    h = d // hs

    shift_state = (cache[0] if cache is not None
                   else jnp.zeros((bsz, d), x.dtype))
    prev = _token_shift(x, shift_state)
    dx = prev - x

    # data-dependent lerp (LoRA over the 5 mix streams)
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", x + dx * p["mix_base"][0],
                               p["mix_lora_a"].astype(x.dtype)))
    lora = lora.reshape(bsz, s, 5, _LORA_R)
    delta = jnp.einsum("bsfr,frd->bsfd", lora, p["mix_lora_b"].astype(x.dtype))
    mix = p["mix_base"].astype(x.dtype)[None, None] + delta   # (B,S,5,d)
    xw, xk, xv, xr, xg = [x + dx * mix[:, :, i] for i in range(5)]

    # decay (per channel, data dependent)
    w = p["w_base"].astype(jnp.float32) + jnp.einsum(
        "bsr,rd->bsd",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["w_lora_a"].astype(x.dtype))
                 ).astype(jnp.float32),
        p["w_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w))                                   # (B,S,d) in (0,1)

    r = jnp.einsum("bsd,dhk->bshk", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xv, p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,dhk->bshk", xg, p["wg"].astype(x.dtype)))
    w = w.reshape(bsz, s, h, hs)
    u = p["u"].astype(jnp.float32)

    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(state, args):
        r_t, k_t, v_t, w_t = args              # (B,H,hs)
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,hs,hs)
        y = jnp.einsum("bhi,bhij->bhj", r_t, state + u[..., :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, y

    state0 = (cache[1].astype(jnp.float32) if cache is not None
              else jnp.zeros((bsz, h, hs, hs), jnp.float32))
    xs = (rf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
          vf.transpose(1, 0, 2, 3), wf.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3)               # (B,S,H,hs)

    # per-head groupnorm
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y * p["ln_x"]["scale"].astype(jnp.float32) \
        + p["ln_x"]["bias"].astype(jnp.float32)
    y = (y.astype(x.dtype) * g)
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(x.dtype))
    new_cache = (x[:, -1], state)
    return out, new_cache


def rwkv6_channel_mix(
    params: Dict,
    x: jax.Array,
    cfg,
    *,
    cache: Optional[jax.Array] = None,   # shift state (B, d)
):
    p = params["cm"]
    bsz, s, d = x.shape
    shift_state = cache if cache is not None else jnp.zeros((bsz, d), x.dtype)
    prev = _token_shift(x, shift_state)
    dx = prev - x
    xk = x + dx * p["mix_k"].astype(x.dtype)
    xr = x + dx * p["mix_r"].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype)))
    return r * kv, x[:, -1]
