"""Mixture-of-Experts layer — DBCSR block-sparse multiply + densification,
re-cast onto a modern workload.

The token->expert dispatch defines a block-sparse (token-block x expert)
matrix multiply; *densification* (paper section III) is the grouped-GEMM
trick: gather each expert's tokens into one contiguous capacity buffer
so the expert compute becomes a batch of large dense GEMMs.  The
'blocked' path keeps per-block small GEMMs (LIBCUSMM regime) and exists
for the paper's blocked-vs-densified comparison (benchmarks/bench_densify).

Distribution (expert parallelism): activations are data-sharded and
replicated over the 'model' axis; expert weights are sharded over
'model' (E_loc = E / tp experts per device).  Because every device
already holds all of its data-shard's tokens, dispatch is LOCAL — each
device gathers tokens routed to *its* experts, runs the grouped GEMM,
scatters partial outputs, and a single psum over 'model' combines them
(the same reduction a row-parallel dense FFN needs, so EP costs no
extra collective vs TP at this layout).  The layer is a shard_map
island inside the otherwise GSPMD-auto program.

Capacity ranking is sort-based (argsort over expert ids + group-start
offsets), never materialising the (T, E, C) one-hot dispatch tensor of
GShard-style einsum MoE — at DeepSeek-V3 scale that tensor would be
~GBs/device while the sort is a few MB.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size, shard_map

from .common import ParamDef, act_fn

__all__ = ["moe_defs", "moe_apply"]


def moe_defs(cfg) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.moe_d_ff
    e = cfg.n_experts
    # moe_fsdp (DeepSeek-671B scale): expert weights additionally shard
    # dim 1 over the data axes — TP-only storage would need ~84 GB/chip.
    # The weights are re-gathered per layer inside moe_local (classic
    # weight-gathered FSDP; the reverse pass reduce-scatters the grads).
    fs = ("pod", "data") if cfg.moe_fsdp else None
    defs = {
        "router": ParamDef((d, e), P(None, None), "normal"),
        "w_gate": ParamDef((e, d, f), P("model", fs, None)),
        "w_up": ParamDef((e, d, f), P("model", fs, None)),
        "w_down": ParamDef((e, f, d), P("model", fs, None)),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        defs["shared"] = {
            "w_gate": ParamDef((d, fs), P(None, "model")),
            "w_up": ParamDef((d, fs), P(None, "model")),
            "w_down": ParamDef((fs, d), P("model", None)),
        }
    return defs


def _capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def _rank_within_expert(flat_eid: jax.Array, n_experts: int):
    """Position of each (token, slot) in its expert's queue.

    Sort-based: O(Tk log Tk) int ops instead of a (Tk, E) one-hot cumsum.
    """
    tk = flat_eid.shape[0]
    order = jnp.argsort(flat_eid)                     # stable
    sorted_eid = flat_eid[order]
    starts = jnp.searchsorted(sorted_eid, jnp.arange(n_experts))
    rank_sorted = jnp.arange(tk) - starts[sorted_eid]
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(tk))
    return rank_sorted[inv]


def moe_local(
    params: Dict,
    x: jax.Array,           # (T, d) — this device's data-shard tokens
    cfg,
    *,
    tp_axis: str = "model",
    local_path: str = "densified",   # densified | blocked
    block_c: int = 64,
    fsdp_axes=None,
    token_gathered: bool = False,    # x is already all-token (partial path)
) -> Tuple[jax.Array, jax.Array]:
    """Per-device MoE body (inside shard_map). Returns (partial_out, aux)."""
    t, d = x.shape
    e, e_loc = cfg.n_experts, cfg.n_experts // axis_size(tp_axis)
    k = cfg.top_k
    cap = _capacity(t, cfg)
    m = jax.lax.axis_index(tp_axis)
    act = act_fn(cfg.act)

    # partial-compute crossover (see moe_apply): token activations are
    # cheaper to move than FSDP weight shards when T_all*d << 3*E_loc*d*f
    partial_compute = token_gathered

    # ---- router (f32 for numerics) -----------------------------------
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    if cfg.router == "sigmoid":      # DeepSeek-V3 style
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gate_w, eid = jax.lax.top_k(scores, k)            # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- capacity ranking & local dispatch indices --------------------
    flat_eid = eid.reshape(-1)                         # (T*k,)
    pos = _rank_within_expert(flat_eid, e)             # (T*k,)
    e_local = flat_eid - m * e_loc
    valid = (e_local >= 0) & (e_local < e_loc) & (pos < cap)
    # invalid entries -> OOB so scatter/gather drop them
    e_ix = jnp.where(valid, e_local, e_loc)
    p_ix = jnp.where(valid, pos, cap)

    # ---- densify: gather tokens into the capacity buffer --------------
    # one scatter per top-k slot: materialises (T, d) per slot instead
    # of one (T*k, d) tensor — 8x smaller peak at DeepSeek's k=8
    e_ix_k = e_ix.reshape(t, k)
    p_ix_k = p_ix.reshape(t, k)
    buf = jnp.zeros((e_loc, cap, d), x.dtype)
    for kk in range(k):
        buf = buf.at[e_ix_k[:, kk], p_ix_k[:, kk]].set(x, mode="drop")

    # ---- expert compute (weights arrive model-sharded: (e_loc, d, f)) --
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    if fsdp_axes and not partial_compute:
        # weight-gathered FSDP: dim 1 was stored sharded over the data
        # axes; gather it for this layer's compute (AD reduce-scatters)
        wg = jax.lax.all_gather(wg, fsdp_axes, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, fsdp_axes, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, fsdp_axes, axis=1, tiled=True)

    def expert_ffn(tokens):  # (e_loc, C, d) -> (e_loc, C, d)
        if partial_compute:
            # d (and f for w_down) stay sharded over the fsdp axes: each
            # shard contracts its slice and the small (E_loc, C, f)
            # activations are psum'd — the decode-side replacement for
            # the 1.4 GB/layer weight gathers (EXPERIMENTS.md §Perf).
            nd = axis_size(fsdp_axes)
            ix = jax.lax.axis_index(fsdp_axes)
            dsl = d // nd
            tok_slice = jax.lax.dynamic_slice_in_dim(
                tokens, ix * dsl, dsl, axis=2)
            g = jnp.einsum("ecd,edf->ecf", tok_slice, wg.astype(tokens.dtype))
            u = jnp.einsum("ecd,edf->ecf", tok_slice, wu.astype(tokens.dtype))
            g = jax.lax.psum(g, fsdp_axes)
            u = jax.lax.psum(u, fsdp_axes)
            h = act(g) * u
            f_all = h.shape[-1]
            fsl = f_all // nd
            h_slice = jax.lax.dynamic_slice_in_dim(h, ix * fsl, fsl, axis=2)
            # partial over f: the combining psum over (tp, fsdp) happens
            # in moe_apply's body
            return jnp.einsum("ecf,efd->ecd", h_slice,
                              wd.astype(tokens.dtype))
        g = jnp.einsum("ecd,edf->ecf", tokens, wg.astype(tokens.dtype))
        u = jnp.einsum("ecd,edf->ecf", tokens, wu.astype(tokens.dtype))
        h = act(g) * u
        return jnp.einsum("ecf,efd->ecd", h, wd.astype(tokens.dtype))

    if local_path == "densified":
        buf_out = expert_ffn(buf)
    elif local_path == "blocked":
        # DBCSR 'blocked' regime: the capacity buffer is processed in
        # small token-blocks, each a separate small GEMM (stack entries).
        nb = cap // block_c
        blocks = buf.reshape(e_loc, nb, block_c, d)

        def per_block(blk):  # (e_loc, block_c, d)
            return expert_ffn(blk)

        buf_out = jax.lax.map(per_block, blocks.transpose(1, 0, 2, 3))
        buf_out = buf_out.transpose(1, 0, 2, 3).reshape(e_loc, cap, d)
    else:
        raise ValueError(local_path)

    # ---- combine: gather back, weight, sum over the k slots ----------
    valid_k = valid.reshape(t, k)
    out = jnp.zeros((t, d), buf_out.dtype)
    for kk in range(k):
        g = buf_out.at[e_ix_k[:, kk], p_ix_k[:, kk]].get(
            mode="fill", fill_value=0)                       # (T, d)
        w_ = (gate_w[:, kk] * valid_k[:, kk]).astype(g.dtype)
        out = out + g * w_[:, None]

    # ---- shared experts (TP within the same shard_map) ----------------
    if cfg.n_shared_experts:
        sh = params["shared"]
        g = jnp.einsum("td,df->tf", x, sh["w_gate"].astype(x.dtype))
        u = jnp.einsum("td,df->tf", x, sh["w_up"].astype(x.dtype))
        out = out + jnp.einsum("tf,fd->td", act(g) * u,
                               sh["w_down"].astype(x.dtype))

    # ---- aux load-balancing loss (Switch style) ------------------------
    me = jnp.mean(jax.nn.one_hot(eid[:, 0], e, dtype=jnp.float32), axis=0)
    ce = jnp.mean(scores, axis=0)
    aux = e * jnp.sum(me * ce)

    return out, aux


def moe_apply(
    params: Dict,
    x: jax.Array,            # (B, S, d) data-sharded, model-replicated
    cfg,
    *,
    mesh,
    dp_axes=("pod", "data"),
    tp_axis: str = "model",
    local_path: str = "densified",
) -> Tuple[jax.Array, jax.Array]:
    """Full MoE layer. Returns (out (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    dp_axes = tuple(a for a in dp_axes if a in mesh.shape)
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    if b % max(n_dp, 1) != 0:
        # batch can't cover the data axes (e.g. long_500k decode, B=1):
        # tokens stay replicated over them; compute is redundant across
        # data shards but correct, and B=1 decode is latency-bound anyway.
        dp_axes = ()
    fsdp = (tuple(a for a in ("pod", "data") if a in mesh.shape)
            if cfg.moe_fsdp else None) or None

    pspec = {
        "router": P(None, None),
        "w_gate": P(tp_axis, fsdp, None),
        "w_up": P(tp_axis, fsdp, None),
        "w_down": P(tp_axis, fsdp, None),
    }
    if cfg.n_shared_experts:
        pspec["shared"] = {"w_gate": P(None, tp_axis),
                           "w_up": P(None, tp_axis),
                           "w_down": P(tp_axis, None)}

    # partial-compute crossover: move tokens (T_all x d) instead of
    # gathering weights (3 x E_loc x d x f) when tokens are much smaller
    # — decisive for decode (T_all ~ 128 vs 44M weight elements/layer).
    n_fsdp = 1
    if cfg.moe_fsdp:
        for a in ("pod", "data"):
            n_fsdp *= mesh.shape.get(a, 1)
    t_all = b * s * (1 if dp_axes else 1)  # global tokens this step
    use_partial = (cfg.moe_fsdp and cfg.moe_small_t_partial
                   and n_fsdp > 1
                   and t_all * 8 < 3 * (cfg.n_experts // mesh.shape[tp_axis])
                   * cfg.moe_d_ff)

    def body(p, xb):
        tloc = xb.shape[0] * xb.shape[1]
        xt = xb.reshape(tloc, d)
        fsdp_b = fsdp
        if use_partial:
            if dp_axes:  # distinct tokens per data shard: gather them
                xt = jax.lax.all_gather(xt, fsdp_b, axis=0, tiled=True)
            out, aux = moe_local(p, xt, cfg, tp_axis=tp_axis,
                                 local_path=local_path, fsdp_axes=fsdp_b,
                                 token_gathered=True)
            out = jax.lax.psum(out, (tp_axis,) + tuple(fsdp_b))
            if dp_axes:  # slice back this shard's tokens
                ix = jax.lax.axis_index(fsdp_b)
                out = jax.lax.dynamic_slice_in_dim(out, ix * tloc, tloc, 0)
        else:
            out, aux = moe_local(p, xt, cfg, tp_axis=tp_axis,
                                 local_path=local_path, fsdp_axes=fsdp_b)
            out = jax.lax.psum(out, tp_axis)
        aux = jax.lax.pmean(aux, tp_axis)
        return out.reshape(xb.shape), aux.reshape(1)

    dp_part = dp_axes if dp_axes else None
    # check_vma=False: with B=1 decode the tokens are replicated over the
    # data axes while FSDP weight-gathers still run over them — outputs
    # are replicated by construction but the static analysis can't see it.
    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(pspec, P(dp_part, None, None)),
        out_specs=(P(dp_part, None, None), P(dp_part)),
        check_vma=False,
    )(params, x)
    return out, jnp.mean(aux)
