"""Multi-head Latent Attention (DeepSeek-V2/V3).

MLA compresses the KV stream into a small latent: per token the cache
stores only (kv_lora_rank + qk_rope_dim) values — 512 + 64 = 576 for
DeepSeek-V3 — instead of 2*H*Dh.  That is what makes the long_500k
decode shape feasible for this architecture (sub-quadratic *memory*):
524288 tokens x 576 x 2B ≈ 0.6 GB/layer before model-axis sharding.

Two computation paths:
  * train / prefill — expand the latent into per-head K_nope and V and
    run normal attention (expansion is re-materialised per block, never
    cached);
  * decode — the *absorbed* form: fold wkv_b's K-half into the query
    (q_nope @ Wk per head -> a query in latent space) and keep the
    attention-weighted sum in latent space, expanding through the
    V-half only for the single new token.  Scores and reads touch only
    the 576-wide latent cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import ParamDef, apply_rope, rms_norm
from .attention import full_causal_attention, blockwise_causal_attention, NEG_INF


def mla_defs(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    h = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": ParamDef((d, qr), P(None, None)),
        "q_a_norm": {"scale": ParamDef((qr,), P(None), "ones")},
        "wq_b": ParamDef((qr, h, dn + dr), P(None, "model", None)),
        "wkv_a": ParamDef((d, kvr + dr), P(None, None)),
        "kv_a_norm": {"scale": ParamDef((kvr,), P(None), "ones")},
        "wk_b": ParamDef((kvr, h, dn), P(None, "model", None)),
        "wv_b": ParamDef((kvr, h, dv), P(None, "model", None)),
        "wo": ParamDef((h, dv, d), P("model", None, None)),
    }


def _project_q(params, x, positions, cfg):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q_lat = jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(x.dtype))
    q_lat = rms_norm(q_lat, params["q_a_norm"]["scale"])
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(params, x, positions, cfg):
    kvr, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(x.dtype))
    c_kv, k_rope = kv[..., :kvr], kv[..., kvr:]
    c_kv = rms_norm(c_kv, params["kv_a_norm"]["scale"])
    # rope part is a single shared "head"
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_apply(
    params: Dict,
    x: jax.Array,
    positions: jax.Array,
    cfg,
    *,
    cache: Optional[Tuple] = None,  # (c_kv_cache, k_rope_cache, cur_len)
    block_q: int = 512,
    block_kv: int = 512,
    long_seq_threshold: int = 8192,
):
    d = cfg.d_model
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = (dn + dr) ** -0.5

    q_nope, q_rope = _project_q(params, x, positions, cfg)
    c_kv, k_rope = _project_kv_latent(params, x, positions, cfg)

    if cache is None:
        # expanded path (train / prefill)
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"].astype(x.dtype))
        v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"].astype(x.dtype))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      k_nope.shape[:3] + (dr,))], axis=-1)
        if x.shape[1] > long_seq_threshold:
            # pad V's head dim up to Q/K's so the fused kernel path can
            # be shared; slice the padding off afterwards.
            out = blockwise_causal_attention(
                q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv))),
                scale=scale, block_q=block_q, block_kv=block_kv)[..., :dv]
        else:
            qk_dim = dn + dr
            out = full_causal_attention(
                q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - dv))),
                scale=scale)[..., :dv]
        new_cache = (c_kv, k_rope)
    else:
        # absorbed decode path: scores/reads stay in latent space
        c_cache, r_cache, cur_len = cache
        c_cache = jax.lax.dynamic_update_slice_in_dim(c_cache, c_kv, cur_len, 1)
        r_cache = jax.lax.dynamic_update_slice_in_dim(r_cache, k_rope, cur_len, 1)
        # absorb wk_b into q:  q_lat (B, 1, H, kvr)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_b"].astype(x.dtype))
        s = (jnp.einsum("bshr,bkr->bhsk", q_lat, c_cache,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshk,bkk2->bhsk" if False else "bshr,bkr->bhsk",
                          q_rope, r_cache,
                          preferred_element_type=jnp.float32)) * scale
        valid = jnp.arange(c_cache.shape[1]) < (cur_len + 1)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        attn_lat = jnp.einsum("bhsk,bkr->bshr", p, c_cache)  # (B,1,H,kvr)
        out = jnp.einsum("bshr,rhk->bshk", attn_lat, params["wv_b"].astype(x.dtype))
        new_cache = (c_cache, r_cache)

    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, new_cache
