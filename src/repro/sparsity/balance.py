"""Costed load balancing: block-row/col permutation of the distribution.

DBCSR assigns block rows and columns to the process grid through a
*randomized* permutation precisely because structured occupancy
(banded Hamiltonians, clustered molecular blocks) otherwise lands all
the retained triples on a few ranks (arXiv:1910.04796, sec. 2).  This
module is that trick as a first-class *plan decision*: given the
operand masks (and optionally norms + ``filter_eps``), score the
per-rank retained-triple imbalance of the identity layout against
greedy-LPT and random row/col permutations, and return the best
``RebalancePlan``.  The planner (repro.planner) selects it only when
the predicted compute saved by flattening the imbalance exceeds the
permutation's amortized cost (one block-row/col shuffle of A, B and an
inverse shuffle of C).

Permutation invariants (the ROADMAP "Rank-exact execution" contract):

* Only the M side (block rows of A and C) and the N side (block cols
  of B and C) are permuted; the K side stays identity.  Permuting K
  would reorder every C block's accumulation run and change the
  floating-point result.
* With pi_k = identity, ``C = invert(permute(A) @ permute(B))`` holds
  BITWISE for schedules whose K-step order is rank-independent (SUMMA
  panels, tall-skinny) — every C element accumulates the same values
  in the same order, just on a different rank.  Cannon's K rotation
  starts at ``(i + j) % pg``, so moving a block row to another rank
  rotates its accumulation order: round-trips are allclose there, not
  bitwise.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .filter import retained_pair_presence

__all__ = [
    "RebalancePlan",
    "chunk_imbalance",
    "chunk_loads",
    "invert_permutation",
    "permute_block_cols",
    "permute_block_rows",
    "plan_rebalance",
    "retained_block_weights",
]


def retained_block_weights(
    a_mask: np.ndarray,
    b_mask: np.ndarray,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
    filter_eps: Optional[float] = None,
) -> np.ndarray:
    """Per-C-block retained-triple counts ``W[i, j]`` — the work the
    rank owning C block (i, j) performs over a full multiply (every
    schedule assigns C chunk (i, j) to rank (i, j), so C-chunk sums of
    ``W`` are the per-rank retained-flop loads the planner prices)."""
    am = np.asarray(a_mask, dtype=bool)
    bm = np.asarray(b_mask, dtype=bool)
    pres = retained_pair_presence(am, bm, a_norms, b_norms, filter_eps)
    return pres.sum(axis=1).astype(np.int64)


def chunk_loads(W: np.ndarray, pr: int, pc: int) -> np.ndarray:
    """Sum ``W`` over the contiguous (pr, pc) chunk decomposition —
    one load per rank of the process grid."""
    nbr, nbc = W.shape
    if nbr % pr or nbc % pc:
        raise ValueError(
            f"weight grid ({nbr},{nbc}) not divisible by mesh {pr}x{pc}")
    return W.reshape(pr, nbr // pr, pc, nbc // pc).sum(axis=(1, 3))


def chunk_imbalance(W: np.ndarray, pr: int, pc: int) -> float:
    """max/mean per-rank load (1.0 = perfectly balanced)."""
    if pr * pc <= 1:
        return 1.0
    loads = chunk_loads(W, pr, pc).astype(np.float64)
    mean = float(loads.mean())
    return float(loads.max()) / mean if mean > 0 else 1.0


def invert_permutation(perm: np.ndarray) -> np.ndarray:
    perm = np.asarray(perm)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm), dtype=perm.dtype)
    return inv


def permute_block_rows(x, perm: np.ndarray, block: int):
    """Reorder block rows: row block ``r`` of the result is row block
    ``perm[r]`` of the input.  Works on numpy and jax arrays."""
    nb = len(perm)
    shaped = x.reshape((nb, block) + tuple(x.shape[1:]))
    return shaped[np.asarray(perm)].reshape(x.shape)


def permute_block_cols(x, perm: np.ndarray, block: int):
    """Reorder block columns (axis 1) the same way."""
    nb = len(perm)
    shaped = x.reshape((x.shape[0], nb, block) + tuple(x.shape[2:]))
    return shaped[:, np.asarray(perm)].reshape(x.shape)


@dataclasses.dataclass(frozen=True)
class RebalancePlan:
    """A chosen block-row/col permutation and its predicted effect."""

    perm_m: np.ndarray          # block-row permutation (A and C rows)
    perm_n: np.ndarray          # block-col permutation (B and C cols)
    imbalance_before: float
    imbalance_after: float
    method: str                 # "identity" | "greedy" | "random[i]"

    @property
    def identity(self) -> bool:
        return self.method == "identity"

    @property
    def inv_m(self) -> np.ndarray:
        return invert_permutation(self.perm_m)

    @property
    def inv_n(self) -> np.ndarray:
        return invert_permutation(self.perm_n)


def _greedy_perm(weights: np.ndarray, parts: int) -> np.ndarray:
    """LPT assignment of block weights into ``parts`` equal-cardinality
    contiguous chunks: heaviest blocks first, each into the currently
    lightest chunk with a free slot."""
    nb = len(weights)
    cap = nb // parts
    order = np.argsort(weights, kind="stable")[::-1]
    loads = np.zeros(parts, dtype=np.float64)
    counts = np.zeros(parts, dtype=np.int64)
    slots: List[List[int]] = [[] for _ in range(parts)]
    for idx in order:
        open_parts = np.flatnonzero(counts < cap)
        p = open_parts[np.argmin(loads[open_parts])]
        slots[p].append(int(idx))
        loads[p] += float(weights[idx])
        counts[p] += 1
    return np.concatenate([np.asarray(s, dtype=np.int64) for s in slots])


def plan_rebalance(
    a_mask: np.ndarray,
    b_mask: np.ndarray,
    pr: int,
    pc: int,
    *,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
    filter_eps: Optional[float] = None,
    n_random: int = 8,
    seed: int = 0,
) -> RebalancePlan:
    """Pick the best of {identity, greedy LPT, ``n_random`` random}
    row/col permutations by predicted per-rank load imbalance.

    Deterministic for a given ``seed``; ties prefer the candidate
    listed first (identity, then greedy), so a uniform pattern never
    pays for a pointless shuffle.
    """
    W = retained_block_weights(a_mask, b_mask, a_norms, b_norms, filter_eps)
    nbr, nbc = W.shape
    ident_m = np.arange(nbr, dtype=np.int64)
    ident_n = np.arange(nbc, dtype=np.int64)
    base = chunk_imbalance(W, pr, pc)
    candidates: List[Tuple[float, np.ndarray, np.ndarray, str]] = [
        (base, ident_m, ident_n, "identity")]
    if pr * pc > 1 and nbr % pr == 0 and nbc % pc == 0:
        gm = _greedy_perm(W.sum(axis=1), pr) if pr > 1 else ident_m
        gn = _greedy_perm(W.sum(axis=0), pc) if pc > 1 else ident_n
        candidates.append(
            (chunk_imbalance(W[gm][:, gn], pr, pc), gm, gn, "greedy"))
        rng = np.random.RandomState(seed)
        for r in range(n_random):
            pm = rng.permutation(nbr) if pr > 1 else ident_m
            pn = rng.permutation(nbc) if pc > 1 else ident_n
            candidates.append(
                (chunk_imbalance(W[pm][:, pn], pr, pc), pm.astype(np.int64),
                 pn.astype(np.int64), f"random[{r}]"))
    best = min(candidates, key=lambda c: c[0])
    return RebalancePlan(perm_m=best[1], perm_n=best[2],
                         imbalance_before=base, imbalance_after=best[0],
                         method=best[3])
