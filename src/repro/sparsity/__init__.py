"""Norm-based on-the-fly filtering — DBCSR's block-sparse heart.

The real DBCSR is a *block-sparse* engine: every block carries a
Frobenius norm, and product contributions with
``norm(A_ik) * norm(B_kj) < eps`` are dropped before they ever reach a
multiplication stack.  This is what makes linear-scaling
electronic-structure workloads (density-matrix purification in CP2K)
feasible — the sparse regime the 2.5D companion paper (Lazzaro et al.,
arXiv:1705.10218) and the tensor follow-up (Sivkov et al.,
arXiv:1910.13555) optimize for.

    norms.py      per-block Frobenius norms (one vmap reduction per
                  block geometry) + the product norm bound
                  ``||C_ij|| <= sum_k ||A_ik|| * ||B_kj||``
    filter.py     ``filter_eps`` predicates shared by every layer:
                  retained-triple counting, the retained C support
                  (product mask), per-step emptiness under eps
    workloads.py  sparsity-evolving workloads (McWeeny purification)
    balance.py    costed load balancing: DBCSR's randomized row/col
                  permutation of the block distribution as a planner
                  decision (rank-exact execution, ISSUE 9)

The eps contract (shared with core/stacks.py, core/engine.py,
core/multiply.py, core/dbcsr.py): a triple (i, k, j) is RETAINED iff
``norm(A_ik) * norm(B_kj) >= eps`` (dropped when the product bound is
strictly below eps), so ``filter_eps=0.0`` retains everything and is
bit-identical to the mask-only path; ``filter_eps=None`` disables the
norm machinery entirely.
"""
from .norms import (block_norms_of, compute_block_norms,
                    normalize_block_norms, product_norm_bound)
from .filter import (count_retained_triples, norm_filter_stats,
                     product_mask, retained_pair_presence)
from .workloads import banded_hamiltonian, initial_density, mcweeny_purify
from .balance import (RebalancePlan, chunk_imbalance, chunk_loads,
                      plan_rebalance, retained_block_weights)

__all__ = [
    "RebalancePlan",
    "chunk_imbalance",
    "chunk_loads",
    "plan_rebalance",
    "retained_block_weights",
    "block_norms_of",
    "compute_block_norms",
    "normalize_block_norms",
    "product_norm_bound",
    "count_retained_triples",
    "norm_filter_stats",
    "product_mask",
    "retained_pair_presence",
    "banded_hamiltonian",
    "initial_density",
    "mcweeny_purify",
]
