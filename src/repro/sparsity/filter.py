"""``filter_eps`` predicates — the single source of truth for what
"retained" means, shared by every layer of the multiply path.

The contract (see the package docstring): triple (i, k, j) is retained
iff it is present under the binary occupancy masks AND its norm-product
bound clears the threshold,

    a_mask[i, k] & b_mask[k, j]  and  a_norms[i, k] * b_norms[k, j] >= eps

``eps = 0`` retains every mask-present triple (any float product is
``>= 0``), which is why the filtered path is bit-identical to the
mask-only path at eps 0.  ``eps = None`` disables norm filtering
entirely — callers that have no norms never pay for the predicate.

Everything here is host-side numpy on block-grid-sized arrays (the same
altitude as the occupancy masks); the only sizable intermediate, the
(nbr, nbk, nbc) pairwise product tensor, is chunked over k so global
grids never materialise more than ``_CHUNK`` pairwise slabs at a time.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

__all__ = ["retained_pair_presence", "count_retained_triples",
           "product_mask", "norm_filter_stats"]

# k-chunk for the pairwise (nbr, chunk, nbc) product slabs
_CHUNK = 64


def _masked_norms(am: np.ndarray, bm: np.ndarray,
                  an: np.ndarray, bn: np.ndarray):
    """Norms with mask-absent blocks forced to 0 so a single ``>= eps``
    comparison (eps > 0) folds both criteria into one; the binary masks
    are still AND-ed in separately for the eps = 0 case."""
    return (np.where(am, an.astype(np.float64), 0.0),
            np.where(bm, bn.astype(np.float64), 0.0))


def retained_pair_presence(
    am: np.ndarray, bm: np.ndarray,
    an: Optional[np.ndarray], bn: Optional[np.ndarray],
    eps: Optional[float],
) -> np.ndarray:
    """Full (nbr, nbk, nbc) retained-triple presence tensor.  Meant for
    tests and small grids; the stack generator computes the same
    predicate row-wise along its Morton traversal instead."""
    pair = am[:, :, None] & bm[None, :, :]
    if eps is None or an is None and bn is None:
        return pair
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    from .norms import normalize_block_norms

    an_, bn_ = normalize_block_norms(nbr, nbk, nbc, an, bn)
    keep = (an_.astype(np.float64)[:, :, None]
            * bn_.astype(np.float64)[None, :, :]) >= float(eps)
    return pair & keep


def count_retained_triples(
    am: np.ndarray, bm: np.ndarray,
    an: Optional[np.ndarray], bn: Optional[np.ndarray],
    eps: Optional[float],
) -> int:
    """Number of retained triples — the numerator of the norm-predicted
    occupancy the planner discounts blocked-path flops by (this replaces
    the binary mask product count when norms are available)."""
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if eps is None or (an is None and bn is None):
        return int((am.astype(np.int64) @ bm.astype(np.int64)).sum())
    from .norms import normalize_block_norms

    an_, bn_ = normalize_block_norms(nbr, nbk, nbc, an, bn)
    an_m, bn_m = _masked_norms(am, bm, an_, bn_)
    eps = float(eps)
    total = 0
    for k0 in range(0, nbk, _CHUNK):
        sl = slice(k0, min(k0 + _CHUNK, nbk))
        slab = an_m[:, sl, None] * bn_m[None, sl, :]
        keep = slab >= eps
        if eps <= 0.0:
            # eps 0 retains every MASK-present triple, including ones
            # whose norms are exactly zero — fold the masks back in
            keep &= am[:, sl, None] & bm[None, sl, :]
        total += int(np.count_nonzero(keep))
    return total


def product_mask(
    am: np.ndarray, bm: np.ndarray,
    an: Optional[np.ndarray], bn: Optional[np.ndarray],
    eps: Optional[float],
) -> np.ndarray:
    """(nbr, nbc) bool: C blocks with at least one retained triple —
    the support the filtered product actually writes.  With
    ``eps=None`` (or no norms) this is the symbolic mask product
    ``(am @ bm) > 0``; under eps it is predictable *before* executing
    (the blocked executor dispatches exactly the retained triples)."""
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    if eps is None or (an is None and bn is None):
        return (am.astype(np.int64) @ bm.astype(np.int64)) > 0
    from .norms import normalize_block_norms

    an_, bn_ = normalize_block_norms(nbr, nbk, nbc, an, bn)
    an_m, bn_m = _masked_norms(am, bm, an_, bn_)
    eps = float(eps)
    out = np.zeros((nbr, nbc), dtype=bool)
    for k0 in range(0, nbk, _CHUNK):
        sl = slice(k0, min(k0 + _CHUNK, nbk))
        slab = an_m[:, sl, None] * bn_m[None, sl, :]
        keep = slab >= eps
        if eps <= 0.0:
            keep &= am[:, sl, None] & bm[None, sl, :]
        out |= keep.any(axis=1)
    return out


def norm_filter_stats(
    am: np.ndarray, bm: np.ndarray,
    an: Optional[np.ndarray], bn: Optional[np.ndarray],
    eps: Optional[float],
    flop_per_triple: int,
) -> dict:
    """Retained-vs-filtered accounting for one (global or per-step)
    triple grid: what the filter dropped and what that saved."""
    nbr, nbk = am.shape
    nbc = bm.shape[1]
    mask_present = int((am.astype(np.int64) @ bm.astype(np.int64)).sum())
    retained = count_retained_triples(am, bm, an, bn, eps)
    return {
        "filter_eps": None if eps is None else float(eps),
        "n_dense_triples": nbr * nbk * nbc,
        "n_mask_triples": mask_present,
        "n_retained_triples": retained,
        "n_norm_filtered_triples": mask_present - retained,
        "norm_retained_fraction":
            retained / mask_present if mask_present else 1.0,
        "norm_filtered_flops": (mask_present - retained) * flop_per_triple,
    }
