"""Per-block Frobenius norms of a padded dense DBCSR payload.

DBCSR keeps a norm per block so the multiply can drop contributions
whose norm-product bound falls below ``filter_eps`` *before* they reach
a multiplication stack (on-the-fly filtering).  Our payloads are padded
dense arrays with absent blocks stored as zeros (core/dbcsr.py), so the
norms of a whole matrix are one blockwise reduction:

  * the reduction is built (and jit-traced) ONCE per block geometry —
    a vmapped per-block sum-of-squares over the ``to_blocks`` layout —
    and reused across every matrix and every call with that geometry
    (shapes retrace inside the jit cache, the Python closure does not
    rebuild),
  * the result is pulled to HOST numpy: norms are static planning
    metadata exactly like the occupancy masks — filtering decisions
    happen at stack-generation time, never inside a traced program.

``DBCSRMatrix`` caches the result as ``block_norms`` and threads it
through pytree flatten/unflatten aux data (same mechanism as
``block_mask``) so norms survive jit round-trips.

Note on the accumulation dtype: norms accumulate in float32 regardless
of payload dtype — they gate an *approximation* (eps-filtering), so
float32 magnitudes are plenty, and a fixed dtype keeps the engine's
content-fingerprint memoization stable across payload dtypes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["compute_block_norms", "block_norms_of",
           "normalize_block_norms", "product_norm_bound",
           "tensor_block_norms"]


@functools.lru_cache(maxsize=None)
def _norm_reduction(block_m: int, block_n: int):
    """The blockwise Frobenius reduction for one block geometry: built
    once, jitted once (per payload shape, via jax's own trace cache)."""

    def per_block(blk):
        b32 = blk.astype(jnp.float32)
        return jnp.sqrt(jnp.sum(b32 * b32))

    @jax.jit
    def reduce(x):
        r, c = x.shape
        nbr, nbc = r // block_m, c // block_n
        blocks = (x.reshape(nbr, block_m, nbc, block_n)
                  .transpose(0, 2, 1, 3)
                  .reshape(nbr * nbc, block_m, block_n))
        return jax.vmap(per_block)(blocks).reshape(nbr, nbc)

    return reduce


def compute_block_norms(x, block_m: int, block_n: int) -> np.ndarray:
    """(rows, cols) payload -> (nbr, nbc) float32 numpy of per-block
    Frobenius norms.  Works on sharded global arrays (the reduction is
    an ordinary jitted program; GSPMD partitions it) and host arrays
    alike; the result always lands on host because it parameterises
    host-side stack generation.
    """
    r, c = x.shape
    if r % block_m or c % block_n:
        raise ValueError(
            f"shape {x.shape} not divisible by block ({block_m},{block_n})")
    out = _norm_reduction(block_m, block_n)(jnp.asarray(x))
    return np.asarray(jax.device_get(out), dtype=np.float32)


def block_norms_of(x, block_m: int, block_n: int,
                   block_mask: Optional[np.ndarray] = None) -> np.ndarray:
    """``compute_block_norms`` with the occupancy mask applied: absent
    blocks report norm 0 even if the payload carries stray nonzeros
    (it should not — absent blocks are stored as zeros — but norms must
    never resurrect a block the mask declares absent)."""
    norms = compute_block_norms(x, block_m, block_n)
    if block_mask is not None:
        norms = np.where(np.asarray(block_mask, dtype=bool), norms,
                         np.float32(0.0)).astype(np.float32)
    return norms


@functools.lru_cache(maxsize=None)
def _norm_reduction_nd(block_sizes: Tuple[int, ...]):
    """N-d generalization of ``_norm_reduction`` for DBCSRTensor
    payloads: one vmapped sum-of-squares per N-d block geometry.  The
    2D case stays on ``_norm_reduction`` so its jit cache — and the
    engine's content fingerprints built on it — are untouched."""
    nd = len(block_sizes)

    @jax.jit
    def reduce(x):
        inter = []
        for d, bs in zip(x.shape, block_sizes):
            inter += [d // bs, bs]
        # interleaved (nb_1, bs_1, ..., nb_N, bs_N) -> block axes first
        y = x.reshape(inter).transpose(
            tuple(range(0, 2 * nd, 2)) + tuple(range(1, 2 * nd, 2)))
        nb = tuple(d // bs for d, bs in zip(x.shape, block_sizes))
        flat = y.astype(jnp.float32).reshape(nb + (-1,))
        return jnp.sqrt(jnp.sum(flat * flat, axis=-1))

    return reduce


def tensor_block_norms(
    x,
    block_sizes: Tuple[int, ...],
    block_mask: Optional[np.ndarray] = None,
) -> np.ndarray:
    """N-d payload -> ``block_grid``-shaped float32 numpy of per-block
    Frobenius norms, mask-zeroed like ``block_norms_of``.

    These norms are EXACT under matricization: the tensor unfold
    (repro.tensor.matricize) permutes elements *within* a block but a
    Frobenius norm is permutation-invariant, so the 2D views of a
    tensor lower this cache through a pure block-grid transpose+reshape
    instead of touching device data again.
    """
    block_sizes = tuple(int(b) for b in block_sizes)
    if len(block_sizes) != np.ndim(x):
        raise ValueError(
            f"block_sizes names {len(block_sizes)} axes but the payload "
            f"has {np.ndim(x)}")
    for ax, (d, bs) in enumerate(zip(np.shape(x), block_sizes)):
        if bs <= 0 or d % bs:
            raise ValueError(
                f"axis {ax}: dim {d} not divisible by block size {bs}")
    out = _norm_reduction_nd(block_sizes)(jnp.asarray(x))
    norms = np.asarray(jax.device_get(out), dtype=np.float32)
    if block_mask is not None:
        norms = np.where(np.asarray(block_mask, dtype=bool), norms,
                         np.float32(0.0)).astype(np.float32)
    return norms


def normalize_block_norms(
    nbr: int,
    nbk: int,
    nbc: int,
    a_norms: Optional[np.ndarray] = None,
    b_norms: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Canonical norm normalization, mirroring
    ``stacks.normalize_block_masks``: ``None`` means unit-norm blocks
    (the filter then degrades to thresholding the known side alone),
    anything else must be a float-coercible array of exactly the block
    grid shape."""
    an = (np.ones((nbr, nbk), dtype=np.float32) if a_norms is None
          else np.asarray(a_norms, dtype=np.float32))
    bn = (np.ones((nbk, nbc), dtype=np.float32) if b_norms is None
          else np.asarray(b_norms, dtype=np.float32))
    if an.shape != (nbr, nbk):
        raise ValueError(
            f"a_norms shape {an.shape} != block grid {(nbr, nbk)}")
    if bn.shape != (nbk, nbc):
        raise ValueError(
            f"b_norms shape {bn.shape} != block grid {(nbk, nbc)}")
    return an, bn


def product_norm_bound(a_norms: np.ndarray,
                       b_norms: np.ndarray) -> np.ndarray:
    """(nbr, nbc) upper bound on the product's block norms:
    ``||C_ij||_F <= sum_k ||A_ik||_F * ||B_kj||_F`` (submultiplicativity
    + triangle inequality).  This is what makes the post-multiply mask
    predictable *before* executing: any C block whose bound is below
    eps is guaranteed filtered."""
    an = np.asarray(a_norms, dtype=np.float64)
    bn = np.asarray(b_norms, dtype=np.float64)
    return an @ bn
