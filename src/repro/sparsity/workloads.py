"""Sparsity-evolving workloads: density-matrix purification.

This is THE workload norm-based filtering exists for (CP2K's
linear-scaling SCF, the driver behind DBCSR): McWeeny purification
iterates

    P  <-  3 P^2 - 2 P^3

from an initial guess built by scaling a (banded, gapped) Hamiltonian
into [0, 1].  Every iterate is a pair of block-sparse multiplies whose
*operands' sparsity evolves*: squaring spreads the band, convergence
toward the spectral projector drives spurious far-band weight to zero,
and ``filter_eps`` prunes it — occupancy rises for an iteration or
two, then decays monotonically toward the converged density's support.
This exercises the whole subsystem at once: per-iteration norms, the
eps-filtered stack plans, empty-step skipping, the planner's
norm-predicted occupancy, and the post-multiply ``filter()`` pass.

All helpers are host-side constructors plus a driver that runs the
iteration through ``dbcsr.multiply(filter_eps=...)`` on a mesh; see
examples/purification.py for the end-to-end run and
benchmarks/bench_filter.py for the traced benchmark.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro import obs

__all__ = ["banded_hamiltonian", "initial_density", "mcweeny_purify"]


def banded_hamiltonian(
    n: int,
    block_size: int,
    *,
    half_bandwidth: int = 4,
    gap: float = 2.0,
    coupling: float = 0.3,
    decay: float = 0.4,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """A gapped block-banded "insulator" Hamiltonian (H, block_mask).

    Orbitals alternate between an occupied level (-gap/2, even global
    index) and a virtual level (+gap/2, odd); block distance d in
    [1, half_bandwidth] carries symmetric random coupling of Frobenius
    norm ``coupling * decay**(d-1)`` that only connects SAME-parity
    orbitals (occupied-occupied / virtual-virtual — the couplings
    commute with the occupation structure, like a Hamiltonian expressed
    in a molecular-orbital-aligned basis).  Gershgorin keeps the two
    level clusters separated as long as the total coupling radius stays
    below gap/2, so the exact density matrix theta(-H) is EXACTLY the
    diagonal parity projector: every off-diagonal block of the
    purification iterate lives in the quadratically-annihilated
    (occ-occ / virt-virt) sectors and decays below any ``filter_eps``
    as the iteration converges.  The result is the canonical
    purification trace: occupancy rises for an iteration or two (the
    band spreads through P^2 / P^3), then decays monotonically to the
    diagonal.
    """
    if n % block_size:
        raise ValueError(f"n={n} not divisible by block_size={block_size}")
    if block_size % 2:
        raise ValueError("block_size must be even (parity structure)")
    nb = n // block_size
    rng = np.random.RandomState(seed)
    H = np.zeros((n, n), dtype=np.float64)
    # alternating two-level diagonal: eigenvalues cluster at +-gap/2
    levels = np.where(np.arange(n) % 2 == 0, -gap / 2.0, gap / 2.0)
    H[np.diag_indices(n)] = levels
    # same-parity entries of a block at any distance: (r + c) even
    # within the block, since global parity == local parity (bs even)
    parity = ((np.arange(block_size)[:, None]
               + np.arange(block_size)[None, :]) % 2) == 0
    mask = np.eye(nb, dtype=bool)
    for d in range(1, min(half_bandwidth, nb - 1) + 1):
        scale = coupling * decay ** (d - 1)
        for i in range(nb - d):
            blk = rng.randn(block_size, block_size) * parity
            blk *= scale / max(np.linalg.norm(blk), 1e-300)
            r = slice(i * block_size, (i + 1) * block_size)
            c = slice((i + d) * block_size, (i + d + 1) * block_size)
            H[r, c] = blk
            H[c, r] = blk.T  # keep H symmetric
            mask[i, i + d] = mask[i + d, i] = True
    return H, mask


def initial_density(H: np.ndarray, mu: float = 0.0) -> np.ndarray:
    """McWeeny's linear initial guess: map H's spectrum into [0, 1]
    with occupied states (eigenvalues below ``mu``) above 1/2,

        P0 = 1/2 I - (H - mu I) / (2 lambda),

    where ``lambda`` bounds the spectral radius of ``H - mu I``
    (Gershgorin discs — no eigensolve).  Purification then drives every
    eigenvalue to 0 or 1, i.e. P0 -> the density matrix theta(mu - H).
    """
    n = H.shape[0]
    radii = np.abs(H).sum(axis=1) - np.abs(np.diag(H))
    diag = np.diag(H)
    lam = max(float(np.max(diag + radii - mu)),
              float(np.max(mu - (diag - radii))), 1e-12)
    return 0.5 * np.eye(n) - (H - mu * np.eye(n)) / (2.0 * lam)


def mcweeny_purify(
    P0,
    *,
    mesh,
    n_iter: int = 10,
    filter_eps: Optional[float] = 1e-6,
    multiply_kw: Optional[dict] = None,
) -> Tuple[object, List[dict]]:
    """Run ``n_iter`` McWeeny iterations of ``P <- 3 P^2 - 2 P^3``
    entirely through ``dbcsr.multiply(filter_eps=...)``.

    ``P0`` is a DBCSRMatrix (repro.core.dbcsr.create of
    ``initial_density``'s output, with the Hamiltonian's band mask).
    Each iteration performs two filtered multiplies (P^2 = P @ P and
    P^3 = P^2 @ P), combines them with add/scale, and applies the
    post-multiply ``filter(eps)`` pass (re-deriving the mask from the
    fresh iterate's actual block norms — DBCSR's behaviour in CP2K).

    Returns ``(P, trace)`` where ``trace`` has one dict per iteration:
    ``occupancy`` (retained-block fraction after filtering),
    ``n_retained_triples`` / ``n_norm_filtered_triples`` (summed over
    the two multiplies, when the blocked path executed),
    ``retained_flops`` / ``filtered_flops``, ``idempotency`` (the
    Frobenius norm ||P^2 - P||, the convergence measure) and
    ``trace_P`` (electron-count conservation).
    """
    from repro.core import dbcsr

    kw = dict(multiply_kw or {})
    P = P0
    trace = []
    for it in range(n_iter):
        P2, plan2 = dbcsr.multiply(P, P, mesh=mesh, filter_eps=filter_eps,
                                   return_plan=True, **kw)
        P3, plan3 = dbcsr.multiply(P2, P, mesh=mesh, filter_eps=filter_eps,
                                   return_plan=True, **kw)
        Pn = dbcsr.add(P2.scale(3.0), P3.scale(-2.0))
        if filter_eps is not None:
            Pn = Pn.filter(filter_eps)

        idem = float(np.linalg.norm(np.asarray(P2.data, dtype=np.float64)
                                    - np.asarray(P.data, dtype=np.float64)))
        entry = {
            "iteration": it,
            "occupancy": Pn.occupancy,
            "n_blocks": (int(Pn.block_mask.sum())
                         if Pn.block_mask is not None
                         else Pn.layout.nblocks),
            "idempotency": idem,
            "trace_P": float(Pn.trace()),
        }
        retained = filtered = busiest = 0
        flop = 2 * (P.layout.block_rows * P.layout.block_cols
                    * P.layout.block_cols)
        have_stats = False
        rank_imbs = []
        for plan in (plan2, plan3):
            st = getattr(plan, "executor_stats", None)
            if st:
                have_stats = True
                retained += st.get("n_entries", 0)
                filtered += st.get("n_norm_filtered_triples", 0)
                # rank-exact runs: the busiest rank's own executed
                # triples (== n_entries on union/collapsed plans)
                busiest += st.get("max_rank_entries",
                                  st.get("n_entries", 0))
                if st.get("rank_imbalance") is not None:
                    rank_imbs.append(st["rank_imbalance"])
        if have_stats:
            entry["n_retained_triples"] = retained
            entry["n_norm_filtered_triples"] = filtered
            entry["retained_flops"] = retained * flop
            entry["filtered_flops"] = filtered * flop
            entry["max_rank_entries"] = busiest
            if rank_imbs:
                entry["rank_imbalance"] = max(rank_imbs)
        if obs.enabled():
            # the canonical sparsity-evolution signal as gauge samples:
            # occupancy rises for a step or two, then decays to the
            # converged support (gauge history renders the curve)
            obs.gauge("purification.occupancy").set(entry["occupancy"])
            obs.gauge("purification.idempotency").set(entry["idempotency"])
        trace.append(entry)
        P = Pn
    return P, trace
