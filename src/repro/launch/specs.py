"""Input specs (ShapeDtypeStruct stand-ins) and step functions for every
(architecture x shape) dry-run cell — weak-type-correct, shardable, no
device allocation."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, get_config
from repro.models import transformer as T
from repro.serve import engine
from repro.train.optimizer import OptConfig, make_optimizer
from repro.train import train_step as TS

__all__ = ["cell_is_supported", "build_cell", "input_specs"]


def cell_is_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention architecture: 500k-token KV is "
                       "quadratic-memory-infeasible; skipped per DESIGN.md §4")
    return True, ""


def opt_for(cfg: ModelConfig) -> OptConfig:
    # fp32 Adam state for >100B-param models does not fit v5e HBM —
    # use factored Adafactor there (DESIGN.md §6).
    big = cfg.name.startswith("deepseek")
    return OptConfig(name="adafactor" if big else "adamw", zero=not big)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs of the step-function *data* arguments."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "embeddings":
            inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                          getattr(jnp, cfg.dtype))
        else:
            inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape.kind == "train":
            return {"inputs": inputs,
                    "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        return {"inputs": inputs}
    # decode: one new token with a KV cache of seq_len
    state, tokens = engine.serve_input_specs(cfg, batch=b, kv_len=s)
    return {"state": state, "tokens": tokens}


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Split the per-device batch so checkpointed layer inputs stay
    under ~4 GiB: n_layers x (B_loc/micro) x S x d x 2B <= 4 GiB."""
    n_dp = 1
    for a in ("pod", "data"):
        n_dp *= mesh.shape.get(a, 1)
    b_loc = max(shape.global_batch // n_dp, 1)
    ckpt_bytes = cfg.num_layers * b_loc * shape.seq_len * cfg.d_model * 2
    if cfg.sequence_parallel:
        ckpt_bytes //= mesh.shape.get("model", 1)
    micro = 1
    budget = 4 * 2**30
    while ckpt_bytes / micro > budget and micro < b_loc:
        micro *= 2
    if cfg.moe:
        # the dispatch/combine tensors materialise (T_loc * top_k, d)
        # per MoE layer — bound them to ~2 GiB per microbatch
        disp = b_loc * shape.seq_len * cfg.top_k * cfg.d_model * 2
        while disp / micro > 2 * 2**30 and micro < b_loc:
            micro *= 2
    return micro


def build_cell(arch: str, shape_name: str, mesh, *,
               n_microbatches: int = 0, cfg=None):
    """Returns (step_fn, args_specs, in_shardings, out_shardings, meta)
    ready for jit(...).lower(*args_specs)."""
    if cfg is None:
        cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} x {shape_name} unsupported: {why}")
    ns = lambda spec: NamedSharding(mesh, spec)

    if shape.kind == "train":
        if n_microbatches == 0:
            n_microbatches = default_microbatches(cfg, shape, mesh)
        opt = make_optimizer(opt_for(cfg))
        p_sh, o_sh, b_sh = TS.shardings_for(cfg, mesh, opt)
        grad_sh = o_sh.get("m") if opt.cfg.name == "adamw" else None
        step = TS.make_train_step(cfg, mesh, opt,
                                  n_microbatches=n_microbatches,
                                  grad_shardings=grad_sh)
        params = T.model_param_shapes(cfg)
        pspecs = T.model_param_specs(cfg)
        pshapes = T.model_param_shapes(cfg)
        ospecs = opt.state_specs(pspecs, pshapes, mesh=mesh)
        opt_state = _opt_state_shapes(opt, params)
        batch = input_specs(cfg, shape)
        args = (params, opt_state, batch)
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = (p_sh, o_sh, None)
        donate = (0, 1)
        meta = {"kind": "train", "cfg": cfg, "shape": shape,
                "n_microbatches": n_microbatches}
        return step, args, in_sh, out_sh, donate, meta

    if shape.kind == "prefill":
        from repro.serve.prefill import prefill_step

        def step(params, inputs):
            return prefill_step(params, inputs, cfg, mesh)

        pspecs = T.model_param_specs(cfg, mesh)
        p_sh = jax.tree_util.tree_map(ns, pspecs,
                                      is_leaf=lambda x: isinstance(x, P))
        params = T.model_param_shapes(cfg)
        batch = input_specs(cfg, shape)
        dp = T.dp_axes(mesh)
        in_spec = (P(dp, None, None) if cfg.input_mode == "embeddings"
                   else P(dp, None))
        args = (params, batch["inputs"])
        in_sh = (p_sh, ns(in_spec))
        out_sh = None
        meta = {"kind": "prefill", "cfg": cfg, "shape": shape}
        return step, args, in_sh, out_sh, (), meta

    # decode
    def step(params, state, tokens):
        return engine.decode_step(params, state, tokens, cfg, mesh)

    pspecs = T.model_param_specs(cfg, mesh)
    p_sh = jax.tree_util.tree_map(ns, pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    params = T.model_param_shapes(cfg)
    sp = input_specs(cfg, shape)
    state_sh, tok_sh = engine.decode_shardings(cfg, mesh, batch=shape.global_batch,
                                               kv_len=shape.seq_len)
    args = (params, sp["state"], sp["tokens"])
    in_sh = (p_sh, state_sh, tok_sh)
    # next_tokens is always (B, 1) int32 (even for embedding-stub archs)
    dp_out = T.dp_axes(mesh)
    n_dp = 1
    for a in dp_out:
        n_dp *= mesh.shape[a]
    if shape.global_batch % max(n_dp, 1) != 0:
        dp_out = ()
    out_sh = (ns(P(dp_out, None)), state_sh)
    donate = (1,)
    meta = {"kind": "decode", "cfg": cfg, "shape": shape}
    return step, args, in_sh, out_sh, donate, meta


def _opt_state_shapes(opt, param_shapes_tree):
    """eval_shape the optimizer init over ShapeDtypeStructs."""
    return jax.eval_shape(opt.init, param_shapes_tree)
