"""Static analysis of compiled (SPMD-partitioned) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts each while-loop
body ONCE — a scan over 61 layers reports 1/61 of the real FLOPs — and
reports nothing about collectives.  This module parses
``compiled.as_text()`` into a call graph, multiplies loop bodies by
their trip counts (parsed from the loop-condition constants; scans
lower to `lt(iv, const)` conditions), and accumulates three roofline
inputs per device:

  * dot/convolution FLOPs,
  * approximate HBM traffic (operand + result bytes of every op at
    fusion boundaries — fusion internals stay in registers/VMEM),
  * collective bytes by kind (ring-model cost: all-reduce counts 2x its
    payload, gather/scatter/permute/all-to-all 1x), with ICI hop
    weighting left to the roofline layer.

All shapes in the partitioned module are already per-device shards, so
totals are per-device numbers — exactly what the roofline terms need.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCosts"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops that do not touch HBM on their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "custom-call", "iota", "get-dimension-size",
    "bitcast-convert", "opt-barrier",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s(.*)$")


def _parse_op_line(line: str):
    """'%name = TYPE opcode(...)' -> (name, type_str, opcode, rest).

    TYPE may be a tuple type containing nested parens and /*index=N*/
    comments, so it is extracted with paren matching, not a regex.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    if rhs.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        type_str, rest = rhs[:end], rhs[end:]
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp:]
    om = re.match(r"\s*([\w\-]+)(?:\.\d+)?\(", rest)
    if not om:
        return None
    opcode = om.group(1)
    # strip trailing .N numeric suffixes some opcodes carry
    opcode = re.sub(r"\.\d+$", "", opcode)
    return name, type_str, opcode, rest


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'(f32[2,3], bf16[4])' or 'f32[2,3]{1,0}' -> [(dtype, shape), ...]"""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",") if x) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, shape in _parse_shapes(type_str):
        total += _DTYPE_BYTES[dt] * math.prod(shape) if shape else _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    type_str: str
    line: str      # rhs after the type (opcode + operands + attrs) —
                   # operand parens are the FIRST parens here, unlike the
                   # full line where a tuple TYPE may come first


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    symtab: Dict[str, str]          # op name -> type string


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    unknown_trip_loops: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_count": dict(self.collective_count),
            "total_collective_bytes": self.total_collective_bytes,
            "unknown_trip_loops": self.unknown_trip_loops,
        }


def _split_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{$",
                     stripped)
        if m and not line.startswith(" "):
            cur = _Computation(m.group(1), [], {})
            comps[cur.name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, type_str, opcode, rest = parsed
            cur.ops.append(_Op(name, opcode, type_str, rest))
            cur.symtab[name] = type_str
    return comps


def _operand_names(line: str) -> List[str]:
    """Operand references of the op call: text inside the outermost (...)."""
    start = line.find("(")
    if start < 0:
        return []
    depth = 0
    end = start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = line[start + 1 : end]
    return re.findall(r"%([\w.\-]+)", inner)


def _attr(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _group_size(line: str, n_partitions: int) -> int:
    """Parse replica_groups=[G,S]<=[...] -> S (participants per group)."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:  # explicit group list: {{0,1,2,3},{...}}
        return len(m.group(1).split(","))
    return n_partitions


def _dot_flops(op: _Op, symtab: Dict[str, str]) -> float:
    res_shapes = _parse_shapes(op.type_str)
    if not res_shapes:
        return 0.0
    _, res_shape = res_shapes[0]
    operands = _operand_names(op.line)
    if not operands:
        return 0.0
    lhs_type = symtab.get(operands[0])
    if lhs_type is None:
        return 0.0
    lhs_shapes = _parse_shapes(lhs_type)
    if not lhs_shapes:
        return 0.0
    _, lhs_shape = lhs_shapes[0]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            contract *= lhs_shape[int(d)]
    return 2.0 * math.prod(res_shape) * contract


def _conv_flops(op: _Op, symtab: Dict[str, str]) -> float:
    # rough: 2 * out_elems * (kernel spatial * in_features)
    res = _parse_shapes(op.type_str)
    operands = _operand_names(op.line)
    if not res or len(operands) < 2:
        return 0.0
    rhs_type = symtab.get(operands[1])
    if rhs_type is None:
        return 0.0
    rhs = _parse_shapes(rhs_type)
    if not rhs:
        return 0.0
    kernel_elems = math.prod(rhs[0][1]) if rhs[0][1] else 1
    out_elems = math.prod(res[0][1]) if res[0][1] else 1
    # kernel includes out-features; divide it out if possible
    return 2.0 * out_elems * max(kernel_elems, 1)


def _fusion_bytes(op: _Op, comps: Dict[str, "_Computation"]) -> int:
    """HBM bytes of a fusion op: outputs + per-parameter reads, where a
    parameter consumed ONLY by (dynamic-)slice/gather ops inside the
    fusion is charged at the slice sizes, not the full operand (remat'd
    blockwise attention reads K/V through in-fusion dynamic-slices —
    charging full operands overcounts by ~100x)."""
    total = _nbytes(op.type_str)
    callee_name = _attr(op.line, "calls")
    callee = comps.get(callee_name) if callee_name else None
    if callee is None:
        return -1  # caller falls back to naive accounting
    for pop in callee.ops:
        if pop.opcode != "parameter":
            continue
        psize = _nbytes(pop.type_str)
        uses = [o for o in callee.ops
                if o.name != pop.name and pop.name in _operand_names(o.line)]
        if uses and all(u.opcode in ("dynamic-slice", "slice", "gather")
                        for u in uses):
            total += sum(_nbytes(u.type_str) for u in uses)
        else:
            total += psize
    return total


def _producer_op(comp: "_Computation", name: str):
    for o in comp.ops:
        if o.name == name:
            return o
    return None


def _is_pure_convert(op: "_Op", comps) -> bool:
    if op.opcode == "convert":
        return True
    if op.opcode != "fusion":
        return False
    callee = _attr(op.line, "calls")
    cal = comps.get(callee)
    if cal is None:
        return False
    return all(o.opcode in ("parameter", "convert", "bitcast", "copy",
                            "transpose", "reshape")
               for o in cal.ops)


def _convert_src_bytes(op: "_Op", comp: "_Computation", comps):
    """Byte size of the convert's source operand (type via symtab)."""
    ops_ = _operand_names(op.line)
    if not ops_:
        return None
    t = comp.symtab.get(ops_[0])
    return _nbytes(t) if t else None


def _trip_count(cond: _Computation,
                comps: Dict[str, "_Computation"],
                depth: int = 0) -> Optional[int]:
    """Scan conditions lower to compare(iv, constant): take the largest
    integer constant in the condition computation.  The compare often
    lives inside a wrapped fusion — follow calls= / to_apply= refs."""
    best = None
    for op in cond.ops:
        m = re.search(r"constant\((\d+)\)", op.line)
        if m:
            v = int(m.group(1))
            if best is None or v > best:
                best = v
        if depth < 2:
            for key in ("calls", "to_apply"):
                ref = _attr(op.line, key)
                if ref and ref in comps:
                    v = _trip_count(comps[ref], comps, depth + 1)
                    if v is not None and (best is None or v > best):
                        best = v
    return best


def analyze_hlo(text: str, *, n_partitions: Optional[int] = None,
                trip_overrides: Optional[Dict[str, int]] = None) -> HloCosts:
    """Walk the module call graph from ENTRY, scaling while bodies by
    their trip counts.  Returns per-device HloCosts."""
    if n_partitions is None:
        m = re.search(r"num_partitions=(\d+)", text)
        n_partitions = int(m.group(1)) if m else 1
    comps = _split_computations(text)

    # computations referenced as fusion bodies / reducers: not walked
    entry_name = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if m:
        entry_name = m.group(1)
    else:  # fall back: computation named like the module entry
        for name in comps:
            if name.startswith("main"):
                entry_name = name
    if entry_name is None or entry_name not in comps:
        raise ValueError("could not locate ENTRY computation")

    memo: Dict[str, HloCosts] = {}

    def visit(name: str) -> HloCosts:
        if name in memo:
            return memo[name]
        comp = comps[name]
        costs = HloCosts()
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                body = _attr(op.line, "body")
                cond = _attr(op.line, "condition")
                trips = None
                if trip_overrides and body in trip_overrides:
                    trips = trip_overrides[body]
                elif cond in comps:
                    trips = _trip_count(comps[cond], comps)
                if trips is None:
                    trips = 1
                    costs.unknown_trip_loops += 1
                sub = visit(body) if body in comps else HloCosts()
                condc = visit(cond) if cond in comps else HloCosts()
                costs.flops += trips * (sub.flops + condc.flops)
                costs.hbm_bytes += trips * (sub.hbm_bytes + condc.hbm_bytes)
                for k, v in sub.collective_bytes.items():
                    costs.collective_bytes[k] += trips * v
                for k, v in sub.collective_count.items():
                    costs.collective_count[k] += trips * v
                costs.unknown_trip_loops += sub.unknown_trip_loops
                continue
            if oc in ("call", "conditional"):
                # count every referenced computation once (conservative)
                for ref in re.findall(
                        r"(?:to_apply|branch_computations=\{)([^,}\s]+)",
                        op.line):
                    ref = ref.strip("%")
                    if ref in comps:
                        sub = visit(ref)
                        costs.flops += sub.flops
                        costs.hbm_bytes += sub.hbm_bytes
                        for k, v in sub.collective_bytes.items():
                            costs.collective_bytes[k] += v
                continue

            is_collective = None
            for c in _COLLECTIVES:
                if oc == c or oc == c + "-start":
                    is_collective = c
                    break
            if is_collective:
                size = _nbytes(op.type_str)
                # CPU lowering hoists bf16->f32 converts in front of
                # dots AND the collectives feeding them; on TPU the
                # payload stays bf16.  Count at source width when the
                # operand is produced by a pure-convert fusion.
                ops_ = _operand_names(op.line)
                if ops_:
                    prod = _producer_op(comp, ops_[0])
                    if prod is not None and _is_pure_convert(prod, comps):
                        src = _convert_src_bytes(prod, comp, comps)
                        if src and src < size:
                            size = src
                g = _group_size(op.line, n_partitions)
                if g <= 1:
                    continue
                if is_collective == "all-reduce":
                    moved = 2.0 * size * (g - 1) / g
                elif is_collective == "reduce-scatter":
                    moved = size * (g - 1)  # result is the scattered shard
                elif is_collective == "all-gather":
                    moved = size * (g - 1) / g  # result is gathered shape
                elif is_collective == "all-to-all":
                    moved = size * (g - 1) / g
                else:  # collective-permute: one send per device
                    moved = size
                costs.collective_bytes[is_collective] += moved
                costs.collective_count[is_collective] += 1
                costs.hbm_bytes += 2.0 * size  # read + write locally
                continue

            if oc.endswith("-done") or oc in _FREE_OPS:
                continue

            if oc == "dot":
                costs.flops += _dot_flops(op, comp.symtab)
            elif oc == "convolution":
                costs.flops += _conv_flops(op, comp.symtab)

            # HBM traffic: result + operand bytes at fusion boundaries.
            # Sliced-access ops only touch the slice, not the operand:
            if oc in ("dynamic-slice", "gather"):
                costs.hbm_bytes += 2 * _nbytes(op.type_str)
            elif oc in ("dynamic-update-slice", "scatter"):
                ops_ = _operand_names(op.line)
                upd = (comp.symtab.get(ops_[1])
                       if len(ops_) > 1 else None)
                costs.hbm_bytes += 2 * (_nbytes(upd) if upd
                                        else _nbytes(op.type_str))
            elif oc == "fusion":
                size = _fusion_bytes(op, comps)
                if size < 0:
                    size = _nbytes(op.type_str)
                    for operand in _operand_names(op.line):
                        t = comp.symtab.get(operand)
                        if t is not None:
                            size += _nbytes(t)
                costs.hbm_bytes += size
            else:
                size = _nbytes(op.type_str)
                for operand in _operand_names(op.line):
                    t = comp.symtab.get(operand)
                    if t is not None:
                        size += _nbytes(t)
                costs.hbm_bytes += size
        memo[name] = costs
        return costs

    return visit(entry_name)
