"""Roofline terms from dry-run artifacts (TPU v5e constants in mesh.HW).

  compute term    = HLO_dot_FLOPs_per_device / peak_FLOP/s
  memory term     = HBM_bytes_per_device    / HBM_bw
  collective term = collective_bytes_per_device / ICI_link_bw

(the HLO analyzer works on the SPMD-partitioned module, so its numbers
are already per-device; chips therefore do NOT divide again here).

MODEL_FLOPS uses the standard 6*N*D (train) / 2*N*D (inference) with
N = active parameter count — the useful-flops numerator that exposes
remat/redundancy waste when compared against compiled HLO FLOPs.
"""
from __future__ import annotations

import math
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["roofline_terms", "model_flops", "param_count"]


def roofline_terms(costs, hw: Dict) -> Dict:
    compute_s = costs.flops / hw["peak_flops_bf16"]
    memory_s = costs.hbm_bytes / hw["hbm_bw"]
    collective_s = costs.total_collective_bytes / hw["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, collective_s)
    terms["dominant"] = {"compute_s": "compute", "memory_s": "memory",
                         "collective_s": "collective"}[dominant]
    # fraction of the bound step time that is useful MXU work
    terms["roofline_fraction"] = compute_s / bound if bound > 0 else 0.0
    return terms


def param_count(cfg: ModelConfig, *, active_only: bool = False) -> int:
    """Analytic parameter count (embedding + per-layer, by layer kind)."""
    d, v = cfg.d_model, cfg.vocab_size
    dh = cfg.resolved_head_dim
    h, hkv = cfg.num_heads, cfg.num_kv_heads

    def attn_params():
        return d * dh * (h + 2 * hkv) + h * dh * d

    def mla_params():
        qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
        dn, dr, dv_ = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        return (d * qr + qr * h * (dn + dr) + d * (kvr + dr)
                + kvr * h * dn + kvr * h * dv_ + h * dv_ * d)

    def mamba_params():
        d_in = cfg.mamba_expand * d
        dt_rank = max(1, d // 16)
        n = cfg.mamba_d_state
        return (d * 2 * d_in + cfg.mamba_conv * d_in
                + d_in * (dt_rank + 2 * n) + dt_rank * d_in
                + d_in * n + 2 * d_in + d_in * d)

    def rwkv_params():
        hs = cfg.rwkv_head_size
        nh = d // hs
        tm = (5 * d + d * 5 * 32 + 5 * 32 * d + d + d * 64 + 64 * d
              + nh * hs + 4 * d * d + 2 * d + d * d)
        cm = 2 * d + d * cfg.d_ff + d * d + cfg.d_ff * d
        return tm + cm

    def dense_ffn(f):
        return d * f * (3 if cfg.glu else 2)

    def moe_ffn(active):
        e = (cfg.top_k if active else cfg.n_experts)
        p = e * 3 * d * cfg.moe_d_ff + d * cfg.n_experts
        p += cfg.n_shared_experts * 3 * d * cfg.moe_d_ff
        return p

    total = v * d + (0 if cfg.tie_embeddings else d * v)
    for l in range(cfg.num_layers):
        mix, ff = cfg.layer_kind(l)
        total += {"attention": attn_params, "mla": mla_params,
                  "mamba": mamba_params, "rwkv6": rwkv_params}[mix]()
        if ff == "dense":
            total += dense_ffn(cfg.d_ff)
        elif ff == "moe":
            total += moe_ffn(active_only)
        total += 2 * d  # norms
    return int(total)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global MODEL_FLOPS for one step of this cell: 6*N_active*D for
    training, 2*N_active*D for inference (D = tokens processed)."""
    n_active = param_count(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1   # decode: one token per sequence
    return 2.0 * n_active * tokens
