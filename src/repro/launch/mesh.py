"""Production meshes.

Target hardware: TPU v5e pods — 256 chips per pod in a 16x16 ICI
torus; the multi-pod configuration stacks 2 pods (512 chips) with the
'pod' axis crossing the inter-pod links.

Axis roles:
  data  — batch / sequence sharding (DP); also the DBCSR engine's grid
          rows.
  model — TP / EP / vocab sharding; the DBCSR engine's grid columns.
  pod   — outer data parallelism for LM training; the 2.5D replication
          (stack) axis for the DBCSR engine (cannon25d).

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

from repro.compat import AxisType, make_mesh as _make_mesh_compat

__all__ = ["make_production_mesh", "make_mesh", "HW"]


# TPU v5e per-chip hardware constants (roofline denominators)
HW = {
    "name": "tpu_v5e",
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh_compat(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh with Auto axis types (tests, reduced configs)."""
    return _make_mesh_compat(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes))
