import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -----------------------------------
import argparse       # noqa: E402
import json           # noqa: E402
import math           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402
from repro.compat import set_mesh

from repro.configs.base import ARCHS, SHAPES, get_config    # noqa: E402
from repro.launch.mesh import make_production_mesh, HW      # noqa: E402
from repro.launch.specs import build_cell, cell_is_supported # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo            # noqa: E402
from repro.launch.roofline import roofline_terms, model_flops # noqa: E402

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, print memory/cost analysis, and persist
the roofline inputs to artifacts/dryrun/.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out artifacts/dryrun

A cell *passes* when .lower().compile() succeeds; bytes-per-device,
FLOPs and the collective schedule land in the JSON artifact that
EXPERIMENTS.md §Dry-run / §Roofline read."""


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, save_hlo: bool = False,
             overrides: dict | None = None, tag: str = "",
             micro: int = 0) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch)
    if overrides:
        import dataclasses as _dc
        # config overrides for §Perf A/B runs, e.g. remat=full
        typed = {}
        for k, v in overrides.items():
            fld = {f.name: f for f in _dc.fields(cfg)}[k]
            typed[k] = (fld.type in ("int", int) and int(v)) or                        (v in ("True", "False") and v == "True") or v
            if fld.type in ("int", int):
                typed[k] = int(v)
            elif str(fld.type) in ("bool", "<class 'bool'>"):
                typed[k] = v in (True, "True", "true", "1")
        cfg = _dc.replace(cfg, **typed)
    shape = SHAPES[shape_name]
    ok, why = cell_is_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "skipped", "why": why}
    if not ok:
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    step, args, in_sh, out_sh, donate, meta = build_cell(
        arch, shape_name, mesh, cfg=cfg, n_microbatches=micro)
    jit_kwargs = dict(in_shardings=in_sh, donate_argnums=donate)
    if out_sh is not None:
        jit_kwargs["out_shardings"] = out_sh
    with set_mesh(mesh):
        lowered = jax.jit(step, **jit_kwargs).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    print(f"[{arch} x {shape_name} x {mesh_name}] memory_analysis:", ma)
    ca = compiled.cost_analysis() or {}
    print(f"[{arch} x {shape_name} x {mesh_name}] cost_analysis flops:",
          ca.get("flops"), "bytes:", ca.get("bytes accessed"))

    hlo_text = compiled.as_text()
    costs = analyze_hlo(hlo_text)
    n_chips = math.prod(mesh.devices.shape)
    terms = roofline_terms(costs, hw=HW)
    mf = model_flops(cfg, shape)

    rec.update({
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device_bytes": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        },
        "xla_cost_analysis": {"flops": ca.get("flops"),
                              "bytes_accessed": ca.get("bytes accessed")},
        "hlo_costs": costs.to_dict(),
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_flop_ratio": (mf / n_chips) / max(costs.flops, 1.0),
    })
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_name}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if save_hlo:
        with open(path.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo_text)
    print(f"[{arch} x {shape_name} x {mesh_name}] OK  "
          f"compile={t_compile:.1f}s  "
          f"peak/dev={rec['memory']['peak_per_device_bytes']/2**30:.2f}GiB  "
          f"terms(ms): C={terms['compute_s']*1e3:.2f} "
          f"M={terms['memory_s']*1e3:.2f} X={terms['collective_s']*1e3:.2f} "
          f"dominant={terms['dominant']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override k=v (repeatable) — §Perf A/B")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--micro", type=int, default=0,
                    help="override train microbatch count (0 = heuristic)")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.override)

    archs = ARCHS if args.arch == "all" else [args.arch.replace("-", "_")]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape, multi_pod=mp,
                                            out_dir=args.out,
                                            save_hlo=args.save_hlo,
                                            overrides=overrides,
                                            tag=args.tag,
                                            micro=args.micro))
                except Exception:
                    failures += 1
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "multi" if mp else "single",
                                    "status": "FAILED"})
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped "
          f"(documented), {failures} FAILED ==")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
