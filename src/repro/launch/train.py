"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b \
        --steps 20 --reduced --mesh 2x2

On real hardware the same entry point drives the full configs over the
production mesh (launch/mesh.py); on this CPU container ``--reduced``
runs the same code path at smoke scale.  Fault tolerance is on by
default: periodic checkpoints, automatic restore, straggler watchdog.
"""
from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mesh", default="2x2",
                    help="'RxC' data x model, 'PxRxC' with pod axis, or "
                         "'production' / 'production-multipod'")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--device-count", type=int, default=0,
                    help="host device override (0 = real devices)")
    args = ap.parse_args()

    if args.device_count:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.device_count}")

    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config, reduced_config
    from repro.launch.mesh import make_production_mesh, make_mesh
    from repro.models import transformer as T
    from repro.train.data import make_batch
    from repro.train.elastic import StragglerWatchdog, run_loop
    from repro.train.optimizer import OptConfig, make_optimizer
    from repro.train.train_step import make_train_step, shardings_for
    from repro.compat import set_mesh

    if args.mesh == "production":
        mesh = make_production_mesh(multi_pod=False)
    elif args.mesh == "production-multipod":
        mesh = make_production_mesh(multi_pod=True)
    else:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh(dims, axes)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} steps={args.steps}")

    opt = make_optimizer(OptConfig(name=args.optimizer, lr=args.lr))
    p_sh, o_sh, b_sh = shardings_for(cfg, mesh, opt)

    params = T.model_init(cfg, jax.random.PRNGKey(0))
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt.init(params), o_sh)
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    step_fn = jax.jit(
        make_train_step(cfg, mesh, opt, n_microbatches=args.microbatches),
        in_shardings=(p_sh, o_sh, b_sh), donate_argnums=(0, 1))

    def mb(step):
        b = make_batch(step, global_batch=args.global_batch,
                       seq_len=args.seq, vocab=cfg.vocab_size,
                       input_mode=cfg.input_mode, d_model=cfg.d_model)
        return jax.device_put({k: jnp.asarray(v) for k, v in b.items()}, b_sh)

    watchdog = StragglerWatchdog()
    with set_mesh(mesh):
        result = run_loop(
            train_step=step_fn, make_batch=mb, params=params,
            opt_state=opt_state, n_steps=args.steps,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            watchdog=watchdog)
    hist = result["history"]
    print(f"done: {len(hist)} steps, restarts={result['restarts']}, "
          f"stragglers={result['stragglers']}")
    if hist:
        print(f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
