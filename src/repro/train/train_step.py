"""Train-step factory: loss -> grad -> clip -> optimizer, with optional
microbatch gradient accumulation, all under explicit shardings."""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from .optimizer import Optimizer, OptConfig, make_optimizer

__all__ = ["make_train_step", "batch_specs", "TrainState"]


def batch_specs(cfg, mesh=None):
    dp = ("pod", "data")
    if mesh is not None:
        dp = tuple(a for a in dp if a in mesh.shape)
    if cfg.input_mode == "embeddings":
        return {"inputs": P(dp, None, None), "labels": P(dp, None)}
    return {"inputs": P(dp, None), "labels": P(dp, None)}


def make_train_step(cfg, mesh, opt: Optimizer, *, n_microbatches: int = 1,
                    grad_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  With n_microbatches > 1 the global batch is split along
    the batch axis and gradients accumulate through a lax.scan —
    per-microbatch activation memory, one optimizer step.
    """

    def loss_fn(params, batch):
        return T.lm_loss(params, batch, cfg, mesh)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def constrain(grads):
        # ZeRO: accumulate/consume grads in the optimizer-state sharding
        # (dp-sharded); GSPMD then reduce-scatters the DP grad sum and
        # all-gathers params once after the update.
        if grad_shardings is None:
            return grads
        return jax.lax.with_sharding_constraint(grads, grad_shardings)

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            loss, metrics, grads = grads_of(params, batch)
            grads = constrain(grads)
        else:
            def reshape(x):
                b = x.shape[0]
                mb = b // n_microbatches
                return x.reshape((n_microbatches, mb) + x.shape[1:])

            micro = jax.tree_util.tree_map(reshape, batch)
            zero_g = constrain(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))

            def acc_step(carry, mb):
                g_acc, loss_acc = carry
                loss, _metrics, g = grads_of(params, mb)
                g = constrain(jax.tree_util.tree_map(
                    lambda x: x.astype(jnp.float32), g))
                g_acc = jax.tree_util.tree_map(lambda a, b_: a + b_, g_acc, g)
                return (constrain(g_acc), loss_acc + loss), None

            (grads, loss), _ = jax.lax.scan(
                acc_step, (zero_g, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(
                lambda g: g / n_microbatches, grads)
            loss = loss / n_microbatches
            metrics = {"nll": loss}

        new_params, new_opt_state, opt_metrics = opt.update(
            grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_opt_state, metrics

    return train_step


def shardings_for(cfg, mesh, opt: Optimizer):
    """(param_shardings, opt_shardings, batch_shardings) NamedShardings."""
    pspecs = T.model_param_specs(cfg, mesh)
    pshapes = T.model_param_shapes(cfg)
    ospecs = opt.state_specs(pspecs, pshapes, mesh=mesh)
    ns = lambda spec: NamedSharding(mesh, spec)
    return (
        jax.tree_util.tree_map(ns, pspecs, is_leaf=lambda x: isinstance(x, P)),
        jax.tree_util.tree_map(ns, ospecs, is_leaf=lambda x: isinstance(x, P)),
        jax.tree_util.tree_map(ns, batch_specs(cfg, mesh),
                               is_leaf=lambda x: isinstance(x, P)),
    )
