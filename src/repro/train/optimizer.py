"""Sharded optimizers: AdamW and Adafactor, with ZeRO-style state sharding.

Optimizer state inherits each parameter's PartitionSpec (TP sharding);
with ``zero=True`` the first unsharded dimension of every state tensor
is additionally sharded over the data axes (ZeRO-1) — at DeepSeek-V3
scale fp32 Adam state cannot live TP-sharded-only (see DESIGN.md §6).
Adafactor's factored second moment is the other lever: ~6 bytes/param
instead of 14.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["make_optimizer", "zero_shard_specs"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"           # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay: float = 0.8
    min_dim_factored: int = 128
    zero: bool = False            # shard optimizer state over data axes


def _clip_by_global_norm(grads, max_norm):
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _adamw_update(grads, state, params, cfg: OptConfig):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, p):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), m, v

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    flat_p = jax.tree_util.tree_leaves(params)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm_, nv_ = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm_)
        new_v.append(nv_)
    unf = functools.partial(jax.tree_util.tree_unflatten, tdef)
    return unf(new_p), {"m": unf(new_m), "v": unf(new_v), "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment)
# ---------------------------------------------------------------------------


def _factored(shape, min_dim) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def _adafactor_init(params, cfg: OptConfig):
    def init_v(p):
        if _factored(p.shape, cfg.min_dim_factored):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "v": jax.tree_util.tree_map(init_v, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _adafactor_update(grads, state, params, cfg: OptConfig):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay)

    def upd(g, v, p):
        g2 = jnp.square(g) + 1e-30
        if _factored(p.shape, cfg.min_dim_factored):
            vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = (vr / jnp.mean(vr, axis=-1, keepdims=True))[..., None] \
                * vc[..., None, :]
            pre = g * jax.lax.rsqrt(denom + 1e-30)
            new_v = {"vr": vr, "vc": vc}
        else:
            nv = beta2 * v["v"] + (1 - beta2) * g2
            pre = g * jax.lax.rsqrt(nv + 1e-30)
            new_v = {"v": nv}
        # update clipping (Adafactor's d=1.0 RMS rule)
        rms = jnp.sqrt(jnp.mean(jnp.square(pre)) + 1e-30)
        pre = pre / jnp.maximum(1.0, rms)
        delta = pre
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), new_v

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_v = tdef.flatten_up_to(state["v"])
    flat_p = jax.tree_util.tree_leaves(params)
    new_p, new_v = [], []
    for g, v, p in zip(flat_g, flat_v, flat_p):
        np_, nv_ = upd(g, v, p)
        new_p.append(np_)
        new_v.append(nv_)
    return (jax.tree_util.tree_unflatten(tdef, new_p),
            {"v": jax.tree_util.tree_unflatten(tdef, new_v), "step": step})


# ---------------------------------------------------------------------------
# public factory
# ---------------------------------------------------------------------------


class Optimizer(NamedTuple):
    init: Callable
    update: Callable          # (grads, state, params) -> (params, state)
    state_specs: Callable     # (param_specs) -> state spec pytree
    cfg: OptConfig


def zero_shard_specs(spec_tree, dp_axes=("pod", "data"), mesh=None):
    """ZeRO-1: shard the first replicated dim of each state over data axes.

    Only applied when the dimension is divisible by the dp extent (the
    caller passes the mesh); otherwise the spec is left unchanged.
    """
    def f(spec, leaf):
        if mesh is None:
            return spec
        n_dp = 1
        for a in dp_axes:
            n_dp *= mesh.shape.get(a, 1)
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (sp, dim) in enumerate(zip(parts, leaf.shape)):
            if sp is None and dim % n_dp == 0 and dim >= n_dp:
                parts[i] = tuple(a for a in dp_axes if a in mesh.shape)
                return P(*parts)
        return spec
    return f


def make_optimizer(cfg: OptConfig = OptConfig()) -> Optimizer:
    if cfg.name == "adamw":
        def init(params):
            return _adamw_init(params)

        def update(grads, state, params):
            grads, gnorm = _clip_by_global_norm(grads, cfg.grad_clip)
            new_params, new_state = _adamw_update(grads, state, params, cfg)
            return new_params, new_state, {"grad_norm": gnorm}

        def state_specs(param_specs, params_shapes, mesh=None):
            sp = param_specs
            if cfg.zero and mesh is not None:
                zf = zero_shard_specs(sp, mesh=mesh)
                sp = jax.tree_util.tree_map(
                    zf, param_specs, params_shapes,
                    is_leaf=lambda x: isinstance(x, P))
            return {"m": sp, "v": sp, "step": P()}

        return Optimizer(init, update, state_specs, cfg)

    if cfg.name == "adafactor":
        def init(params):
            return _adafactor_init(params, cfg)

        def update(grads, state, params):
            grads, gnorm = _clip_by_global_norm(grads, cfg.grad_clip)
            new_params, new_state = _adafactor_update(grads, state, params, cfg)
            return new_params, new_state, {"grad_norm": gnorm}

        def state_specs(param_specs, params_shapes, mesh=None):
            def f(spec, shape):
                if _factored(shape.shape, cfg.min_dim_factored):
                    parts = list(spec) + [None] * (len(shape.shape) - len(spec))
                    return {"vr": P(*parts[:-1]),
                            "vc": P(*(parts[:-2] + parts[-1:]))}
                return {"v": spec}
            v = jax.tree_util.tree_map(
                f, param_specs, params_shapes,
                is_leaf=lambda x: isinstance(x, P))
            return {"v": v, "step": P()}

        return Optimizer(init, update, state_specs, cfg)

    raise ValueError(cfg.name)
