"""Synthetic deterministic data pipeline.

Streams are a pure function of (step, position) so every restart —
including an elastic restart on a different device count — reproduces
the identical token sequence: the property checkpoint/restart tests
assert on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "make_batch"]


def _mix(a: np.ndarray, b: int) -> np.ndarray:
    # splitmix-style integer hash, vectorised
    x = (a.astype(np.uint64) + np.uint64(b) * np.uint64(0x9E3779B97F4A7C15))
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


def make_batch(step: int, *, global_batch: int, seq_len: int, vocab: int,
               input_mode: str = "tokens", d_model: int = 0) -> Dict:
    """Deterministic batch for ``step`` (host-side numpy)."""
    idx = np.arange(global_batch * (seq_len + 1), dtype=np.uint64)
    toks = (_mix(idx, step + 1) % np.uint64(max(vocab - 1, 1))).astype(np.int32)
    toks = toks.reshape(global_batch, seq_len + 1)
    inputs, labels = toks[:, :-1], toks[:, 1:]
    if input_mode == "embeddings":
        # stub modality frontend: hash -> gaussian-ish embeddings
        flat = _mix(np.arange(global_batch * seq_len, dtype=np.uint64),
                    step + 7919)
        u = (flat % np.uint64(10_000)).astype(np.float32) / 5000.0 - 1.0
        emb = np.tile(u.reshape(global_batch, seq_len, 1), (1, 1, d_model))
        scale = 1.0 / np.sqrt(np.arange(1, d_model + 1, dtype=np.float32))
        return {"inputs": (emb * scale).astype(np.float32),
                "labels": labels.copy()}
    return {"inputs": inputs.copy(), "labels": labels.copy()}


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    input_mode: str = "tokens"
    d_model: int = 0
    start_step: int = 0

    def __iter__(self) -> Iterator[Dict]:
        step = self.start_step
        while True:
            yield make_batch(step, global_batch=self.global_batch,
                             seq_len=self.seq_len, vocab=self.vocab,
                             input_mode=self.input_mode, d_model=self.d_model)
            step += 1
