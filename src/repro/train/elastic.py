"""Fault tolerance & elasticity: restart-from-checkpoint, failure
injection, straggler watchdog, elastic re-mesh.

At 1000+ node scale the failure model is: a node dies (collective
hangs / jax runtime error), the job restarts on the surviving set, and
training resumes from the last checkpoint — possibly on a different
device count.  The pieces here implement that loop in-process:

  * ``run_loop`` — the supervised training loop: catches step failures,
    restores the last checkpoint, and continues; deterministic data
    (train/data.py) makes the recovery bit-reproducible.
  * ``FailureInjector`` — raises at configurable steps (tests use it to
    prove recovery works).
  * ``StragglerWatchdog`` — EMA step-time monitor; in a synchronous-
    collective design a straggler shows up as a slow *step*, and the
    mitigation at fleet level is eviction + elastic re-mesh, which maps
    here to triggering a checkpoint + re-mesh callback.
  * elastic re-mesh itself is restore_checkpoint with the new mesh's
    shardings (see tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

from . import checkpoint as ckpt

__all__ = ["FailureInjector", "StragglerWatchdog", "run_loop"]


class FailureInjector:
    """Raises RuntimeError at the given steps (once each)."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.remove(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than ``threshold`` x the EMA step time."""

    threshold: float = 3.0
    alpha: float = 0.1
    ema: Optional[float] = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        straggler = self.ema is not None and dt > self.threshold * self.ema
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        if straggler:
            self.flagged += 1
        return straggler


def run_loop(
    *,
    train_step: Callable,        # (params, opt_state, batch) -> (p, o, metrics)
    make_batch: Callable,        # step -> batch (deterministic)
    params: Any,
    opt_state: Any,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    failure_injector: Optional[FailureInjector] = None,
    watchdog: Optional[StragglerWatchdog] = None,
    max_restarts: int = 10,
) -> Dict:
    """Supervised training loop with checkpoint/restart recovery."""
    state = {"params": params, "opt": opt_state}
    step = 0
    restarts = 0
    last = ckpt.latest_step(ckpt_dir)
    if last is not None:
        state = ckpt.restore_checkpoint(ckpt_dir, last, state)
        step = last

    history = []
    while step < n_steps:
        try:
            if failure_injector is not None:
                failure_injector.check(step)
            t0 = time.perf_counter()
            batch = make_batch(step)
            p, o, metrics = train_step(state["params"], state["opt"], batch)
            state = {"params": p, "opt": o}
            dt = time.perf_counter() - t0
            if watchdog is not None:
                watchdog.observe(dt)
            history.append({"step": step,
                            "loss": float(metrics["loss"]), "dt": dt})
            step += 1
            if step % ckpt_every == 0:
                ckpt.save_checkpoint(ckpt_dir, step, state)
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            last = ckpt.latest_step(ckpt_dir)
            if last is None:
                step = 0  # restart from scratch
                continue
            state = ckpt.restore_checkpoint(ckpt_dir, last, state)
            step = last
    return {"history": history, "restarts": restarts,
            "final_state": state,
            "stragglers": watchdog.flagged if watchdog else 0}
