"""Checkpointing: sharded-aware save/restore with manifest, rotation,
and elastic re-shard on restore.

Layout:  <dir>/step_<N>/manifest.json + one .npy per leaf (keyed by the
flattened tree path).  Restore accepts a *different* mesh / sharding
than the one that saved — arrays are loaded to host and re-placed with
the target sharding, which is the elastic-rescale path (checkpoint on
512 chips, resume on 256, or CPU).

On a real multi-host deployment each host writes only the shards it
owns (jax.experimental.multihost_utils / distributed arrays); the
single-process container collapses that to full-array writes, but the
manifest format and restore path are host-count-agnostic.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, state: Any,
                    *, keep_last: int = 3) -> str:
    """Write state pytree at <directory>/step_<step>. Atomic via rename."""
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    manifest = {"step": step, "leaves": []}
    for path, leaf in leaves:
        key = _leaf_key(path)
        fname = key.replace("/", "__") + ".npy"
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "key": key, "file": fname,
            "shape": list(arr.shape), "dtype": str(arr.dtype),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _rotate(directory, keep_last)
    return final


def _rotate(directory: str, keep_last: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``target`` (pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure) re-places leaves
    — pass shardings built from a *different* mesh to rescale."""
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        if shardings is not None else [None] * len(paths))
    out = []
    for (path, leaf), sh in zip(paths, shard_leaves):
        key = _leaf_key(path)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(d, by_key[key]["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target {leaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Periodic save with optional async (background-thread) writes."""

    def __init__(self, directory: str, every: int = 100,
                 keep_last: int = 3, async_save: bool = True):
        self.directory = directory
        self.every = every
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, state: Any) -> bool:
        if step % self.every:
            return False
        self.wait()
        # device_get in the caller's thread for a consistent snapshot
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state)
        if self.async_save:
            self._thread = threading.Thread(
                target=save_checkpoint,
                args=(self.directory, step, host_state),
                kwargs={"keep_last": self.keep_last}, daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.directory, step, host_state,
                            keep_last=self.keep_last)
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
