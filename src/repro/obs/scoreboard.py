"""Planner scoreboard: predicted-vs-actual cost per executed plan.

Each :func:`repro.obs.record_plan_outcome` row carries the cost
model's ``predicted_s`` and the measured dispatch ``measured_s`` for
one executed multiply.  The scoreboard aggregates them per algorithm
into absolute and *signed* relative error

    rel_err = (predicted_s - measured_s) / measured_s

(positive = the model overpredicts, negative = underpredicts), which
is what ``planner.calibrate --check-drift`` thresholds on: a cost
model whose median |rel_err| drifts past ~1x no longer ranks
candidates reliably on this machine and needs recalibration.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

__all__ = ["planner_scoreboard", "render_scoreboard", "check_drift"]


def _median(vals: Sequence[float]) -> float:
    if not vals:
        return float("nan")
    s = sorted(vals)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def planner_scoreboard(records: Sequence[dict]) -> Dict[str, dict]:
    """Aggregate plan-outcome rows into per-algorithm error stats.

    Rows must carry ``algorithm``, ``predicted_s`` and ``measured_s``;
    rows with non-positive measurements are skipped (a plan whose
    dispatch never ran carries no signal).

    Rows are grouped by their root-span KIND, not just by algorithm:
    plain ``multiply``/``multiply_batched`` rows keep the bare
    algorithm as their group key (the schema ``calibrate
    --check-drift`` has always thresholded on), while other roots —
    e.g. ``contract`` rows, whose end-to-end measurement includes the
    unfold/refold copies their plan also prices — group under
    ``"<kind>:<algorithm>"`` so their different cost structure never
    pollutes the 2D algorithms' drift statistics.
    """
    by_algo: Dict[str, List[dict]] = {}
    for r in records:
        algo = r.get("algorithm")
        pred = r.get("predicted_s")
        meas = r.get("measured_s")
        if not algo or pred is None or meas is None:
            continue
        kind = r.get("kind")
        if kind not in (None, "multiply", "multiply_batched"):
            algo = f"{kind}:{algo}"
        pred, meas = float(pred), float(meas)
        if meas <= 0.0 or not math.isfinite(pred) or not math.isfinite(meas):
            continue
        by_algo.setdefault(str(algo), []).append(
            {"predicted_s": pred, "measured_s": meas,
             "abs_err_s": abs(pred - meas),
             "rel_err": (pred - meas) / meas})
    out: Dict[str, dict] = {}
    for algo, rows in sorted(by_algo.items()):
        rel = [r["rel_err"] for r in rows]
        out[algo] = {
            "n": len(rows),
            "predicted_total_s": sum(r["predicted_s"] for r in rows),
            "measured_total_s": sum(r["measured_s"] for r in rows),
            "abs_err_median_s": _median([r["abs_err_s"] for r in rows]),
            "rel_err_median": _median(rel),
            "rel_err_mean": sum(rel) / len(rel),
            "abs_rel_err_median": _median([abs(e) for e in rel]),
        }
    return out


def render_scoreboard(sb: Dict[str, dict]) -> str:
    """Fixed-width table of the per-algorithm scoreboard."""
    if not sb:
        return "planner scoreboard: no recorded plan outcomes"
    lines = [
        f"{'algorithm':<16} {'n':>4} {'predicted':>11} {'measured':>11} "
        f"{'abs err med':>11} {'rel err med':>11}",
    ]
    for algo, row in sb.items():
        lines.append(
            f"{algo:<16} {row['n']:>4} "
            f"{row['predicted_total_s']*1e3:>9.2f}ms "
            f"{row['measured_total_s']*1e3:>9.2f}ms "
            f"{row['abs_err_median_s']*1e3:>9.3f}ms "
            f"{row['rel_err_median']:>+10.1%}")
    return "\n".join(lines)


def check_drift(records: Sequence[dict], *, threshold: float = 1.0,
                min_samples: int = 1) -> dict:
    """Flag algorithms whose median |relative error| exceeds
    ``threshold``.  Returns ``{"ok", "flagged", "scoreboard",
    "threshold"}``; algorithms with fewer than ``min_samples``
    outcomes are reported but never flagged (not enough signal)."""
    sb = planner_scoreboard(records)
    flagged = {}
    for algo, row in sb.items():
        err = row["abs_rel_err_median"]
        if row["n"] >= min_samples and err > threshold:
            flagged[algo] = err
    return {"ok": not flagged, "flagged": flagged, "scoreboard": sb,
            "threshold": threshold}
