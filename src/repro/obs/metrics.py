"""Process-wide metrics registry: counters, gauges, histograms.

One source of truth for every number the library already reports
through ad-hoc ``stats()`` dicts — plan-cache hits, service
retries/degradations, ABFT detections, fused-vs-looped dispatch
decisions, request latency percentiles.  Publishers call
``counter(name, **labels).inc()`` etc.; the legacy ``stats()`` views
read the same objects back so callers keep their old dict shapes.

Metrics are keyed on ``(kind, name, sorted(labels))`` so the same
name may carry different label sets (e.g. one counter per
``MultiplyService`` instance via ``service=<name>``).

This module deliberately imports nothing from ``repro.core`` or
``repro.planner`` (they import us), and nothing heavyweight: the
registry itself must stay cheap enough that merely *existing* costs
nothing on the disabled path.  Like the rest of the library it is
single-threaded by design — no locks.
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "registry", "counter", "gauge", "histogram", "metrics_snapshot",
    "clear_metrics",
]

LabelsKey = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Dict[str, object]) -> LabelsKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing count (events, bytes, flops)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: LabelsKey):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name!r}: negative inc {v}")
        self.value += v


class Gauge:
    """Last-set value, with a bounded sample history so callers can
    render decay curves (e.g. purification occupancy per iteration)."""

    __slots__ = ("name", "labels", "value", "samples", "max_samples")

    def __init__(self, name: str, labels: LabelsKey, max_samples: int = 4096):
        self.name = name
        self.labels = labels
        self.value = 0.0
        self.samples: List[float] = []
        self.max_samples = max_samples

    def set(self, v: float) -> None:
        self.value = float(v)
        self.samples.append(self.value)
        if len(self.samples) > self.max_samples:
            del self.samples[: len(self.samples) - self.max_samples]


class Histogram:
    """Stored-sample histogram with exact percentiles.

    Sample counts here are small (per-request latencies, per-plan
    occupancies), so we keep raw values rather than buckets; the
    percentile math matches ``np.percentile(..., interpolation=
    'linear')`` so the service's legacy p50/p99 stay bit-identical.
    """

    __slots__ = ("name", "labels", "values", "max_samples", "_n_dropped")

    def __init__(self, name: str, labels: LabelsKey,
                 max_samples: int = 65536):
        self.name = name
        self.labels = labels
        self.values: List[float] = []
        self.max_samples = max_samples
        self._n_dropped = 0

    def observe(self, v: float) -> None:
        if len(self.values) >= self.max_samples:
            self._n_dropped += 1
            return
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values) + self._n_dropped

    @property
    def sum(self) -> float:
        return float(sum(self.values))

    def percentile(self, p: float) -> float:
        """Linear-interpolation percentile (numpy-compatible)."""
        if not self.values:
            return 0.0
        vals = sorted(self.values)
        if len(vals) == 1:
            return vals[0]
        rank = (p / 100.0) * (len(vals) - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, len(vals) - 1)
        frac = rank - lo
        return vals[lo] * (1.0 - frac) + vals[hi] * frac


class MetricsRegistry:
    """Keyed store of Counter/Gauge/Histogram instances.

    ``counter()``/``gauge()``/``histogram()`` are get-or-create: the
    first call mints the metric, later calls return the same object,
    so publishers never need registration boilerplate.
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, str, LabelsKey], object] = {}

    def _get(self, kind: str, cls, name: str, labels: Dict[str, object],
             **kw):
        key = (kind, name, _labels_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, key[2], **kw)
            self._metrics[key] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", Histogram, name, labels)

    def __len__(self) -> int:
        return len(self._metrics)

    def __contains__(self, name: str) -> bool:
        return any(k[1] == name for k in self._metrics)

    def clear(self) -> None:
        self._metrics.clear()

    def snapshot(self) -> Dict[str, dict]:
        """JSON-ready dump: ``{kind: {"name{a=b}": summary}}``."""
        out: Dict[str, dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for (kind, name, labels), m in sorted(self._metrics.items()):
            label_s = ",".join(f"{k}={v}" for k, v in labels)
            full = f"{name}{{{label_s}}}" if label_s else name
            if kind == "counter":
                out["counters"][full] = m.value
            elif kind == "gauge":
                out["gauges"][full] = {"value": m.value,
                                       "samples": list(m.samples)}
            else:
                out["histograms"][full] = {
                    "count": m.count, "sum": m.sum,
                    "p50": m.percentile(50), "p99": m.percentile(99),
                }
        return out


# the process-wide registry every publisher shares
REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return REGISTRY


def counter(name: str, **labels) -> Counter:
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return REGISTRY.histogram(name, **labels)


def metrics_snapshot() -> Dict[str, dict]:
    return REGISTRY.snapshot()


def clear_metrics() -> None:
    REGISTRY.clear()
