"""repro.obs — unified telemetry: spans, metrics, traces, scoreboard.

The observability layer for the multiply pipeline (ISSUE 8):

  spans      ``span("multiply")`` nesting plan -> dispatch ->
             schedule-step -> comm/stacks, plus verify -> repair, with
             comm-bytes/flops/occupancy attributes from the existing
             schedule and executor metadata (telemetry.py)
  metrics    process-wide registry of counters/gauges/histograms that
             the legacy ``stats()`` dicts are thin views over
             (metrics.py)
  exporters  Chrome-trace/Perfetto JSON per multiply, JSONL event log,
             and ``python -m repro.obs report`` (export.py, report.py)
  scoreboard predicted-vs-actual planner cost per executed algorithm,
             consumed by ``planner.calibrate --check-drift``
             (scoreboard.py)

Contract (mirrors PR 7's ``verify=None``): telemetry is OFF by
default, and when off the multiply paths are bit-identical and add
zero registry entries — instrumented call sites check one local bool
and skip all timing/span work.  Explicit publishers (service counters,
``plan_cache_stats()``) use the registry as their storage even when
tracing is off; that is their data living in one place, not overhead.

Typical use::

    from repro import obs
    obs.enable(log_dir="artifacts/obs")
    c, plan = dbcsr.multiply(a, b, mesh=mesh, return_plan=True)
    obs.write_chrome_trace("artifacts/obs/trace.json", obs.last_trace())
    print(obs.render_scoreboard(
        obs.planner_scoreboard(obs.plan_outcomes())))

This package imports nothing from ``repro.core``/``repro.planner``
(they import us) and no jax — it is safe at any layer.
"""
from .telemetry import (  # noqa: F401
    SpanRecord, Tracer, NOOP_SPAN, enable, disable, enabled, get_tracer,
    span, maybe_span, event, last_trace, record_plan_outcome,
    plan_outcomes, clear_plan_outcomes, EVENTS_LOG, PLAN_OUTCOMES_LOG,
)
from .metrics import (  # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, REGISTRY, registry,
    counter, gauge, histogram, metrics_snapshot, clear_metrics,
)
from .export import (  # noqa: F401
    to_chrome_trace, write_chrome_trace, validate_chrome_trace,
    write_jsonl, read_jsonl,
)
from .scoreboard import (  # noqa: F401
    planner_scoreboard, render_scoreboard, check_drift,
)
from .report import (  # noqa: F401
    category_breakdown, render_breakdown, render_timeline,
)

__all__ = [
    "SpanRecord", "Tracer", "NOOP_SPAN", "enable", "disable", "enabled",
    "get_tracer", "span", "maybe_span", "event", "last_trace",
    "record_plan_outcome", "plan_outcomes", "clear_plan_outcomes",
    "EVENTS_LOG", "PLAN_OUTCOMES_LOG",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "registry", "counter", "gauge", "histogram", "metrics_snapshot",
    "clear_metrics",
    "to_chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "write_jsonl", "read_jsonl",
    "planner_scoreboard", "render_scoreboard", "check_drift",
    "category_breakdown", "render_breakdown", "render_timeline",
]
