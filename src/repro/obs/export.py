"""Exporters: Chrome-trace/Perfetto JSON timelines and JSONL logs.

``to_chrome_trace`` maps :class:`SpanRecord` rows onto the Chrome
Trace Event Format (complete events, ``ph: "X"``) that both
``chrome://tracing`` and https://ui.perfetto.dev render: ``ts``/``dur``
in microseconds, rebased so the earliest span starts at 0, one ``tid``
lane per trace (i.e. per multiply) so concurrent service requests
stack into separate rows.  Span attrs ride along in ``args`` together
with ``span_id``/``parent_id`` so the nesting survives the round trip.

``validate_chrome_trace`` is the schema check the CI bench gates on:
shape, required fields, and parent/child interval containment.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

from .telemetry import SpanRecord

__all__ = [
    "to_chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "write_jsonl", "read_jsonl",
]

_US = 1e6  # seconds -> microseconds


def to_chrome_trace(spans: Sequence[SpanRecord], *,
                    process_name: str = "repro") -> dict:
    """Build a Chrome-trace dict from span records."""
    spans = [s for s in spans if s.dur >= 0.0]
    if not spans:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t_base = min(s.t0 for s in spans)
    tids = {}
    events: List[dict] = [{
        "ph": "M", "pid": 0, "tid": 0, "name": "process_name",
        "args": {"name": process_name},
    }]
    for s in sorted(spans, key=lambda s: (s.trace_id, s.t0, s.span_id)):
        tid = tids.setdefault(s.trace_id, len(tids))
        args: Dict[str, object] = {"span_id": s.span_id}
        if s.parent_id is not None:
            args["parent_id"] = s.parent_id
        for k, v in s.attrs.items():
            args[k] = v if isinstance(v, (int, float, str, bool,
                                          type(None))) else str(v)
        events.append({
            "ph": "X", "pid": 0, "tid": tid,
            "name": s.name, "cat": s.cat,
            "ts": (s.t0 - t_base) * _US, "dur": s.dur * _US,
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: Sequence[SpanRecord], *,
                       process_name: str = "repro") -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(to_chrome_trace(spans, process_name=process_name), f)
    return path


def validate_chrome_trace(obj: object) -> List[str]:
    """Schema check; returns a list of errors (empty == valid).

    Checks the Trace Event Format invariants the viewers rely on plus
    our own: complete events carry name/cat/ts/dur/pid/tid, times are
    finite and non-negative, ``args.parent_id`` references an existing
    span on the same lane, and every child interval is contained in
    its parent's (1 us slack for float rounding).
    """
    errs: List[str] = []
    if not isinstance(obj, dict):
        return ["trace is not a JSON object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    if not any(isinstance(e, dict) and e.get("ph") == "X" for e in events):
        errs.append("no complete ('X') events")
    by_id: Dict[object, dict] = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errs.append(f"event[{i}] is not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "M", "i", "I"):
            errs.append(f"event[{i}] has unsupported ph={ph!r}")
            continue
        if ph != "X":
            continue
        for field in ("name", "ts", "dur", "pid", "tid"):
            if field not in e:
                errs.append(f"event[{i}] missing {field!r}")
        name = e.get("name")
        if not isinstance(name, str) or not name:
            errs.append(f"event[{i}] name must be a non-empty string")
        for field in ("ts", "dur"):
            v = e.get(field)
            if not isinstance(v, (int, float)) or v != v or v < 0:
                errs.append(f"event[{i}] {field} must be a finite "
                            f"non-negative number, got {v!r}")
        args = e.get("args", {})
        if not isinstance(args, dict):
            errs.append(f"event[{i}] args must be an object")
            continue
        sid = args.get("span_id")
        if sid is not None:
            by_id[(e.get("tid"), sid)] = e
    # nesting: child interval inside parent's, on the same lane
    slack = 1.0  # us
    for (tid, sid), e in by_id.items():
        pid_ = e.get("args", {}).get("parent_id")
        if pid_ is None:
            continue
        parent = by_id.get((tid, pid_))
        if parent is None:
            errs.append(f"span {sid} references missing parent {pid_}")
            continue
        if e["ts"] < parent["ts"] - slack:
            errs.append(f"span {sid} starts before parent {pid_}")
        if (e["ts"] + e["dur"]) > (parent["ts"] + parent["dur"]) + slack:
            errs.append(f"span {sid} ends after parent {pid_}")
    return errs


def write_jsonl(path: str, rows: Sequence[dict], *, mode: str = "a") -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, mode) as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    return path


def read_jsonl(path: str) -> List[dict]:
    rows: List[dict] = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows
