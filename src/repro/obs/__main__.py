"""CLI entry: ``python -m repro.obs report [--dir ...] [--timeline]``."""
import sys

from . import report


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] != "report":
        print("usage: python -m repro.obs report [--dir DIR] [--timeline]",
              file=sys.stderr)
        return 2
    return report.main(argv[1:])


if __name__ == "__main__":
    raise SystemExit(main())
