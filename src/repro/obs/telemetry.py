"""Structured spans and the predicted-vs-actual plan-outcome log.

A :class:`Tracer` records :class:`SpanRecord` rows — host-side timed
intervals with parent/child nesting — for the multiply pipeline:

    multiply                       (root, one per dbcsr.multiply)
      plan                         planner decision
      dispatch                     device execution (block_until_ready)
        prologue / step[t] / epilogue   schedule model, scaled to fit
          comm, stacks                  the measured dispatch wall time
      verify                       ABFT checksum verification
        repair                     re-execution after a detection
          dispatch ...

Telemetry is OFF by default and the contract is *zero overhead, bit
identical results* when off: instrumented call sites test a local
``_tele`` flag (``obs.enabled()`` and not under ``jax.jit`` tracing)
once per call and skip every span/timing/``block_until_ready`` when it
is false.  ``span()`` returns a shared no-op object when disabled, so
stray call sites cost one attribute check.

``enable(log_dir=...)`` additionally appends every completed trace to
``<log_dir>/events.jsonl`` and every plan outcome (predicted vs
measured cost per executed plan) to ``<log_dir>/plan_outcomes.jsonl``
— the file ``planner.calibrate --check-drift`` consumes.

This module must not import jax or anything from ``repro.core`` /
``repro.planner`` (they import us).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import time
from typing import Dict, List, Optional

__all__ = [
    "SpanRecord", "Tracer", "enable", "disable", "enabled",
    "get_tracer", "span", "maybe_span", "event", "last_trace",
    "record_plan_outcome", "plan_outcomes", "clear_plan_outcomes",
    "EVENTS_LOG", "PLAN_OUTCOMES_LOG",
]

EVENTS_LOG = "events.jsonl"
PLAN_OUTCOMES_LOG = "plan_outcomes.jsonl"


@dataclasses.dataclass
class SpanRecord:
    """One timed interval.  ``t0`` is ``time.perf_counter()`` seconds;
    ``dur`` is seconds (synthetic schedule-step spans get explicit
    ``t0``/``dur`` carved out of the measured dispatch interval)."""

    name: str
    cat: str
    span_id: int
    parent_id: Optional[int]
    trace_id: int
    t0: float
    dur: float = -1.0
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {
            "name": self.name, "cat": self.cat, "span_id": self.span_id,
            "parent_id": self.parent_id, "trace_id": self.trace_id,
            "t0": self.t0, "dur": self.dur, "attrs": dict(self.attrs),
        }

    @staticmethod
    def from_dict(d: dict) -> "SpanRecord":
        return SpanRecord(
            name=d["name"], cat=d.get("cat", "span"),
            span_id=int(d["span_id"]), parent_id=d.get("parent_id"),
            trace_id=int(d.get("trace_id", d["span_id"])),
            t0=float(d["t0"]), dur=float(d["dur"]),
            attrs=dict(d.get("attrs") or {}))


class _ActiveSpan:
    """Context manager for an open span; ``set()`` attaches attrs."""

    __slots__ = ("_tracer", "rec")

    def __init__(self, tracer: "Tracer", rec: SpanRecord):
        self._tracer = tracer
        self.rec = rec

    def set(self, **attrs) -> None:
        self.rec.attrs.update(attrs)

    def __enter__(self) -> "_ActiveSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.rec.attrs.setdefault("error", exc_type.__name__)
        self._tracer.end(self.rec)
        return False


class _NoopSpan:
    """Shared do-nothing span for the disabled path."""

    __slots__ = ()
    rec = None

    def set(self, **attrs) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NOOP_SPAN = _NoopSpan()


class Tracer:
    """Collects spans; nesting follows an explicit begin/end stack."""

    def __init__(self, log_dir: Optional[str] = None):
        self.spans: List[SpanRecord] = []
        self.log_dir = log_dir
        self._stack: List[SpanRecord] = []
        self._ids = itertools.count(1)
        self._root_ids: List[int] = []

    # -- core span lifecycle -------------------------------------------
    def begin(self, name: str, cat: str = "span", **attrs) -> SpanRecord:
        parent = self._stack[-1] if self._stack else None
        sid = next(self._ids)
        rec = SpanRecord(
            name=name, cat=cat, span_id=sid,
            parent_id=parent.span_id if parent else None,
            trace_id=parent.trace_id if parent else sid,
            t0=time.perf_counter(), attrs=dict(attrs))
        self._stack.append(rec)
        return rec

    def end(self, rec: SpanRecord) -> None:
        rec.dur = time.perf_counter() - rec.t0
        # tolerate a stack skew from an exception mid-span: pop to rec
        while self._stack:
            top = self._stack.pop()
            if top is rec:
                break
        self.spans.append(rec)
        if rec.parent_id is None:
            self._root_ids.append(rec.span_id)
            self._flush_trace(rec)

    def emit(self, name: str, cat: str, *, t0: float, dur: float,
             parent: Optional[SpanRecord] = None,
             attrs: Optional[dict] = None) -> SpanRecord:
        """Append a synthetic (already-timed) span, e.g. schedule-step
        intervals carved out of a measured dispatch."""
        rec = SpanRecord(
            name=name, cat=cat, span_id=next(self._ids),
            parent_id=parent.span_id if parent is not None else None,
            trace_id=(parent.trace_id if parent is not None
                      else next(self._ids)),
            t0=float(t0), dur=float(dur), attrs=dict(attrs or {}))
        self.spans.append(rec)
        return rec

    def span(self, name: str, cat: str = "span", **attrs) -> _ActiveSpan:
        return _ActiveSpan(self, self.begin(name, cat, **attrs))

    def current(self) -> Optional[SpanRecord]:
        return self._stack[-1] if self._stack else None

    # -- trace queries -------------------------------------------------
    def trace(self, trace_id: int) -> List[SpanRecord]:
        out = [s for s in self.spans if s.trace_id == trace_id]
        out.sort(key=lambda s: (s.t0, s.span_id))
        return out

    def last_trace(self) -> List[SpanRecord]:
        if not self._root_ids:
            return []
        return self.trace(self._root_ids[-1])

    def clear(self) -> None:
        self.spans.clear()
        self._stack.clear()
        self._root_ids.clear()

    # -- JSONL event log -----------------------------------------------
    def _flush_trace(self, root: SpanRecord) -> None:
        if not self.log_dir:
            return
        path = os.path.join(self.log_dir, EVENTS_LOG)
        with open(path, "a") as f:
            for s in self.trace(root.trace_id):
                f.write(json.dumps(s.to_dict()) + "\n")


# -- module state ------------------------------------------------------
_ENABLED = False
_TRACER: Optional[Tracer] = None
_LOG_DIR: Optional[str] = None
_PLAN_OUTCOMES: List[dict] = []


def enable(log_dir: Optional[str] = None, *, reset: bool = True) -> Tracer:
    """Turn telemetry on.  ``log_dir`` additionally streams completed
    traces and plan outcomes to JSONL files there.  ``reset=False``
    keeps an existing tracer's spans across enable/disable cycles."""
    global _ENABLED, _TRACER, _LOG_DIR
    if log_dir is not None:
        os.makedirs(log_dir, exist_ok=True)
    _LOG_DIR = log_dir
    if _TRACER is None or reset:
        _TRACER = Tracer(log_dir=log_dir)
    else:
        _TRACER.log_dir = log_dir
    _ENABLED = True
    return _TRACER


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


def get_tracer() -> Optional[Tracer]:
    return _TRACER if _ENABLED else None


def span(name: str, cat: str = "span", **attrs):
    """Open a span on the active tracer; no-op when disabled."""
    if not _ENABLED or _TRACER is None:
        return NOOP_SPAN
    return _TRACER.span(name, cat, **attrs)


def maybe_span(cond: bool, name: str, cat: str = "span", **attrs):
    """``span()`` gated on a call-site flag (e.g. the per-call
    ``_tele`` bool that also excludes ``jax.jit`` tracing)."""
    if not cond:
        return NOOP_SPAN
    return span(name, cat, **attrs)


def event(name: str, cat: str = "event", **attrs) -> None:
    """Zero-duration marker attached to the innermost open span."""
    if not _ENABLED or _TRACER is None:
        return
    t = time.perf_counter()
    _TRACER.emit(name, cat, t0=t, dur=0.0, parent=_TRACER.current(),
                 attrs=attrs)


def last_trace() -> List[SpanRecord]:
    return _TRACER.last_trace() if _TRACER is not None else []


# -- predicted-vs-actual planner accounting ----------------------------
def record_plan_outcome(**fields) -> None:
    """Log one executed plan: ``algorithm``, ``predicted_s``,
    ``measured_s`` plus free-form context (geometry, densify,
    occupancy).  Feeds the planner scoreboard and
    ``planner.calibrate --check-drift``."""
    if not _ENABLED:
        return
    rec = dict(fields)
    _PLAN_OUTCOMES.append(rec)
    if _LOG_DIR:
        path = os.path.join(_LOG_DIR, PLAN_OUTCOMES_LOG)
        with open(path, "a") as f:
            f.write(json.dumps(rec) + "\n")


def plan_outcomes() -> List[dict]:
    return list(_PLAN_OUTCOMES)


def clear_plan_outcomes() -> None:
    _PLAN_OUTCOMES.clear()
