"""Report rendering + the ``python -m repro.obs report`` CLI.

Consumes the JSONL logs a traced run leaves behind
(``events.jsonl`` + ``plan_outcomes.jsonl`` under ``--dir``) and
renders the two views the paper's evidence needs:

  breakdown   comm-vs-compute-vs-verify wall-time split, summed over
              span categories (plan / comm / compute / verify /
              repair) across all recorded multiplies
  scoreboard  predicted-vs-actual planner cost per algorithm

``render_timeline`` prints one trace as an indented tree — the same
nesting the Chrome-trace export shows graphically.
"""
from __future__ import annotations

import argparse
import collections
import os
from typing import Dict, List, Optional, Sequence

from .telemetry import SpanRecord, EVENTS_LOG, PLAN_OUTCOMES_LOG
from .export import read_jsonl
from .scoreboard import planner_scoreboard, render_scoreboard

__all__ = ["category_breakdown", "render_breakdown", "render_timeline",
           "main"]

# categories whose spans are mutually exclusive slices of a dispatch
# ("matricize" = the tensor subsystem's unfold/refold phases under a
# contract root — disjoint from the nested multiply's own phases)
_PHASE_CATS = ("plan", "matricize", "comm", "compute", "verify", "repair")


def category_breakdown(spans: Sequence[SpanRecord]) -> Dict[str, float]:
    """Total seconds per span category.

    ``comm``/``compute`` are the synthetic schedule-step children of a
    dispatch (model-weighted slices of the measured wall time), so
    comm + compute ~= dispatch.  ``verify`` is reported *exclusive* of
    nested repair re-execution — a repaired multiply shows its second
    dispatch under ``repair``, not double-counted under ``verify``.
    """
    by_id = {s.span_id: s for s in spans}
    out: Dict[str, float] = collections.defaultdict(float)
    for s in spans:
        if s.dur < 0 or s.cat not in _PHASE_CATS:
            continue
        out[s.cat] += s.dur
    # make verify exclusive of its repair children
    for s in spans:
        if s.cat != "repair" or s.dur < 0:
            continue
        parent = by_id.get(s.parent_id)
        if parent is not None and parent.cat == "verify":
            out["verify"] -= s.dur
    roots = [s for s in spans if s.parent_id is None and s.dur >= 0]
    out["total"] = sum(s.dur for s in roots)
    return dict(out)


def render_breakdown(spans: Sequence[SpanRecord]) -> str:
    bd = category_breakdown(spans)
    total = bd.get("total", 0.0)
    lines = ["where the time went (all recorded multiplies):"]
    for cat in _PHASE_CATS:
        if cat not in bd:
            continue
        frac = bd[cat] / total if total > 0 else 0.0
        lines.append(f"  {cat:<8} {bd[cat]*1e3:9.2f} ms  {frac:6.1%}")
    lines.append(f"  {'total':<8} {total*1e3:9.2f} ms")
    return "\n".join(lines)


def render_timeline(spans: Sequence[SpanRecord], *,
                    max_steps: int = 6) -> str:
    """One trace as an indented tree (collapses long step runs)."""
    spans = [s for s in spans if s.dur >= 0]
    if not spans:
        return "(empty trace)"
    children: Dict[Optional[int], List[SpanRecord]] = \
        collections.defaultdict(list)
    for s in spans:
        children[s.parent_id].append(s)
    for v in children.values():
        v.sort(key=lambda s: (s.t0, s.span_id))
    lines: List[str] = []

    def _attrs(s: SpanRecord) -> str:
        keys = ("algorithm", "comm_bytes", "flops", "occupancy",
                "rank_imbalance", "skipped", "detected", "repaired")
        parts = [f"{k}={s.attrs[k]}" for k in keys
                 if s.attrs.get(k) is not None]
        return ("  [" + " ".join(parts) + "]") if parts else ""

    def _walk(parent_id: Optional[int], depth: int) -> None:
        kids = children.get(parent_id, [])
        steps = [s for s in kids if s.cat == "schedule-step"]
        shown = kids
        if len(steps) > max_steps:
            keep = set(id(s) for s in steps[:max_steps // 2]
                       ) | set(id(s) for s in steps[-max_steps // 2:])
            shown = [s for s in kids
                     if s.cat != "schedule-step" or id(s) in keep]
        n_hidden = len(kids) - len(shown)
        for s in shown:
            lines.append(f"{'  ' * depth}{s.name:<20} "
                         f"{s.dur*1e3:9.3f} ms{_attrs(s)}")
            _walk(s.span_id, depth + 1)
        if n_hidden > 0:
            lines.append(f"{'  ' * depth}... ({n_hidden} more steps)")

    roots = children.get(None, [])
    for root in roots:
        lines.append(f"{root.name:<20} {root.dur*1e3:9.3f} ms"
                     f"{_attrs(root)}")
        _walk(root.span_id, 1)
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs report",
        description="Render the comm/compute/verify breakdown and the "
                    "planner predicted-vs-actual scoreboard from a "
                    "traced run's JSONL logs.")
    ap.add_argument("--dir", default=os.path.join("artifacts", "obs"),
                    help="log directory passed to obs.enable(log_dir=...)")
    ap.add_argument("--timeline", action="store_true",
                    help="also print the last trace as a tree")
    args = ap.parse_args(argv)

    events = read_jsonl(os.path.join(args.dir, EVENTS_LOG))
    outcomes = read_jsonl(os.path.join(args.dir, PLAN_OUTCOMES_LOG))
    if not events and not outcomes:
        print(f"no telemetry logs under {args.dir!r} — run with "
              f"obs.enable(log_dir={args.dir!r}) first")
        return 1
    spans = [SpanRecord.from_dict(d) for d in events]
    n_traces = len({s.trace_id for s in spans})
    print(f"{len(spans)} spans over {n_traces} traces, "
          f"{len(outcomes)} plan outcomes from {args.dir}")
    if spans:
        print()
        print(render_breakdown(spans))
        if args.timeline:
            last_tid = max(s.trace_id for s in spans)
            print()
            print(render_timeline([s for s in spans
                                   if s.trace_id == last_tid]))
    if outcomes:
        print()
        print("planner scoreboard (predicted vs measured, signed "
              "rel err = (pred-meas)/meas):")
        print(render_scoreboard(planner_scoreboard(outcomes)))
    return 0
