"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H GQA kv=4 (head_dim 128, QK-norm), 128 experts
top-8 (expert ff 768), vocab 151936.  Pure full-attention -> long_500k
skipped (DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    head_dim=128, d_ff=6144, vocab_size=151936,
    qk_norm=True, rope_theta=1e6,
    moe=True, n_experts=128, top_k=8, moe_d_ff=768,
    remat="full",
)
