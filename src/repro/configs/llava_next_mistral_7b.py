"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Backbone only per assignment: 32L d_model=4096 32H GQA kv=8, SwiGLU
ff 14336, vocab 32000.  The anyres vision tower is a STUB:
input_specs() provides precomputed patch+text embeddings
(input_mode='embeddings').  Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    rope_theta=1e6,
    input_mode="embeddings",
    remat="full",
)
