"""Model/run configuration dataclasses + the architecture registry."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "get_config", "ARCHS",
           "reduced_config"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    norm: str = "rmsnorm"
    act: str = "silu"
    glu: bool = True
    qkv_bias: bool = False
    mlp_bias: bool = False
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    pos_emb: str = "rope"          # rope | sinusoidal | none
    tie_embeddings: bool = False
    # layer pattern (hybrid archs)
    mixer: str = "attention"       # attention | mla | rwkv6 | mamba
    attn_every: int = 1            # jamba: attn layer when l % attn_every == attn_offset
    attn_offset: int = 0
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    moe_every: int = 1
    moe_offset: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.0
    router: str = "softmax"
    moe_fsdp: bool = False         # FSDP-shard expert weights over data axes
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # mamba
    mamba_d_state: int = 16
    mamba_conv: int = 4
    mamba_expand: int = 2
    # rwkv
    rwkv_head_size: int = 64
    # stub frontends ([audio]/[vlm]): inputs are precomputed embeddings
    input_mode: str = "tokens"     # tokens | embeddings
    # multi-token prediction (deepseek-v3)
    mtp: bool = False
    mtp_weight: float = 0.3
    # numerics / memory policy
    dtype: str = "bfloat16"
    remat: str = "none"            # none | full | dots
    # --- beyond-paper optimization levers (EXPERIMENTS.md §Perf) ------
    sequence_parallel: bool = False  # shard residual stream seq over TP
    head_pad_factor: int = 1         # pad (q, kv) heads by an integer
                                     # factor so they shard over TP
    moe_small_t_partial: bool = True # FSDP MoE: activation-partial path
                                     # instead of weight gathers when the
                                     # token count is small (decode)
    # attention blocking (long-sequence path)
    long_seq_threshold: int = 1024
    attn_block_q: int = 2048
    attn_block_kv: int = 2048
    # which serve shapes are valid (sub-quadratic-memory archs only for 500k)
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def layer_kind(self, l: int) -> Tuple[str, str]:
        """(mixer_kind, ffn_kind) of layer l."""
        if self.mixer == "rwkv6":
            return "rwkv6", "rwkv_cm"
        if self.mixer == "mla":
            mix = "mla"
        elif self.attn_every > 1:
            mix = "attention" if l % self.attn_every == self.attn_offset else "mamba"
        else:
            mix = self.mixer
        if self.moe and l >= self.first_dense_layers and \
                (l % self.moe_every == self.moe_offset):
            ff = "moe"
        else:
            ff = "dense"
        return mix, ff


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCHS = [
    "deepseek_v3_671b",
    "qwen3_moe_30b_a3b",
    "starcoder2_3b",
    "qwen2_1_5b",
    "granite_20b",
    "granite_34b",
    "musicgen_medium",
    "jamba_v0_1_52b",
    "rwkv6_1_6b",
    "llava_next_mistral_7b",
]


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test scale: same family/structure, tiny dims."""
    def rd(x, lo, cap):
        return max(lo, min(x, cap))

    base = dict(
        num_layers=rd(cfg.num_layers, 2,
                      max(4, cfg.attn_every, cfg.moe_every * 2,
                          cfg.first_dense_layers + 2)),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        moe_d_ff=64 if cfg.moe else 0,
        n_experts=8 if cfg.moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.moe else 0,
        n_shared_experts=cfg.n_shared_experts and 1,
        # drop-free capacity at smoke scale so prefill+decode is exactly
        # teacher-forced forward (capacity drops are order-dependent)
        capacity_factor=8.0 if cfg.moe else cfg.capacity_factor,
        first_dense_layers=min(cfg.first_dense_layers, 1),
        q_lora_rank=64 if cfg.q_lora_rank else 0,
        kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        qk_nope_dim=32 if cfg.qk_nope_dim else 0,
        qk_rope_dim=16 if cfg.qk_rope_dim else 0,
        v_head_dim=32 if cfg.v_head_dim else 0,
        rwkv_head_size=32,
        long_seq_threshold=cfg.long_seq_threshold,
        dtype="float32",
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
