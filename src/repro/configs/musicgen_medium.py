"""MusicGen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48L d_model=1536 24H MHA, GELU ff 6144 (non-GLU), LayerNorm,
sinusoidal positions, vocab 2048 (per-codebook).  The EnCodec frontend
is a STUB: input_specs() provides precomputed frame embeddings
(input_mode='embeddings').  Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    norm="layernorm", act="gelu", glu=False,
    rope=False, pos_emb="sinusoidal",
    input_mode="embeddings",
    head_pad_factor=2,  # §Perf: 24 heads -> 48, shardable over TP=16
    remat="full",
)
