"""Jamba-v0.1 52B [arXiv:2403.19887] — Mamba+attention 1:7, MoE.

32L d_model=4096; attention layer once per 8 (offset 4), Mamba
elsewhere; MoE (16 experts top-2, ff 14336) every other layer; GQA
kv=8 on attention layers; no positional encoding (Mamba provides
position).  Hybrid: Mamba state is O(1) and only 4 layers carry KV ->
long_500k runs.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    rope=False, pos_emb="none",
    mixer="attention", attn_every=8, attn_offset=4,
    moe=True, n_experts=16, top_k=2, moe_d_ff=14336,
    moe_every=2, moe_offset=1,
    mamba_d_state=16, mamba_conv=4, mamba_expand=2,
    supports_long_context=True,
    remat="full",
)
