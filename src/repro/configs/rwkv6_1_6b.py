"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892] — attention-free,
data-dependent decay.

24L d_model=2048, head_size 64 (32 heads), channel-mix ff 7168,
vocab 65536.  O(1) recurrent state -> long_500k runs.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    mixer="rwkv6", rwkv_head_size=64,
    rope=False, pos_emb="none",
    supports_long_context=True,
    remat="full",
)
