"""Granite-34B (code) [arXiv:2405.04324] — llama-arch, MQA kv=1.

88L d_model=6144 48H kv=1, SwiGLU ff 24576, vocab 49152.
Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    remat="full",
)
