"""StarCoder2-3B [arXiv:2402.19173].

30L d_model=3072 24H GQA kv=2, d_ff=12288 (GELU, non-GLU), LayerNorm,
RoPE, biases, vocab 49152. Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    norm="layernorm", act="gelu", glu=False,
    qkv_bias=True, mlp_bias=True, rope_theta=1e5,
    head_pad_factor=2,  # §Perf: 24 heads -> 48, shardable over TP=16
    remat="full",
)
