"""DeepSeek-V3 671B [arXiv:2412.19437; hf].

61L d_model=7168 128H, MLA (q_lora 1536 / kv_lora 512 / nope 128 /
rope 64 / v 128), MoE 256 routed top-8 + 1 shared (expert ff 2048),
first 3 layers dense (ff 18432), vocab 129280, MTP.  The assignment
spec "GQA kv=128" denotes MLA's 128 effective heads; d_ff=2048 is the
per-expert intermediate.  MLA's 576-wide latent KV makes long_500k
feasible (sub-quadratic memory) — see DESIGN.md §4.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=18432, vocab_size=129280,
    mixer="mla",
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    moe=True, n_experts=256, top_k=8, moe_d_ff=2048,
    n_shared_experts=1, first_dense_layers=3, router="sigmoid",
    mtp=True,
    moe_fsdp=True,   # 671B: expert weights must shard over data axes too
    supports_long_context=True,   # MLA latent KV = 576 B/token/layer
    # sequence_parallel=True was REFUTED for MoE-FSDP at this scale
    # (EXPERIMENTS.md §Perf iteration 1): the MoE shard_map boundary
    # forces per-layer re-gathers of the sequence, and micro=1
    # ballooned the (T*k, d) dispatch tensors to 7.5 GB/layer.
    remat="save_moe",  # §Perf iteration 2: no expert re-gather in bwd
)
