"""Qwen2-1.5B [arXiv:2407.10671].

28L d_model=1536 12H GQA kv=2, SwiGLU ff 8960, QKV bias, RMSNorm,
tied embeddings, vocab 151936. Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
    head_pad_factor=4,  # §Perf: 12 heads -> 48, shardable over TP=16
    remat="full",
)
