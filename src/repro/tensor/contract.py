"""contract — execute a blocked tensor contraction on the 2D engine.

The pipeline (one obs root span ``contract`` when telemetry is on):

  parse     einsum.parse_contraction -> (contracted, A-free, B-free)
  plan      per-layout geometry stats (matricize.contraction_layout_
            stats) -> planner.plan_contract picks the matricization
            (and, via plan_multiply per layout, the 2D algorithm/path)
  matricize unfold A and B into DBCSRMatrix views, lowering masks and
            norms (span ``matricize``)
  multiply  the existing dbcsr.multiply, pinned to the planned
            algorithm/path so the executed 2D product matches the
            priced one (nested ``multiply`` span, eps filtering, ABFT
            verify=, rank_exact= all compose here unchanged)
  fold      refold payload + retained mask into the spec's output
            frame (span ``matricize`` again)

Determinism contract: at a fixed layout the result is bitwise equal to
hand-matricizing the operands and calling ``dbcsr.multiply`` directly —
the fold is a pure element permutation.  Different layouts change the
fused accumulation ORDER, so cross-layout results agree to float
tolerance (allclose vs the dense einsum oracle), not bitwise; that is
the same caveat as the 2D algorithms themselves.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro import obs

from .einsum import (EinsumSpecError, parse_contraction,
                     validate_contraction_operands)
from .matricize import (Layout, contraction_layout_stats, enumerate_layouts,
                        fold_to_tensor, layout_operands, unfold_tensor)
from .tensor import DBCSRTensor

__all__ = ["contract"]


def _resolve_layouts(con, layout):
    """The candidate layout set: all of them under "auto", exactly one
    when the caller pins a ``Layout`` or its label."""
    allowed = enumerate_layouts(con)
    if layout is None or layout == "auto":
        return allowed
    if isinstance(layout, Layout):
        if layout not in allowed:
            raise EinsumSpecError(
                f"layout {layout.label} is not a legal matricization of "
                f"{con.spec!r}")
        return (layout,)
    wanted = [L for L in allowed if L.label == str(layout)]
    if not wanted:
        raise EinsumSpecError(
            f"unknown layout {layout!r} for {con.spec!r}; legal: "
            f"{[L.label for L in allowed]}")
    return (wanted[0],)


def contract(
    spec: str,
    a: DBCSRTensor,
    b: DBCSRTensor,
    *,
    mesh,
    algorithm: str = "auto",
    layout="auto",
    densify: Optional[bool] = None,
    filter_eps: Optional[float] = None,
    verify: Optional[str] = None,
    rank_exact: Optional[bool] = None,
    return_plan: bool = False,
    **kw,
):
    """C = contraction of A and B per ``spec`` (see dbcsr.contract for
    the full API documentation)."""
    con = parse_contraction(spec)
    validate_contraction_operands(con, a, b)
    tele = obs.enabled() and not (
        isinstance(a.data, jax.core.Tracer)
        or isinstance(b.data, jax.core.Tracer))
    if not tele:
        return _contract(con, a, b, mesh=mesh, algorithm=algorithm,
                         layout=layout, densify=densify,
                         filter_eps=filter_eps, verify=verify,
                         rank_exact=rank_exact, return_plan=return_plan,
                         **kw)
    with obs.span("contract", cat="contract", spec=con.normalized,
                  algorithm=algorithm):
        return _contract(con, a, b, mesh=mesh, algorithm=algorithm,
                         layout=layout, densify=densify,
                         filter_eps=filter_eps, verify=verify,
                         rank_exact=rank_exact, return_plan=return_plan,
                         _tele=True, **kw)


def _contract(con, a, b, *, mesh, algorithm, layout, densify, filter_eps,
              verify, rank_exact, return_plan, _tele=False, **kw):
    from repro.core import dbcsr
    from repro.planner.plan import plan_contract

    if filter_eps is not None:
        # norms feed BOTH the per-layout occupancy/imbalance pricing
        # and (lowered through the unfold) the inner multiply's filter
        a.norms()
        b.norms()
    pr, pc = a.grid.grid_shape(mesh)
    layouts = _resolve_layouts(con, layout)
    with obs.maybe_span(_tele, "plan", cat="plan",
                        n_layouts=len(layouts)):
        stats = tuple(
            contraction_layout_stats(con, L, a, b, mesh_shape=(pr, pc),
                                     filter_eps=filter_eps,
                                     rank_exact=rank_exact)
            for L in layouts)
        cplan = plan_contract(
            con.normalized, stats, mesh_shape=(pr, pc),
            dtype=a.data.dtype,
            algorithm=None if algorithm == "auto" else algorithm,
            densify=densify)
    chosen = next(s for s in stats if s.label == cplan.layout)
    lsrc, lrows, lcols, rsrc, rrows, rcols, crows, ccols = \
        layout_operands(con, chosen.layout)
    left = a if lsrc == "a" else b
    right = b if rsrc == "b" else a
    lidx = con.a_indices if lsrc == "a" else con.b_indices
    ridx = con.b_indices if rsrc == "b" else con.a_indices
    dims = {**dict(zip(con.a_indices, a.shape)),
            **dict(zip(con.b_indices, b.shape))}
    bs = {**dict(zip(con.a_indices, a.block_sizes)),
          **dict(zip(con.b_indices, b.block_sizes))}

    t0 = time.perf_counter() if _tele else 0.0
    with obs.maybe_span(_tele, "matricize", cat="matricize",
                        layout=cplan.layout, phase="unfold"):
        ma = unfold_tensor(left, lidx, lrows, lcols, mesh=mesh)
        mb = unfold_tensor(right, ridx, rrows, rcols, mesh=mesh)
    # pinned to the contraction plan's choices so the executed 2D
    # product is exactly the priced one (densify passed explicitly:
    # a pinned algorithm with densify=None would fall back to the
    # legacy densified default, not the planner's path)
    c2d, mplan = dbcsr.multiply(
        ma, mb, mesh=mesh, algorithm=cplan.plan.algorithm,
        densify=cplan.plan.densify, filter_eps=filter_eps,
        verify=verify, rank_exact=rank_exact, return_plan=True, **kw)
    with obs.maybe_span(_tele, "matricize", cat="matricize",
                        layout=cplan.layout, phase="fold"):
        out = fold_to_tensor(c2d, con.out_indices, crows, ccols, dims, bs,
                             a.grid, mesh=mesh)
    # graft the executed stats onto the PLANNED multiply plan (whose
    # candidate table covers the full auto enumeration — the executed
    # inner plan was pinned, so its own table holds one candidate)
    executed = dataclasses.replace(
        cplan,
        plan=dataclasses.replace(
            cplan.plan, executor_stats=mplan.executor_stats,
            schedule_stats=mplan.schedule_stats,
            verification=mplan.verification),
        verification=mplan.verification)
    out.last_plan = executed
    out.verification = mplan.verification
    if _tele and not executed.trivial:
        jax.block_until_ready(out.data)
        obs.record_plan_outcome(
            kind="contract", spec=con.normalized,
            algorithm=executed.plan.algorithm, layout=executed.layout,
            densify=bool(executed.plan.densify),
            m=chosen.m, k=chosen.k, n=chosen.n,
            occupancy=float(chosen.occupancy),
            predicted_s=float(cplan.predicted_s),
            measured_s=float(time.perf_counter() - t0))
    return (out, executed) if return_plan else out
