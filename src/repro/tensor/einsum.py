"""Einsum front-end for blocked sparse tensor contractions.

Parses a two-operand contraction spec (``"ijk,kl->ijl"``) into the
three index groups the matricization layer (matricize.py) lowers onto
the 2D multiply engine:

  contracted   indices shared by A and B and absent from the output —
               they fuse into the inner (k) dimension of the 2D product
  A-free       indices of A that survive into the output — they fuse
               into the row dimension of the matricized A
  B-free       indices of B that survive into the output — the column
               dimension of the matricized B

The legal spec language is exactly what one ``DBCSRMatrix`` multiply
can express after matricization (arXiv:1910.13555's lowering):

  * single-letter indices, no repeats within one operand (no traces /
    diagonals),
  * at least one contracted index (outer products have no inner
    dimension to lower onto),
  * no batch indices — an index shared by A, B *and* the output would
    need a block-diagonal 3D product the 2D engine cannot express,
  * the output is a permutation of A-free + B-free — an index that
    appears in one operand but not the output would be a sum-reduction,
    which is an unfold of a *different* contraction, not this one.

Violations raise :class:`EinsumSpecError`, a typed
:class:`repro.robustness.guards.DbcsrValidationError` subclass, so the
service/validation layers catch tensor spec errors exactly like matrix
shape errors.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Tuple

from repro.robustness.guards import (DbcsrValidationError,
                                     GridMismatchError, ShapeMismatchError)

__all__ = ["EinsumSpecError", "ContractionSpec", "parse_contraction",
           "validate_contraction_operands"]


class EinsumSpecError(DbcsrValidationError):
    """Malformed or unsupported tensor contraction spec."""


_SPEC_RE = re.compile(r"^([A-Za-z]+),([A-Za-z]+)->([A-Za-z]*)$")


@dataclasses.dataclass(frozen=True)
class ContractionSpec:
    """A parsed, validated two-operand contraction.

    Index tuples preserve the operand order of appearance; the output
    tuple preserves the caller's requested output order (the refold
    target frame).
    """

    spec: str
    a_indices: Tuple[str, ...]
    b_indices: Tuple[str, ...]
    out_indices: Tuple[str, ...]
    contracted: Tuple[str, ...]    # ordered by appearance in A
    a_free: Tuple[str, ...]        # ordered by appearance in A
    b_free: Tuple[str, ...]        # ordered by appearance in B

    @property
    def normalized(self) -> str:
        """Canonical whitespace-free spelling; ``parse_contraction``
        round-trips through it (property-tested)."""
        return (f"{''.join(self.a_indices)},{''.join(self.b_indices)}"
                f"->{''.join(self.out_indices)}")


def parse_contraction(spec: str) -> ContractionSpec:
    """Parse and validate ``"<A>,<B>-><out>"`` into index groups.

    Raises :class:`EinsumSpecError` on syntax errors, repeated indices
    within an operand, batch (shared free) indices, outer products
    (no contracted index), sum-reductions (a free index missing from
    the output), or output indices that name no operand axis.
    """
    if not isinstance(spec, str):
        raise EinsumSpecError(f"contraction spec must be a str, got "
                              f"{type(spec).__name__}")
    compact = spec.replace(" ", "")
    m = _SPEC_RE.match(compact)
    if m is None:
        raise EinsumSpecError(
            f"malformed contraction spec {spec!r}: expected "
            f"'<letters>,<letters>-><letters>' (two operands, single-"
            f"letter indices)")
    a_s, b_s, out_s = m.group(1), m.group(2), m.group(3)
    for name, s in (("A", a_s), ("B", b_s), ("output", out_s)):
        if len(set(s)) != len(s):
            raise EinsumSpecError(
                f"{spec!r}: repeated index in {name} subscript {s!r} "
                f"(traces/diagonals are not lowerable to one 2D multiply)")
    a_idx, b_idx, out_idx = tuple(a_s), tuple(b_s), tuple(out_s)
    a_set, b_set, out_set = set(a_idx), set(b_idx), set(out_idx)

    unknown = out_set - (a_set | b_set)
    if unknown:
        raise EinsumSpecError(
            f"{spec!r}: output index(es) {sorted(unknown)} appear in "
            f"neither operand")
    batch = a_set & b_set & out_set
    if batch:
        raise EinsumSpecError(
            f"{spec!r}: batch index(es) {sorted(batch)} are shared by "
            f"A, B and the output — a 2D matricized multiply cannot "
            f"express block-diagonal batch contractions")
    contracted = tuple(i for i in a_idx if i in b_set)
    if not contracted:
        raise EinsumSpecError(
            f"{spec!r}: no contracted index — outer products have no "
            f"inner dimension to lower onto dbcsr.multiply")
    a_free = tuple(i for i in a_idx if i not in b_set)
    b_free = tuple(i for i in b_idx if i not in a_set)
    dropped = (set(a_free) | set(b_free)) - out_set
    if dropped:
        raise EinsumSpecError(
            f"{spec!r}: free index(es) {sorted(dropped)} missing from "
            f"the output — sum-reductions over free axes are not part "
            f"of this contraction's lowering")
    return ContractionSpec(
        spec=compact, a_indices=a_idx, b_indices=b_idx,
        out_indices=out_idx, contracted=contracted, a_free=a_free,
        b_free=b_free)


def validate_contraction_operands(con: ContractionSpec, a, b) -> None:
    """Structural validation of a (spec, A, B) contraction request.

    Checks rank-vs-subscript agreement, per-shared-index dimension and
    block-size agreement (the fused inner dimension must tile
    identically on both sides), and grid compatibility.  Raises typed
    :class:`DbcsrValidationError` subclasses, mirroring
    ``guards.validate_multiply_request`` for matrices.
    """
    if a.ndim != len(con.a_indices):
        raise ShapeMismatchError(
            f"{con.spec!r}: A subscript names {len(con.a_indices)} "
            f"axes but the tensor has {a.ndim}")
    if b.ndim != len(con.b_indices):
        raise ShapeMismatchError(
            f"{con.spec!r}: B subscript names {len(con.b_indices)} "
            f"axes but the tensor has {b.ndim}")
    dims = {}
    blocks = {}
    for t, idx in ((a, con.a_indices), (b, con.b_indices)):
        for ax, label in enumerate(idx):
            d, bs = int(t.shape[ax]), int(t.block_sizes[ax])
            if label in dims:
                if dims[label] != d:
                    raise ShapeMismatchError(
                        f"{con.spec!r}: index {label!r} has dim "
                        f"{dims[label]} in A but {d} in B")
                if blocks[label] != bs:
                    raise ShapeMismatchError(
                        f"{con.spec!r}: index {label!r} has block size "
                        f"{blocks[label]} in A but {bs} in B — the "
                        f"fused inner dimension must tile identically")
            dims[label] = d
            blocks[label] = bs
    ga, gb = a.grid, b.grid
    if (ga.row_axis, ga.col_axis, ga.stack_axis) != (
            gb.row_axis, gb.col_axis, gb.stack_axis):
        raise GridMismatchError(
            f"A on grid axes ({ga.row_axis}, {ga.col_axis}, "
            f"stack={ga.stack_axis}); B on grid axes ({gb.row_axis}, "
            f"{gb.col_axis}, stack={gb.stack_axis})")
