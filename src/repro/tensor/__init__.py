"""repro.tensor — blocked sparse tensor algebra on the 2D engine.

The DBCSR tensor extension (arXiv:1910.13555): N-d blocked tensors
(``DBCSRTensor``) whose contractions lower onto the existing
``dbcsr.multiply`` by matricization — masks and norms included, the
layout choice priced by the planner.  Public entry points:

  create_tensor       blocked N-d container from a host array
  contract            ``contract("ijk,kl->ijl", A, B, ...)``
  parse_contraction   the einsum front-end (typed validation)
  enumerate_layouts   every legal matricization of a parsed spec
"""
from .contract import contract
from .einsum import (ContractionSpec, EinsumSpecError, parse_contraction,
                     validate_contraction_operands)
from .matricize import (Layout, LayoutStats, contraction_layout_stats,
                        enumerate_layouts, fold_to_tensor, unfold_tensor)
from .tensor import DBCSRTensor, create_tensor

__all__ = [
    "DBCSRTensor",
    "create_tensor",
    "contract",
    "ContractionSpec",
    "EinsumSpecError",
    "parse_contraction",
    "validate_contraction_operands",
    "Layout",
    "LayoutStats",
    "contraction_layout_stats",
    "enumerate_layouts",
    "unfold_tensor",
    "fold_to_tensor",
]
