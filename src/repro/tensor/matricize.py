"""Matricization: unfold an N-d blocked tensor into a 2D DBCSRMatrix
view, and fold a 2D product back into the N-d output frame.

The contraction ``C[a_free + b_free] = sum_k A[a_free, k] B[k, b_free]``
lowers onto ``dbcsr.multiply`` by fusing each index group into one
blocked matrix dimension.  The unfold is BLOCK-level, not element-level:
each axis ``d = nb * bs`` is first split ``(nb, bs)``, then all block
axes of a group are brought together ahead of all intra-block axes

    (nb_1, bs_1, ..., nb_N, bs_N)
        -> (nb_r..., bs_r..., nb_c..., bs_c...)   [one transpose]
        -> (R, C)                                  [one reshape]

so the fused dimension is again uniformly blocked with block size
``prod(bs_group)`` and the row-major fused block index runs over the
group's block grid.  This is what makes the lowering exact and cheap in
metadata:

  * bijection — 2D block ``(I, J)`` of the view contains exactly the
    elements of one N-d block, so an N-d block is retained iff its
    matricized image is (mask lowering is a pure block-grid
    transpose+reshape, ``unfold_grid``),
  * norm exactness — the unfold permutes elements *within* a block, and
    Frobenius norms are permutation-invariant, so the N-d norm cache
    lowers through the same grid transpose with no device work.

A ``Layout`` fixes the three free choices of the lowering: the fusion
order of the A-free group (matrix rows), of the contracted group (the
shared inner dimension — MUST match between both operands or the block
columns of the A view and block rows of the B view would disagree), of
the B-free group (matrix cols), and whether the product is computed
transposed (``swapped``: the B view is the left operand computing
``C^T``).  All of them produce the same output tensor up to float
accumulation order; they differ in 2D shape, mask geometry, per-rank
balance and copy cost — which is why layout choice is routed through
the planner (``repro.planner.plan_contract``) instead of hardcoded.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.blocking import BlockLayout
from repro.core.dbcsr import DBCSRMatrix, _sharding

from .einsum import ContractionSpec
from .tensor import DBCSRTensor

__all__ = ["Layout", "LayoutStats", "enumerate_layouts", "unfold_grid",
           "fold_grid", "unfold_tensor", "fold_to_tensor",
           "unfold_is_trivial", "contraction_layout_stats"]


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


@dataclasses.dataclass(frozen=True)
class Layout:
    """One legal matricization of a contraction.

    a_rows   permutation of the A-free group (row fusion order)
    k_order  permutation of the contracted group (shared inner fusion
             order — used by BOTH operand views)
    b_cols   permutation of the B-free group (col fusion order)
    swapped  compute the product transposed: the matricized B (rows =
             b_cols, cols = k) is the LEFT operand, the matricized A
             (rows = k, cols = a_rows) the right, and the 2D result
             ``C^T`` folds back through the mirrored group assignment
    """

    a_rows: Tuple[str, ...]
    k_order: Tuple[str, ...]
    b_cols: Tuple[str, ...]
    swapped: bool = False

    @property
    def label(self) -> str:
        a, k, c = ("".join(self.a_rows), "".join(self.k_order),
                   "".join(self.b_cols))
        if self.swapped:
            return f"({c}|{k})@({k}|{a})^T"
        return f"({a}|{k})@({k}|{c})"


def enumerate_layouts(con: ContractionSpec) -> Tuple[Layout, ...]:
    """Every legal matricization of ``con``: all fusion orders of the
    three index groups x the transposed variant.  The spec-order
    unswapped layout comes first (the "obvious" lowering)."""
    out = []
    for ap in itertools.permutations(con.a_free):
        for kp in itertools.permutations(con.contracted):
            for bp in itertools.permutations(con.b_free):
                for sw in (False, True):
                    out.append(Layout(ap, kp, bp, sw))
    return tuple(out)


# -- unfold / fold of payloads and block grids -------------------------

def _unfold_perm(indices: Sequence[str], rows: Sequence[str],
                 cols: Sequence[str]) -> Tuple[int, ...]:
    """Transpose permutation over the interleaved (nb_1, bs_1, ...,
    nb_N, bs_N) axes bringing the row group's block axes first, then its
    intra-block axes, then the col group's."""
    pos = {label: ax for ax, label in enumerate(indices)}
    return tuple([2 * pos[r] for r in rows]
                 + [2 * pos[r] + 1 for r in rows]
                 + [2 * pos[c] for c in cols]
                 + [2 * pos[c] + 1 for c in cols])


def unfold_is_trivial(indices: Sequence[str], rows: Sequence[str],
                      cols: Sequence[str]) -> bool:
    """True iff the unfold moves no data (the transpose is the
    identity) — exactly the 2D spec-order case, where the matricized
    view IS the tensor payload."""
    perm = _unfold_perm(indices, rows, cols)
    return perm == tuple(range(len(perm)))


def unfold_array(x, indices: Sequence[str], rows: Sequence[str],
                 cols: Sequence[str], block_sizes: Sequence[int]):
    """Block-level unfold of an N-d payload (jax or numpy) into its
    (R, C) matricized view."""
    inter = []
    for d, bs in zip(x.shape, block_sizes):
        inter += [d // bs, bs]
    dims = dict(zip(indices, x.shape))
    y = x.reshape(inter).transpose(_unfold_perm(indices, rows, cols))
    return y.reshape(_prod(dims[r] for r in rows),
                     _prod(dims[c] for c in cols))


def unfold_grid(g: np.ndarray, indices: Sequence[str],
                rows: Sequence[str], cols: Sequence[str]) -> np.ndarray:
    """Block-grid unfold (masks / norms): pure transpose+reshape on the
    host grid — the mask/norm lowering semantics of the subsystem."""
    pos = {label: ax for ax, label in enumerate(indices)}
    perm = [pos[r] for r in rows] + [pos[c] for c in cols]
    p = len(rows)
    t = np.ascontiguousarray(np.transpose(g, perm))
    return t.reshape(_prod(t.shape[:p]), _prod(t.shape[p:]))


def fold_array(x2d, out_indices: Sequence[str], rows: Sequence[str],
               cols: Sequence[str], nb: dict, bs: dict):
    """Inverse of ``unfold_array``: fold a (R, C) payload whose row/col
    groups are ``rows``/``cols`` back into the N-d frame ordered by
    ``out_indices`` (any permutation of rows + cols)."""
    p, q = len(rows), len(cols)
    shape = ([nb[r] for r in rows] + [bs[r] for r in rows]
             + [nb[c] for c in cols] + [bs[c] for c in cols])
    y = x2d.reshape(shape)
    bpos, ipos = {}, {}
    for i, r in enumerate(rows):
        bpos[r], ipos[r] = i, p + i
    for j, c in enumerate(cols):
        bpos[c], ipos[c] = 2 * p + j, 2 * p + q + j
    perm = []
    for o in out_indices:
        perm += [bpos[o], ipos[o]]
    return y.transpose(perm).reshape([nb[o] * bs[o] for o in out_indices])


def fold_grid(g2d: np.ndarray, out_indices: Sequence[str],
              rows: Sequence[str], cols: Sequence[str],
              nb: dict) -> np.ndarray:
    """Inverse of ``unfold_grid`` for block masks/norms."""
    shape = [nb[r] for r in rows] + [nb[c] for c in cols]
    y = np.asarray(g2d).reshape(shape)
    group = list(rows) + list(cols)
    perm = [group.index(o) for o in out_indices]
    return np.ascontiguousarray(np.transpose(y, perm))


def unfold_tensor(t: DBCSRTensor, indices: Sequence[str],
                  rows: Sequence[str], cols: Sequence[str], *,
                  mesh) -> DBCSRMatrix:
    """Matricize a blocked tensor into a DBCSRMatrix sharded over the
    process grid, lowering its mask and (if cached) its norm cache —
    the retained-iff-image-retained contract."""
    data = unfold_array(t.data, indices, rows, cols, t.block_sizes)
    data = jax.device_put(data, _sharding(mesh, t.grid))
    bs = dict(zip(indices, t.block_sizes))
    layout = BlockLayout(int(data.shape[0]), int(data.shape[1]),
                         _prod(bs[r] for r in rows),
                         _prod(bs[c] for c in cols))
    mask = norms = None
    if t.block_mask is not None:
        mask = unfold_grid(t.block_mask, indices, rows, cols)
    if t.block_norms is not None:
        norms = unfold_grid(t.block_norms, indices, rows,
                            cols).astype(np.float32)
    return DBCSRMatrix(data, layout, t.grid, mask, norms)


def fold_to_tensor(c: DBCSRMatrix, out_indices: Sequence[str],
                   rows: Sequence[str], cols: Sequence[str],
                   dims: dict, bs: dict, grid, *, mesh) -> DBCSRTensor:
    """Fold a 2D product back into the N-d output frame (the refold
    frame guarantee: the result's axis order is exactly the spec's
    output order, independent of which layout executed)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    nb = {o: dims[o] // bs[o] for o in out_indices}
    data = fold_array(c.data, out_indices, rows, cols, nb, bs)
    data = jax.device_put(data, NamedSharding(mesh, P()))
    mask = None
    if c.block_mask is not None:
        mask = fold_grid(c.block_mask, out_indices, rows, cols, nb)
    return DBCSRTensor(data, tuple(bs[o] for o in out_indices), grid, mask)


# -- per-layout planning statistics ------------------------------------

@dataclasses.dataclass(frozen=True)
class LayoutStats:
    """Everything the planner needs to price one matricization: the 2D
    problem it induces, its (layout-invariant) retained occupancy, its
    (layout-dependent) per-rank imbalance, and the unfold/refold copy
    traffic.  Frozen + hashable: this tuple IS the contraction plan
    cache key's layout component."""

    layout: Layout
    label: str
    m: int
    k: int
    n: int
    block_m: int
    block_k: int
    block_n: int
    occupancy: float
    rank_imbalance: Optional[float]
    copy_bytes: int
    feasible: bool
    reason: str = ""


def layout_operands(con: ContractionSpec, layout: Layout):
    """Resolve which tensor matricizes to which side of the 2D product:
    returns ``(left_src, left_rows, left_cols, right_src, right_rows,
    right_cols, c_rows, c_cols)`` with src in {"a", "b"} and the C
    groups naming the 2D product's row/col index groups."""
    if layout.swapped:
        return ("b", layout.b_cols, layout.k_order,
                "a", layout.k_order, layout.a_rows,
                layout.b_cols, layout.a_rows)
    return ("a", layout.a_rows, layout.k_order,
            "b", layout.k_order, layout.b_cols,
            layout.a_rows, layout.b_cols)


def contraction_layout_stats(
    con: ContractionSpec,
    layout: Layout,
    a: DBCSRTensor,
    b: DBCSRTensor,
    *,
    mesh_shape: Tuple[int, int],
    filter_eps: Optional[float] = None,
    rank_exact=None,
) -> LayoutStats:
    """Price the geometry of one layout (no cost-model evaluation here
    — that is ``plan_contract``'s job; this computes the inputs it is
    priced on, mirroring core/multiply.py's occupancy and rank-exact
    imbalance resolution on the matricized masks)."""
    from repro.core.multiply import _global_occupancy

    dims = {**dict(zip(con.a_indices, a.shape)),
            **dict(zip(con.b_indices, b.shape))}
    bs = {**dict(zip(con.a_indices, a.block_sizes)),
          **dict(zip(con.b_indices, b.block_sizes))}
    lsrc, lrows, lcols, rsrc, rrows, rcols, crows, ccols = \
        layout_operands(con, layout)
    left = a if lsrc == "a" else b
    right = b if rsrc == "b" else a
    lidx = con.a_indices if lsrc == "a" else con.b_indices
    ridx = con.b_indices if rsrc == "b" else con.a_indices

    m = _prod(dims[x] for x in lrows)
    k = _prod(dims[x] for x in lcols)
    n = _prod(dims[x] for x in rcols)
    block_m = _prod(bs[x] for x in lrows)
    block_k = _prod(bs[x] for x in lcols)
    block_n = _prod(bs[x] for x in rcols)

    am = bm = an = bn = None
    if left.block_mask is not None:
        am = unfold_grid(left.block_mask, lidx, lrows, lcols)
    if right.block_mask is not None:
        bm = unfold_grid(right.block_mask, ridx, rrows, rcols)
    if filter_eps is not None:
        if left.block_norms is not None:
            an = unfold_grid(left.block_norms, lidx, lrows,
                             lcols).astype(np.float32)
        if right.block_norms is not None:
            bn = unfold_grid(right.block_norms, ridx, rrows,
                             rcols).astype(np.float32)
    occ = _global_occupancy(m, k, n, block_m, block_k, block_n,
                            am, bm, an, bn, filter_eps)

    pr, pc = mesh_shape[0], mesh_shape[1]
    nbr, nbk, nbc = m // block_m, k // block_k, n // block_n
    feasible, reason = True, ""
    if nbr % pr or nbc % pc:
        feasible = False
        reason = (f"block grid {nbr}x{nbc} not divisible by mesh "
                  f"{pr}x{pc}")

    # per-rank retained-triple imbalance of THIS layout's C-chunk
    # decomposition — the layout-dependent signal (occupancy is
    # layout-invariant: the retained triples are the same set, only
    # their arrangement over ranks changes).  Mirrors the resolution in
    # core/multiply.py so the inner multiply replans to the same answer.
    rank_imb = None
    masked = am is not None or bm is not None or filter_eps is not None
    if (feasible and rank_exact is not False and masked and pr * pc > 1):
        from repro.core.stacks import normalize_block_masks
        from repro.sparsity.balance import (chunk_imbalance,
                                            retained_block_weights)
        from repro.sparsity.norms import normalize_block_norms

        amf, bmf = normalize_block_masks(nbr, nbk, nbc, am, bm)
        an_g = bn_g = None
        if filter_eps is not None:
            an_g, bn_g = normalize_block_norms(nbr, nbk, nbc, an, bn)
            an_g = np.where(amf, an_g, np.float32(0.0))
            bn_g = np.where(bmf, bn_g, np.float32(0.0))
        rank_imb = chunk_imbalance(
            retained_block_weights(amf, bmf, an_g, bn_g, filter_eps),
            pr, pc)

    # unfold/refold traffic: one read + one write per moved payload;
    # a trivial (identity-permutation) unfold moves nothing
    itemsize = int(np.dtype(left.data.dtype).itemsize)
    copy = 0
    if not unfold_is_trivial(lidx, lrows, lcols):
        copy += 2 * left.data.size
    if not unfold_is_trivial(ridx, rrows, rcols):
        copy += 2 * right.data.size
    out_idx = con.out_indices
    if not unfold_is_trivial(out_idx, crows, ccols):
        copy += 2 * m * n
    return LayoutStats(
        layout=layout, label=layout.label, m=m, k=k, n=n,
        block_m=block_m, block_k=block_k, block_n=block_n,
        occupancy=float(occ),
        rank_imbalance=None if rank_imb is None else float(rank_imb),
        copy_bytes=int(copy * itemsize), feasible=feasible, reason=reason)
