"""DBCSRTensor — N-dimensional blocked tensor container.

The tensor analogue of ``DBCSRMatrix`` (arXiv:1910.13555): every axis
``d_a`` is uniformly tiled into ``nb_a`` blocks of size ``bs_a``, and
the tensor carries a static N-d block occupancy mask plus lazily-cached
per-block Frobenius norms.  Exactly like the 2D container, absent
blocks are stored as zeros in the dense payload so shapes stay static
under jit, and the mask/norms travel through the pytree aux as
``(shape, bytes)`` so block sparsity survives jit/vmap round-trips.

Distribution model: the N-d payload lives *replicated* on the mesh —
the process-grid distribution happens at matricization time
(matricize.py unfolds the tensor into a 2D ``DBCSRMatrix`` view sharded
over the (row_axis, col_axis) grid, which is where the paper's tensors
actually live during a contraction).  The N-d frame is the user frame;
the 2D frame is the execution frame.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.blocking import GridSpec

__all__ = ["DBCSRTensor", "create_tensor"]


def _expand_mask(mask: np.ndarray, block_sizes: Tuple[int, ...]) -> np.ndarray:
    """Element-level expansion of an N-d block mask (each block entry
    repeated bs_a times along axis a)."""
    full = mask
    for ax, bs in enumerate(block_sizes):
        full = np.repeat(full, bs, axis=ax)
    return full


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DBCSRTensor:
    """A blocked N-d tensor.

    data        : N-d jax.Array (replicated on the mesh; see module doc)
    block_sizes : per-axis uniform block size, ``len == data.ndim``
    grid        : mesh-axis names matricized views are sharded over
    block_mask  : optional N-d numpy bool of shape ``block_grid``
    block_norms : optional N-d numpy float32 — per-block Frobenius
                  norms, lazily computed/cached by ``norms()`` and
                  lowered through matricization for ``filter_eps``

    Results of ``dbcsr.contract`` additionally carry the executed
    ``ContractionPlan`` as a plain ``last_plan`` attribute (host-side
    observability only — not part of the pytree, does not survive jit).
    """

    data: jax.Array
    block_sizes: Tuple[int, ...]
    grid: GridSpec
    block_mask: Optional[np.ndarray] = None
    block_norms: Optional[np.ndarray] = None

    # -- pytree protocol (mirrors DBCSRMatrix: data is the only leaf) --
    def tree_flatten(self):
        mask_aux = (None if self.block_mask is None
                    else (self.block_mask.shape, self.block_mask.tobytes()))
        norms_aux = None
        if self.block_norms is not None:
            norms = np.ascontiguousarray(self.block_norms, dtype=np.float32)
            norms_aux = (norms.shape, norms.tobytes())
        return (self.data,), (tuple(self.block_sizes), self.grid,
                              mask_aux, norms_aux)

    @classmethod
    def tree_unflatten(cls, aux, children):
        block_sizes, grid, mask_aux, norms_aux = aux
        mask = None
        if mask_aux is not None:
            shape, raw = mask_aux
            mask = np.frombuffer(raw, dtype=bool).reshape(shape).copy()
        norms = None
        if norms_aux is not None:
            shape, raw = norms_aux
            norms = np.frombuffer(raw, dtype=np.float32).reshape(shape).copy()
        return cls(children[0], block_sizes, grid, mask, norms)

    # -- blocked-tensor API --------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.data.shape)

    @property
    def ndim(self) -> int:
        return self.data.ndim

    @property
    def block_grid(self) -> Tuple[int, ...]:
        return tuple(d // bs for d, bs in zip(self.shape, self.block_sizes))

    @property
    def nblocks(self) -> int:
        n = 1
        for nb in self.block_grid:
            n *= nb
        return n

    @property
    def occupancy(self) -> float:
        if self.block_mask is None:
            return 1.0
        return float(self.block_mask.mean())

    def norms(self, recompute: bool = False) -> np.ndarray:
        """Per-block Frobenius norms (N-d float32 numpy of shape
        ``block_grid``), cached after the first call.  Mask-absent
        blocks report 0.  Exact under matricization: a block's
        Frobenius norm is invariant to the intra-block element
        permutation the unfold applies, so the 2D views lower this
        cache instead of recomputing it."""
        if self.block_norms is None or recompute:
            from repro.sparsity.norms import tensor_block_norms

            self.block_norms = tensor_block_norms(
                self.data, self.block_sizes, self.block_mask)
        return self.block_norms

    def filter(self, eps: float) -> "DBCSRTensor":
        """Post-contraction filtering in the tensor frame: drop every
        block with ``norm < eps`` (blocks exactly at eps survive,
        matching the 2D ``DBCSRMatrix.filter`` contract), zeroing the
        dropped payload.  Never resurrects a mask-absent block."""
        norms = self.norms()
        mask = norms >= float(eps)
        if self.block_mask is not None:
            mask &= self.block_mask
        full = _expand_mask(mask, self.block_sizes)
        data = self.data * jnp.asarray(full, dtype=self.data.dtype)
        new_norms = np.where(mask, norms, np.float32(0.0)).astype(np.float32)
        return DBCSRTensor(data, self.block_sizes, self.grid, mask, new_norms)


def create_tensor(
    array,
    *,
    mesh: Mesh,
    grid: GridSpec = GridSpec(),
    block_sizes: Tuple[int, ...],
    block_mask: Optional[np.ndarray] = None,
    compute_norms: bool = False,
) -> DBCSRTensor:
    """Create a blocked N-d tensor from a host/global array (the tensor
    analogue of ``dbcsr.create``).  Every axis must be divisible by its
    block size; a ``block_mask`` of shape ``block_grid`` zeroes absent
    blocks' payload so dense math matches sparse semantics.
    ``compute_norms=True`` eagerly fills the norm cache."""
    block_sizes = tuple(int(b) for b in block_sizes)
    if len(block_sizes) != np.ndim(array):
        raise ValueError(
            f"block_sizes names {len(block_sizes)} axes but the array "
            f"has {np.ndim(array)}")
    for ax, (d, bs) in enumerate(zip(np.shape(array), block_sizes)):
        if bs <= 0 or d % bs:
            raise ValueError(
                f"axis {ax}: dim {d} not divisible by block size {bs}")
    data = jax.device_put(array, NamedSharding(mesh, P()))
    if block_mask is not None:
        block_grid = tuple(d // bs for d, bs in
                           zip(np.shape(array), block_sizes))
        if block_mask.shape != block_grid:
            raise ValueError(
                f"block_mask shape {block_mask.shape} != block grid "
                f"{block_grid}")
        block_mask = np.ascontiguousarray(block_mask, dtype=bool)
        full = _expand_mask(block_mask, block_sizes)
        data = data * jnp.asarray(full, dtype=data.dtype)
    out = DBCSRTensor(data, block_sizes, grid, block_mask)
    if compute_norms:
        out.norms()
    return out
