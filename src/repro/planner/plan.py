"""plan_multiply — pick (algorithm, local path, 2.5D replication,
stack params) for one distributed multiply.

This is the paper's driver behaviour made explicit: DBCSR's headline
win over vendor PDGEMM comes from choosing the right decomposition per
(shape, occupancy, mesh), and this module makes that choice the
library default (``distributed_matmul(algorithm="auto")`` and
``dbcsr.multiply`` route through here).

The planner evaluates every feasible candidate through the analytic
models in ``cost_model.py`` (constants from ``calibrate.py``), resolves
the blocked path's ``align`` / ``stack_tile`` through the
occupancy-binned autotune winners table
(``repro.kernels.smm.autotune.best_params_meta``), and memoizes the
result in an LRU cache keyed on the full problem signature — a second
identical call performs ZERO cost-model evaluations (asserted by
tests/test_planner.py via ``cost_model.N_EVALS``).

An empty product short-circuits to a trivial zero-cost plan *before*
any candidate is costed: the blocked-path model divides by
occupancy-derived quantities and must never see occupancy zero (the
``_masks_empty`` contract shared with core/multiply.py).  This fires
both for an empty binary-mask product AND for a norm-predicted-empty
product — eps filtering (repro.sparsity) can empty a product whose
binary masks are non-empty, in which case ``_global_occupancy``
reports 0.0 and the trivial (all-steps-skipped) plan executes.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import numpy as np

from .cost_model import (BATCHED_ALGORITHMS, CandidateCost, HardwareModel,
                         Problem, algorithm_steps, batched_dispatch_cost,
                         candidate_cost, enumerate_candidates, feasible,
                         overlap_efficiency, rebalance_cost_s,
                         verify_overhead_s)

__all__ = ["MultiplyPlan", "BatchedMultiplyPlan", "ContractionPlan",
           "LayoutCandidate", "plan_multiply", "plan_multiply_batched",
           "plan_contract", "decide_verify", "plan_cache_info",
           "plan_cache_clear", "plan_cache_stats",
           "contract_cache_info", "contract_cache_clear",
           "DEFAULT_VERIFY_BUDGET"]

_PLAN_CACHE_SIZE = 512

# verify="auto" enables checksum verification only when its predicted
# overhead stays within this fraction of the plan's predicted time —
# the same 25% ceiling bench_abft.py gates the MEASURED overhead at.
DEFAULT_VERIFY_BUDGET = 0.25


@dataclasses.dataclass(frozen=True)
class MultiplyPlan:
    """The planner's decision for one multiply, plus its receipts.

    ``candidates`` holds every evaluated configuration (feasible or
    not) so ``explain()`` can show *why* the winner won.  After
    execution, core/multiply.py attaches the executed blocked-path
    stack statistics as ``executor_stats`` (a ``dataclasses.replace``
    copy — cached plan objects stay stats-free).
    """

    algorithm: str
    densify: bool
    c_repl: int
    align: Optional[bool]          # blocked path only, else None
    stack_tile: Optional[int]      # blocked path only, else None
    params_source: Optional[str]   # winners-table provenance
    occupancy: float
    predicted_s: float
    trivial: bool
    candidates: Tuple[CandidateCost, ...]
    pipeline_depth: int = 1        # schedule-engine depth to execute at
    overlap_eff: float = 0.0       # calibrated overlap term of the winner
    executor_stats: Optional[dict] = None
    schedule_stats: Optional[dict] = None
    # ABFT outcome (core/multiply.py attaches post-execution, like the
    # stats above — cached plan objects stay verification-free): pricing
    # from decide_verify plus the VerificationReport when it ran
    verification: Optional[dict] = None
    # rank-exact pricing (ISSUE 9): the per-rank retained-triple
    # imbalance (max/mean) the blocked candidates were charged under,
    # and the costed permutation-pass decision (sparsity/balance.py) —
    # rebalance is selected iff the compute the flattened imbalance
    # saves exceeds the permutation's amortized cost
    rank_imbalance: float = 1.0
    rebalance: bool = False
    rebalance_saved_s: float = 0.0
    rebalance_cost_s: float = 0.0
    # tensor contractions (repro.tensor): the matricization layout this
    # plan executes under, e.g. "(ij|k)@(k|l)" — None for plain 2D
    # multiplies.  plan_contract stamps it on the winning layout's plan.
    layout: Optional[str] = None

    @property
    def chosen(self) -> Optional[CandidateCost]:
        for c in self.candidates:
            if (c.algorithm == self.algorithm and c.densify == self.densify
                    and c.c_repl == self.c_repl):
                return c
        return None

    def explain(self) -> str:
        """Human-readable per-candidate predicted costs."""
        path = "densified" if self.densify else "blocked"
        head = (f"plan: {self.algorithm} + {path}"
                + (f" (c={self.c_repl})" if self.c_repl > 1 else "")
                + (f"  layout={self.layout}" if self.layout else "")
                + f"  occupancy={self.occupancy:.3g}"
                + f"  predicted={self.predicted_s * 1e3:.3g} ms")
        if self.trivial:
            return head + "  [trivial: empty mask product, nothing to do]"
        head += (f"\n  schedule: pipeline_depth={self.pipeline_depth} "
                 f"overlap_eff={self.overlap_eff:.2f} [calibrated]")
        if self.stack_tile is not None:
            head += (f"\n  stack params: align={self.align} "
                     f"stack_tile={self.stack_tile} [{self.params_source}]")
        if self.rank_imbalance > 1.0 or self.rebalance:
            verdict = ("applied" if self.rebalance else "declined")
            head += (f"\n  rank imbalance: {self.rank_imbalance:.2f} "
                     f"rebalance={verdict} "
                     f"(saves {self.rebalance_saved_s * 1e3:.3g} ms vs "
                     f"{self.rebalance_cost_s * 1e3:.3g} ms permute cost)")
        lines = [head,
                 f"  {'candidate':26s} {'comm_ms':>9s} {'compute_ms':>11s} "
                 f"{'overhead_ms':>12s} {'overlap_ms':>11s} {'total_ms':>9s} "
                 f"{'imbal':>6s}"]
        for c in sorted(self.candidates, key=lambda c: c.total_s):
            star = "*" if c is self.chosen else " "
            if c.feasible:
                lines.append(
                    f"{star} {c.label:26s} {c.comm_s * 1e3:9.3f} "
                    f"{c.compute_s * 1e3:11.3f} {c.overhead_s * 1e3:12.3f} "
                    f"{-c.overlap_s * 1e3:11.3f} {c.total_s * 1e3:9.3f} "
                    f"{c.imbalance:6.2f}")
            else:
                lines.append(f"{star} {c.label:26s} {'-':>9s} {'-':>11s} "
                             f"{'-':>12s} {'-':>11s} {'-':>9s}  "
                             f"infeasible: {c.reason}")
        return "\n".join(lines)


def _normalize_mesh_shape(mesh_shape) -> Tuple[int, int, int]:
    t = tuple(int(x) for x in mesh_shape)
    if len(t) == 2:
        return t + (1,)
    if len(t) == 3:
        return t
    raise ValueError(f"mesh_shape must be (pr, pc) or (pr, pc, c): {t}")


def _trivial_plan(prob: Problem, algorithm: Optional[str],
                  densify: Optional[bool]) -> MultiplyPlan:
    """Empty product (mask-empty, or norm-predicted-empty under a
    filter_eps): nothing will be multiplied, so return a zero-cost plan
    without costing any candidate (the blocked model would divide by
    zero occupancy).  The blocked path is preferred — its all-empty
    step plans skip every dispatch — falling back to whatever geometry
    the mesh admits."""
    if algorithm is not None:
        order = [(algorithm, densify if densify is not None else False),
                 (algorithm, True)]
    else:
        order = [(a, d) for d in (False, True)
                 for a in ("cannon25d" if prob.c_stack > 1 else "cannon",
                           "cannon", "summa", "ts_k", "ts_m", "ts_n")]
    for algo, dens in order:
        if feasible(prob, algo, dens, prob.c_stack if algo == "cannon25d"
                    else 1):
            return MultiplyPlan(
                algorithm=algo, densify=bool(dens),
                c_repl=prob.c_stack if algo == "cannon25d" else 1,
                align=None, stack_tile=None, params_source=None,
                occupancy=0.0, predicted_s=0.0, trivial=True,
                candidates=())
    # nothing fits (degenerate mesh/shape): let the executor raise its
    # own loud error; report the densified fallback
    return MultiplyPlan(algorithm=algorithm or "summa", densify=True,
                        c_repl=1, align=None, stack_tile=None,
                        params_source=None, occupancy=0.0, predicted_s=0.0,
                        trivial=True, candidates=())


def _winners_stamp():
    """Content stamp of the autotune winners table; part of the plan
    cache key so an in-process sweep (or a fresh table written by
    bench/autotune runs) invalidates plans that baked in its params."""
    import os

    from repro.kernels.smm.autotune import DEFAULT_CACHE

    try:
        st = os.stat(DEFAULT_CACHE)
        return (st.st_mtime_ns, st.st_size)
    except OSError:
        return None


@functools.lru_cache(maxsize=_PLAN_CACHE_SIZE)
def _plan_cached(
    m: int, k: int, n: int,
    block_m: int, block_k: int, block_n: int,
    pr: int, pc: int, c_stack: int,
    occupancy: float, itemsize: int,
    algorithm: Optional[str], densify: Optional[bool],
    stack_size: Optional[int], align: Optional[bool],
    hw: HardwareModel,
    winners_stamp=None,
    rank_imbalance: Optional[float] = None,
) -> MultiplyPlan:
    prob = Problem(m, k, n, block_m, block_k, block_n, occupancy,
                   itemsize, pr, pc, c_stack)

    # stack params for the blocked candidates: the occupancy-binned
    # autotune winner (and its recorded throughput, when the sweep ran
    # on this container) feeds the model; caller pins win
    from repro.kernels.smm.autotune import best_params_meta

    meta = best_params_meta(block_m, block_k, block_n, fill=occupancy)
    tuned_align = align if align is not None else meta["align"]
    tuned_tile = stack_size if stack_size is not None else meta["stack_tile"]
    smm_rate = (meta["gflops"] * 1e9) if meta.get("gflops") else None

    candidates = enumerate_candidates(
        hw, prob, algorithm, densify,
        stack_tile=tuned_tile, smm_flops_per_s=smm_rate,
        rank_imbalance=rank_imbalance)
    ranked = sorted([c for c in candidates if c.feasible],
                    key=lambda c: c.total_s)
    if not ranked:
        # no fully-feasible candidate: fall back to the least-bad
        # geometry-valid one (finite total = only the memory gate
        # tripped); a forced configuration is honoured regardless (the
        # executor raises its own loud error if it truly cannot run)
        ranked = sorted([c for c in candidates
                         if math.isfinite(c.total_s)],
                        key=lambda c: c.total_s)
    if ranked:
        best = ranked[0]
    elif algorithm is not None:
        best = candidates[0]
    else:
        reasons = "; ".join(f"{c.label}: {c.reason}" for c in candidates)
        raise ValueError(f"no feasible multiply candidate — {reasons}")

    blocked = not best.densify
    # costed permutation pass (sparsity/balance.py): flattening the
    # per-rank imbalance scales the blocked winner's max-rank compute
    # back toward the mean; apply iff the saving beats the permutation's
    # amortized cost.  Densified winners execute the full local GEMM
    # regardless of the mask layout, so there is nothing to rebalance.
    imb = max(float(rank_imbalance), 1.0) if rank_imbalance else 1.0
    rebalance = False
    saved_s = permute_s = 0.0
    if blocked and imb > 1.0 and math.isfinite(best.compute_s):
        permute_s = rebalance_cost_s(hw, prob)
        saved_s = best.compute_s * (1.0 - 1.0 / imb)
        rebalance = saved_s > permute_s
    # schedule-engine depth: double-buffer whenever the winner's
    # schedule has more than one step (depth 2 never predicts slower —
    # overlap_s >= 0); single-step schedules gain nothing from a second
    # buffer, so plans record the serial depth for them
    steps = algorithm_steps(prob, best.algorithm, best.c_repl)
    return MultiplyPlan(
        algorithm=best.algorithm,
        densify=best.densify,
        c_repl=best.c_repl,
        align=bool(tuned_align) if blocked else None,
        stack_tile=int(tuned_tile) if blocked else None,
        params_source=meta["source"] if blocked else None,
        occupancy=occupancy,
        predicted_s=best.total_s,
        trivial=False,
        candidates=candidates,
        pipeline_depth=2 if steps > 1 else 1,
        overlap_eff=overlap_efficiency(hw, best.algorithm),
        rank_imbalance=imb,
        rebalance=rebalance,
        rebalance_saved_s=saved_s,
        rebalance_cost_s=permute_s,
    )


def plan_multiply(
    m: int,
    k: int,
    n: int,
    *,
    blocks: Tuple[int, int, int] = (64, 64, 64),
    mesh_shape=(1, 1),
    occupancy: float = 1.0,
    dtype=np.float32,
    algorithm: Optional[str] = None,
    densify: Optional[bool] = None,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    hw: Optional[HardwareModel] = None,
    rank_imbalance: Optional[float] = None,
) -> MultiplyPlan:
    """Choose how to run C = A @ B of global shape (m, k) x (k, n).

    blocks      (block_m, block_k, block_n) of the blocked layout
    mesh_shape  (pr, pc) process grid, or (pr, pc, c) with a 2.5D
                stack/pod axis of size c
    occupancy   present-triple fraction of the dense block-triple grid
                (1.0 = dense; 0.0 = empty product -> trivial plan)
    algorithm   force a data-exchange algorithm (None = planner's pick)
    densify     force the local path (None = planner's pick)
    stack_size/align  pin the blocked path's stack params (None = the
                occupancy-binned autotune winner)
    hw          cost-model constants (None = calibrate.get_hardware_model)
    rank_imbalance  max/mean per-rank retained-triple load from the
                caller's mask decomposition (sparsity.balance): switches
                blocked compute to rank-exact max-rank pricing and arms
                the costed permutation-pass decision; None keeps the
                legacy union-plan pricing

    Results are LRU-cached on the full signature: a second identical
    call returns the cached plan with zero cost-model evaluations.
    """
    pr, pc, c_stack = _normalize_mesh_shape(mesh_shape)
    bm, bk, bn = (int(b) for b in blocks)
    occ = float(occupancy)
    if occ <= 0.0:
        return _trivial_plan(
            Problem(m, k, n, bm, bk, bn, 0.0, int(np.dtype(dtype).itemsize),
                    pr, pc, c_stack),
            algorithm, densify)
    if hw is None:
        from .calibrate import get_hardware_model

        hw = get_hardware_model()
    return _plan_cached(
        int(m), int(k), int(n), bm, bk, bn, pr, pc, c_stack,
        round(occ, 9), int(np.dtype(dtype).itemsize),
        algorithm, None if densify is None else bool(densify),
        stack_size, align, hw, _winners_stamp(),
        None if rank_imbalance is None else round(float(rank_imbalance), 6))


@dataclasses.dataclass(frozen=True)
class BatchedMultiplyPlan:
    """The planner's fuse-or-loop decision for a batch of ``n_requests``
    same-configuration multiplies, wrapping the shared per-request
    ``MultiplyPlan``.

    ``fuse`` prices one fused batched dispatch (G-fold payload, ONE
    message sequence / launch, ``padding_frac`` wasted compute rows)
    against G single dispatches (G-fold message latency and host
    dispatch cost) — ``cost_model.batched_dispatch_cost``.  After
    execution, core/multiply_batched.py attaches the fused dispatch's
    padding / cross-request plan-sharing accounting as
    ``executor_stats``.
    """

    n_requests: int
    fuse: bool
    algorithm: str
    densify: bool
    padding_frac: float            # estimated cross-request padding waste
    predicted_fused_s: float
    predicted_looped_s: float
    per_request: MultiplyPlan
    executor_stats: Optional[dict] = None

    # -- per-request plan fields the batched executor consumes ---------
    @property
    def stack_tile(self) -> Optional[int]:
        return self.per_request.stack_tile

    @property
    def align(self) -> Optional[bool]:
        return self.per_request.align

    @property
    def pipeline_depth(self) -> int:
        return self.per_request.pipeline_depth

    @property
    def trivial(self) -> bool:
        return self.per_request.trivial

    @property
    def predicted_speedup(self) -> float:
        """Looped-over-fused predicted time ratio (> 1 favours fusing)."""
        if self.predicted_fused_s <= 0.0:
            return 1.0
        return self.predicted_looped_s / self.predicted_fused_s

    def explain(self) -> str:
        head = (f"batched plan: {self.n_requests} requests -> "
                + ("FUSE" if self.fuse else "LOOP")
                + f"  fused={self.predicted_fused_s * 1e3:.3g} ms"
                + f"  looped={self.predicted_looped_s * 1e3:.3g} ms"
                + f"  padding={self.padding_frac:.3g}")
        return head + "\n" + self.per_request.explain()


def plan_multiply_batched(
    n_requests: int,
    m: int,
    k: int,
    n: int,
    *,
    blocks: Tuple[int, int, int] = (64, 64, 64),
    mesh_shape=(1, 1),
    occupancy: float = 1.0,
    dtype=np.float32,
    algorithm: Optional[str] = None,
    densify: Optional[bool] = None,
    padding_frac: float = 0.0,
    stack_size: Optional[int] = None,
    align: Optional[bool] = None,
    hw: Optional[HardwareModel] = None,
) -> BatchedMultiplyPlan:
    """Plan a batch of ``n_requests`` same-geometry multiplies.

    The per-request choice runs through the ordinary (LRU-cached)
    ``plan_multiply`` restricted to the batch-capable algorithms
    (``cost_model.BATCHED_ALGORITHMS`` — the schedules that generalize
    over a leading product dim); ``occupancy`` is the batch's MEAN
    retained-triple fraction and ``padding_frac`` the caller's estimate
    of the fused dispatch's cross-request padding waste (the
    occupancy-spread of the bucket).  An empty batch plan
    (``trivial``) always reports ``fuse=False`` — there is nothing to
    amortize.
    """
    if algorithm is not None and algorithm not in BATCHED_ALGORITHMS:
        raise ValueError(
            f"batched dispatch supports {BATCHED_ALGORITHMS}, got "
            f"{algorithm!r}")
    algos = (algorithm,) if algorithm is not None else BATCHED_ALGORITHMS
    plans = [
        plan_multiply(m, k, n, blocks=blocks, mesh_shape=mesh_shape,
                      occupancy=occupancy, dtype=dtype, algorithm=algo,
                      densify=densify, stack_size=stack_size, align=align,
                      hw=hw)
        for algo in algos
    ]
    best = min(plans, key=lambda p: p.predicted_s)
    g = int(n_requests)
    if best.trivial:
        return BatchedMultiplyPlan(
            n_requests=g, fuse=False, algorithm=best.algorithm,
            densify=best.densify, padding_frac=float(padding_frac),
            predicted_fused_s=0.0, predicted_looped_s=0.0,
            per_request=best)
    if hw is None:
        from .calibrate import get_hardware_model

        hw = get_hardware_model()
    chosen = best.chosen
    if chosen is not None:
        fused_s, looped_s = batched_dispatch_cost(
            hw, chosen, g, padding_frac)
    else:
        # forced configuration with no costed candidate: amortize the
        # dispatch price alone
        looped_s = g * (best.predicted_s + hw.dispatch_s)
        fused_s = g * best.predicted_s + hw.dispatch_s
    return BatchedMultiplyPlan(
        n_requests=g,
        fuse=bool(g > 1 and fused_s <= looped_s),
        algorithm=best.algorithm,
        densify=best.densify,
        padding_frac=float(padding_frac),
        predicted_fused_s=fused_s,
        predicted_looped_s=looped_s,
        per_request=best,
    )


@dataclasses.dataclass(frozen=True)
class LayoutCandidate:
    """One priced matricization of a tensor contraction: the 2D
    problem the layout induces, its copy traffic, and its best multiply
    plan's predicted time (infeasible layouts carry the reason
    instead)."""

    layout: str
    m: int
    k: int
    n: int
    occupancy: float
    rank_imbalance: float
    copy_s: float
    multiply_s: float
    total_s: float
    algorithm: str
    densify: bool
    feasible: bool
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class ContractionPlan:
    """The planner's decision for one tensor contraction: WHICH
    matricization layout to execute (the new candidate axis on top of
    the 2D algorithm/path choice), wrapping the winning layout's
    ``MultiplyPlan`` (its ``layout`` field stamped).

    ``predicted_s = copy_s + plan.predicted_s``: a layout is priced as
    its unfold/refold data movement (``cost_model.matricize_cost_s``)
    plus its own 2D multiply plan — each layout gets its own occupancy
    and per-rank imbalance estimate from the matricized masks.
    """

    spec: str
    layout: str
    copy_s: float
    predicted_s: float
    layouts: Tuple[LayoutCandidate, ...]
    plan: MultiplyPlan
    verification: Optional[dict] = None

    @property
    def algorithm(self) -> str:
        return self.plan.algorithm

    @property
    def densify(self) -> bool:
        return self.plan.densify

    @property
    def trivial(self) -> bool:
        return self.plan.trivial

    @property
    def chosen(self) -> Optional[LayoutCandidate]:
        for c in self.layouts:
            if c.layout == self.layout:
                return c
        return None

    def explain(self) -> str:
        """Per-layout predicted costs (the layout column), then the
        winning layout's full multiply-plan breakdown."""
        head = (f"contraction plan: {self.spec}  layout={self.layout}"
                f"  algorithm={self.algorithm}"
                f"  predicted={self.predicted_s * 1e3:.3g} ms")
        lines = [head,
                 f"  {'layout':26s} {'m x k x n':>18s} {'occ':>6s} "
                 f"{'imbal':>6s} {'copy_ms':>8s} {'mult_ms':>8s} "
                 f"{'total_ms':>9s}"]
        for c in sorted(self.layouts,
                        key=lambda c: (not c.feasible, c.total_s)):
            star = "*" if c.layout == self.layout else " "
            shape = f"{c.m}x{c.k}x{c.n}"
            if c.feasible:
                lines.append(
                    f"{star} {c.layout:26s} {shape:>18s} "
                    f"{c.occupancy:6.3f} {c.rank_imbalance:6.2f} "
                    f"{c.copy_s * 1e3:8.3f} {c.multiply_s * 1e3:8.3f} "
                    f"{c.total_s * 1e3:9.3f}")
            else:
                lines.append(f"{star} {c.layout:26s} {shape:>18s} "
                             f"infeasible: {c.reason}")
        return "\n".join(lines) + "\n" + self.plan.explain()


@functools.lru_cache(maxsize=_PLAN_CACHE_SIZE)
def _plan_contract_cached(
    spec: str,
    stats: tuple,
    pr: int, pc: int,
    itemsize: int,
    algorithm: Optional[str],
    densify: Optional[bool],
    hw: HardwareModel,
    winners_stamp=None,
) -> ContractionPlan:
    from .cost_model import matricize_cost_s

    cands = []
    best = None       # (total_s, LayoutCandidate, MultiplyPlan)
    for ls in stats:
        if not ls.feasible:
            cands.append(LayoutCandidate(
                layout=ls.label, m=ls.m, k=ls.k, n=ls.n,
                occupancy=ls.occupancy,
                rank_imbalance=ls.rank_imbalance or 1.0,
                copy_s=0.0, multiply_s=math.inf, total_s=math.inf,
                algorithm="-", densify=False, feasible=False,
                reason=ls.reason))
            continue
        dtype = {4: np.float32, 8: np.float64, 2: np.float16}.get(
            itemsize, np.float32)
        try:
            mp = plan_multiply(
                ls.m, ls.k, ls.n,
                blocks=(ls.block_m, ls.block_k, ls.block_n),
                mesh_shape=(pr, pc), occupancy=ls.occupancy,
                dtype=dtype, algorithm=algorithm, densify=densify,
                hw=hw, rank_imbalance=ls.rank_imbalance)
        except ValueError as e:
            cands.append(LayoutCandidate(
                layout=ls.label, m=ls.m, k=ls.k, n=ls.n,
                occupancy=ls.occupancy,
                rank_imbalance=ls.rank_imbalance or 1.0,
                copy_s=0.0, multiply_s=math.inf, total_s=math.inf,
                algorithm="-", densify=False, feasible=False,
                reason=str(e)))
            continue
        copy_s = matricize_cost_s(hw, ls.copy_bytes)
        total = copy_s + mp.predicted_s
        cand = LayoutCandidate(
            layout=ls.label, m=ls.m, k=ls.k, n=ls.n,
            occupancy=ls.occupancy,
            rank_imbalance=mp.rank_imbalance,
            copy_s=copy_s, multiply_s=mp.predicted_s, total_s=total,
            algorithm=mp.algorithm, densify=mp.densify, feasible=True)
        cands.append(cand)
        if best is None or total < best[0]:
            best = (total, cand, mp)
    if best is None:
        reasons = "; ".join(f"{c.layout}: {c.reason}" for c in cands)
        raise ValueError(f"no feasible matricization for {spec!r} on a "
                         f"{pr}x{pc} grid — {reasons}")
    total, cand, mp = best
    return ContractionPlan(
        spec=spec, layout=cand.layout, copy_s=cand.copy_s,
        predicted_s=total, layouts=tuple(cands),
        plan=dataclasses.replace(mp, layout=cand.layout))


def plan_contract(
    spec: str,
    layout_stats,
    *,
    mesh_shape=(1, 1),
    dtype=np.float32,
    algorithm: Optional[str] = None,
    densify: Optional[bool] = None,
    hw: Optional[HardwareModel] = None,
) -> ContractionPlan:
    """Choose the matricization layout (and, through ``plan_multiply``,
    the 2D algorithm + local path) for one tensor contraction.

    ``layout_stats`` is the tuple of per-layout geometry statistics
    from ``repro.tensor.matricize.contraction_layout_stats`` — frozen
    and hashable, so together with the normalized spec and the mesh it
    forms the contraction signature the result is LRU-cached on: a
    second identical contraction performs ZERO cost-model evaluations
    (shared ``_PLAN_CACHE_SIZE`` budget with the multiply cache; the
    per-layout ``plan_multiply`` sub-plans land in that cache too, so
    the inner multiply of an executed contraction replans for free).
    """
    pr, pc, _ = _normalize_mesh_shape(mesh_shape)
    if hw is None:
        from .calibrate import get_hardware_model

        hw = get_hardware_model()
    return _plan_contract_cached(
        str(spec), tuple(layout_stats), pr, pc,
        int(np.dtype(dtype).itemsize),
        algorithm, None if densify is None else bool(densify),
        hw, _winners_stamp())


def contract_cache_info():
    return _plan_contract_cached.cache_info()


def contract_cache_clear() -> None:
    _plan_contract_cached.cache_clear()


def decide_verify(
    plan: Optional[MultiplyPlan],
    m: int,
    k: int,
    n: int,
    *,
    blocks: Tuple[int, int, int],
    itemsize: int = 4,
    budget: Optional[float] = None,
    hw: Optional[HardwareModel] = None,
) -> dict:
    """Price ABFT checksum verification against a plan — the costed
    half of ``verify="auto"`` (core/multiply.py).

    Returns ``{"auto_enabled", "predicted_overhead_s", "overhead_frac",
    "budget"}``: verification is auto-enabled when the predicted
    checksum overhead (``cost_model.verify_overhead_s``) fits within
    ``budget`` (default ``DEFAULT_VERIFY_BUDGET``) of the plan's
    predicted multiply time.  A trivial (empty-product) plan reports
    infinite relative overhead — there is nothing worth verifying.
    """
    if budget is None:
        budget = DEFAULT_VERIFY_BUDGET
    budget = float(budget)
    if hw is None:
        from .calibrate import get_hardware_model

        hw = get_hardware_model()
    bm, _, bn = (int(x) for x in blocks)
    overhead = verify_overhead_s(hw, int(m), int(k), int(n), bm, bn,
                                 int(itemsize))
    base = 0.0 if plan is None else float(plan.predicted_s)
    if plan is not None and plan.trivial:
        frac = math.inf
    else:
        frac = overhead / base if base > 0.0 else math.inf
    return {
        "auto_enabled": bool(frac <= budget),
        "predicted_overhead_s": float(overhead),
        "overhead_frac": float(frac),
        "budget": budget,
    }


def plan_cache_info():
    return _plan_cached.cache_info()


def plan_cache_clear() -> None:
    _plan_cached.cache_clear()


def plan_cache_stats() -> dict:
    """Planner LRU accounting: hits / misses / evictions.

    ``evictions`` is derived as ``misses - currsize``: every miss
    inserts one entry, so entries beyond the current size must have
    been evicted.  Valid because ``plan_cache_clear`` resets the
    counters and the size together.

    A thin view over the obs metrics registry (ISSUE 8): the LRU's
    ``cache_info()`` is synced into ``planner.plan_cache.*`` gauges and
    the returned dict is read back from those gauges — one source of
    truth shared with ``python -m repro.obs report`` consumers, same
    return shape as ever for callers.
    """
    from repro import obs

    info = _plan_cached.cache_info()
    reg = obs.registry()
    synced = {
        "hits": int(info.hits),
        "misses": int(info.misses),
        "currsize": int(info.currsize),
        "maxsize": int(info.maxsize),
        "evictions": max(int(info.misses) - int(info.currsize), 0),
    }
    for key, v in synced.items():
        reg.gauge(f"planner.plan_cache.{key}").set(v)
    return {key: int(reg.gauge(f"planner.plan_cache.{key}").value)
            for key in synced}
