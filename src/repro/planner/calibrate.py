"""Fit the planner's hardware constants from measured artifacts.

The cost models in ``cost_model.py`` are only as good as their
constants.  Three sources, later ones overriding earlier:

  1. ``cost_model.DEFAULT_HARDWARE`` — documented built-in defaults
     (sane for this CPU container, see HardwareModel docstring).
  2. ``fit_from_artifacts`` — the existing bench trajectory under
     ``artifacts/bench/``: kernels.json (dense GEMM and fused-smm
     rates), sparse_smoke.json / sparse.json (per-stack-entry overhead
     as the slope of dispatch time over triple count), densify.json
     (cross-check of the dense rate on the densified local path).
  3. ``artifacts/planner_calibration.json`` — constants written by this
     module's CLI or by ``micro_calibrate`` (benchmarks/bench_planner.py
     runs it so the regret gate judges the planner against constants
     measured on the same machine, same process).

``get_hardware_model()`` resolves the merge once and caches it; the
plan cache (plan.py) keys on the resolved HardwareModel value, so a
recalibration automatically invalidates stale plans.

    PYTHONPATH=src python -m repro.planner.calibrate [--micro]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

import numpy as np

from .cost_model import DEFAULT_HARDWARE, HardwareModel

__all__ = [
    "DEFAULT_CALIBRATION",
    "DEFAULT_PLAN_LOG",
    "drift_report",
    "fit_from_artifacts",
    "micro_calibrate",
    "measure_overlap",
    "get_hardware_model",
    "save_calibration",
    "invalidate_cache",
]

DEFAULT_CALIBRATION = os.path.join("artifacts", "planner_calibration.json")
DEFAULT_BENCH_DIR = os.path.join("artifacts", "bench")

_CACHED: Optional[HardwareModel] = None


def _load_json(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def fit_from_artifacts(bench_dir: str = DEFAULT_BENCH_DIR) -> Dict[str, float]:
    """Extract whatever constants the recorded bench artifacts support.

    Returns a (possibly empty) partial dict — communication constants
    cannot be fitted from these single-process artifacts and keep their
    defaults unless a calibration file / micro_calibrate provides them.
    """
    out: Dict[str, float] = {}

    kernels = _load_json(os.path.join(bench_dir, "kernels.json")) or []
    dense = [r["gflops"] for r in kernels if r.get("kernel") == "dense_dot"]
    if dense:
        out["flops_per_s"] = max(dense) * 1e9
    fused = [r["fused_gflops"] for r in kernels
             if r.get("kernel") == "smm_dispatch" and "fused_gflops" in r]
    if fused:
        out["smm_flops_per_s"] = max(fused) * 1e9

    # densified local path cross-check: effective big-GEMM rate incl.
    # the densify copies — keep the more conservative estimate
    densify = _load_json(os.path.join(bench_dir, "densify.json")) or []
    eff = [2.0 * r["m"] * r["k"] * r["n"] / r["t_densified_s"]
           for r in densify if r.get("t_densified_s")]
    if eff and "flops_per_s" in out:
        out["flops_per_s"] = min(out["flops_per_s"], max(eff))
    elif eff:
        out["flops_per_s"] = max(eff)

    # per-entry overhead: slope of sparse dispatch time over triple
    # count, net of the pure-flop time at the fitted smm rate
    sparse = (_load_json(os.path.join(bench_dir, "sparse.json"))
              or _load_json(os.path.join(bench_dir, "sparse_smoke.json")))
    if sparse and sparse.get("rows"):
        rows = sparse["rows"]
        nt = np.array([r["n_triples"] for r in rows], dtype=float)
        ts = np.array([r["t_sparse_s"] for r in rows], dtype=float)
        if len(rows) >= 2 and np.ptp(nt) > 0:
            slope = float(np.polyfit(nt, ts, 1)[0])
            block = int(sparse.get("block", 8))
            flop_per_entry = 2.0 * block ** 3 / out.get(
                "smm_flops_per_s", DEFAULT_HARDWARE.smm_flops_per_s)
            out["stack_entry_s"] = max(slope - flop_per_entry, 1e-8)
    return out


def micro_calibrate(mesh=None, grid=None, reps: int = 5) -> Dict[str, float]:
    """Measure constants live, in-process (seconds of work, not minutes).

    Times a dense dot for ``flops_per_s``, fused-executor runs at two
    block sizes for (``smm_flops_per_s``, ``stack_entry_s``) — two
    equations, two unknowns — and, when a multi-device ``mesh``/``grid``
    is given, a large and a tiny psum for (``bytes_per_s``,
    ``latency_s``) plus the schedule engine's achieved comm/compute
    overlap per algorithm (``measure_overlap`` -> ``overlap_*``).
    Intended for bench_planner and the CLI; library calls never trigger
    measurement implicitly.
    """
    import time

    import jax
    import jax.numpy as jnp

    def best_of(fn, *args):
        jax.block_until_ready(fn(*args))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    out: Dict[str, float] = {}
    rng = np.random.RandomState(0)

    s = 384
    a = jnp.asarray(rng.randn(s, s).astype(np.float32))
    b = jnp.asarray(rng.randn(s, s).astype(np.float32))
    t = best_of(jax.jit(lambda a, b: a @ b), a, b)
    out["flops_per_s"] = 2.0 * s ** 3 / max(t, 1e-9)

    # two block sizes => separate the per-flop rate from the per-entry
    # overhead: slope_b = 2*b^3/F + E
    from repro.core.densify import to_blocks
    from repro.core.engine import build_executor_plan, execute_plan

    slopes = {}
    for block in (8, 16):
        nb = 8
        dim = block * nb
        af = jnp.asarray(rng.randn(dim, dim).astype(np.float32))
        bf = jnp.asarray(rng.randn(dim, dim).astype(np.float32))
        ab, bb = to_blocks(af, block, block), to_blocks(bf, block, block)
        c0 = jnp.zeros((nb * nb, block, block), jnp.float32)
        times = {}
        for fill in (1.0, 0.25):
            mask = None
            if fill < 1.0:
                mask = np.zeros(nb * nb, dtype=bool)
                mask[rng.choice(nb * nb, int(fill * nb * nb),
                                replace=False)] = True
                mask = mask.reshape(nb, nb)
            plan = build_executor_plan(dim, dim, dim, block, block, block,
                                       512, a_mask=mask)
            times[fill] = (best_of(jax.jit(
                lambda ab, bb, c0, p=plan: execute_plan(
                    p, ab, bb, c0, kernel="ref")), ab, bb, c0),
                plan.n_entries)
        (t_hi, n_hi), (t_lo, n_lo) = times[1.0], times[0.25]
        if n_hi > n_lo:
            slopes[block] = max((t_hi - t_lo) / (n_hi - n_lo), 1e-9)
    if len(slopes) == 2:
        s8, s16 = slopes[8], slopes[16]
        df = 2.0 * (16 ** 3 - 8 ** 3)
        if s16 > s8:
            out["smm_flops_per_s"] = df / (s16 - s8)
            out["stack_entry_s"] = max(s8 - 2.0 * 8 ** 3
                                       / out["smm_flops_per_s"], 1e-8)
        else:  # overhead-dominated regime: slope IS the entry cost
            out["stack_entry_s"] = s8
            out["smm_flops_per_s"] = DEFAULT_HARDWARE.smm_flops_per_s

    if mesh is not None and grid is not None and mesh.devices.size > 1:
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map

        axes = (grid.row_axis, grid.col_axis)
        spec = P(axes[0], axes[1])
        pr, pc = grid.grid_shape(mesh)

        # MARGINAL per-collective cost: a single timed jit call carries
        # ~0.1-1 ms of fixed dispatch overhead that every *multiply*
        # pays once, not once per collective — so time a chain of n
        # data-dependent psums against a chain of 1 and difference them
        def chain(n):
            # payload size rides on the input array; the body is the
            # same n-deep data-dependent psum chain either way
            def body(x):
                for i in range(n):
                    x = jax.lax.psum(x + np.float32(i), axes)
                return x

            return jax.jit(shard_map(body, mesh=mesh, in_specs=(spec,),
                                     out_specs=P(None, None),
                                     check_vma=False))

        reps_n = 8
        tiny = jnp.ones((pr, pc), jnp.float32)
        dt_tiny = best_of(chain(reps_n), tiny) - best_of(chain(1), tiny)
        out["latency_s"] = max(dt_tiny / (reps_n - 1), 1e-7)
        side = 256
        big = jnp.ones((pr * side, pc * side), jnp.float32)
        dt_big = best_of(chain(reps_n), big) - best_of(chain(1), big)
        per_msg = max(dt_big / (reps_n - 1) - out["latency_s"], 1e-9)
        bytes_moved = 2.0 * side * side * 4  # per-device shard, both ways
        out["bytes_per_s"] = bytes_moved / per_msg

        # achieved comm/compute overlap of the schedule engine, judged
        # against the bandwidth just measured
        hw = DEFAULT_HARDWARE.replace(
            **{k: v for k, v in out.items()
               if k in DEFAULT_HARDWARE.to_dict()})
        out.update(measure_overlap(mesh, grid, reps=reps, hw=hw))
    return out


def measure_overlap(mesh=None, grid=None, reps: int = 5,
                    hw=None) -> Dict[str, float]:
    """Measure the schedule engine's *achieved* comm/compute overlap.

    For each multi-step algorithm the mesh admits, times the same
    multiply at ``pipeline_depth=1`` (serial) and ``pipeline_depth=2``
    (double-buffered) and converts the saving into an efficiency in
    [0, 1] against the model's predicted communication time:

        overlap_<algo> = (t_serial - t_pipelined) / comm_s_model

    This is the calibration source for the cost model's per-algorithm
    overlap discount (``HardwareModel.overlap_*``) — measured, not
    assumed, so a backend where XLA cannot hide collectives (e.g. the
    CPU interpret-mode container) calibrates to ~0 and the planner
    predicts serial behaviour.  ``overlap_ts`` reuses the Cannon value:
    the ts_* operand prefetch hides behind the same dot issue
    mechanism, but a single-step schedule gives nothing to difference.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.core.multiply import distributed_matmul

    out: Dict[str, float] = {}
    if mesh is None or grid is None or mesh.devices.size <= 1:
        return out
    if hw is None:
        hw = get_hardware_model()

    def best_of(fn, *args):
        jax.block_until_ready(fn(*args))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    from jax.sharding import NamedSharding, PartitionSpec as P

    rng = np.random.RandomState(0)
    pr, pc = grid.grid_shape(mesh)
    c_stack = grid.stack_size(mesh)

    def timed_pair(algo, m, k, n, **kw):
        a = jnp.asarray(rng.randn(m, k).astype(np.float32))
        b = jnp.asarray(rng.randn(k, n).astype(np.float32))
        sh = NamedSharding(mesh, P(grid.row_axis, grid.col_axis))
        a, b = jax.device_put(a, sh), jax.device_put(b, sh)
        fns = [jax.jit(lambda x, y, d=d: distributed_matmul(
            x, y, mesh=mesh, grid=grid, algorithm=algo, densify=True,
            pipeline_depth=d, **kw)) for d in (1, 2)]
        return best_of(fns[0], a, b), best_of(fns[1], a, b)

    def overlap_eff(t1, t2, comm_model_s):
        # measurability gate: when the model says communication is under
        # 10% of the serial runtime, a depth-1 vs depth-2 difference is
        # dominated by timing jitter and the quotient saved/comm would
        # amplify noise into a bogus efficiency (the CPU interpret-mode
        # backend lands here: compute dwarfs modelled comm, and it truly
        # cannot hide collectives — 0 is the honest answer).  Otherwise
        # a saving inside the 5%-of-t1 jitter band still calibrates to 0.
        saved = t1 - t2
        if comm_model_s < 0.1 * t1 or saved < 0.05 * t1:
            saved = 0.0
        return float(min(max(saved / comm_model_s, 0.0), 1.0))

    e = 4  # f32 operands
    targets = []
    if pr == pc:
        side = 128 * pr
        ml = side // pr
        comm = pr * 2 * ml * ml * e            # pg shifts of (a, b) chunks
        targets.append(("overlap_cannon", "cannon", side, comm, {}))
    side_s = 128 * max(pr, pc)
    mls, nls = side_s // pr, side_s // pc
    import math as _math

    n_panels = pc if pr == pc else _math.lcm(pr, pc)
    kls = side_s // n_panels
    comm_s_bytes = 2 * n_panels * (mls * kls + kls * nls) * e
    targets.append(("overlap_summa", "summa", side_s, comm_s_bytes, {}))

    for key, algo, side, comm_bytes, kw in targets:
        try:
            t1, t2 = timed_pair(algo, side, side, side, **kw)
        except Exception:
            continue
        comm_model_s = comm_bytes / hw.bytes_per_s
        if comm_model_s <= 0:
            continue
        out[key] = overlap_eff(t1, t2, comm_model_s)

    if "overlap_cannon" in out:
        # same ppermute pipeline, 1/c of the steps — reuse unless a
        # stack-axis mesh is available to measure directly
        out.setdefault("overlap_cannon25d", out["overlap_cannon"])
        out.setdefault("overlap_ts", out["overlap_cannon"])
    if c_stack > 1 and pr == pc:
        try:
            side = 128 * pr
            t1, t2 = timed_pair("cannon25d", side, side, side)
            ml = side // pr
            comm = (pr // c_stack) * 2 * ml * ml * e
            out["overlap_cannon25d"] = overlap_eff(
                t1, t2, comm / hw.bytes_per_s)
        except Exception:
            pass
    return out


def get_hardware_model(path: Optional[str] = None,
                       bench_dir: Optional[str] = None) -> HardwareModel:
    """Resolve defaults <- artifact fits <- calibration file (cached)."""
    global _CACHED
    if _CACHED is not None and path is None and bench_dir is None:
        return _CACHED
    merged = DEFAULT_HARDWARE.to_dict()
    merged.update(fit_from_artifacts(bench_dir or DEFAULT_BENCH_DIR))
    saved = _load_json(path or DEFAULT_CALIBRATION)
    if saved:
        merged.update({k: v for k, v in saved.items()
                       if k in merged and isinstance(v, (int, float))})
    hw = HardwareModel.from_dict(merged)
    if path is None and bench_dir is None:
        _CACHED = hw
    return hw


def invalidate_cache() -> None:
    global _CACHED
    _CACHED = None


def save_calibration(constants: Dict[str, float],
                     path: str = DEFAULT_CALIBRATION) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({k: float(v) for k, v in constants.items()}, f, indent=1)
    invalidate_cache()
    return path


DEFAULT_PLAN_LOG = os.path.join("artifacts", "obs", "plan_outcomes.jsonl")


def drift_report(path: str = DEFAULT_PLAN_LOG, *,
                 threshold: float = 1.0, min_samples: int = 1) -> dict:
    """Check the telemetry layer's predicted-vs-actual plan-outcome log
    (``obs.record_plan_outcome`` rows, written by traced multiplies and
    ``benchmarks/bench_obs.py``) for calibration drift: algorithms
    whose median |relative error| exceeds ``threshold`` are flagged —
    the signal that this machine's constants need recalibration."""
    from repro.obs import read_jsonl
    from repro.obs.scoreboard import check_drift

    records = read_jsonl(path)
    result = check_drift(records, threshold=threshold,
                         min_samples=min_samples)
    result["path"] = path
    result["n_records"] = len(records)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench-dir", default=DEFAULT_BENCH_DIR)
    ap.add_argument("--out", default=DEFAULT_CALIBRATION)
    ap.add_argument("--micro", action="store_true",
                    help="also measure constants live (dense dot, fused "
                         "executor; single-device only from this CLI)")
    ap.add_argument("--check-drift", action="store_true",
                    help="instead of calibrating, read the predicted-vs-"
                         "actual plan-outcome log and warn when a per-"
                         "algorithm median |rel err| exceeds the "
                         "threshold")
    ap.add_argument("--drift-log", default=DEFAULT_PLAN_LOG,
                    help="plan-outcome JSONL (obs.enable(log_dir=...))")
    ap.add_argument("--drift-threshold", type=float, default=1.0,
                    help="median |predicted-measured|/measured per "
                         "algorithm above which drift is flagged")
    ap.add_argument("--strict", action="store_true",
                    help="with --check-drift: exit nonzero when drift "
                         "is flagged (or the log is missing/empty)")
    args = ap.parse_args()

    if args.check_drift:
        from repro.obs.scoreboard import render_scoreboard

        result = drift_report(args.drift_log,
                              threshold=args.drift_threshold)
        if not result["n_records"]:
            print(f"no plan outcomes at {args.drift_log} — run a traced "
                  f"multiply (obs.enable(log_dir=...)) or "
                  f"benchmarks/bench_obs.py first")
            if args.strict:
                raise SystemExit(1)
            return
        print(render_scoreboard(result["scoreboard"]))
        for algo, err in sorted(result["flagged"].items()):
            print(f"WARNING: {algo}: median |rel err| {err:.2f} exceeds "
                  f"drift threshold {args.drift_threshold:.2f} — "
                  f"recalibrate (python -m repro.planner.calibrate)")
        if result["ok"]:
            print(f"calibration drift OK ({result['n_records']} outcomes, "
                  f"threshold {args.drift_threshold:.2f})")
        elif args.strict:
            raise SystemExit(
                f"calibration drift: {sorted(result['flagged'])}")
        return

    constants = fit_from_artifacts(args.bench_dir)
    if args.micro:
        constants.update(micro_calibrate())
    path = save_calibration(constants, args.out)
    hw = get_hardware_model(path, args.bench_dir)
    print("fitted constants:")
    for k, v in hw.to_dict().items():
        src = ("calibrated" if k in constants else "default")
        print(f"  {k:20s} {v:12.4g}  [{src}]")
    print("wrote ->", path)


if __name__ == "__main__":
    main()
