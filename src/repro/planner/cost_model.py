"""Analytic per-algorithm cost models for the multiply planner.

The paper's driver layer wins ("up to 2.5x over optimized PDGEMM for
matrices of different sizes and shapes") because it picks the right
decomposition per problem, not because any single kernel is fastest.
The communication-volume models here follow the 2.5D companion paper
(Lazzaro et al., arXiv:1705.10218, section 3) specialised to the four
data-exchange algorithms this repo implements:

  cannon      (m*k + k*n) * e / pg   bytes/device over pg shift steps
  cannon25d   cannon / c shift volume + one C reduction, at the cost of
              c-fold operand replication memory (the classic
              communication-avoiding trade; infeasible when the
              replicas do not fit ``mem_bytes``)
  summa       2*(m*k/pr + k*n/pc)*e  (masked-allreduce panel broadcast
              moves ~2x the optimal bcast volume — the baseline's
              handicap that benchmarks/bench_vs_pgemm.py measures)
  ts_*        O(1) in P: one (m, n) partial reduction (ts_k) or one
              operand replication bcast (ts_m / ts_n); per the paper
              the big dimension's operand is assumed already sharded.

Local-path costs:

  densified   full 2*m*k*n flops at the big-GEMM rate (absent blocks
              are stored zeros, so occupancy does NOT discount flops)
              plus the densify/undensify copy.
  blocked     only RETAINED triples dispatch: flops are discounted by
              the triple occupancy, padded up to whole ``stack_tile``
              scan rows (the executor's real dispatch shape), plus a
              per-entry scheduling overhead.  When the operands carry
              block norms and a ``filter_eps`` (repro.sparsity), the
              occupancy the caller passes is the NORM-PREDICTED
              retained-triple fraction (mask-present triples clearing
              the eps norm-product bound, core/multiply.py
              ``_global_occupancy``), not the binary mask fill — the
              on-the-fly filter's savings price into every blocked
              candidate.  Occupancy zero is a contract violation here —
              the caller (plan.py) must short-circuit an empty product
              (mask-empty OR norm-predicted-empty under eps) to a
              trivial plan *before* any candidate is costed (this is
              where the old divide-by-zero lived).

Comm/compute overlap (the schedule engine, core/schedule.py): at
``pipeline_depth >= 2`` the driver issues step t+1's ppermute / panel
broadcast while step t's stacks execute, hiding part of the
communication behind compute.  The model discounts each candidate by

    overlap_s = eff(algorithm) * min(overlappable_comm_s, compute_s)

where ``overlappable_comm_s`` is the algorithm's pipelined comm volume
(all but the un-hideable first/last transfer: Cannon shifts, SUMMA
panel broadcasts, the ts_* operand prefetch) and ``eff`` is the
per-algorithm *measured* overlap efficiency in [0, 1]
(``HardwareModel.overlap_*``, fitted by ``calibrate.measure_overlap``
from depth-1 vs depth-2 timings — this replaces the old ts-only
"prefetchable so latency-light" special case with calibrated data).

Hardware constants live in ``HardwareModel``; defaults are documented
below and overridden by ``repro.planner.calibrate`` from measured
artifacts.  Every candidate evaluation bumps ``N_EVALS`` so tests (and
the plan-cache contract) can prove a cached plan re-evaluates nothing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = [
    "HardwareModel",
    "Problem",
    "CandidateCost",
    "DEFAULT_HARDWARE",
    "candidate_cost",
    "batched_dispatch_cost",
    "verify_overhead_s",
    "enumerate_candidates",
    "feasible",
    "rebalance_cost_s",
    "matricize_cost_s",
    "overlap_efficiency",
    "algorithm_steps",
    "ts_crossover_ratio",
    "ALGORITHMS",
    "BATCHED_ALGORITHMS",
]

# bumped once per candidate_cost evaluation; the plan cache test
# asserts this stays flat across a cache hit
N_EVALS = 0

ALGORITHMS = ("cannon", "cannon25d", "summa", "ts_k", "ts_m", "ts_n")

# algorithms whose schedules are batch-shape-agnostic and therefore
# eligible for the fused product-batched dispatch
# (core/multiply_batched.py); "summa_gather" (summa with
# bcast="gather") is priced by the model below but only when pinned —
# it never enters the auto enumeration, its sqrt(P)-fold operand
# replication makes it a niche small-K configuration
BATCHED_ALGORITHMS = ("cannon", "summa")


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Calibratable hardware constants (all SI).

    Defaults are fitted to this container's measured artifacts (see
    ROADMAP "Planner" section for provenance):

      flops_per_s         dense-GEMM rate; artifacts/bench/kernels.json
                          dense_dot row (~127 GF/s CPU interpret)
      smm_flops_per_s     blocked-stack rate; kernels.json smm_dispatch
                          fused rows (~5-21 GF/s)
      stack_entry_s       per-triple scheduling overhead; slope of
                          t_sparse vs n_triples in
                          artifacts/bench/sparse_smoke.json (~3 us)
      bytes_per_s         interconnect bandwidth per device (host
                          backend: effectively memcpy)
      latency_s           per-collective dispatch latency (host backend
                          ~0.2 ms; TPU ~1 us — calibration overrides)
      densify_bytes_per_s densify/undensify copy bandwidth
      mem_bytes           per-device memory capacity (gates 2.5D
                          replication and ts_* operand replication)
      overlap_*           measured comm/compute overlap efficiency in
                          [0, 1] per algorithm family (fraction of the
                          pipelined communication the schedule engine
                          hides behind compute at pipeline_depth >= 2;
                          calibrate.measure_overlap fits these from
                          depth-1 vs depth-2 timings).  Defaults are 0
                          — serial-equivalent predictions — until a
                          calibration run measures the real machine.
    """

    flops_per_s: float = 1.25e11
    smm_flops_per_s: float = 1.0e10
    stack_entry_s: float = 3.0e-6
    bytes_per_s: float = 1.0e10
    latency_s: float = 2.0e-4
    densify_bytes_per_s: float = 2.0e10
    mem_bytes: float = 8.0e9
    overlap_cannon: float = 0.0
    overlap_cannon25d: float = 0.0
    overlap_summa: float = 0.0
    overlap_ts: float = 0.0
    # per-request host-side dispatch cost: shard_map closure build +
    # trace/compile-cache lookup + launch of one distributed multiply
    # (the fixed price a looped dispatch pays PER product and a fused
    # batched dispatch pays once; see batched_dispatch_cost).  Host
    # backend ~2 ms measured; ``from_dict`` filters unknown keys so
    # pre-existing calibration artifacts stay loadable.
    dispatch_s: float = 2.0e-3

    def replace(self, **kw) -> "HardwareModel":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "HardwareModel":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: float(v) for k, v in d.items() if k in names})


DEFAULT_HARDWARE = HardwareModel()


@dataclasses.dataclass(frozen=True)
class Problem:
    """Static description of one distributed multiply."""

    m: int
    k: int
    n: int
    block_m: int
    block_k: int
    block_n: int
    occupancy: float        # retained-triple fraction of the dense grid
                            # (norm-predicted under a filter_eps)
    itemsize: int           # operand dtype bytes
    pr: int
    pc: int
    c_stack: int = 1        # available 2.5D replication (mesh stack axis)

    @property
    def p2d(self) -> int:
        return self.pr * self.pc

    @property
    def p_all(self) -> int:
        return self.pr * self.pc * self.c_stack


@dataclasses.dataclass(frozen=True)
class CandidateCost:
    """Predicted cost of one (algorithm, local path) candidate."""

    algorithm: str
    densify: bool
    c_repl: int
    feasible: bool
    reason: str             # infeasibility reason ("" when feasible)
    comm_s: float
    compute_s: float
    overhead_s: float       # message latency + densify copies
    overlap_s: float        # comm hidden behind compute (subtracted)
    mem_bytes: float
    total_s: float
    # rank-exact pricing: the per-rank load imbalance (max/mean retained
    # triples) the blocked compute was charged under — 1.0 when the
    # candidate is densified, imbalance-free, or priced by the legacy
    # union model
    imbalance: float = 1.0

    @property
    def label(self) -> str:
        path = "densified" if self.densify else "blocked"
        c = f" c={self.c_repl}" if self.c_repl > 1 else ""
        return f"{self.algorithm}+{path}{c}"


def _infeasible(algorithm: str, densify: bool, c_repl: int,
                reason: str) -> CandidateCost:
    return CandidateCost(algorithm, densify, c_repl, False, reason,
                         math.inf, math.inf, math.inf, 0.0, math.inf,
                         math.inf)


def overlap_efficiency(hw: HardwareModel, algorithm: str) -> float:
    """The calibrated comm/compute overlap efficiency for one
    algorithm family, clamped to [0, 1]."""
    if algorithm.startswith("ts_"):
        eff = hw.overlap_ts
    else:
        eff = getattr(hw, f"overlap_{algorithm}", 0.0)
    return min(max(float(eff), 0.0), 1.0)


def algorithm_steps(prob: Problem, algorithm: str, c_repl: int = 1) -> int:
    """Data-exchange step count of the algorithm's schedule (1 for the
    tall-skinny variants); 0 when the geometry is infeasible.  Used by
    the planner to decide whether a pipeline depth > 1 buys anything."""
    reason, geom = _local_geometry(prob, algorithm, c_repl)
    return 0 if reason is not None else int(geom[3])


def _local_geometry(prob: Problem, algorithm: str,
                    c_repl: int) -> Tuple[Optional[str], tuple]:
    """Per-step local-multiply (ml, kl, nl) and step count for the
    algorithm, or an infeasibility reason."""
    m, k, n = prob.m, prob.k, prob.n
    pr, pc = prob.pr, prob.pc
    if algorithm in ("cannon", "cannon25d"):
        if pr != pc:
            return f"square grid required, got {pr}x{pc}", ()
        pg = pr
        if m % pg or k % pg or n % pg:
            return f"shape not divisible by grid side {pg}", ()
        if algorithm == "cannon25d":
            if c_repl < 2:
                return "no replication axis", ()
            if pg % c_repl:
                return f"grid side {pg} % replication {c_repl} != 0", ()
        steps = pg if algorithm == "cannon" else pg // c_repl
        return None, (m // pg, k // pg, n // pg, steps)
    if algorithm == "summa":
        n_panels = math.lcm(pr, pc)
        if m % pr or n % pc or k % n_panels:
            return (f"shape not divisible by summa grid {pr}x{pc} "
                    f"({n_panels} panels)", ())
        return None, (m // pr, k // n_panels, n // pc, n_panels)
    if algorithm == "summa_gather":
        # summa with bcast="gather" (PUMMA-style): one prologue
        # all-gather, then a SINGLE full-local-K multiply — any grid
        # shape, K never partitioned locally
        if m % pr or n % pc:
            return f"shape not divisible by gather grid {pr}x{pc}", ()
        return None, (m // pr, k, n // pc, 1)
    if algorithm in ("ts_k", "ts_m", "ts_n"):
        p = prob.p_all
        if algorithm == "ts_k":
            # reduce_scatter (the dispatcher's default) also tiles the
            # output's M over all devices
            if k % p or m % p:
                return f"k/m not divisible by {p} devices", ()
            return None, (m, k // p, n, 1)
        if algorithm == "ts_m":
            if m % p:
                return f"m not divisible by {p} devices", ()
            return None, (m // p, k, n, 1)
        if n % p:
            return f"n not divisible by {p} devices", ()
        return None, (m, k, n // p, 1)
    return f"unknown algorithm {algorithm!r}", ()


def _local_step_cost(hw: HardwareModel, prob: Problem, densify: bool,
                     ml: int, kl: int, nl: int,
                     stack_tile: Optional[int],
                     smm_flops_per_s: Optional[float],
                     union_ranks: int = 1,
                     rank_max_occ: Optional[float] = None):
    """(compute_s, overhead_s, reason) of ONE local multiply step.

    ``union_ranks`` models the legacy SPMD union-plan contract
    (core/multiply.py with ``rank_exact=False``): each data-exchange
    step executes the UNION of the present triples of every rank
    sharing the traced program, so the executed occupancy is
    ``1 - (1 - occ)^R`` for R unioned ranks — substantially above the
    global triple fill at moderate sparsity.

    ``rank_max_occ`` switches to rank-exact pricing (core/engine.py
    rank slabs): each rank executes only its own retained triples, and
    a step's wall time is bounded by the BUSIEST rank, so compute is
    charged as ``max_rank(retained_flops)`` — the mean occupancy times
    the measured per-rank imbalance, never union-inflated.
    """
    e = prob.itemsize
    if densify:
        flops = 2.0 * ml * kl * nl
        copy_bytes = (ml * kl + kl * nl + ml * nl) * e
        return (flops / hw.flops_per_s,
                copy_bytes / hw.densify_bytes_per_s, None)
    bm, bk, bn = prob.block_m, prob.block_k, prob.block_n
    if ml % bm or kl % bk or nl % bn:
        return None, None, (f"local ({ml},{kl},{nl}) not divisible by "
                            f"blocks ({bm},{bk},{bn})")
    occ = prob.occupancy
    if occ <= 0.0:
        # the divide-by-zero the trivial-plan short-circuit exists for:
        # an empty product has no blocked cost, the caller must not ask
        raise ValueError(
            "blocked-path cost undefined at zero occupancy; callers must "
            "short-circuit an empty mask product to a trivial plan")
    if rank_max_occ is not None:
        # rank-exact execution: charge the busiest rank's retained fill
        occ = min(max(float(rank_max_occ), 1e-12), 1.0)
    elif occ < 1.0 and union_ranks > 1:
        occ = 1.0 - (1.0 - occ) ** union_ranks
    dense_triples = (ml // bm) * (kl // bk) * (nl // bn)
    present = occ * dense_triples
    # occupancy discounts the blocked path's flops — only present
    # triples dispatch.  pad_plans pads stacks to the LONGEST stack (not
    # to stack_tile), and greedy whole-run packing keeps that waste
    # second-order, so padding is folded into stack_entry_s (the fitted
    # slope of dispatch time over triple count) rather than modelled as
    # whole-tile scans.  ``stack_tile`` still bounds stack count for the
    # latency-free scan (no extra charge).
    rate = smm_flops_per_s or hw.smm_flops_per_s
    flops = present * 2.0 * bm * bk * bn
    return (flops / rate + present * hw.stack_entry_s, 0.0, None)


def candidate_cost(
    hw: HardwareModel,
    prob: Problem,
    algorithm: str,
    densify: bool,
    c_repl: int = 1,
    *,
    stack_tile: Optional[int] = None,
    smm_flops_per_s: Optional[float] = None,
    pipeline_depth: int = 2,
    rank_imbalance: Optional[float] = None,
) -> CandidateCost:
    """Predicted execution cost of one candidate configuration.

    ``stack_tile`` / ``smm_flops_per_s`` let the planner thread the
    occupancy-binned autotune winner (and its recorded throughput) into
    the blocked-path model instead of the global constant.
    ``pipeline_depth`` mirrors the schedule engine's knob: depth >= 2
    applies the calibrated per-algorithm overlap discount to the
    pipelined communication (the driver's default); depth 1 predicts
    the serial loop.  ``rank_imbalance`` (max/mean per-rank retained
    triples, from the caller's mask decomposition) switches the blocked
    compute charge from the legacy union inflation to rank-exact
    max-rank pricing: ``occ * imbalance`` capped at 1.
    """
    global N_EVALS
    N_EVALS += 1
    e = prob.itemsize
    reason, geom = _local_geometry(prob, algorithm, c_repl)
    if reason is not None:
        return _infeasible(algorithm, densify, c_repl, reason)
    ml, kl, nl, steps = geom
    # ranks whose present triples are unioned into one SPMD step plan
    # (core/multiply.py mask slicing): every (replica, i, j) for cannon,
    # the factored row x column unions for summa, all shards for ts_*
    union_ranks = {"cannon": prob.pr * prob.pc,
                   "cannon25d": prob.pr * prob.pc * c_repl,
                   "summa": prob.pr * prob.pc,
                   "summa_gather": prob.pr * prob.pc}.get(algorithm,
                                                         prob.p_all)
    rank_max_occ = None
    imbalance = 1.0
    if rank_imbalance is not None and not densify:
        imbalance = max(float(rank_imbalance), 1.0)
        rank_max_occ = min(prob.occupancy * imbalance, 1.0)
    compute_1, overhead_1, reason = _local_step_cost(
        hw, prob, densify, ml, kl, nl, stack_tile, smm_flops_per_s,
        union_ranks, rank_max_occ)
    if reason is not None:
        return _infeasible(algorithm, densify, c_repl, reason)
    compute_s = steps * compute_1
    overhead_s = steps * overhead_1

    # -- communication volume & message count (bytes per device) ------
    # ``overlappable`` is the slice of comm_bytes the schedule engine's
    # double buffering can hide behind compute: everything except the
    # transfer no compute step runs beside (Cannon's last shift has no
    # next multiply; SUMMA's first broadcast has no previous one;
    # synchronizing reductions depend on the compute and cannot hide)
    if algorithm == "cannon":
        shift_bytes = (ml * kl + kl * nl) * e
        comm_bytes = steps * shift_bytes
        overlappable = (steps - 1) * shift_bytes
        messages = 2 * (steps + 1)          # skew + shifts, A and B
        mem = (ml * kl + kl * nl + ml * nl) * e
    elif algorithm == "cannon25d":
        # per-replica: 1/c of the shifts, plus one partial-C reduction
        # over the stack axis (f32 partials); paper-model accounting
        # charges the c-fold operand replication to memory
        shift_bytes = (ml * kl + kl * nl) * e
        comm_bytes = steps * shift_bytes + 2.0 * ml * nl * 4
        overlappable = (steps - 1) * shift_bytes
        messages = 2 * (steps + 1) + max(c_repl.bit_length() - 1, 1)
        mem = c_repl * (ml * kl + kl * nl) * e + ml * nl * e
    elif algorithm == "summa":
        # masked-allreduce broadcast moves ~2x the optimal panel volume
        panel_bytes = 2.0 * (ml * kl + kl * nl) * e
        comm_bytes = steps * panel_bytes
        overlappable = (steps - 1) * panel_bytes
        messages = 2 * steps
        mem = (prob.m * prob.k + prob.k * prob.n) / prob.p2d * e \
            + ml * nl * e
    elif algorithm == "summa_gather":
        # prologue all-gather: each device receives the rest of its
        # FULL-K row panel of A (over the column axis) and column panel
        # of B (over the row axis), then computes with no further
        # communication.  kl == k here, so the resident gathered panels
        # are a sqrt(P)-fold (pc-fold for A, pr-fold for B) operand
        # replication relative to the 2-D sharded layout — THAT is the
        # memory hazard the mem gate below must price (the old model
        # charged only the sharded operands and let the planner walk
        # into an OOM at scale).
        comm_bytes = (ml * kl * (1.0 - 1.0 / prob.pc)
                      + kl * nl * (1.0 - 1.0 / prob.pr)) * e
        overlappable = 0.0      # prologue: no earlier compute to hide it
        messages = max(prob.pc.bit_length() - 1, 1) \
            + max(prob.pr.bit_length() - 1, 1)
        mem = (ml * kl + kl * nl + ml * nl) * e
    elif algorithm == "ts_k":
        # one reduce_scatter of the (m, n) f32 partial product: O(1) in
        # P — a *synchronizing* collective with a data dependency on the
        # local compute, so it pays message latency and cannot hide;
        # operands reshard from the canonical P(row, col) layout to the
        # K-sharded layout (~1/P of each operand received per device),
        # which IS prefetchable ahead of the dot
        p = prob.p_all
        reshard = (prob.m * prob.k + prob.k * prob.n) * e / p
        comm_bytes = prob.m * prob.n * 4.0 + reshard
        overlappable = reshard
        messages = max(p.bit_length() - 1, 1)
        mem = (ml * kl + kl * nl + ml * nl) * e
    elif algorithm == "ts_m":
        # zero-communication compute once B is replicated; the input
        # movement is the full-B broadcast plus A's reshard (~1/P) —
        # all prefetchable ahead of the single local dot
        p = prob.p_all
        comm_bytes = prob.k * prob.n * e + prob.m * prob.k * e / p
        overlappable = comm_bytes
        messages = 1
        mem = (ml * kl + kl * nl + ml * nl) * e
    else:  # ts_n
        p = prob.p_all
        comm_bytes = prob.m * prob.k * e + prob.k * prob.n * e / p
        overlappable = comm_bytes
        messages = 1
        mem = (ml * kl + kl * nl + ml * nl) * e

    comm_s = comm_bytes / hw.bytes_per_s
    overhead_s += messages * hw.latency_s
    # calibrated overlap discount: the ts_* operand prefetch applies at
    # any depth (it is not a loop property); the pipelined-loop overlap
    # of the multi-step algorithms needs the double-buffered driver
    eff = overlap_efficiency(hw, algorithm)
    if not algorithm.startswith("ts_") and (pipeline_depth < 2 or steps < 2):
        eff = 0.0
    overlap_s = eff * min(overlappable / hw.bytes_per_s, compute_s)
    total = comm_s + compute_s + overhead_s - overlap_s
    if mem > hw.mem_bytes:
        # geometry works but the replicas/shards don't fit: infeasible,
        # yet the totals stay finite so a caller with NO feasible
        # candidate can still fall back to the least-bad configuration
        return CandidateCost(
            algorithm, densify, c_repl, False,
            f"needs {mem / 1e9:.2f} GB/device > {hw.mem_bytes / 1e9:.2f} GB",
            comm_s, compute_s, overhead_s, overlap_s, mem, total,
            imbalance=imbalance)
    return CandidateCost(algorithm, densify, c_repl, True, "",
                         comm_s, compute_s, overhead_s, overlap_s, mem, total,
                         imbalance=imbalance)


def batched_dispatch_cost(
    hw: HardwareModel,
    chosen: CandidateCost,
    n_requests: int,
    padding_frac: float = 0.0,
) -> Tuple[float, float]:
    """Predicted ``(fused_s, looped_s)`` for running ``n_requests``
    same-configuration products through ONE fused batched dispatch vs a
    Python loop of single dispatches — the planner's fuse-or-loop
    decision (core/multiply_batched.py + the batching service).

    The looped dispatch pays the per-request fixed costs G times over:
    message latency / densify copies (``overhead_s``) and the host-side
    dispatch price (``dispatch_s`` — shard_map closure build, trace
    lookup, launch).  The fused dispatch moves G times the payload
    through ONE message sequence and ONE launch, so only the
    volume-proportional terms (comm, compute, their overlap) scale with
    G; its penalty is the cross-request padding of the shared stack
    shape (``padding_frac`` — wasted compute rows, see
    ``BatchedExecutorPlan.padding_frac``).  Fusing therefore pays
    exactly when the amortized fixed costs outweigh the padding waste.
    """
    g = max(int(n_requests), 1)
    pf = max(float(padding_frac), 0.0)
    per_request = chosen.comm_s + chosen.compute_s - chosen.overlap_s
    looped_s = g * (per_request + chosen.overhead_s + hw.dispatch_s)
    fused_s = g * (chosen.comm_s + chosen.compute_s * (1.0 + pf)
                   - chosen.overlap_s) + chosen.overhead_s + hw.dispatch_s
    return fused_s, looped_s


def verify_overhead_s(
    hw: HardwareModel,
    m: int,
    k: int,
    n: int,
    block_m: int,
    block_n: int,
    itemsize: int,
) -> float:
    """Predicted price of ABFT checksum verification of one product
    (repro.robustness.abft) — what makes ``verify="auto"`` a costed
    decision like every other planner choice.

    Charged terms, matching what ``verify_product`` executes:

      * the augmented checksum contractions ``S_A @ B`` (block_m x k x n)
        and ``A @ T_B`` (m x k x block_n) at the dense-GEMM rate, plus
        the C row/column reductions (~2*m*n flop-equivalents),
      * one pass over each payload for the operand/result finite
        tripwires and checksum sums, priced as copy bandwidth,
      * the checksum products' cross-device reduction volume
        ``(block_m*n + m*block_n) * e`` plus a handful of collective
        latencies (residuals land on host).

    Relative to the multiply's own 2*m*k*n flops the flop overhead is
    ~(block_m/m + block_n/n): small blocks on big matrices verify for
    a few percent; tiny problems are latency-dominated and ``auto``
    correctly declines them.
    """
    flops = 2.0 * block_m * k * n + 2.0 * m * k * block_n + 2.0 * m * n
    touch_bytes = 2.0 * (m * k + k * n + m * n) * itemsize
    comm_bytes = (block_m * n + m * block_n) * itemsize
    return (flops / hw.flops_per_s
            + touch_bytes / hw.densify_bytes_per_s
            + comm_bytes / hw.bytes_per_s
            + 4.0 * hw.latency_s)


def feasible(prob: Problem, algorithm: str, densify: bool,
             c_repl: int = 1) -> bool:
    """Divisibility/geometry feasibility only — no cost evaluation (and
    no ``N_EVALS`` bump), usable at zero occupancy for trivial plans."""
    reason, geom = _local_geometry(prob, algorithm, c_repl)
    if reason is not None:
        return False
    if not densify:
        ml, kl, nl = geom[0], geom[1], geom[2]
        if ml % prob.block_m or kl % prob.block_k or nl % prob.block_n:
            return False
    return True


def enumerate_candidates(
    hw: HardwareModel,
    prob: Problem,
    algorithm: Optional[str] = None,
    densify: Optional[bool] = None,
    *,
    stack_tile: Optional[int] = None,
    smm_flops_per_s: Optional[float] = None,
    pipeline_depth: int = 2,
    rank_imbalance: Optional[float] = None,
) -> Tuple[CandidateCost, ...]:
    """Cost every candidate in the (algorithm x local-path x c) space,
    optionally constrained to a forced algorithm / local path."""
    algos = ALGORITHMS if algorithm is None else (algorithm,)
    paths = (True, False) if densify is None else (bool(densify),)
    out = []
    for algo in algos:
        crs = ((prob.c_stack,) if prob.c_stack > 1 else (1,)) \
            if algo == "cannon25d" else (1,)
        for cr in crs:
            for dens in paths:
                out.append(candidate_cost(
                    hw, prob, algo, dens, cr, stack_tile=stack_tile,
                    smm_flops_per_s=smm_flops_per_s,
                    pipeline_depth=pipeline_depth,
                    rank_imbalance=rank_imbalance))
    return tuple(out)


def rebalance_cost_s(hw: HardwareModel, prob: Problem) -> float:
    """Amortized price of the load-balancing permutation pass
    (sparsity/balance.py): one block-row shuffle of A, one block-col
    shuffle of B, and the inverse row+col shuffle of C — four payload
    passes priced at the host copy bandwidth, plus one dispatch."""
    e = prob.itemsize
    passes = (prob.m * prob.k + prob.k * prob.n + 2.0 * prob.m * prob.n) * e
    return passes / hw.densify_bytes_per_s + hw.dispatch_s


def matricize_cost_s(hw: HardwareModel, copy_bytes) -> float:
    """Price of a tensor layout's unfold/refold data movement
    (repro.tensor.matricize reports the moved bytes: one read + one
    write per non-trivial unfold of A, B and refold of C), at the same
    host copy bandwidth as the densify pass.  This is the copy term a
    matricization candidate carries on top of its 2D multiply plan."""
    if copy_bytes <= 0:
        return 0.0
    return float(copy_bytes) / hw.densify_bytes_per_s


def ts_crossover_ratio(hw: Optional[HardwareModel] = None,
                       p_total: int = 16, base: int = 4096,
                       itemsize: int = 4) -> float:
    """Shape ratio at which the tall-skinny algorithm's O(1) volume
    beats Cannon's O(1/sqrt(P)) under the cost model — the planner-owned
    replacement for ``classify_shape``'s historical hardcoded 8.0.

    Scans k/m over [1, 64] for the canonical (base, r*base, base)
    problem on a sqrt(p_total) square grid and returns the first ratio
    where ts_k is predicted cheaper; clamped to [2, 64], falling back
    to the legacy constant when the model never crosses over.
    """
    if hw is None:
        from .calibrate import get_hardware_model  # no cycle: lazy

        hw = get_hardware_model()
    pg = max(int(math.isqrt(p_total)), 1)
    try:
        for r in range(1, 65):
            prob = Problem(base, r * base, base, 64, 64, 64, 1.0,
                           itemsize, pg, pg)
            ts = candidate_cost(hw, prob, "ts_k", True)
            ca = candidate_cost(hw, prob, "cannon", True)
            if ts.feasible and ca.feasible and ts.total_s < ca.total_s:
                return float(min(max(r, 2), 64))
    except Exception:
        pass
    return 8.0  # legacy constant (tall_skinny.DEFAULT_TS_RATIO)
