"""Cost-model-driven multiply planner (the paper's driver layer).

    from repro.planner import plan_multiply
    plan = plan_multiply(4096, 4096, 4096, blocks=(64, 64, 64),
                         mesh_shape=(4, 4), occupancy=0.2)
    print(plan.explain())

``distributed_matmul(algorithm="auto")`` and ``dbcsr.multiply`` route
through ``plan_multiply``; ``calibrate`` fits the cost-model constants
from measured artifacts.
"""
from .cost_model import (ALGORITHMS, DEFAULT_HARDWARE, CandidateCost,
                         HardwareModel, Problem, candidate_cost,
                         enumerate_candidates, ts_crossover_ratio)
from .calibrate import get_hardware_model, micro_calibrate, save_calibration
from .plan import (MultiplyPlan, plan_cache_clear, plan_cache_info,
                   plan_multiply)

__all__ = [
    "ALGORITHMS", "DEFAULT_HARDWARE", "CandidateCost", "HardwareModel",
    "Problem", "candidate_cost", "enumerate_candidates",
    "ts_crossover_ratio", "get_hardware_model", "micro_calibrate",
    "save_calibration", "MultiplyPlan", "plan_cache_clear",
    "plan_cache_info", "plan_multiply",
]
