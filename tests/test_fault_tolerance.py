"""Fault tolerance: checkpoint/restart recovery (bit-exact), failure
injection, straggler watchdog, elastic re-mesh restore."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train.data import make_batch, SyntheticLM
from repro.train.elastic import FailureInjector, StragglerWatchdog, run_loop
from repro.train.optimizer import OptConfig, make_optimizer
from repro.train.train_step import make_train_step


@pytest.fixture()
def setup(tmp_path):
    cfg = reduced_config(get_config("qwen2_1_5b"), num_layers=2, d_model=64,
                         d_ff=128, vocab_size=128, num_heads=2,
                         num_kv_heads=1, head_dim=32)
    mesh = make_mesh((1, 1), ("data", "model"))
    params = T.model_init(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(OptConfig(lr=1e-3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, mesh, opt))
    mb = lambda s: {k: jnp.asarray(v) for k, v in make_batch(
        s, global_batch=4, seq_len=8, vocab=cfg.vocab_size).items()}
    return cfg, mesh, params, opt_state, step, mb, str(tmp_path / "ckpt")


def test_checkpoint_roundtrip(setup):
    cfg, mesh, params, opt_state, step, mb, d = setup
    state = {"params": params, "opt": opt_state}
    ckpt.save_checkpoint(d, 7, state)
    assert ckpt.latest_step(d) == 7
    restored = ckpt.restore_checkpoint(d, 7, state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rotation(setup):
    cfg, mesh, params, opt_state, step, mb, d = setup
    state = {"params": params, "opt": opt_state}
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(d, s, state, keep_last=2)
    assert sorted(ckpt.all_steps(d)) == [4, 5]


def test_recovery_bit_exact(setup):
    """Train 6 steps straight vs. train-with-injected-failure-at-4 and
    recovery from the step-4 checkpoint: identical final params
    (deterministic data pipeline => bit-reproducible recovery)."""
    cfg, mesh, params, opt_state, step, mb, d = setup

    def run(fail, ckdir):
        p = jax.tree_util.tree_map(jnp.copy, params)
        o = jax.tree_util.tree_map(jnp.copy, opt_state)
        res = run_loop(
            train_step=step, make_batch=mb, params=p, opt_state=o,
            n_steps=6, ckpt_dir=ckdir, ckpt_every=2,
            failure_injector=FailureInjector(fail_at=fail and [4] or []))
        return res

    r_plain = run(False, d + "_plain")
    r_fail = run(True, d + "_fail")
    assert r_fail["restarts"] == 1
    for a, b in zip(jax.tree_util.tree_leaves(r_plain["final_state"]["params"]),
                    jax.tree_util.tree_leaves(r_fail["final_state"]["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_watchdog():
    w = StragglerWatchdog(threshold=3.0)
    for _ in range(10):
        w.observe(0.1)
    assert w.flagged == 0
    assert w.observe(1.0) is True
    assert w.flagged == 1


def test_elastic_remesh_restore(setup, tmp_path):
    """Save under one mesh, restore under a different device layout —
    the elastic-rescale path (512 chips -> 256 in production maps to
    1x1 -> 1 device here; the semantics are re-placement by sharding)."""
    cfg, mesh, params, opt_state, step, mb, d = setup
    ckpt.save_checkpoint(d, 3, {"params": params})
    mesh2 = make_mesh((1,), ("model",))
    specs = T.model_param_specs(cfg, mesh2)
    shardings = jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh2, P(*[None] * len(sp))), specs,
        is_leaf=lambda x: isinstance(x, P))
    restored = ckpt.restore_checkpoint(d, 3, {"params": params},
                                       {"params": shardings})
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_determinism():
    b1 = make_batch(11, global_batch=4, seq_len=16, vocab=100)
    b2 = make_batch(11, global_batch=4, seq_len=16, vocab=100)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = make_batch(12, global_batch=4, seq_len=16, vocab=100)
    assert not np.array_equal(b1["inputs"], b3["inputs"])
    # labels are next-token shifted inputs
    it = iter(SyntheticLM(vocab=100, seq_len=16, global_batch=4))
    first = next(it)
    np.testing.assert_array_equal(first["inputs"][:, 1:],
                                  first["labels"][:, :-1])
