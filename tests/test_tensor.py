"""Tensor subsystem battery (repro.tensor): einsum front-end property
tests, unfold/fold round trips, layout-exhaustive contraction identity
vs the hand-matricized 2D multiply and the dense einsum oracle, eps
filtering, ABFT verify= in the refolded frame, rank-exact threading,
planner layout caching, and the obs contract span/scoreboard wiring.

Single-device tests run inline on the default 1-device backend (the
conftest contract); 2x2-mesh coverage runs in one subprocess with its
own XLA_FLAGS, mirroring tests/test_distributed.py's pattern.
"""
import itertools

import numpy as np
import pytest

from conftest import run_subprocess_devices

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.compat import make_mesh  # noqa: E402
from repro.core import dbcsr  # noqa: E402
from repro.core.blocking import GridSpec  # noqa: E402
from repro.robustness import chaos  # noqa: E402
from repro.robustness.guards import DbcsrValidationError  # noqa: E402
from repro.tensor import (DBCSRTensor, EinsumSpecError,  # noqa: E402
                          contract, create_tensor, enumerate_layouts,
                          parse_contraction)
from repro.tensor.matricize import (contraction_layout_stats,  # noqa: E402
                                    fold_array, fold_grid, fold_to_tensor,
                                    layout_operands, unfold_array,
                                    unfold_grid, unfold_tensor)

EXEC_KW = dict(densify=False, local_kernel="ref", pipeline_depth=1)


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def _grid():
    return GridSpec("data", "model")


def _tensor(rng, shape, block_sizes, *, fill=1.0, mesh=None):
    data = rng.randn(*shape).astype(np.float32)
    mask = None
    if fill < 1.0:
        bg = tuple(d // b for d, b in zip(shape, block_sizes))
        mask = rng.rand(*bg) < fill
        mask.flat[0] = True
    return create_tensor(data, mesh=mesh, grid=_grid(),
                         block_sizes=block_sizes, block_mask=mask)


# ---------------------------------------------------------------------------
# einsum front-end: property tests (exhaustive enumeration, no hypothesis)
# ---------------------------------------------------------------------------

def _valid_specs():
    """Every valid two-operand spec shape over 2-4 index tensors:
    all contracted-subset choices and orders, contracted placed at
    either end of B, and several output permutations."""
    letters = "abcdefg"
    specs = set()
    for na in (2, 3, 4):
        a_idx = tuple(letters[:na])
        for nb in (2, 3, 4):
            for nc in range(1, min(na, nb) + 1):
                for ksub in itertools.combinations(a_idx, nc):
                    b_free = tuple(letters[na:na + nb - nc])
                    for korder in {ksub, ksub[::-1]}:
                        for b_idx in {korder + b_free, b_free + korder}:
                            a_free = tuple(x for x in a_idx
                                           if x not in ksub)
                            free = a_free + b_free
                            outs = {free, free[::-1]}
                            if len(free) > 1:
                                outs.add(free[1:] + free[:1])
                            for out in outs:
                                specs.add(f"{''.join(a_idx)},"
                                          f"{''.join(b_idx)}->"
                                          f"{''.join(out)}")
    return sorted(specs)


def test_spec_parsing_round_trips_exhaustively():
    specs = _valid_specs()
    assert len(specs) > 200  # a real property sweep, not a handful
    for s in specs:
        p = parse_contraction(s)
        # round trip: the normalized spelling re-parses to itself
        assert p.normalized == s
        assert parse_contraction(p.normalized) == p
        # group laws: contracted = A intersect B, free partitioned,
        # output a permutation of the free union
        a_set, b_set = set(p.a_indices), set(p.b_indices)
        assert set(p.contracted) == a_set & b_set
        assert set(p.a_free) == a_set - b_set
        assert set(p.b_free) == b_set - a_set
        assert sorted(p.out_indices) == sorted(p.a_free + p.b_free)
        # layouts: every enumerated one is distinct and label-stable
        layouts = enumerate_layouts(p)
        assert len(set(layouts)) == len(layouts)
        assert len({L.label for L in layouts}) == len(layouts)


def test_spec_parsing_tolerates_whitespace():
    assert parse_contraction(" ijk , kl -> ijl ").normalized == "ijk,kl->ijl"


@pytest.mark.parametrize("bad", [
    "ijjk->ik",          # no comma
    "ij,jk",             # no arrow
    "ij;jk->ik",         # bad separator
    "i1,1j->ij",         # non-letter index
    "",                  # empty
    "ij,->i",            # empty operand
    "iij,jk->ik",        # repeated index in A
    "ij,jkk->ij",        # repeated index in B
    "ij,jk->ikk",        # repeated index in output
    "ij,jk->ikz",        # output index in neither operand
    "ij,jk->ijk",        # batch index (shared + in output)
    "ij,kl->ijkl",       # outer product: nothing contracted
    "ij,jk->i",          # sum-reduction: free index dropped
    "ij,jk->k",          # sum-reduction on the A side
])
def test_spec_parsing_rejects_malformed(bad):
    with pytest.raises(EinsumSpecError):
        parse_contraction(bad)
    # the typed-taxonomy contract: catchable as DbcsrValidationError
    with pytest.raises(DbcsrValidationError):
        parse_contraction(bad)


def test_mismatched_operands_raise_typed_errors(rng):
    mesh = _mesh11()
    A = _tensor(rng, (16, 8, 32), (8, 4, 8), mesh=mesh)
    with pytest.raises(DbcsrValidationError):  # rank vs subscript
        contract("ij,jk->ik", A, A, mesh=mesh)
    B_dim = _tensor(rng, (16, 16), (8, 8), mesh=mesh)
    with pytest.raises(DbcsrValidationError):  # shared dim mismatch
        contract("ijk,kl->ijl", A, B_dim, mesh=mesh)
    B_blk = _tensor(rng, (32, 16), (16, 8), mesh=mesh)
    with pytest.raises(DbcsrValidationError):  # shared block mismatch
        contract("ijk,kl->ijl", A, B_blk, mesh=mesh)
    B_ok = _tensor(rng, (32, 16), (8, 8), mesh=mesh)
    with pytest.raises(EinsumSpecError):       # unknown pinned layout
        contract("ijk,kl->ijl", A, B_ok, mesh=mesh, layout="(zz|z)@(z|z)")


# ---------------------------------------------------------------------------
# unfold / fold: exact inverses at every group split
# ---------------------------------------------------------------------------

def test_unfold_fold_round_trip_all_splits(rng):
    indices = ("i", "j", "k")
    shape, bsizes = (12, 8, 6), (4, 2, 3)
    x = rng.randn(*shape).astype(np.float32)
    g = rng.randn(*(d // b for d, b in zip(shape, bsizes))) \
        .astype(np.float32)
    dims = dict(zip(indices, shape))
    bs = dict(zip(indices, bsizes))
    nb = {l: dims[l] // bs[l] for l in indices}
    for r in (1, 2):
        for rows in itertools.permutations(indices, r):
            rest = [l for l in indices if l not in rows]
            for cols in itertools.permutations(rest):
                y = unfold_array(x, indices, rows, cols, bsizes)
                assert y.shape == (
                    np.prod([dims[l] for l in rows]),
                    np.prod([dims[l] for l in cols]))
                back = fold_array(np.asarray(y), indices, rows, cols,
                                  nb, bs)
                assert np.array_equal(back, x)
                g2 = unfold_grid(g, indices, rows, cols)
                gback = fold_grid(g2, indices, rows, cols, nb)
                assert np.array_equal(gback, g)


def test_unfold_lowers_mask_and_norms_exactly(rng):
    # an N-d block is retained iff its matricized image is, and the
    # lowered norms equal the 2D view's own norms (norm exactness)
    mesh = _mesh11()
    A = _tensor(rng, (16, 8, 32), (8, 4, 8), fill=0.5, mesh=mesh)
    A.norms()
    m2 = unfold_tensor(A, ("i", "j", "k"), ("i", "j"), ("k",), mesh=mesh)
    assert int(m2.block_mask.sum()) == int(A.block_mask.sum())
    recomputed = m2.norms(recompute=True)
    np.testing.assert_allclose(
        unfold_grid(A.block_norms, ("i", "j", "k"), ("i", "j"), ("k",)),
        recomputed, rtol=1e-6)


# ---------------------------------------------------------------------------
# contraction identity: every layout, bitwise vs hand-matricized,
# allclose vs the dense einsum oracle; eps in {None, 0} bitwise
# ---------------------------------------------------------------------------

SPECS = [
    # (spec, a shape, a blocks, b shape, b blocks): 2-, 3-, 4-index
    ("ij,jk->ik", (32, 32), (8, 8), (32, 16), (8, 8)),
    ("ijk,kl->ijl", (16, 8, 32), (8, 4, 8), (32, 16), (8, 8)),
    ("abcd,ce->abde", (8, 8, 8, 8), (4, 4, 4, 4), (8, 8), (4, 4)),
]


@pytest.mark.parametrize("fill", [1.0, 0.5, 0.05])
@pytest.mark.parametrize("case", SPECS, ids=[s[0] for s in SPECS])
def test_contract_every_layout_bitwise_and_oracle(rng, case, fill):
    spec, ash, abs_, bsh, bbs = case
    mesh = _mesh11()
    A = _tensor(rng, ash, abs_, fill=fill, mesh=mesh)
    B = _tensor(rng, bsh, bbs, fill=fill, mesh=mesh)
    con = parse_contraction(spec)
    oracle = np.einsum(spec, np.asarray(A.data), np.asarray(B.data))
    dims = {**dict(zip(con.a_indices, A.shape)),
            **dict(zip(con.b_indices, B.shape))}
    bs = {**dict(zip(con.a_indices, A.block_sizes)),
          **dict(zip(con.b_indices, B.block_sizes))}
    scale = max(float(np.abs(oracle).max()), 1.0)
    for L in enumerate_layouts(con):
        C, plan = contract(spec, A, B, mesh=mesh, layout=L,
                           return_plan=True, **EXEC_KW)
        assert plan.layout == L.label
        assert plan.plan.layout == L.label
        # allclose to the dense oracle
        assert np.abs(np.asarray(C.data) - oracle).max() < 1e-5 * scale
        # bitwise identical to refolding the hand-matricized multiply
        lsrc, lrows, lcols, rsrc, rrows, rcols, crows, ccols = \
            layout_operands(con, L)
        left, lidx = (A, con.a_indices) if lsrc == "a" \
            else (B, con.b_indices)
        right, ridx = (B, con.b_indices) if rsrc == "b" \
            else (A, con.a_indices)
        ma = unfold_tensor(left, lidx, lrows, lcols, mesh=mesh)
        mb = unfold_tensor(right, ridx, rrows, rcols, mesh=mesh)
        hand_kw = {**EXEC_KW, "densify": plan.plan.densify}
        c2d = dbcsr.multiply(ma, mb, mesh=mesh,
                             algorithm=plan.plan.algorithm, **hand_kw)
        hand = fold_to_tensor(c2d, con.out_indices, crows, ccols,
                              dims, bs, A.grid, mesh=mesh)
        assert np.array_equal(np.asarray(C.data), np.asarray(hand.data))
        if C.block_mask is not None:
            assert np.array_equal(C.block_mask, hand.block_mask)
        # eps=0 retains everything: bitwise identical to eps=None
        C0 = contract(spec, A, B, mesh=mesh, layout=L, filter_eps=0.0,
                      **EXEC_KW)
        assert np.array_equal(np.asarray(C.data), np.asarray(C0.data))


def test_contract_filter_eps_subtractive(rng):
    # eps drops triples with ||A_blk||*||B_blk|| < eps: block-row i=1
    # of A is scaled to ~1e-9, so every output block there loses all
    # its contributions while i=0 keeps every one (hence stays bitwise)
    mesh = _mesh11()
    data = rng.randn(16, 8, 32).astype(np.float32)
    data[8:] *= 1e-9
    A = create_tensor(data, mesh=mesh, grid=_grid(),
                      block_sizes=(8, 4, 8))
    B = _tensor(rng, (32, 16), (8, 8), mesh=mesh)
    C0 = contract("ijk,kl->ijl", A, B, mesh=mesh, **EXEC_KW)
    Ce = contract("ijk,kl->ijl", A, B, mesh=mesh, filter_eps=1.0,
                  **EXEC_KW)
    assert Ce.block_mask is not None
    assert Ce.block_mask[0].all()
    assert not Ce.block_mask[1].any()
    data0, datae = np.asarray(C0.data), np.asarray(Ce.data)
    assert np.array_equal(datae[:8], data0[:8])  # untouched rows bitwise
    assert not datae[8:].any()                   # dropped rows zeroed


# ---------------------------------------------------------------------------
# verify= / rank_exact= threading (satellite: ABFT in the tensor frame)
# ---------------------------------------------------------------------------

def test_contract_verify_detects_localizes_repairs_in_tensor_frame(rng):
    mesh = _mesh11()
    A = _tensor(rng, (16, 8, 32), (8, 4, 8), fill=0.8, mesh=mesh)
    B = _tensor(rng, (32, 16), (8, 8), fill=0.8, mesh=mesh)
    L = enumerate_layouts(parse_contraction("ijk,kl->ijl"))[0]
    kw = dict(mesh=mesh, layout=L, **EXEC_KW)

    clean = contract("ijk,kl->ijl", A, B, **kw)
    assert clean.verification is None

    cv = contract("ijk,kl->ijl", A, B, verify="checksum", **kw)
    assert cv.verification["enabled"]
    assert not cv.verification["report"].detected
    assert np.array_equal(np.asarray(cv.data), np.asarray(clean.data))

    # corrupt one block of the MATRICIZED product mid-flight: the
    # layout (ij|k)@(k|l) has 2D blocks of (8*4, 8)
    hook = chaos.FaultInjector(seed=7).one_shot_result_hook(
        1, 1, block_m=32, block_n=8, mode="bitflip")
    with chaos.result_corruption(hook):
        cr = contract("ijk,kl->ijl", A, B, verify="checksum", **kw)
    rep = cr.verification["report"]
    assert rep.detected
    assert rep.flagged_blocks == ((1, 1),)
    assert rep.repaired and rep.n_recomputed_blocks >= 1
    # the repair lands in the REFOLDED tensor frame: bitwise clean
    assert np.array_equal(np.asarray(cr.data), np.asarray(clean.data))
    # and the plan the result carries reports the verification outcome
    assert cr.last_plan.verification["report"].detected


def test_contract_battery_2x2_mesh_with_rank_exact():
    # {2,3,4}-index specs x fills on a 2x2 mesh (own XLA_FLAGS), plus
    # rank_exact=True/False bitwise agreement on a rank-independent
    # schedule and verify= threading
    code = """
import numpy as np
from repro.compat import make_mesh
from repro.core.blocking import GridSpec
from repro.tensor import contract, create_tensor

rng = np.random.RandomState(0)
mesh = make_mesh((2, 2), ("data", "model"))
grid = GridSpec("data", "model")
EXEC_KW = dict(densify=False, local_kernel="ref", pipeline_depth=1)

def tensor(shape, blocks, fill):
    data = rng.randn(*shape).astype(np.float32)
    mask = None
    if fill < 1.0:
        bg = tuple(d // b for d, b in zip(shape, blocks))
        mask = rng.rand(*bg) < fill
        mask.flat[0] = True
    return create_tensor(data, mesh=mesh, grid=grid, block_sizes=blocks,
                         block_mask=mask)

SPECS = [
    ("ij,jk->ik", (32, 32), (8, 8), (32, 16), (8, 8)),
    ("ijk,kl->ijl", (16, 8, 32), (8, 4, 8), (32, 16), (8, 8)),
    ("abcd,ce->abde", (8, 8, 8, 8), (4, 4, 4, 4), (8, 8), (4, 4)),
]
for spec, ash, abs_, bsh, bbs in SPECS:
    for fill in (1.0, 0.5, 0.05):
        A = tensor(ash, abs_, fill)
        B = tensor(bsh, bbs, fill)
        C, plan = contract(spec, A, B, mesh=mesh, return_plan=True,
                           **EXEC_KW)
        oracle = np.einsum(spec, np.asarray(A.data), np.asarray(B.data))
        scale = max(float(np.abs(oracle).max()), 1.0)
        err = np.abs(np.asarray(C.data) - oracle).max()
        assert err < 1e-5 * scale, (spec, fill, err)
        assert C.shape == oracle.shape

# rank-exact vs union: bitwise on a rank-independent K-order schedule
A = tensor((16, 8, 32), (8, 4, 8), 0.4)
B = tensor((32, 16), (8, 8), 0.4)
kw = dict(mesh=mesh, algorithm="summa", **EXEC_KW)
Cr, pr_ = contract("ijk,kl->ijl", A, B, rank_exact=True,
                   return_plan=True, **kw)
Cu = contract("ijk,kl->ijl", A, B, rank_exact=False, **kw)
assert np.array_equal(np.asarray(Cr.data), np.asarray(Cu.data))
assert pr_.plan.rank_imbalance >= 1.0
Cv = contract("ijk,kl->ijl", A, B, verify="checksum", **kw)
assert Cv.verification["enabled"]
assert not Cv.verification["report"].detected
print("2x2 battery OK")
"""
    out = run_subprocess_devices(code, n_devices=4)
    assert "2x2 battery OK" in out


# ---------------------------------------------------------------------------
# planner: layout costing + contraction-signature cache
# ---------------------------------------------------------------------------

def test_plan_contract_caches_on_contraction_signature(rng):
    from repro.planner import cost_model
    from repro.planner.plan import contract_cache_clear

    mesh = _mesh11()
    A = _tensor(rng, (16, 8, 32), (8, 4, 8), fill=0.5, mesh=mesh)
    B = _tensor(rng, (32, 16), (8, 8), fill=0.5, mesh=mesh)
    contract_cache_clear()
    C1, p1 = contract("ijk,kl->ijl", A, B, mesh=mesh, return_plan=True,
                      **EXEC_KW)
    n0 = cost_model.N_EVALS
    C2, p2 = contract("ijk,kl->ijl", A, B, mesh=mesh, return_plan=True,
                      **EXEC_KW)
    assert cost_model.N_EVALS == n0  # zero evaluations on the repeat
    assert p2.layout == p1.layout
    assert np.array_equal(np.asarray(C1.data), np.asarray(C2.data))
    # a different mask is a different signature -> replan, not a stale hit
    A2 = _tensor(rng, (16, 8, 32), (8, 4, 8), fill=0.3, mesh=mesh)
    contract("ijk,kl->ijl", A2, B, mesh=mesh, **EXEC_KW)
    assert cost_model.N_EVALS > n0


def test_plan_contract_explain_has_layout_column(rng):
    mesh = _mesh11()
    A = _tensor(rng, (16, 8, 32), (8, 4, 8), fill=0.5, mesh=mesh)
    B = _tensor(rng, (32, 16), (8, 8), fill=0.5, mesh=mesh)
    _, plan = contract("ijk,kl->ijl", A, B, mesh=mesh, return_plan=True,
                       **EXEC_KW)
    text = plan.explain()
    assert "layout" in text
    for L in enumerate_layouts(parse_contraction("ijk,kl->ijl")):
        assert L.label in text           # every candidate layout listed
    assert f"layout={plan.layout}" in text
    assert plan.chosen is not None and plan.chosen.feasible
    # executed stats grafted from the inner multiply
    assert plan.plan.executor_stats is not None


def test_layout_stats_occupancy_invariant_imbalance_not(rng):
    # the retained-triple set is layout-invariant; its arrangement over
    # ranks is not — a block-row-structured mask balances differently
    # matricized (i|jk) vs (j|ik)
    con = parse_contraction("ijk,kl->ijl")
    mesh = _mesh11()
    mask = np.zeros((4, 2, 4), dtype=bool)
    mask[0] = True  # all occupancy in one i block-row
    A = create_tensor(np.random.RandomState(3).randn(16, 8, 32)
                      .astype(np.float32), mesh=mesh, grid=_grid(),
                      block_sizes=(4, 4, 8), block_mask=mask)
    B = _tensor(np.random.RandomState(4), (32, 16), (8, 8), mesh=mesh)
    occ = set()
    for L in enumerate_layouts(con):
        s = contraction_layout_stats(con, L, A, B, mesh_shape=(2, 2))
        occ.add(round(s.occupancy, 12))
        assert s.m * s.n * s.k == 16 * 8 * 32 * 16
    assert len(occ) == 1


# ---------------------------------------------------------------------------
# container: pytree round trip, norms, filter
# ---------------------------------------------------------------------------

def test_tensor_pytree_round_trip(rng):
    mesh = _mesh11()
    A = _tensor(rng, (16, 8, 32), (8, 4, 8), fill=0.5, mesh=mesh)
    A.norms()
    leaves, treedef = jax.tree_util.tree_flatten(A)
    A2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(A2, DBCSRTensor)
    assert A2.block_sizes == A.block_sizes
    assert np.array_equal(A2.block_mask, A.block_mask)
    assert np.array_equal(A2.block_norms, A.block_norms)
    assert np.array_equal(np.asarray(A2.data), np.asarray(A.data))


def test_tensor_filter_and_occupancy(rng):
    mesh = _mesh11()
    A = _tensor(rng, (16, 8, 32), (8, 4, 8), fill=0.5, mesh=mesh)
    filt = A.filter(1e30)
    assert filt.occupancy == 0.0
    assert not np.asarray(filt.data).any()
    keep = A.filter(0.0)
    assert np.array_equal(keep.block_mask, A.block_mask)
    assert np.array_equal(np.asarray(keep.data), np.asarray(A.data))


# ---------------------------------------------------------------------------
# obs: contract -> matricize -> multiply span tree + scoreboard rows
# ---------------------------------------------------------------------------

def test_contract_span_tree_and_outcome_row(rng, tmp_path):
    from repro import obs

    mesh = _mesh11()
    A = _tensor(rng, (16, 8, 32), (8, 4, 8), fill=0.5, mesh=mesh)
    B = _tensor(rng, (32, 16), (8, 8), fill=0.5, mesh=mesh)
    obs.enable(log_dir=str(tmp_path))
    try:
        obs.clear_plan_outcomes()
        contract("ijk,kl->ijl", A, B, mesh=mesh, **EXEC_KW)
        spans = obs.last_trace()
        outcomes = list(obs.plan_outcomes())
    finally:
        obs.disable()
    roots = [s for s in spans if s.parent_id is None]
    assert [r.name for r in roots] == ["contract"]
    kids = [s.name for s in spans if s.parent_id == roots[0].span_id]
    assert "matricize" in kids and "multiply" in kids and "plan" in kids
    rows = [r for r in outcomes if r.get("kind") == "contract"]
    assert len(rows) == 1
    row = rows[0]
    assert row["algorithm"] and row["layout"]
    assert row["predicted_s"] > 0 and row["measured_s"] > 0
    # the inner multiply recorded its own row too, schema unchanged
    assert any(r.get("kind") == "multiply" for r in outcomes)


def test_scoreboard_groups_contract_rows_without_breaking_drift():
    from repro.obs.scoreboard import check_drift, planner_scoreboard

    records = [
        {"kind": "multiply", "algorithm": "summa",
         "predicted_s": 1e-3, "measured_s": 1e-3},
        {"algorithm": "cannon",            # legacy row without kind
         "predicted_s": 2e-3, "measured_s": 2e-3},
        {"kind": "contract", "algorithm": "summa",
         "layout": "(ij|k)@(k|l)",
         "predicted_s": 3e-3, "measured_s": 4e-3},
    ]
    sb = planner_scoreboard(records)
    # multiply rows keep the bare-algorithm key calibrate thresholds on
    assert set(sb) == {"summa", "cannon", "contract:summa"}
    assert sb["summa"]["n"] == 1
    drift = check_drift(records, threshold=1.0)
    assert drift["ok"]
