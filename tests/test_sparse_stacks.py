"""Occupancy-aware stack generation (ISSUE 2): mask filtering in the
Generation phase, ragged-run scheduling invariants, mask-fingerprint
plan memoization, occupancy-binned autotune lookup, and the sparse
distributed paths (per-shift / per-panel union plans + empty-step
skipping) against masked-densified oracles."""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_subprocess_devices
from repro.core import engine
from repro.core.blocking import BlockLayout
from repro.core.densify import blocked_local_matmul
from repro.core.cannon import cannon_step_masks as _cannon_pair_masks
from repro.core.multiply import _masks_empty, _stepwise_blocked_lm
from repro.core.summa import summa_step_masks as _summa_panel_masks
from repro.core.stacks import build_stacks


def _expand(mask, bs):
    return np.repeat(np.repeat(mask, bs, 0), bs, 1)


# ---------------------------------------------------------------------------
# Generation phase: dense bit-identity + masked filtering
# ---------------------------------------------------------------------------


def test_dense_masks_bit_identical():
    """All-true masks must reproduce the dense enumeration exactly —
    same stacks, same triples, same dtype (acceptance criterion)."""
    a = BlockLayout(64, 96, 16, 16)
    b = BlockLayout(96, 80, 16, 16)
    for stack_size in (5, 13, 30_000):
        dense = build_stacks(a, b, stack_size=stack_size)
        masked = build_stacks(
            a, b, stack_size=stack_size,
            a_mask=np.ones((4, 6), bool), b_mask=np.ones((6, 5), bool))
        assert len(dense) == len(masked)
        for p, q in zip(dense, masked):
            assert p.triples.dtype == q.triples.dtype == np.int32
            np.testing.assert_array_equal(p.triples, q.triples)


def test_masked_triple_count_is_mask_product():
    rng = np.random.RandomState(3)
    a = BlockLayout(32, 48, 8, 8)
    b = BlockLayout(48, 40, 8, 8)
    am = rng.rand(4, 6) < 0.4
    bm = rng.rand(6, 5) < 0.4
    plans = build_stacks(a, b, stack_size=7, a_mask=am, b_mask=bm)
    expected = int((am.astype(np.int64) @ bm.astype(np.int64)).sum())
    assert sum(p.size for p in plans) == expected
    # every triple's (i, k) and (k, j) are present in the masks
    for p in plans:
        i, kk = p.triples[:, 0] // 6, p.triples[:, 0] % 6
        kk2, j = p.triples[:, 1] // 5, p.triples[:, 1] % 5
        np.testing.assert_array_equal(kk, kk2)
        assert am[i, kk].all() and bm[kk, j].all()


@pytest.mark.parametrize("fill", [0.6, 0.25, 0.1])
def test_run_contiguity_on_ragged_runs(fill):
    """Scheduler invariants under ragged k-runs: within every stack each
    C block's updates form one contiguous run, and no run is split
    across stacks (each C block lives in exactly one stack)."""
    rng = np.random.RandomState(int(fill * 100))
    a = BlockLayout(64, 96, 8, 8)
    b = BlockLayout(96, 72, 8, 8)
    am = rng.rand(8, 12) < fill
    bm = rng.rand(12, 9) < fill
    plans = build_stacks(a, b, stack_size=20, a_mask=am, b_mask=bm)
    owners = {}
    for si, p in enumerate(plans):
        c = p.triples[:, 2]
        seen = set()
        prev = None
        for x in c.tolist():
            if x != prev:
                assert x not in seen, "C block revisited non-contiguously"
                seen.add(x)
                prev = x
        for x in seen:
            assert x not in owners, "C block's k-run split across stacks"
            owners[x] = si
        # stacks respect the size cap unless a single run exceeds it
        if p.size > 20:
            assert len(seen) == 1


def test_empty_rows_and_cols():
    """An empty A block-row / B block-col produce no triples for the
    corresponding C row / col."""
    a = BlockLayout(32, 32, 8, 8)
    b = BlockLayout(32, 32, 8, 8)
    am = np.ones((4, 4), bool)
    am[2, :] = False  # empty A block-row
    bm = np.ones((4, 4), bool)
    bm[:, 1] = False  # empty B block-col
    plans = build_stacks(a, b, a_mask=am, b_mask=bm)
    c_idx = np.concatenate([p.triples[:, 2] for p in plans])
    ci, cj = c_idx // 4, c_idx % 4
    assert not (ci == 2).any() and not (cj == 1).any()
    assert sum(p.size for p in plans) == 3 * 4 * 3


# ---------------------------------------------------------------------------
# executor vs masked-densified oracle (acceptance: fills + structured)
# ---------------------------------------------------------------------------


def _mask_case(name, nb, rng):
    if name == "empty_row":
        am = np.ones((nb, nb), bool)
        am[1, :] = False
        return am, np.ones((nb, nb), bool)
    if name == "empty_col":
        bm = np.ones((nb, nb), bool)
        bm[:, 2] = False
        return np.ones((nb, nb), bool), bm
    fill = float(name)
    return rng.rand(nb, nb) < fill, rng.rand(nb, nb) < fill


@pytest.mark.parametrize("kernel", ["ref", "smm"])
@pytest.mark.parametrize("case", ["1.0", "0.5", "0.1",
                                  "empty_row", "empty_col"])
def test_masked_executor_vs_densified_oracle(case, kernel, rng):
    block, nb = 8, 5
    m = k = n = block * nb
    am, bm = _mask_case(case, nb, np.random.RandomState(hash(case) % 1000))
    A = rng.randn(m, k).astype(np.float32) * _expand(am, block)
    B = rng.randn(k, n).astype(np.float32) * _expand(bm, block)

    f = blocked_local_matmul(m, k, n, block_m=block, block_k=block,
                             block_n=block, stack_size=2 * nb, kernel=kernel,
                             a_mask=am, b_mask=bm)
    C = np.asarray(f(jnp.asarray(A), jnp.asarray(B)))
    np.testing.assert_allclose(C, A @ B, rtol=0, atol=1e-4)
    # the plan dispatches exactly the present triples
    plan = f.executor_plan
    assert plan.n_entries == int((am.astype(np.int64) @ bm.astype(np.int64)).sum())
    stats = plan.stats()
    assert stats["n_dense_triples"] == nb ** 3
    assert stats["n_skipped_triples"] == nb ** 3 - plan.n_entries
    assert stats["occupancy"] == pytest.approx(plan.n_entries / nb ** 3)


def test_ten_percent_occupancy_triple_bound(rng):
    """Acceptance criterion: at 10% block occupancy the plan holds at
    most 15% of the dense triple count."""
    nb = 10
    mask_rng = np.random.RandomState(7)
    am = mask_rng.rand(nb, nb) < 0.1
    # one-sided 10% mask: triple fraction == mask fill exactly
    f = blocked_local_matmul(nb * 8, nb * 8, nb * 8, block_m=8, block_k=8,
                             block_n=8, kernel="ref", a_mask=am)
    plan = f.executor_plan
    assert plan.n_entries <= 0.15 * plan.n_dense_triples
    # two-sided 10% masks: ~1% expected, far under the bound
    bm = mask_rng.rand(nb, nb) < 0.1
    g = blocked_local_matmul(nb * 8, nb * 8, nb * 8, block_m=8, block_k=8,
                             block_n=8, kernel="ref", a_mask=am, b_mask=bm)
    assert g.executor_plan.n_entries <= 0.15 * g.executor_plan.n_dense_triples


def test_empty_product_executor_is_noop():
    block, nb = 8, 4
    m = k = n = block * nb
    f = blocked_local_matmul(m, k, n, block_m=block, block_k=block,
                             block_n=block, kernel="ref",
                             a_mask=np.zeros((nb, nb), bool))
    assert f.executor_plan.n_stacks == 0
    C = np.asarray(f(jnp.zeros((m, k), jnp.float32),
                     jnp.ones((k, n), jnp.float32)))
    assert (C == 0).all()


# ---------------------------------------------------------------------------
# plan memoization on mask content fingerprints
# ---------------------------------------------------------------------------


def test_plan_memo_hits_on_mask_content():
    rng = np.random.RandomState(11)
    mask = rng.rand(8, 8) < 0.5
    p1 = engine.build_executor_plan(64, 64, 64, 8, 8, 8, 100, a_mask=mask)
    # distinct array object, identical content -> same memoized plan
    p2 = engine.build_executor_plan(64, 64, 64, 8, 8, 8, 100,
                                    a_mask=mask.copy())
    assert p1 is p2
    # different content -> different plan
    other = mask.copy()
    other[0, 0] = not other[0, 0]
    p3 = engine.build_executor_plan(64, 64, 64, 8, 8, 8, 100, a_mask=other)
    assert p3 is not p1
    # dense plan is distinct from any masked plan
    p4 = engine.build_executor_plan(64, 64, 64, 8, 8, 8, 100)
    assert p4 is not p1


def test_plan_build_leaves_caller_mask_writable():
    """Fingerprinting copies the mask: the caller's array must stay
    writable (evolving sparsity patterns re-fingerprint per content)."""
    mask = np.ones((8, 8), bool)
    engine.build_executor_plan(64, 64, 64, 8, 8, 8, 100, a_mask=mask)
    mask[0, 0] = False  # must not raise "read-only"
    p = engine.build_executor_plan(64, 64, 64, 8, 8, 8, 100, a_mask=mask)
    assert p.n_entries == 8 * 8 * 8 - 8  # one absent A block = nbk fewer


# ---------------------------------------------------------------------------
# occupancy-binned autotune lookup
# ---------------------------------------------------------------------------


def test_fill_bin_snapping():
    from repro.kernels.smm.autotune import fill_bin
    assert fill_bin(1.0) == 1.0
    assert fill_bin(0.9) == 1.0
    assert fill_bin(0.4) == 0.5
    assert fill_bin(0.18) == 0.2
    assert fill_bin(0.04) == 0.05
    assert fill_bin(0.0001) == 0.05


def test_best_params_occupancy_binned(tmp_path):
    from repro.kernels.smm.autotune import best_params, best_params_for
    cache = tmp_path / "smm_autotune.json"
    cache.write_text(json.dumps({
        "22": {"best": {"align": True, "stack_tile": 30000}},
        "22@0.05": {"best": {"align": False, "stack_tile": 1024}},
    }))
    path = str(cache)
    # dense lookup -> legacy un-suffixed key
    assert best_params(22, path) == (True, 30000)
    # sparse lookup -> occupancy-binned winner (not the dense one)
    assert best_params(22, path, fill=0.04) == (False, 1024)
    assert best_params_for(22, 22, 22, path, fill=0.04) == (False, 1024)
    # bin with no recorded sweep falls back to the dense entry
    assert best_params(22, path, fill=0.4) == (True, 30000)


def test_stack_executor_resolves_binned_defaults(tmp_path, monkeypatch):
    """A 10%-fill workload resolves stack_size from its occupancy bin,
    not the dense winner."""
    from repro.kernels.smm import autotune
    cache = tmp_path / "smm_autotune.json"
    cache.write_text(json.dumps({
        "8": {"best": {"align": False, "stack_tile": 30000}},
        "8@0.05": {"best": {"align": False, "stack_tile": 64}},
    }))
    monkeypatch.setattr(autotune, "DEFAULT_CACHE", str(cache))
    mask_rng = np.random.RandomState(5)
    am = mask_rng.rand(10, 10) < 0.1
    bm = mask_rng.rand(10, 10) < 0.1
    f = blocked_local_matmul(80, 80, 80, block_m=8, block_k=8, block_n=8,
                             kernel="ref", a_mask=am, b_mask=bm)
    assert f.stack_size == 64
    g = blocked_local_matmul(80, 80, 80, block_m=8, block_k=8, block_n=8,
                             kernel="ref")
    assert g.stack_size == 30000


# ---------------------------------------------------------------------------
# distributed-layer mask slicing (host-side helpers, no devices needed)
# ---------------------------------------------------------------------------


def test_cannon_pair_masks_skip_steps():
    """A confined to chunk-column 0 and B to chunk-row 0 on a 2x2 grid:
    device (i, j) needs chunk q = (i+j+t) % 2 of both, so only shift
    step 0 (where some rank has q=0... for i+j even) can be non-empty
    at t making (i+j+t) % 2 == 0."""
    am = np.zeros((8, 8), bool)
    am[:, :4] = True   # A present only in chunk column q=0
    bm = np.zeros((8, 8), bool)
    bm[:4, :] = True   # B present only in chunk row q=0
    pairs = _cannon_pair_masks(am, bm, 2)
    # q=0 is reached by (i+j+t) % 2 == 0; both t=0 (i+j even) and t=1
    # (i+j odd) have ranks hitting q=0 -> both steps non-empty...
    assert [p.any() for p in pairs] == [True, True]
    # ...but confine A to the (0, 0) chunk only: product needs i=0, q=0
    # => t = (0 - 0 - 0) % 2 = 0 and j=0; step 1 is empty and skipped.
    am2 = np.zeros((8, 8), bool)
    am2[:4, :4] = True
    bm2 = np.zeros((8, 8), bool)
    bm2[:4, :4] = True  # B chunk (0, 0) only
    pairs2 = _cannon_pair_masks(am2, bm2, 2)
    assert [p.any() for p in pairs2] == [True, False]
    lm = _stepwise_blocked_lm(32, 32, 32, mask_steps=[
        {"pair_mask": p} for p in pairs2],
        block_m=8, block_k=8, block_n=8, stack_size=None, align=None,
        kernel="ref")
    assert lm.stepwise and lm.empty_steps == frozenset({1})


def test_summa_panel_masks_skip_panels():
    """B empty in the K range of panel 1 -> that panel is skipped."""
    am = np.ones((8, 8), bool)
    bm = np.ones((8, 8), bool)
    bm[4:, :] = False  # panel 1's K block range is empty in B
    panels = _summa_panel_masks(am, bm, 2, 2, 2)
    assert not _masks_empty({"a_mask": panels[0][0], "b_mask": panels[0][1]})
    assert _masks_empty({"a_mask": panels[1][0], "b_mask": panels[1][1]})


# ---------------------------------------------------------------------------
# distributed sparse battery (multi-device subprocess)
# ---------------------------------------------------------------------------


SPARSE_BATTERY = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.core import dbcsr
from repro.core.blocking import GridSpec
from repro.core.multiply import distributed_matmul

rng = np.random.RandomState(0)
out = {}
bs = 8
M, K, N = 64, 96, 80
am = rng.rand(M // bs, K // bs) < 0.3
bm = rng.rand(K // bs, N // bs) < 0.3
expand = lambda m: np.repeat(np.repeat(m, bs, 0), bs, 1)
A = rng.randn(M, K).astype(np.float32) * expand(am)
B = rng.randn(K, N).astype(np.float32) * expand(bm)
ref = A @ B

grid = GridSpec("data", "model")
mesh = make_mesh((2, 2), ("data", "model"))
sh = NamedSharding(mesh, P("data", "model"))
Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
C = distributed_matmul(Ad, Bd, mesh=mesh, grid=grid, algorithm="cannon",
                       densify=False, block_m=bs, block_k=bs, block_n=bs,
                       local_kernel="ref", a_mask=am, b_mask=bm)
out["cannon_sparse_2x2"] = float(np.max(np.abs(np.asarray(C) - ref)))
for bcast in ("psum", "gather"):
    C = distributed_matmul(Ad, Bd, mesh=mesh, grid=grid, algorithm="summa",
                           densify=False, block_m=bs, block_k=bs, block_n=bs,
                           local_kernel="ref", a_mask=am, b_mask=bm,
                           bcast=bcast)
    out[f"summa_{bcast}_sparse_2x2"] = float(np.max(np.abs(np.asarray(C) - ref)))

# non-square summa with masks (per-panel plans + panel mask unions)
mesh21 = make_mesh((2, 1), ("data", "model"))
sh21 = NamedSharding(mesh21, P("data", "model"))
A21, B21 = jax.device_put(A, sh21), jax.device_put(B, sh21)
C = distributed_matmul(A21, B21, mesh=mesh21, grid=grid, algorithm="summa",
                       densify=False, block_m=bs, block_k=bs, block_n=bs,
                       local_kernel="ref", a_mask=am, b_mask=bm)
out["summa_psum_sparse_2x1"] = float(np.max(np.abs(np.asarray(C) - ref)))

# 2.5D cannon with masks (per-inner-step unions over replicas)
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
grid3 = GridSpec("data", "model", stack_axis="pod")
M2 = K2 = N2 = 64
am2 = rng.rand(M2 // bs, K2 // bs) < 0.4
bm2 = rng.rand(K2 // bs, N2 // bs) < 0.4
A2 = rng.randn(M2, K2).astype(np.float32) * expand(am2)
B2 = rng.randn(K2, N2).astype(np.float32) * expand(bm2)
sh3 = NamedSharding(mesh3, P("data", "model"))
A2d, B2d = jax.device_put(A2, sh3), jax.device_put(B2, sh3)
C = distributed_matmul(A2d, B2d, mesh=mesh3, grid=grid3,
                       algorithm="cannon25d", densify=False, block_m=bs,
                       block_k=bs, block_n=bs, local_kernel="ref",
                       a_mask=am2, b_mask=bm2)
out["cannon25d_sparse"] = float(np.max(np.abs(np.asarray(C) - A2 @ B2)))

# dbcsr API end-to-end: blocked sparse multiply + symbolic result mask
Am = dbcsr.create(A, mesh=mesh, grid=grid, block_size=bs, block_mask=am)
Bm = dbcsr.create(B, mesh=mesh, grid=grid, block_size=bs, block_mask=bm)
Cm = dbcsr.multiply(Am, Bm, mesh=mesh, algorithm="cannon", densify=False,
                    local_kernel="ref")
out["dbcsr_blocked_sparse"] = float(np.max(np.abs(np.asarray(Cm.data) - ref)))
sym = (am.astype(np.int64) @ bm.astype(np.int64)) > 0
out["dbcsr_mask_matches"] = bool((Cm.block_mask == sym).all())
# numeric support is contained in the symbolic mask
Cb = np.asarray(Cm.data).reshape(M // bs, bs, N // bs, bs)
support = np.abs(Cb).max(axis=(1, 3)) > 0
out["support_in_mask"] = bool((support <= sym).all())
print("JSON" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sparse_battery():
    stdout = run_subprocess_devices(SPARSE_BATTERY, n_devices=8, timeout=900)
    line = [l for l in stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


@pytest.mark.parametrize("key", [
    "cannon_sparse_2x2", "summa_psum_sparse_2x2", "summa_gather_sparse_2x2",
    "summa_psum_sparse_2x1", "cannon25d_sparse", "dbcsr_blocked_sparse",
])
def test_distributed_sparse_matches_masked_dense(sparse_battery, key):
    assert sparse_battery[key] < 2e-4, (key, sparse_battery[key])


def test_distributed_sparse_mask_flow(sparse_battery):
    assert sparse_battery["dbcsr_mask_matches"]
    assert sparse_battery["support_in_mask"]
