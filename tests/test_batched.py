"""Batched multiply stack: grouped executor oracle, fuse-or-loop
planner, ``dbcsr.multiply_batched`` bit-identity vs the looped path,
and the continuous-batching service.

Single-device tests run inline; the multi-device bit-identity battery
runs on a 2x2 host mesh in one subprocess (conftest pattern).
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_subprocess_devices

from repro.compat import make_mesh
from repro.core import dbcsr
from repro.core.engine import batched_stack_executor, stack_executor
from repro.planner.cost_model import (
    BATCHED_ALGORITHMS, HardwareModel, Problem, batched_dispatch_cost,
    candidate_cost)
from repro.planner.plan import (
    plan_cache_clear, plan_cache_stats, plan_multiply, plan_multiply_batched)


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def _rand_mask(rng, nbr, nbc, fill):
    if fill >= 1.0:
        return None
    mask = rng.rand(nbr, nbc) < fill
    mask[0, 0] = True            # keep at least one block
    return mask


# ---------------------------------------------------------------------------
# grouped executor oracle: fused batch vs per-group executors, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fills", [(1.0, 1.0, 1.0), (1.0, 0.5, 0.05)])
def test_batched_executor_matches_per_group(rng, fills):
    m, k, n = 128, 192, 64
    bm, bk, bn = 32, 32, 32
    g = len(fills)
    a = rng.randn(g, m, k).astype(np.float32)
    b = rng.randn(g, k, n).astype(np.float32)
    group_masks = []
    for gi, fill in enumerate(fills):
        am = _rand_mask(rng, m // bm, k // bk, fill)
        if am is not None:
            a[gi] *= np.repeat(np.repeat(am, bm, 0), bk, 1)
        group_masks.append({} if am is None else {"a_mask": am})
    fused = batched_stack_executor(
        g, m, k, n, block_m=bm, block_k=bk, block_n=bn,
        kernel="ref", group_masks=group_masks)
    got = np.asarray(fused(jnp.asarray(a), jnp.asarray(b)))
    for gi in range(g):
        solo = stack_executor(
            m, k, n, block_m=bm, block_k=bk, block_n=bn, kernel="ref",
            stack_size=fused.stack_size, align=fused.align,
            **group_masks[gi])
        want = np.asarray(solo(jnp.asarray(a[gi]), jnp.asarray(b[gi])))
        assert np.array_equal(got[gi], want), (gi, fills)


def test_batched_executor_smm_kernel(rng):
    # the Pallas-backed smm path against the ref path (allclose — the
    # kernels differ in accumulation instruction, not semantics)
    g, m, k, n = 2, 64, 64, 64
    a = jnp.asarray(rng.randn(g, m, k).astype(np.float32))
    b = jnp.asarray(rng.randn(g, k, n).astype(np.float32))
    f_smm = batched_stack_executor(g, m, k, n, block_m=32, block_k=32,
                                   block_n=32, kernel="smm")
    f_ref = batched_stack_executor(g, m, k, n, block_m=32, block_k=32,
                                   block_n=32, kernel="ref")
    np.testing.assert_allclose(np.asarray(f_smm(a, b)),
                               np.asarray(f_ref(a, b)), atol=1e-4)


def test_batched_plan_stats(rng):
    g = 3
    masks = [{}, {}, {"a_mask": _rand_mask(rng, 4, 4, 0.4)}]
    f = batched_stack_executor(g, 128, 128, 128, block_m=32, block_k=32,
                               block_n=32, kernel="ref", group_masks=masks)
    st = f.batched_plan.stats()
    assert st["n_groups"] == g
    assert len(st["per_group"]) == g
    # the two dense groups share one memoized plan
    assert st["n_shared_plans"] < g
    # sparse group padded up to the dense groups' pow-2 stack shape
    assert st["n_padding"] > 0
    assert 0.0 < st["padding_frac"] < 1.0
    assert st["n_entries"] + st["n_padding"] \
        == f.batched_plan.triples.shape[0] * f.batched_plan.triples.shape[1]


# ---------------------------------------------------------------------------
# planner: fuse-or-loop pricing, cache stats, summa-gather memory gate
# ---------------------------------------------------------------------------

def test_plan_multiply_batched_fuse_decision():
    # many small same-geometry requests: trace/launch amortization wins
    bp = plan_multiply_batched(16, 256, 256, 256, mesh_shape=(1, 1))
    assert bp.fuse and bp.n_requests == 16
    assert bp.algorithm in BATCHED_ALGORITHMS
    assert bp.predicted_speedup > 1.0
    assert "FUSE" in bp.explain()
    # nothing to amortize for a single request
    assert not plan_multiply_batched(1, 256, 256, 256).fuse
    # empty product -> trivial, never fused
    assert not plan_multiply_batched(8, 256, 256, 256, occupancy=0.0).fuse
    with pytest.raises(ValueError):
        plan_multiply_batched(4, 256, 256, 256, algorithm="cannon25d")


def test_batched_dispatch_cost_padding_penalty():
    hw = HardwareModel()
    prob = Problem(512, 512, 512, 64, 64, 64, 1.0, 4, 1, 1)
    chosen = candidate_cost(hw, prob, "cannon", True)
    fused0, looped = batched_dispatch_cost(hw, chosen, 8, 0.0)
    fused_padded, _ = batched_dispatch_cost(hw, chosen, 8, 0.5)
    assert fused0 < looped           # amortization wins without padding
    assert fused_padded > fused0     # padding priced as wasted compute


def test_plan_cache_stats():
    plan_cache_clear()
    s0 = plan_cache_stats()
    assert s0["hits"] == s0["misses"] == s0["evictions"] == 0
    plan_multiply(384, 384, 384)
    plan_multiply(384, 384, 384)
    s1 = plan_cache_stats()
    assert s1["misses"] >= 1 and s1["hits"] >= 1
    assert s1["currsize"] >= 1
    assert s1["evictions"] == max(s1["misses"] - s1["currsize"], 0)


def test_summa_gather_memory_gate():
    hw = HardwareModel()
    prob = Problem(1024, 1024, 1024, 64, 64, 64, 1.0, 4, 4, 4)
    gather = candidate_cost(hw, prob, "summa_gather", True)
    summa = candidate_cost(hw, prob, "summa", True)
    # gathered full-K panels: sqrt(P)-fold operand replication
    assert gather.mem_bytes > 2 * summa.mem_bytes
    ml, nl, e = 1024 // 4, 1024 // 4, 4
    assert gather.mem_bytes == (ml * 1024 + 1024 * nl + ml * nl) * e
    # the gate trips when the replicas don't fit
    tight = HardwareModel(mem_bytes=float(gather.mem_bytes) - 1.0)
    assert not candidate_cost(tight, prob, "summa_gather", True).feasible
    assert candidate_cost(tight, prob, "summa", True).feasible
    # pinned summa+gather plans through the replication-aware model
    plan = plan_multiply(1024, 1024, 1024, mesh_shape=(4, 4),
                         algorithm="summa_gather", hw=hw)
    assert plan.chosen is not None
    assert plan.chosen.algorithm == "summa_gather"
    assert plan.chosen.mem_bytes == gather.mem_bytes


# ---------------------------------------------------------------------------
# dbcsr api: bucket key, add(recompute_norms), batched vs looped
# ---------------------------------------------------------------------------

def test_bucket_key_contract(rng):
    mesh = _mesh11()
    A = rng.randn(128, 128).astype(np.float32)
    a = dbcsr.create(A, mesh=mesh, block_size=64)
    b = dbcsr.create(A, mesh=mesh, block_size=64)
    assert dbcsr._bucket_key(a, b, None) == dbcsr._bucket_key(b, a, None)
    # eps is part of the key
    assert dbcsr._bucket_key(a, b, 1e-3) != dbcsr._bucket_key(a, b, None)
    # occupancy bin is part of the key
    mask = np.zeros((2, 2), bool)
    mask[0, 0] = True
    a_sp = dbcsr.create(A, mesh=mesh, block_size=64, block_mask=mask)
    assert dbcsr._bucket_key(a_sp, b, None) != dbcsr._bucket_key(a, b, None)
    # geometry is part of the key
    c = dbcsr.create(rng.randn(128, 256).astype(np.float32),
                     mesh=mesh, block_size=64)
    assert dbcsr._bucket_key(a, c, None) != dbcsr._bucket_key(a, b, None)


def test_add_recompute_norms(rng):
    mesh = _mesh11()
    A = rng.randn(128, 128).astype(np.float32)
    B = rng.randn(128, 128).astype(np.float32)
    a = dbcsr.create(A, mesh=mesh, block_size=64, compute_norms=True)
    b = dbcsr.create(B, mesh=mesh, block_size=64, compute_norms=True)
    lazy = dbcsr.add(a, b)
    assert lazy.block_norms is None      # default: cache stays empty
    eager = dbcsr.add(a, b, recompute_norms=True)
    assert eager.block_norms is not None
    np.testing.assert_allclose(eager.block_norms, lazy.norms(), rtol=1e-6)


def _make_requests(rng, mesh, geoms_fills, block_size=32):
    reqs, refs = [], []
    for (m, k, n), fill in geoms_fills:
        A = rng.randn(m, k).astype(np.float32)
        B = rng.randn(k, n).astype(np.float32)
        am = _rand_mask(rng, m // block_size, k // block_size, fill)
        a = dbcsr.create(A, mesh=mesh, block_size=block_size, block_mask=am)
        b = dbcsr.create(B, mesh=mesh, block_size=block_size)
        reqs.append((a, b))
        refs.append((np.asarray(a.data), B))
    return reqs, refs


@pytest.mark.parametrize("algorithm", ["cannon", "summa"])
def test_multiply_batched_bit_identity_1x1(rng, algorithm):
    # the acceptance oracle: fused == looped BITWISE on the blocked
    # path at depth 1, eps 0, across fills and mixed geometries
    mesh = _mesh11()
    geoms_fills = [
        ((128, 96, 64), 1.0), ((128, 96, 64), 1.0),   # same bucket
        ((128, 96, 64), 0.5), ((128, 96, 64), 0.05),  # other fill bins
        ((64, 64, 128), 1.0),                         # other geometry
    ]
    reqs, refs = _make_requests(rng, mesh, geoms_fills)
    kw = dict(mesh=mesh, algorithm=algorithm, densify=False,
              local_kernel="ref", pipeline_depth=1)
    fused, report = dbcsr.multiply_batched(reqs, fused=True,
                                           return_plan=True, **kw)
    looped = dbcsr.multiply_batched(reqs, fused=False, **kw)
    assert report["n_buckets"] == 4
    assert report["n_fused_requests"] == len(reqs)
    for i, (c_f, c_l) in enumerate(zip(fused, looped)):
        assert np.array_equal(np.asarray(c_f.data), np.asarray(c_l.data)), i
        Am, B = refs[i]
        np.testing.assert_allclose(np.asarray(c_f.data), Am @ B, atol=1e-3)


def test_multiply_batched_auto_and_filter(rng):
    mesh = _mesh11()
    geoms_fills = [((128, 128, 128), 1.0)] * 3
    reqs, refs = _make_requests(rng, mesh, geoms_fills, block_size=64)
    # planner-driven fuse decision (algorithm/densify free)
    out, report = dbcsr.multiply_batched(reqs, mesh=mesh, return_plan=True)
    for i, c in enumerate(out):
        Am, B = refs[i]
        np.testing.assert_allclose(np.asarray(c.data), Am @ B, atol=1e-3)
    assert report["buckets"][0]["plan"] is not None
    # eps filtering: fused result support == looped result support
    eps = 1e-2
    f_eps = dbcsr.multiply_batched(reqs, mesh=mesh, algorithm="cannon",
                                   densify=False, local_kernel="ref",
                                   filter_eps=eps, fused=True,
                                   pipeline_depth=1)
    l_eps = [dbcsr.multiply(a, b, mesh=mesh, algorithm="cannon",
                            densify=False, local_kernel="ref",
                            filter_eps=eps, pipeline_depth=1)
             for a, b in reqs]
    for c_f, c_l in zip(f_eps, l_eps):
        assert np.array_equal(c_f.block_mask, c_l.block_mask)
        assert np.array_equal(np.asarray(c_f.data), np.asarray(c_l.data))


def test_multiply_batched_gather_rejected(rng):
    mesh = _mesh11()
    reqs, _ = _make_requests(rng, mesh, [((64, 64, 64), 1.0)] * 2)
    with pytest.raises(ValueError):
        dbcsr.multiply_batched(reqs, mesh=mesh, algorithm="summa",
                               bcast="gather", fused=True)
    # unpinned it degrades to the looped path instead of raising
    out = dbcsr.multiply_batched(reqs, mesh=mesh, algorithm="summa",
                                 bcast="gather")
    assert len(out) == 2


# ---------------------------------------------------------------------------
# serving layer: SLO/max_batch draining with an injected clock
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_multiply_service(rng):
    from repro.serve.multiply_service import MultiplyService

    mesh = _mesh11()
    clk = FakeClock()
    svc = MultiplyService(mesh, slo_s=1.0, max_batch=4, clock=clk,
                          algorithm="cannon", densify=False,
                          local_kernel="ref", pipeline_depth=1)
    reqs, refs = _make_requests(
        rng, mesh, [((128, 128, 128), 1.0)] * 6, block_size=64)
    tickets = [svc.submit(a, b) for a, b in reqs]
    # full bucket (max_batch=4) fires immediately; 2 wait on the SLO
    done = svc.poll()
    assert sorted(done) == tickets[:4]
    assert svc.n_pending == 2
    clk.t = 0.5
    assert svc.poll() == []          # inside the SLO window: keep waiting
    clk.t = 1.01
    assert sorted(svc.poll()) == tickets[4:]
    assert svc.n_pending == 0
    for t, (Am, B) in zip(tickets, refs):
        np.testing.assert_allclose(np.asarray(svc.result(t).data),
                                   Am @ B, atol=1e-3)
    st = svc.stats()
    assert st["n_requests"] == 6 and st["n_dispatches"] == 2
    assert st["n_fused_requests"] == 6
    assert st["latency_p99_s"] >= st["latency_p50_s"] >= 0.0
    # flush drains regardless of SLO; result() pops
    t7 = svc.submit(*reqs[0])
    assert svc.flush() == [t7]
    svc.result(t7)
    with pytest.raises(KeyError):
        svc.result(t7)


def test_multiply_service_bucketing(rng):
    from repro.serve.multiply_service import MultiplyService

    mesh = _mesh11()
    clk = FakeClock()
    svc = MultiplyService(mesh, slo_s=0.0, max_batch=8, clock=clk,
                          algorithm="cannon", densify=False,
                          local_kernel="ref", pipeline_depth=1)
    reqs, _ = _make_requests(
        rng, mesh, [((64, 64, 64), 1.0), ((64, 64, 128), 1.0),
                    ((64, 64, 64), 1.0)])
    for a, b in reqs:
        svc.submit(a, b)
    # slo_s=0: everything due on the first poll, but in TWO dispatches
    # (two geometry buckets)
    done = svc.poll()
    assert sorted(done) == [0, 1, 2]
    assert svc.stats()["n_dispatches"] == 2


# ---------------------------------------------------------------------------
# multi-device battery: fused == looped bitwise on a 2x2 mesh
# ---------------------------------------------------------------------------

BATTERY = r"""
import json
import numpy as np, jax, jax.numpy as jnp

from repro.compat import make_mesh
from repro.core import dbcsr

rng = np.random.RandomState(0)
out = {}
mesh = make_mesh((2, 2), ("data", "model"))

def requests(geoms_fills, bs):
    reqs, refs = [], []
    for (m, k, n), fill in geoms_fills:
        A = rng.randn(m, k).astype(np.float32)
        B = rng.randn(k, n).astype(np.float32)
        am = None
        if fill < 1.0:
            am = rng.rand(m // bs, k // bs) < fill
            am[0, 0] = True
        a = dbcsr.create(A, mesh=mesh, block_size=bs, block_mask=am)
        b = dbcsr.create(B, mesh=mesh, block_size=bs)
        reqs.append((a, b)); refs.append((np.asarray(a.data), B))
    return reqs, refs

geoms = [((128, 128, 64), 1.0), ((128, 128, 64), 1.0),
         ((128, 128, 64), 0.5), ((128, 128, 64), 0.05),
         ((64, 128, 128), 1.0)]
for algo in ("cannon", "summa"):
    reqs, refs = requests(geoms, 32)
    kw = dict(mesh=mesh, algorithm=algo, densify=False,
              local_kernel="ref", pipeline_depth=1)
    fused, rep = dbcsr.multiply_batched(reqs, fused=True, return_plan=True,
                                        **kw)
    looped = dbcsr.multiply_batched(reqs, fused=False, **kw)
    out[f"{algo}_bitwise"] = max(
        float(np.max(np.abs(np.asarray(f.data) - np.asarray(l.data)))
              if not np.array_equal(np.asarray(f.data), np.asarray(l.data))
              else 0.0)
        for f, l in zip(fused, looped))
    out[f"{algo}_exact"] = all(
        np.array_equal(np.asarray(f.data), np.asarray(l.data))
        for f, l in zip(fused, looped))
    out[f"{algo}_ref"] = max(
        float(np.max(np.abs(np.asarray(f.data) - Am @ B)))
        for f, (Am, B) in zip(fused, refs))
    out[f"{algo}_fused_requests"] = rep["n_fused_requests"]

# densified fused path (allclose contract, not bitwise)
reqs, refs = requests([((128, 128, 128), 1.0)] * 4, 64)
dens = dbcsr.multiply_batched(reqs, mesh=mesh, algorithm="cannon",
                              densify=True, fused=True)
out["densified_ref"] = max(
    float(np.max(np.abs(np.asarray(c.data) - Am @ B)))
    for c, (Am, B) in zip(dens, refs))

print("JSON" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def battery_results():
    stdout = run_subprocess_devices(BATTERY, n_devices=4, timeout=900)
    line = [l for l in stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


@pytest.mark.parametrize("algo", ["cannon", "summa"])
def test_distributed_batched_bit_identity(battery_results, algo):
    assert battery_results[f"{algo}_exact"] is True, \
        battery_results[f"{algo}_bitwise"]
    assert battery_results[f"{algo}_fused_requests"] == 5
    assert battery_results[f"{algo}_ref"] < 2e-4


def test_distributed_batched_densified(battery_results):
    assert battery_results["densified_ref"] < 2e-4
