"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (the FULL
configs are exercised only via the dry-run)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from repro.compat import set_mesh

from repro.configs.base import ARCHS, get_config, reduced_config
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.serve import engine
from repro.serve.prefill import prefill_step
from repro.train.optimizer import OptConfig, make_optimizer
from repro.train.train_step import make_train_step


@pytest.fixture(scope="module")
def mesh():
    # single real device: 1x1x1 production-shaped mesh
    return make_mesh((1, 1, 1), ("pod", "data", "model"))


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, cfg.vocab_size, (b, s)).astype(np.int32)
    if cfg.input_mode == "embeddings":
        inputs = rng.randn(b, s, cfg.d_model).astype(np.float32)
    else:
        inputs = rng.randint(0, cfg.vocab_size, (b, s)).astype(np.int32)
    return {"inputs": jnp.asarray(inputs), "labels": jnp.asarray(labels)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch, mesh):
    cfg = reduced_config(get_config(arch))
    params = T.model_init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    with set_mesh(mesh):
        logits, hidden, aux, _ = jax.jit(
            lambda p, b: T.forward(p, b["inputs"], cfg, mesh))(params, batch)
        loss, metrics = jax.jit(
            lambda p, b: T.lm_loss(p, b, cfg, mesh))(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert hidden.shape == (2, 16, cfg.d_model)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch, mesh):
    cfg = reduced_config(get_config(arch))
    params = T.model_init(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(OptConfig(lr=5e-3))
    opt_state = opt.init(params)
    step = make_train_step(cfg, mesh, opt)
    batch = _batch(cfg)
    with set_mesh(mesh):
        jstep = jax.jit(step, donate_argnums=(0, 1))
        losses = []
        for _ in range(4):
            params, opt_state, metrics = jstep(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses   # same batch -> must descend


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, mesh):
    """Prefill then one decode step == forward over the full sequence.

    This is the strongest correctness property of the serving stack:
    KV/latent/state caches must reproduce the teacher-forced logits.
    """
    cfg = reduced_config(get_config(arch))
    params = T.model_init(cfg, jax.random.PRNGKey(1))
    b, s = 2, 12
    batch = _batch(cfg, b=b, s=s, seed=3)
    inputs = batch["inputs"]
    with set_mesh(mesh):
        # full forward logits at the last position
        logits_full, _, _, _ = T.forward(params, inputs, cfg, mesh)
        # prefill on the first s-1 tokens, then decode token s-1
        prefix = inputs[:, : s - 1]
        _, cache, cur = prefill_step(params, prefix, cfg, mesh)
        # grow каждый cache's sequence dim to s (prefill caches cover s-1)
        def grow(x, shapes):
            return x
        state = {"cache": _pad_cache(cfg, cache, b, s - 1, s + 4),
                 "cur_len": cur}
        last = inputs[:, s - 1:]
        next_tok, _ = engine.decode_step(params, state, last, cfg, mesh)
    lf = np.asarray(logits_full[:, -1], np.float32)
    expected = lf.argmax(-1)
    got = np.asarray(next_tok)[:, 0]
    np.testing.assert_array_equal(got, expected)


def _pad_cache(cfg, cache, batch, cur_len, max_len):
    """Embed prefill caches (seq dim cur_len) into decode caches of
    max_len — attention/mla caches pad the seq dim; state caches pass."""
    target = T.cache_shapes(cfg, batch, max_len)

    def pad(x, t):
        x = jnp.asarray(x)
        if x.shape == t.shape:
            return x.astype(t.dtype)
        pads = [(0, ts - xs) for xs, ts in zip(x.shape, t.shape)]
        return jnp.pad(x, pads).astype(t.dtype)

    return jax.tree_util.tree_map(pad, cache, target)
