"""Fused stack executor (core/engine.py): equivalence vs the jnp oracle
across the paper's block sizes, the single-compile property (one smm
trace per block geometry, not per stack), plan memoization, autotune
default resolution, and the blocked-path local-geometry regression
(blocked vs densified on 1x1 and 2x2 meshes)."""
import json
import types

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_subprocess_devices
from repro.core import engine
from repro.core.blocking import BlockLayout, GridSpec
from repro.core.densify import blocked_local_matmul, from_blocks, to_blocks
from repro.core.multiply import distributed_matmul
from repro.core.stacks import build_stacks, pad_plans, stack_statistics
from repro.kernels.smm.ref import smm_process_stack_ref


# ---------------------------------------------------------------------------
# executor vs oracle equivalence (paper block sizes, ragged final stack)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel", ["ref", "smm"])
@pytest.mark.parametrize("block", [4, 22, 64])
def test_executor_matches_oracle(block, kernel, rng):
    nb = 3
    m = k = n = block * nb
    a = jnp.asarray(rng.randn(m, k).astype(np.float32))
    b = jnp.asarray(rng.randn(k, n).astype(np.float32))

    # stack_size = 2 k-runs; nb*nb = 9 runs total -> 5 stacks, ragged tail
    f = blocked_local_matmul(m, k, n, block_m=block, block_k=block,
                             block_n=block, stack_size=2 * nb, kernel=kernel)
    plan = f.executor_plan
    assert plan.n_stacks > 1, "test must exercise the multi-stack scan"
    assert plan.n_padding > 0, "test must exercise the ragged final stack"

    c = np.asarray(f(a, b))

    # oracle 1: one un-padded mega-stack through the jnp reference
    triples = jnp.asarray(np.concatenate([p.triples for p in f.plans]))
    c0 = jnp.zeros((nb * nb, block, block), jnp.float32)
    oracle = np.asarray(from_blocks(
        smm_process_stack_ref(to_blocks(a, block, block),
                              to_blocks(b, block, block), c0, triples),
        nb, nb))
    # oracle 2: the dense product itself
    dense = np.asarray(a) @ np.asarray(b)

    tol = 1e-4 * block
    np.testing.assert_allclose(c, oracle, rtol=0, atol=tol)
    np.testing.assert_allclose(c, dense, rtol=0, atol=tol)


def test_executor_rectangular_blocks(rng):
    """Non-uniform (bm, bk, bn) geometry through the fused path."""
    bm, bk, bn = 8, 16, 4
    m, k, n = 4 * bm, 3 * bk, 5 * bn
    a = jnp.asarray(rng.randn(m, k).astype(np.float32))
    b = jnp.asarray(rng.randn(k, n).astype(np.float32))
    f = blocked_local_matmul(m, k, n, block_m=bm, block_k=bk, block_n=bn,
                             stack_size=5, kernel="ref")
    np.testing.assert_allclose(np.asarray(f(a, b)),
                               np.asarray(a) @ np.asarray(b),
                               rtol=0, atol=1e-4)


# ---------------------------------------------------------------------------
# single-compile property: scan traces the smm kernel once per geometry
# ---------------------------------------------------------------------------


def _count_named_calls(jaxpr, name) -> int:
    """Call-site equations (pjit etc.) named ``name``, recursing into
    every sub-jaxpr (scan bodies, nested calls)."""
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.params.get("name") == name:
            count += 1
        for v in eqn.params.values():
            subs = v if isinstance(v, (list, tuple)) else [v]
            for s in subs:
                if isinstance(s, jax.core.ClosedJaxpr):
                    s = s.jaxpr
                if isinstance(s, jax.core.Jaxpr):
                    count += _count_named_calls(s, name)
    return count


def test_fused_executor_traces_smm_once():
    block, nb = 8, 4
    m = k = n = block * nb
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)

    f = blocked_local_matmul(m, k, n, block_m=block, block_k=block,
                             block_n=block, stack_size=2 * nb, align=False,
                             kernel="smm")
    n_stacks = f.executor_plan.n_stacks
    assert n_stacks > 1

    fused = jax.make_jaxpr(f)(a, b).jaxpr
    assert _count_named_calls(fused, "smm_process_stack") == 1, \
        "fused executor must embed exactly one smm call (inside the scan)"

    # the legacy per-plan loop embeds one call per stack
    plan = f.executor_plan

    def looped(a, b):
        ab = to_blocks(a, block, block)
        bb = to_blocks(b, block, block)
        c0 = jnp.zeros((plan.nbr * plan.nbc, block, block), jnp.float32)
        c = engine.execute_plans_looped(list(plan.plans), ab, bb, c0,
                                        kernel="smm", align=False)
        return from_blocks(c, plan.nbr, plan.nbc)

    looped_jaxpr = jax.make_jaxpr(looped)(a, b).jaxpr
    assert _count_named_calls(looped_jaxpr, "smm_process_stack") == n_stacks


# ---------------------------------------------------------------------------
# host-side plan construction: padding contract + memoization
# ---------------------------------------------------------------------------


def test_pad_plans_mask_and_sentinel():
    a = BlockLayout(64, 96, 16, 16)
    b = BlockLayout(96, 80, 16, 16)
    plans = build_stacks(a, b, stack_size=13)  # runs of 6 -> ragged stacks
    padded = pad_plans(plans)
    tile = max(p.size for p in plans)
    assert padded.shape == (len(plans), tile, 4)
    n_c = plans[0].n_c_blocks
    total = sum(p.size for p in plans)
    assert int(padded[:, :, 3].sum()) == total
    valid = padded[:, :, 3].astype(bool)
    # padding rows: zeroed a/b, sentinel c one past the real C blocks
    assert (padded[~valid][:, 2] == n_c).all()
    assert (padded[~valid][:, :2] == 0).all()
    # real rows reproduce the original triples, in order
    flat = padded[valid][:, :3]
    np.testing.assert_array_equal(flat, np.concatenate(
        [p.triples for p in plans]))
    # stats surface the padding
    stats = stack_statistics(plans, stack_tile=tile)
    assert stats["n_padding"] == len(plans) * tile - total
    assert 0 < stats["fill"] <= 1


def test_executor_plan_memoized():
    p1 = engine.build_executor_plan(64, 64, 64, 16, 16, 16, 1000)
    p2 = engine.build_executor_plan(64, 64, 64, 16, 16, 16, 1000)
    assert p1 is p2, "plan construction must be memoized per geometry"
    p3 = engine.build_executor_plan(64, 64, 64, 16, 16, 16, 999)
    assert p3 is not p1


def test_autotune_defaults_resolved():
    from repro.kernels.smm.autotune import best_params_for
    f = blocked_local_matmul(64, 64, 64, block_m=16, block_k=16, block_n=16)
    assert (f.align, f.stack_size) == best_params_for(16, 16, 16)
    # explicit overrides win over the winners table
    g = blocked_local_matmul(64, 64, 64, block_m=16, block_k=16, block_n=16,
                             stack_size=7, align=False)
    assert (g.align, g.stack_size) == (False, 7)


def test_best_params_reads_winners_table(tmp_path):
    from repro.kernels.smm.autotune import best_params
    cache = tmp_path / "smm_autotune.json"
    cache.write_text(json.dumps(
        {"22": {"best": {"align": True, "stack_tile": 4096}}}))
    assert best_params(22, str(cache)) == (True, 4096)
    assert best_params(99, str(cache)) == (True, 30000)  # heuristic fallback


# ---------------------------------------------------------------------------
# blocked-path local-geometry regression (multiply.py)
# ---------------------------------------------------------------------------


def test_blocked_nonsquare_grid_raises():
    """The cannon blocked path must refuse non-square grids loudly
    instead of silently building wrong StackPlan geometry.  (Summa
    no longer rejects non-square grids: its blocked path builds
    per-panel plans — covered by the geometry battery below; it still
    rejects shapes whose panels don't block-divide.)"""
    mesh = types.SimpleNamespace(shape={"data": 2, "model": 4})
    a = jnp.zeros((64, 96), jnp.float32)
    b = jnp.zeros((96, 80), jnp.float32)
    with pytest.raises(ValueError):
        distributed_matmul(a, b, mesh=mesh, grid=GridSpec("data", "model"),
                           algorithm="cannon", densify=False,
                           block_m=8, block_k=8, block_n=8)
    # 2x4 grid: N/pc = 20 does not divide into 8-blocks -> loud error
    with pytest.raises(ValueError, match="divisible"):
        distributed_matmul(a, b, mesh=mesh, grid=GridSpec("data", "model"),
                           algorithm="summa", densify=False,
                           block_m=8, block_k=8, block_n=8)


GEOMETRY_BATTERY = r"""
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.core.blocking import GridSpec
from repro.core.multiply import distributed_matmul

rng = np.random.RandomState(0)
out = {}
M, K, N = 64, 96, 80
A = rng.randn(M, K).astype(np.float32)
B = rng.randn(K, N).astype(np.float32)
ref = A @ B
for pg in (1, 2):
    mesh = make_mesh((pg, pg), ("data", "model"))
    grid = GridSpec("data", "model")
    sh = NamedSharding(mesh, P("data", "model"))
    Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
    C = distributed_matmul(Ad, Bd, mesh=mesh, grid=grid,
                           algorithm="cannon", densify=False,
                           block_m=8, block_k=8, block_n=8,
                           local_kernel="ref")
    Cd = distributed_matmul(Ad, Bd, mesh=mesh, grid=grid,
                            algorithm="cannon", densify=True)
    out[f"blocked_vs_dense_{pg}x{pg}"] = float(
        np.max(np.abs(np.asarray(C) - ref)))
    out[f"blocked_vs_densified_{pg}x{pg}"] = float(
        np.max(np.abs(np.asarray(C) - np.asarray(Cd))))
    # summa blocked, both broadcast variants (gather's local multiply
    # sees the full K extent — a distinct stack-plan geometry)
    for bcast in ("psum", "gather"):
        Cs = distributed_matmul(Ad, Bd, mesh=mesh, grid=grid,
                                algorithm="summa", densify=False,
                                block_m=8, block_k=8, block_n=8,
                                local_kernel="ref", bcast=bcast)
        out[f"summa_{bcast}_blocked_{pg}x{pg}"] = float(
            np.max(np.abs(np.asarray(Cs) - ref)))

# non-square grids: summa's blocked path builds per-panel plans (panel
# K-extent k/lcm(pr,pc) != the local K extent), no longer a ValueError
for pr, pc in ((1, 2), (2, 1)):
    mesh = make_mesh((pr, pc), ("data", "model"))
    grid = GridSpec("data", "model")
    sh = NamedSharding(mesh, P("data", "model"))
    Ad, Bd = jax.device_put(A, sh), jax.device_put(B, sh)
    for bcast in ("psum", "gather"):
        Cs = distributed_matmul(Ad, Bd, mesh=mesh, grid=grid,
                                algorithm="summa", densify=False,
                                block_m=8, block_k=8, block_n=8,
                                local_kernel="ref", bcast=bcast)
        out[f"summa_{bcast}_blocked_{pr}x{pc}"] = float(
            np.max(np.abs(np.asarray(Cs) - ref)))
print("JSON" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def geometry_results():
    stdout = run_subprocess_devices(GEOMETRY_BATTERY, n_devices=4,
                                    timeout=600)
    line = [l for l in stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


@pytest.mark.parametrize("key", [
    "blocked_vs_dense_1x1", "blocked_vs_densified_1x1",
    "blocked_vs_dense_2x2", "blocked_vs_densified_2x2",
    "summa_psum_blocked_1x1", "summa_gather_blocked_1x1",
    "summa_psum_blocked_2x2", "summa_gather_blocked_2x2",
    "summa_psum_blocked_1x2", "summa_gather_blocked_1x2",
    "summa_psum_blocked_2x1", "summa_gather_blocked_2x1",
])
def test_blocked_local_geometry(geometry_results, key):
    assert geometry_results[key] < 2e-4, (key, geometry_results[key])


def test_executor_rejects_mismatched_operands(rng):
    """Shapes that divide into the blocks but disagree with the plan's
    geometry must fail loudly, not execute with clamped block indices."""
    f = blocked_local_matmul(32, 32, 32, block_m=8, block_k=8, block_n=8,
                             kernel="ref")
    with pytest.raises(ValueError, match="stack executor built for"):
        f(jnp.zeros((16, 64), jnp.float32), jnp.zeros((64, 32), jnp.float32))
