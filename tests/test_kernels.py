"""Per-kernel allclose vs the pure-jnp oracles: shape/dtype sweeps in
interpret mode (kernel bodies execute on CPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.blocking import BlockLayout
from repro.core.stacks import build_stacks
from repro.core.densify import to_blocks, from_blocks
from repro.kernels.smm.ops import smm_process_stack
from repro.kernels.smm.ref import smm_process_stack_ref
from repro.kernels.tiled_matmul.ops import tiled_matmul
from repro.kernels.tiled_matmul.ref import tiled_matmul_ref
from repro.kernels.grouped_gemm.ops import grouped_gemm
from repro.kernels.grouped_gemm.ref import grouped_gemm_ref


# ---------------------------------------------------------------------------
# smm (LIBCUSMM analogue)
# ---------------------------------------------------------------------------

SMM_CASES = [
    # (m, k, n, bm, bk, bn)  — includes the paper's 22/64 block sizes
    (32, 48, 40, 8, 8, 8),
    (44, 66, 22, 22, 22, 22),
    (128, 128, 128, 64, 64, 64),
    (64, 128, 96, 16, 32, 24),
    (12, 8, 4, 4, 4, 4),       # paper's very-small block test
]


@pytest.mark.parametrize("m,k,n,bm,bk,bn", SMM_CASES)
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_smm_vs_ref_and_dense(m, k, n, bm, bk, bn, dtype, rng):
    a = rng.randn(m, k).astype(dtype)
    b = rng.randn(k, n).astype(dtype)
    a_blocks = to_blocks(jnp.asarray(a), bm, bk)
    b_blocks = to_blocks(jnp.asarray(b), bk, bn)
    plans = build_stacks(BlockLayout(m, k, bm, bk),
                         BlockLayout(k, n, bk, bn), stack_size=64)
    nbr, nbc = m // bm, n // bn
    c = jnp.zeros((nbr * nbc, bm, bn), jnp.float32)
    c_ref = c
    for p in plans:
        t = jnp.asarray(p.triples)
        c = smm_process_stack(a_blocks, b_blocks, c, t)
        c_ref = smm_process_stack_ref(a_blocks, b_blocks, c_ref, t)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref),
                               rtol=1e-5, atol=1e-5)
    dense = from_blocks(c, nbr, nbc)
    np.testing.assert_allclose(np.asarray(dense),
                               a.astype(np.float32) @ b.astype(np.float32),
                               rtol=1e-4, atol=1e-4)


def test_smm_mxu_aligned_pad(rng):
    """align=True pads blocks to (8,128) multiples — results identical."""
    m, k, n, bs = 44, 44, 44, 22
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    a_blocks = to_blocks(jnp.asarray(a), bs, bs)
    b_blocks = to_blocks(jnp.asarray(b), bs, bs)
    plans = build_stacks(BlockLayout(m, k, bs, bs), BlockLayout(k, n, bs, bs))
    c0 = jnp.zeros((4, bs, bs), jnp.float32)
    c1 = c0
    for p in plans:
        t = jnp.asarray(p.triples)
        c0 = smm_process_stack(a_blocks, b_blocks, c0, t, align=False)
        c1 = smm_process_stack(a_blocks, b_blocks, c1, t, align=True)
    np.testing.assert_allclose(np.asarray(c0), np.asarray(c1),
                               rtol=1e-6, atol=1e-6)


def test_smm_bf16_inputs(rng):
    m = k = n = 64
    bs = 16
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    a_blocks = to_blocks(jnp.asarray(a, jnp.bfloat16), bs, bs)
    b_blocks = to_blocks(jnp.asarray(b, jnp.bfloat16), bs, bs)
    plans = build_stacks(BlockLayout(m, k, bs, bs), BlockLayout(k, n, bs, bs))
    c = jnp.zeros((16, bs, bs), jnp.float32)
    for p in plans:
        c = smm_process_stack(a_blocks, b_blocks, c, jnp.asarray(p.triples))
    ref = smm_process_stack_ref(a_blocks, b_blocks,
                                jnp.zeros_like(c), jnp.asarray(
                                    np.concatenate([p.triples for p in plans])))
    np.testing.assert_allclose(np.asarray(c), np.asarray(ref),
                               rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# tiled matmul (cuBLAS analogue)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (300, 500, 200),
                                   (64, 1024, 32), (17, 33, 9)])
@pytest.mark.parametrize("tiles", [(128, 128, 128), (64, 32, 256)])
def test_tiled_matmul(m, k, n, tiles, rng):
    bm, bn, bk = tiles
    a = rng.randn(m, k).astype(np.float32)
    b = rng.randn(k, n).astype(np.float32)
    out = tiled_matmul(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bn, bk=bk)
    ref = tiled_matmul_ref(jnp.asarray(a), jnp.asarray(b))
    # k-tiled accumulation reassociates the f32 sum vs one flat dot
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=1e-4)


def test_tiled_matmul_bf16(rng):
    a = rng.randn(256, 256).astype(np.float32)
    b = rng.randn(256, 128).astype(np.float32)
    out = tiled_matmul(jnp.asarray(a, jnp.bfloat16), jnp.asarray(b, jnp.bfloat16),
                       bm=128, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=3e-2, atol=3e-1)


# ---------------------------------------------------------------------------
# grouped gemm (densified MoE)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,c,d,f", [(4, 96, 160, 224), (8, 64, 64, 64),
                                     (2, 33, 17, 50)])
def test_grouped_gemm(e, c, d, f, rng):
    t = rng.randn(e, c, d).astype(np.float32)
    w = rng.randn(e, d, f).astype(np.float32)
    out = grouped_gemm(jnp.asarray(t), jnp.asarray(w), bc=32, bf=64, bk=64)
    ref = grouped_gemm_ref(jnp.asarray(t), jnp.asarray(w))
    # k-tiled accumulation reassociates the f32 sum vs one flat dot
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
