"""Robustness battery: ABFT checksums, fault injection, guards, and the
degrading/retrying multiply service.

Single-device tests run inline on the default 1-device backend (the
conftest contract); the 2x2-mesh chaos matrix runs in a subprocess with
its own XLA_FLAGS, mirroring tests/test_batched.py's battery pattern.
"""
import json

import numpy as np
import pytest

from conftest import run_subprocess_devices

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.compat import make_mesh  # noqa: E402
from repro.core import dbcsr  # noqa: E402
from repro.robustness import abft, chaos, guards  # noqa: E402

EXEC_KW = dict(densify=False, local_kernel="ref", pipeline_depth=1)


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def _operand(rng, m, n, *, block=32, fill=1.0, mesh=None):
    data = rng.randn(m, n).astype(np.float32)
    mask = None
    if fill < 1.0:
        mask = rng.rand(m // block, n // block) < fill
        mask[0, 0] = True
    return dbcsr.create(data, mesh=mesh, block_size=block, block_mask=mask)


# ---------------------------------------------------------------------------
# abft: checksum residuals, tolerances, detection, repair
# ---------------------------------------------------------------------------

def test_checksum_residuals_clean_below_tolerance(rng):
    a = rng.randn(96, 64).astype(np.float32)
    b = rng.randn(64, 96).astype(np.float32)
    c = a @ b
    rep = abft.verify_product(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c),
                              block_m=32, block_k=32, block_n=32)
    assert not rep.detected
    assert rep.flagged_blocks == ()
    # residuals are small but tolerances must dominate them
    assert (rep.row_residual <= rep.row_tol).all()
    assert (rep.col_residual <= rep.col_tol).all()


@pytest.mark.parametrize("mode", chaos.FAULT_MODES)
def test_verify_product_detects_and_localizes(rng, mode):
    a = rng.randn(96, 64).astype(np.float32)
    b = rng.randn(64, 128).astype(np.float32)
    c = a @ b
    inj = chaos.FaultInjector(seed=3)
    bad = inj.corrupt_block(jnp.asarray(c), 2, 1, block_m=32, block_n=32,
                            mode=mode)
    rep = abft.verify_product(jnp.asarray(a), jnp.asarray(b), bad,
                              block_m=32, block_k=32, block_n=32)
    assert rep.detected
    assert rep.flagged_blocks == ((2, 1),)


def test_verify_product_detects_nan_corruption(rng):
    # NaN residuals must trip detection, never sneak under a tolerance
    a = rng.randn(64, 64).astype(np.float32)
    b = rng.randn(64, 64).astype(np.float32)
    c = (a @ b).copy()
    c[5, 40] = np.nan
    rep = abft.verify_product(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c),
                              block_m=32, block_k=32, block_n=32)
    assert rep.detected
    assert (0, 1) in rep.flagged_blocks


def test_splice_blocks_repairs_exactly(rng):
    c = jnp.asarray(rng.randn(96, 96).astype(np.float32))
    fresh = jnp.asarray(rng.randn(96, 96).astype(np.float32))
    out = np.asarray(abft.splice_blocks(c, fresh, [(1, 2)], 32, 32))
    ref = np.asarray(c).copy()
    ref[32:64, 64:96] = np.asarray(fresh)[32:64, 64:96]
    assert (out == ref).all()


def test_verify_and_repair_raises_on_persistent_corruption(rng):
    a = jnp.asarray(rng.randn(64, 64).astype(np.float32))
    b = jnp.asarray(rng.randn(64, 64).astype(np.float32))
    bad = chaos.corrupt_block(a @ b, 0, 0, block_m=32, block_n=32,
                              mode="nan", rng=np.random.RandomState(0))

    with pytest.raises(guards.CorruptionDetectedError) as ei:
        abft.verify_and_repair(a, b, bad, recompute=lambda: bad,
                               block_m=32, block_k=32, block_n=32)
    assert ei.value.report.detected
    assert ei.value.report.repair_attempted and not ei.value.report.repaired


# ---------------------------------------------------------------------------
# multiply-level: verify= end-to-end on a 1x1 mesh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["cannon", "summa"])
@pytest.mark.parametrize("fill", [1.0, 0.05])
def test_multiply_verify_detect_localize_repair(rng, algorithm, fill):
    mesh = _mesh11()
    a = _operand(rng, 128, 128, fill=fill, mesh=mesh)
    b = _operand(rng, 128, 128, fill=fill, mesh=mesh)
    kw = dict(mesh=mesh, algorithm=algorithm, **EXEC_KW)

    clean = dbcsr.multiply(a, b, **kw)
    # verify=None must be bit-identical to the pre-existing behaviour
    # and attach no verification payload
    assert clean.verification is None

    # clean verified run: no false positive, bit-identical result
    cv = dbcsr.multiply(a, b, verify="checksum", **kw)
    assert cv.verification["enabled"]
    assert not cv.verification["report"].detected
    assert (np.asarray(cv.data) == np.asarray(clean.data)).all()

    # corrupt the max-norm block of the result; detect, localize
    # exactly, repair to the bitwise-clean product
    from repro.sparsity.norms import compute_block_norms
    norms = compute_block_norms(clean.data, 32, 32)
    i0, j0 = np.unravel_index(int(np.argmax(norms)), norms.shape)
    inj = chaos.FaultInjector(seed=7)
    hook = inj.one_shot_result_hook(int(i0), int(j0), block_m=32,
                                    block_n=32, mode="bitflip")
    with chaos.result_corruption(hook):
        cr = dbcsr.multiply(a, b, verify="checksum", **kw)
    rep = cr.verification["report"]
    assert rep.detected
    assert rep.flagged_blocks == ((int(i0), int(j0)),)
    assert rep.repaired and rep.n_recomputed_blocks >= 1
    assert (np.asarray(cr.data) == np.asarray(clean.data)).all()


def test_multiply_verify_no_false_positive_with_eps_filter(rng):
    # eps-filtered triples shift the result away from the unfiltered
    # product; the dropped-mass term in the tolerance must absorb that
    mesh = _mesh11()
    a = _operand(rng, 128, 128, fill=0.3, mesh=mesh)
    b = _operand(rng, 128, 128, fill=0.3, mesh=mesh)
    for eps in (1e-3, 1e-1, 5.0):
        c = dbcsr.multiply(a, b, mesh=mesh, filter_eps=eps,
                           verify="checksum", **EXEC_KW)
        if c.verification["enabled"]:
            assert not c.verification["report"].detected, f"eps={eps}"


def test_purification_iterated_multiplies_no_false_positive():
    # iterated multiplies (density-matrix purification) accumulate
    # float error; the norm-aware tolerance must not flag clean runs
    from repro.sparsity import banded_hamiltonian, initial_density
    from repro.sparsity.workloads import mcweeny_purify

    mesh = _mesh11()
    H, mask = banded_hamiltonian(128, 32, seed=0)
    P0 = initial_density(H, mu=0.0)
    P = dbcsr.create(P0.astype(np.float32), mesh=mesh, block_size=32,
                     block_mask=mask)
    _, trace = mcweeny_purify(
        P, mesh=mesh, n_iter=4, filter_eps=1e-5,
        multiply_kw=dict(verify="checksum", **EXEC_KW))
    assert len(trace) == 4  # no CorruptionDetectedError raised


def test_multiply_verify_invalid_mode(rng):
    mesh = _mesh11()
    a = _operand(rng, 64, 64, mesh=mesh)
    with pytest.raises(ValueError, match="verify"):
        dbcsr.multiply(a, a, mesh=mesh, verify="paranoid", **EXEC_KW)


def test_batched_verify_forces_looped_and_rejects_pinned_fused(rng):
    mesh = _mesh11()
    pairs = [(_operand(rng, 64, 64, mesh=mesh),
              _operand(rng, 64, 64, mesh=mesh)) for _ in range(3)]
    results, report = dbcsr.multiply_batched(
        pairs, mesh=mesh, verify="checksum", return_plan=True, **EXEC_KW)
    assert all(not b["fused"] for b in report["buckets"])
    for (a, b), c in zip(pairs, results):
        ref = dbcsr.multiply(a, b, mesh=mesh, **EXEC_KW)
        assert (np.asarray(c.data) == np.asarray(ref.data)).all()
        assert not c.verification["report"].detected
    with pytest.raises(ValueError, match="fused"):
        dbcsr.multiply_batched(pairs, mesh=mesh, verify="checksum",
                               fused=True, **EXEC_KW)


# ---------------------------------------------------------------------------
# planner: verify="auto" is a costed decision
# ---------------------------------------------------------------------------

def test_decide_verify_budget():
    from repro.planner.calibrate import get_hardware_model
    from repro.planner.plan import decide_verify, plan_multiply

    hw = get_hardware_model()
    # large square problem: checksum flops are O(1/nblocks) of the
    # multiply -> enabled under the default budget
    big = plan_multiply(2048, 2048, 2048, blocks=(64, 64, 64), hw=hw)
    d_big = decide_verify(big, 2048, 2048, 2048, blocks=(64, 64, 64), hw=hw)
    assert d_big["auto_enabled"]
    assert d_big["overhead_frac"] <= d_big["budget"]
    # tiny problem: fixed latencies dominate -> declined
    small = plan_multiply(64, 64, 64, blocks=(32, 32, 32), hw=hw)
    d_small = decide_verify(small, 64, 64, 64, blocks=(32, 32, 32), hw=hw)
    assert not d_small["auto_enabled"]
    # a zero budget declines everything
    d_zero = decide_verify(big, 2048, 2048, 2048, blocks=(64, 64, 64),
                           budget=0.0, hw=hw)
    assert not d_zero["auto_enabled"]


def test_multiply_verify_auto_prices_overhead(rng):
    mesh = _mesh11()
    a = _operand(rng, 64, 64, mesh=mesh)
    c = dbcsr.multiply(a, a, mesh=mesh, verify="auto", **EXEC_KW)
    info = c.verification
    assert info["mode"] == "auto"
    assert "overhead_frac" in info and "predicted_overhead_s" in info
    # explicit generous budget forces it on even for a small problem
    c2 = dbcsr.multiply(a, a, mesh=mesh, verify="auto",
                        verify_budget=1e9, **EXEC_KW)
    assert c2.verification["enabled"]
    assert c2.verification["report"] is not None


# ---------------------------------------------------------------------------
# guards: typed validation taxonomy + tripwires
# ---------------------------------------------------------------------------

def test_guards_finite_tripwires(rng):
    x = jnp.asarray(rng.randn(8, 8).astype(np.float32))
    assert guards.all_finite(x)
    assert not guards.all_finite(x.at[3, 3].set(jnp.nan))
    with pytest.raises(guards.NonFiniteOperandError):
        guards.assert_finite(x.at[0, 0].set(jnp.inf), "A")
    with pytest.raises(guards.NonFiniteResultError):
        guards.assert_finite(x.at[0, 0].set(jnp.inf), "C", kind="result")
    assert guards.all_finite(jnp.arange(4))  # integer dtypes: trivially ok


def test_guards_validate_multiply_request(rng):
    mesh = _mesh11()
    a = _operand(rng, 64, 64, mesh=mesh)
    b = _operand(rng, 64, 96, mesh=mesh)
    guards.validate_multiply_request(a, b)  # clean pair passes

    # inner-dimension mismatch
    with pytest.raises(guards.ShapeMismatchError):
        guards.validate_multiply_request(b, b)

    # mask inconsistency: wrong mask shape
    bad = _operand(rng, 64, 64, mesh=mesh)
    bad.block_mask = np.ones((3, 3), dtype=bool)
    with pytest.raises(guards.MaskConsistencyError):
        guards.validate_multiply_request(bad, b)

    # norm-cache inconsistency: nonzero norm outside the mask
    nb = _operand(rng, 64, 64, fill=0.5, mesh=mesh)
    if nb.block_norms is not None and nb.block_mask is not None \
            and not nb.block_mask.all():
        norms = np.asarray(nb.block_norms).copy()
        norms[~nb.block_mask] = 1.0
        nb.block_norms = norms
        with pytest.raises(guards.NormConsistencyError):
            guards.validate_multiply_request(nb, b)

    # taxonomy: every typed error is a DbcsrValidationError is a ValueError
    for exc in (guards.ShapeMismatchError, guards.GridMismatchError,
                guards.MaskConsistencyError, guards.NormConsistencyError,
                guards.NonFiniteOperandError, guards.NonFiniteResultError):
        assert issubclass(exc, guards.DbcsrValidationError)
        assert issubclass(exc, ValueError)


# ---------------------------------------------------------------------------
# chaos: deterministic injection
# ---------------------------------------------------------------------------

def test_fault_injector_deterministic(rng):
    # compare BIT PATTERNS, not float values: flipping the exponent MSB
    # of a value in [1, 2) lands on NaN (by design — the detector must
    # catch nonfinite corruption), and NaN != NaN would make float
    # equality report two identical injections as different
    def bits(x):
        return np.asarray(x).view(np.uint32)

    c = jnp.asarray(rng.randn(64, 64).astype(np.float32))
    one = chaos.FaultInjector(seed=5).corrupt_block(
        c, 1, 1, block_m=32, block_n=32, mode="bitflip")
    two = chaos.FaultInjector(seed=5).corrupt_block(
        c, 1, 1, block_m=32, block_n=32, mode="bitflip")
    other = chaos.FaultInjector(seed=6).corrupt_block(
        c, 1, 1, block_m=32, block_n=32, mode="bitflip")
    assert (bits(one) == bits(two)).all()
    assert (bits(one) != bits(c)).any()
    assert (bits(one) != bits(other)).any()
    # corruption stays inside the target block
    delta = bits(one) != bits(c)
    delta[32:64, 32:64] = False
    assert not delta.any()


def test_one_shot_hook_fires_once(rng):
    c = jnp.asarray(rng.randn(64, 64).astype(np.float32))
    hook = chaos.FaultInjector(seed=0).one_shot_result_hook(
        0, 0, block_m=32, block_n=32, mode="nan")
    first = hook(c)
    assert np.isnan(np.asarray(first)).any()
    second = hook(c)  # identity after the first firing
    assert (np.asarray(second) == np.asarray(c)).all()


def test_dispatch_fault_injector():
    inj = chaos.DispatchFaultInjector(fail_first=2)
    with pytest.raises(chaos.TransientDispatchError):
        inj.check(stage="fused", attempt=0)
    with pytest.raises(chaos.TransientDispatchError):
        inj.check(stage="fused", attempt=1)
    inj.check(stage="fused", attempt=2)  # budget exhausted: passes
    staged = chaos.DispatchFaultInjector(fail_stages=("fused",))
    with pytest.raises(chaos.TransientDispatchError):
        staged.check(stage="fused", attempt=0)
    staged.check(stage="looped", attempt=0)


# ---------------------------------------------------------------------------
# service: retry/degradation ladder, error tickets, ticket taxonomy
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _service(mesh, **kw):
    from repro.serve.multiply_service import MultiplyService

    kw.setdefault("slo_s", 0.0)
    kw.setdefault("max_batch", 8)
    kw.setdefault("clock", FakeClock())
    kw.setdefault("sleep", lambda s: None)
    return MultiplyService(mesh, **{**EXEC_KW, **kw})


def test_service_ticket_taxonomy(rng):
    from repro.serve.multiply_service import (TicketPendingError,
                                              UnknownTicketError)

    mesh = _mesh11()
    svc = _service(mesh)
    t = svc.submit(_operand(rng, 64, 64, mesh=mesh),
                   _operand(rng, 64, 64, mesh=mesh))
    with pytest.raises(TicketPendingError):
        svc.result(t)          # still queued
    with pytest.raises(UnknownTicketError):
        svc.result(t + 100)    # never submitted
    svc.poll()
    svc.result(t)
    with pytest.raises(UnknownTicketError):
        svc.result(t)          # already retrieved
    # both are KeyError subclasses (backwards compatibility)
    assert issubclass(TicketPendingError, KeyError)
    assert issubclass(UnknownTicketError, KeyError)


def test_service_retries_transient_failures(rng):
    mesh = _mesh11()
    slept = []
    svc = _service(mesh, sleep=slept.append, max_retries=2, backoff_s=0.05,
                   fault_injector=chaos.DispatchFaultInjector(fail_first=2))
    a, b = _operand(rng, 64, 64, mesh=mesh), _operand(rng, 64, 64, mesh=mesh)
    ref = dbcsr.multiply(a, b, mesh=mesh, **EXEC_KW)
    t = svc.submit(a, b)
    assert svc.poll() == [t]
    assert (np.asarray(svc.result(t).data) == np.asarray(ref.data)).all()
    st = svc.stats()
    assert st["n_retries"] == 2 and st["n_degradations"] == 0
    assert st["n_error_tickets"] == 0
    assert slept == [0.05, 0.1]  # exponential backoff


def test_service_degrades_to_looped(rng):
    mesh = _mesh11()
    svc = _service(mesh, max_retries=1,
                   fault_injector=chaos.DispatchFaultInjector(
                       fail_stages=("fused",)))
    a, b = _operand(rng, 64, 64, mesh=mesh), _operand(rng, 64, 64, mesh=mesh)
    t = svc.submit(a, b)
    svc.poll()
    svc.result(t)
    st = svc.stats()
    assert st["n_degradations"] == 1
    assert st["buckets"][-1]["stage"] == "looped"


def test_service_per_request_isolation(rng):
    # every batched rung fails -> per-request isolation still delivers
    mesh = _mesh11()
    svc = _service(mesh, max_retries=0,
                   fault_injector=chaos.DispatchFaultInjector(
                       fail_stages=("fused", "looped")))
    a, b = _operand(rng, 64, 64, mesh=mesh), _operand(rng, 64, 64, mesh=mesh)
    ref = dbcsr.multiply(a, b, mesh=mesh, **EXEC_KW)
    t = svc.submit(a, b)
    done = svc.poll()
    assert done == [t]  # poll() never loses tickets
    assert (np.asarray(svc.result(t).data) == np.asarray(ref.data)).all()
    st = svc.stats()
    assert st["n_degradations"] == 2
    assert st["buckets"][-1]["stage"] == "per_request"


def test_service_poison_request_quarantined(rng):
    # ISSUE acceptance: a poison request in a fused batch yields an
    # error ticket for that request only; every other request's result
    # is bit-identical to a clean run
    mesh = _mesh11()
    svc = _service(mesh)
    good = [(_operand(rng, 64, 64, mesh=mesh),
             _operand(rng, 64, 64, mesh=mesh)) for _ in range(3)]
    bad_a = _operand(rng, 64, 64, mesh=mesh)
    bad_a.data = bad_a.data.at[0, 0].set(jnp.nan)
    refs = [dbcsr.multiply(a, b, mesh=mesh, **EXEC_KW) for a, b in good]
    t_good = [svc.submit(a, b) for a, b in good]
    t_bad = svc.submit(bad_a, _operand(rng, 64, 64, mesh=mesh))
    done = svc.poll()
    assert sorted(done) == sorted(t_good + [t_bad])
    for t, ref in zip(t_good, refs):
        assert (np.asarray(svc.result(t).data) == np.asarray(ref.data)).all()
    with pytest.raises(guards.NonFiniteResultError):
        svc.result(t_bad)
    st = svc.stats()
    assert st["n_error_tickets"] == 1
    assert st["n_nonfinite_quarantined"] == 1
    assert st["n_completed"] == 3


def test_service_validates_at_submit(rng):
    mesh = _mesh11()
    svc = _service(mesh)
    a = _operand(rng, 64, 64, mesh=mesh)
    bad = _operand(rng, 64, 64, mesh=mesh)
    bad.block_mask = np.ones((5, 5), dtype=bool)
    with pytest.raises(guards.MaskConsistencyError):
        svc.submit(a, bad)     # rejected synchronously, no ticket burned
    with pytest.raises(guards.ShapeMismatchError):
        svc.submit(a, _operand(rng, 96, 64, mesh=mesh))
    assert svc.stats()["n_requests"] == 0
    # validation is optional
    loose = _service(mesh, validate=False)
    t = loose.submit(a, bad)
    assert isinstance(t, int)


def test_service_verify_forwarded(rng):
    # verify= flows through the service kw into the looped multiply
    mesh = _mesh11()
    svc = _service(mesh, verify="checksum")
    a, b = _operand(rng, 64, 64, mesh=mesh), _operand(rng, 64, 64, mesh=mesh)
    t = svc.submit(a, b)
    svc.poll()
    c = svc.result(t)
    assert c.verification is not None
    assert not c.verification["report"].detected


# ---------------------------------------------------------------------------
# 2x2 mesh battery: chaos matrix in a subprocess
# ---------------------------------------------------------------------------

BATTERY = r"""
import json
from repro.compat import make_mesh
from repro.robustness.chaos import run_injection_matrix

mesh = make_mesh((2, 2), ("data", "model"))
rows = run_injection_matrix(mesh, "2x2", algorithms=("cannon", "summa"),
                            fills=(1.0, 0.05), modes=("bitflip", "nan"),
                            geometry=(128, 128, 128), block=32, seed=0)
out = {
    "n_rows": len(rows),
    "inject_ok": all(r["ok"] for r in rows if r["mode"] not in
                     ("clean", "clean_eps")),
    "clean_ok": all(not r["detected"] for r in rows if r["mode"] in
                    ("clean", "clean_eps")),
    "all_localized": all(r["localized_exact"] for r in rows
                         if r["mode"] not in ("clean", "clean_eps")),
}
print("JSON" + json.dumps(out))
"""


def test_chaos_matrix_2x2_mesh():
    stdout = run_subprocess_devices(BATTERY, n_devices=4, timeout=900)
    line = [l for l in stdout.splitlines() if l.startswith("JSON")][-1]
    out = json.loads(line[4:])
    assert out["n_rows"] > 0
    assert out["inject_ok"], stdout
    assert out["clean_ok"], stdout
    assert out["all_localized"], stdout
